"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.0):
    def f(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(total_steps, 1), 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1 - final_frac) * cos)

    return f


def linear_warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), final_frac)

    def f(step):
        stepf = step.astype(jnp.float32)
        warm = base_lr * stepf / max(warmup_steps, 1)
        return jnp.where(stepf < warmup_steps, warm, cos(step - warmup_steps))

    return f
