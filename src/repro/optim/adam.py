"""Adam / AdamW in pure JAX (no optax in the container).

Two interfaces:
  * array-level (``adam_init``/``adam_update``) — used by dictionary learning;
  * pytree-level (``adamw_tree_*``) — used by the LM training loop. Moments
    live in the same sharding as the params (ZeRO-1-style sharding happens via
    the param PartitionSpecs, not here).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: Array


def adam_init(params: Array) -> AdamState:
    z = jnp.zeros_like(params, dtype=jnp.float32)
    return AdamState(mu=z, nu=z, count=jnp.int32(0))


def adam_update(
    params: Array,
    grad: Array,
    state: AdamState,
    *,
    lr: Array | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[Array, AdamState]:
    count = state.count + 1
    g = grad.astype(jnp.float32)
    mu = b1 * state.mu + (1 - b1) * g
    nu = b2 * state.nu + (1 - b2) * g * g
    t = count.astype(jnp.float32)
    mu_hat = mu / (1 - b1**t)
    nu_hat = nu / (1 - b2**t)
    new = params.astype(jnp.float32) - lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
    return new.astype(params.dtype), AdamState(mu=mu, nu=nu, count=count)


def adamw_tree_init(params: Any) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros), count=jnp.int32(0))


def adamw_tree_update(
    params: Any,
    grads: Any,
    state: AdamState,
    *,
    lr: Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Any, AdamState]:
    count = state.count + 1
    t = count.astype(jnp.float32)
    c1 = 1 - b1**t
    c2 = 1 - b2**t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
        newp = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(mu=new_mu, nu=new_nu, count=count)
