from repro.optim.adam import AdamState, adam_init, adam_update, adamw_tree_init, adamw_tree_update
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
from repro.optim.clip import clip_by_global_norm
