"""KIVI-style asymmetric KV quantization (Liu et al. 2024b).

Key cache: *per-channel* group quantization (groups of g along the token
axis, statistics per channel) — keys have outlier channels, so channel-wise
scales preserve them. Value cache: *per-token* group quantization (groups of
g along the channel axis). Both int2 or int4, with a full-precision residual
buffer of the most recent tokens (token axis length padded to group size).

Memory per vector at head_dim m: m*bits/8 + 2*2*(m/g) bytes of scales/zeros
(key) — e.g. m=128, g=32, 2-bit: 32 + 16 = 48B vs 256B fp16 → 18.75% + buffer,
matching the paper's "21.1%" KIVI-2 rows once the buffer is included.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _quant(x: Array, bits: int, axis: int):
    """Asymmetric min/max quantization along ``axis`` returning
    (codes uint8, scale, zero)."""
    lo = jnp.min(x, axis=axis, keepdims=True)
    hi = jnp.max(x, axis=axis, keepdims=True)
    qmax = (1 << bits) - 1
    scale = (hi - lo) / qmax + 1e-8
    q = jnp.clip(jnp.round((x - lo) / scale), 0, qmax).astype(jnp.uint8)
    return q, scale.astype(jnp.float32), lo.astype(jnp.float32)


def _dequant(q: Array, scale: Array, zero: Array) -> Array:
    return q.astype(jnp.float32) * scale + zero


class KIVICache(NamedTuple):
    k_q: Array      # (B, KV, T_max, m) uint8 codes (per-channel groups over T)
    k_scale: Array  # (B, KV, T_max//g, m)
    k_zero: Array
    v_q: Array      # (B, KV, T_max, m) uint8 codes (per-token groups over m)
    v_scale: Array  # (B, KV, T_max, m//g)
    v_zero: Array
    k_buf: Array    # (B, KV, n_b, m) residual full-precision
    v_buf: Array
    t_q: Array      # (B,) quantized tokens (multiple of g)
    buf_len: Array  # (B,)


class KIVIPolicy:
    def __init__(self, bits: int = 2, group: int = 32, n_b: int = 128):
        self.bits, self.g, self.n_b = bits, group, n_b

    def init(self, batch, kv_heads, head_dim, t_max):
        g, n_b = self.g, self.n_b
        tq = max(((t_max - n_b) // g) * g, g)
        z8 = jnp.zeros((batch, kv_heads, tq, head_dim), jnp.uint8)
        zc = jnp.zeros((batch,), jnp.int32)
        return KIVICache(
            k_q=z8, k_scale=jnp.zeros((batch, kv_heads, tq // g, head_dim), jnp.float32),
            k_zero=jnp.zeros((batch, kv_heads, tq // g, head_dim), jnp.float32),
            v_q=z8, v_scale=jnp.zeros((batch, kv_heads, tq, head_dim // g), jnp.float32),
            v_zero=jnp.zeros((batch, kv_heads, tq, head_dim // g), jnp.float32),
            k_buf=jnp.zeros((batch, kv_heads, n_b + g, head_dim), jnp.bfloat16),
            v_buf=jnp.zeros((batch, kv_heads, n_b + g, head_dim), jnp.bfloat16),
            t_q=zc, buf_len=zc)

    def _quant_tokens(self, K, V):
        """K/V (B, KV, Tg, m) with Tg multiple of g -> quantized fields."""
        B, KV, Tg, m = K.shape
        g = self.g
        kg = K.astype(jnp.float32).reshape(B, KV, Tg // g, g, m)
        k_q, k_s, k_z = _quant(kg, self.bits, axis=3)      # per-channel over group
        vg = V.astype(jnp.float32).reshape(B, KV, Tg, m // g, g)
        v_q, v_s, v_z = _quant(vg, self.bits, axis=4)      # per-token over channels
        return (k_q.reshape(B, KV, Tg, m), k_s[:, :, :, 0], k_z[:, :, :, 0],
                v_q.reshape(B, KV, Tg, m), v_s[..., 0], v_z[..., 0])

    def prefill(self, cache, K, V, ctx):
        B, KV, T, m = K.shape
        g, n_b = self.g, self.n_b
        n_q = max(((T - n_b) // g) * g, 0)
        if n_q:
            kq, ks, kz, vq, vs, vz = self._quant_tokens(K[:, :, :n_q], V[:, :, :n_q])
            cache = cache._replace(
                k_q=jax.lax.dynamic_update_slice(cache.k_q, kq, (0, 0, 0, 0)),
                k_scale=jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, 0, 0, 0)),
                k_zero=jax.lax.dynamic_update_slice(cache.k_zero, kz, (0, 0, 0, 0)),
                v_q=jax.lax.dynamic_update_slice(cache.v_q, vq, (0, 0, 0, 0)),
                v_scale=jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, 0, 0, 0)),
                v_zero=jax.lax.dynamic_update_slice(cache.v_zero, vz, (0, 0, 0, 0)),
                t_q=jnp.full((B,), n_q, jnp.int32))
        rest = T - n_q
        k_buf = jnp.zeros_like(cache.k_buf)
        v_buf = jnp.zeros_like(cache.v_buf)
        k_buf = jax.lax.dynamic_update_slice(
            k_buf, K[:, :, n_q:].astype(k_buf.dtype), (0, 0, 0, 0))
        v_buf = jax.lax.dynamic_update_slice(
            v_buf, V[:, :, n_q:].astype(v_buf.dtype), (0, 0, 0, 0))
        return cache._replace(k_buf=k_buf, v_buf=v_buf,
                              buf_len=jnp.full((B,), rest, jnp.int32))

    def decode(self, cache, k_t, v_t, ctx, *, active=None, s_cap=None):
        """Per-row bookkeeping: rows flush their oldest group independently.
        The flush work is computed every step and selected per row (a baseline
        trade: no lax.cond on a batched predicate)."""
        g = self.g
        B = k_t.shape[0]
        b_idx = jnp.arange(B)
        act = (jnp.ones((B,), jnp.bool_) if active is None
               else jnp.asarray(active, jnp.bool_))
        nbuf = cache.k_buf.shape[2]
        wp = jnp.clip(cache.buf_len, 0, nbuf - 1)

        def put(buf, x_t):
            cur = buf[b_idx, :, wp]
            payload = jnp.where(act[:, None, None], x_t.astype(buf.dtype), cur)
            return buf.at[b_idx, :, wp].set(payload)

        k_buf = put(cache.k_buf, k_t)
        v_buf = put(cache.v_buf, v_t)
        buf_len = cache.buf_len + act.astype(jnp.int32)

        # rows whose buffer exceeds n_b by a full group quantize their oldest g
        do = buf_len >= self.n_b + g                              # (B,)
        kq, ks, kz, vq, vs, vz = self._quant_tokens(
            k_buf[:, :, :g], v_buf[:, :, :g])
        Tq = cache.k_q.shape[2]
        tok_w = jnp.clip(cache.t_q, 0, Tq - g)                    # group-aligned
        tok_pos = tok_w[:, None] + jnp.arange(g)[None, :]         # (B, g)

        def store_tokens(arr, new):
            # advanced indices (dims 0, 2) move to the front: (B, g, KV, ·)
            cur = arr[b_idx[:, None], :, tok_pos]
            payload = jnp.where(do[:, None, None, None],
                                jnp.moveaxis(new, 2, 1).astype(arr.dtype), cur)
            return arr.at[b_idx[:, None], :, tok_pos].set(payload)

        def store_group(arr, new):                                # (B, KV, 1, ·)
            grp_w = tok_w // g
            cur = arr[b_idx, :, grp_w]
            payload = jnp.where(do[:, None, None], new[:, :, 0].astype(arr.dtype), cur)
            return arr.at[b_idx, :, grp_w].set(payload)

        k_q = store_tokens(cache.k_q, kq)
        v_q = store_tokens(cache.v_q, vq)
        v_scale = store_tokens(cache.v_scale, vs)
        v_zero = store_tokens(cache.v_zero, vz)
        k_scale = store_group(cache.k_scale, ks)   # (B, KV, 1, m)
        k_zero = store_group(cache.k_zero, kz)

        # per-row ring shift by g for flushed rows (gather; roll is lockstep)
        shift = (jnp.arange(nbuf)[None, :] + g * do.astype(jnp.int32)[:, None]) % nbuf
        reorder = lambda buf: jnp.moveaxis(buf[b_idx[:, None], :, shift], 1, 2)
        return cache._replace(
            k_q=k_q, k_scale=k_scale, k_zero=k_zero,
            v_q=v_q, v_scale=v_scale, v_zero=v_zero,
            k_buf=reorder(k_buf), v_buf=reorder(v_buf),
            t_q=jnp.where(do, cache.t_q + g, cache.t_q),
            buf_len=jnp.where(do, buf_len - g, buf_len))

    def attend(self, cache, q, ctx, *, window=None):
        from repro.core.attention import NEG_INF, per_batch
        B, KV, G, m = q.shape
        g = self.g
        qf = q.astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(m))
        # dequantize (XLA fuses this into the matmul stream)
        Tq = cache.k_q.shape[2]
        k_deq = _dequant(cache.k_q.reshape(B, KV, Tq // g, g, m),
                         cache.k_scale[:, :, :, None], cache.k_zero[:, :, :, None])
        k_deq = k_deq.reshape(B, KV, Tq, m)
        v_deq = _dequant(cache.v_q.reshape(B, KV, Tq, m // g, g),
                         cache.v_scale[..., None], cache.v_zero[..., None])
        v_deq = v_deq.reshape(B, KV, Tq, m)
        t_qb, buf_lenb = per_batch(cache.t_q), per_batch(cache.buf_len)
        s_q = jnp.einsum("bkgm,bktm->bkgt", qf, k_deq) * scale
        pos = jnp.arange(Tq)[None, None, None]
        valid = pos < t_qb
        length = t_qb + buf_lenb
        if window is not None:
            valid &= pos >= (length - window)
        s_q = jnp.where(valid, s_q, NEG_INF)
        s_b = jnp.einsum("bkgm,bkrm->bkgr", qf, cache.k_buf.astype(jnp.float32)) * scale
        nb = cache.k_buf.shape[2]
        s_b = jnp.where(jnp.arange(nb)[None, None, None] < buf_lenb, s_b, NEG_INF)
        p = jax.nn.softmax(jnp.concatenate([s_q, s_b], axis=-1), axis=-1)
        out = jnp.einsum("bkgt,bktm->bkgm", p[..., :Tq], v_deq)
        out += jnp.einsum("bkgr,bkrm->bkgm", p[..., Tq:],
                          cache.v_buf.astype(jnp.float32))
        return out

    def length(self, cache):
        return cache.t_q + cache.buf_len

    def kv_size_fraction(self, m: int) -> float:
        """Steady-state bytes per vector vs fp16 (excluding buffer)."""
        payload = m * self.bits / 8
        meta = 2 * 4 * (m / self.g)  # fp32 scale+zero per group
        return (payload + meta) / (2 * m)
