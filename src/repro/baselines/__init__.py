"""Baselines the paper compares against (Tables 2-3, Figure 1):
KIVI (per-channel key / per-token value group quantization), HF-style
per-token quantization, and SnapKV/H2O-flavoured eviction — all implemented
as CachePolicy objects so they run through the same serving stack as Lexico.
"""
from repro.baselines.kivi import KIVIPolicy
from repro.baselines.per_token_quant import PerTokenQuantPolicy
from repro.baselines.eviction import EvictionPolicy
