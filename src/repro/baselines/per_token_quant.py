"""HF-style per-token KV quantization (the paper's 'Per-Token' baseline).

Every cached vector is quantized independently (asymmetric min/max over its
channels) at ``bits`` precision, with a small residual window of recent
tokens in full precision (HF's `KVQuant`-style residual_length).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.kivi import _dequant, _quant

Array = jax.Array


class PTQCache(NamedTuple):
    k_q: Array      # (B, KV, T_max, m) uint8
    k_scale: Array  # (B, KV, T_max, 1)
    k_zero: Array
    v_q: Array
    v_scale: Array
    v_zero: Array
    k_buf: Array    # (B, KV, n_b, m)
    v_buf: Array
    t_q: Array      # (B,) int32
    buf_len: Array  # (B,) int32
    buf_start: Array  # (B,) int32


class PerTokenQuantPolicy:
    def __init__(self, bits: int = 4, n_b: int = 128):
        self.bits, self.n_b = bits, n_b

    def init(self, batch, kv_heads, head_dim, t_max):
        tq = max(t_max - self.n_b, 1)
        z8 = jnp.zeros((batch, kv_heads, tq, head_dim), jnp.uint8)
        zs = jnp.zeros((batch, kv_heads, tq, 1), jnp.float32)
        zb = jnp.zeros((batch, kv_heads, self.n_b, head_dim), jnp.bfloat16)
        zc = jnp.zeros((batch,), jnp.int32)
        return PTQCache(z8, zs, zs, z8, zs, zs, zb, zb, zc, zc, zc)

    def prefill(self, cache, K, V, ctx):
        B, KV, T, m = K.shape
        n_q = T - self.n_b
        kq, ks, kz = _quant(K[:, :, :n_q].astype(jnp.float32), self.bits, axis=-1)
        vq, vs, vz = _quant(V[:, :, :n_q].astype(jnp.float32), self.bits, axis=-1)
        upd = lambda a, b: jax.lax.dynamic_update_slice(a, b, (0, 0, 0, 0))
        fill = lambda v: jnp.full((B,), v, jnp.int32)
        return cache._replace(
            k_q=upd(cache.k_q, kq), k_scale=upd(cache.k_scale, ks),
            k_zero=upd(cache.k_zero, kz),
            v_q=upd(cache.v_q, vq), v_scale=upd(cache.v_scale, vs),
            v_zero=upd(cache.v_zero, vz),
            k_buf=K[:, :, n_q:].astype(cache.k_buf.dtype),
            v_buf=V[:, :, n_q:].astype(cache.v_buf.dtype),
            t_q=fill(n_q), buf_len=fill(self.n_b), buf_start=fill(0))

    def decode(self, cache, k_t, v_t, ctx, *, active=None, s_cap=None):
        n_b = self.n_b
        B = k_t.shape[0]
        b_idx = jnp.arange(B)
        act = (jnp.ones((B,), jnp.bool_) if active is None
               else jnp.asarray(active, jnp.bool_))
        full = cache.buf_len >= n_b
        evict = full & act
        old_k = cache.k_buf[b_idx, :, cache.buf_start][:, :, None]   # (B,KV,1,m)
        old_v = cache.v_buf[b_idx, :, cache.buf_start][:, :, None]
        kq, ks, kz = _quant(old_k.astype(jnp.float32), self.bits, axis=-1)
        vq, vs, vz = _quant(old_v.astype(jnp.float32), self.bits, axis=-1)
        t_w = jnp.clip(cache.t_q, 0, cache.k_q.shape[2] - 1)

        def store(arr, new):
            cur = arr[b_idx, :, t_w]                                # (B,KV,·)
            payload = jnp.where(evict[:, None, None],
                                new[:, :, 0].astype(arr.dtype), cur)
            return arr.at[b_idx, :, t_w].set(payload)

        cache = cache._replace(
            k_q=store(cache.k_q, kq), k_scale=store(cache.k_scale, ks),
            k_zero=store(cache.k_zero, kz),
            v_q=store(cache.v_q, vq), v_scale=store(cache.v_scale, vs),
            v_zero=store(cache.v_zero, vz),
            t_q=jnp.where(evict, cache.t_q + 1, cache.t_q))
        write_pos = jnp.where(full, cache.buf_start, cache.buf_len)

        def ring(buf, x_t):
            cur = buf[b_idx, :, write_pos]
            payload = jnp.where(act[:, None, None], x_t.astype(buf.dtype), cur)
            return buf.at[b_idx, :, write_pos].set(payload)

        return cache._replace(
            k_buf=ring(cache.k_buf, k_t), v_buf=ring(cache.v_buf, v_t),
            buf_len=jnp.where(act & ~full, cache.buf_len + 1, cache.buf_len),
            buf_start=jnp.where(evict, (cache.buf_start + 1) % n_b, cache.buf_start))

    def attend(self, cache, q, ctx, *, window=None):
        from repro.core.attention import NEG_INF, per_batch
        B, KV, G, m = q.shape
        qf = q.astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(m))
        k_deq = _dequant(cache.k_q, cache.k_scale, cache.k_zero)
        v_deq = _dequant(cache.v_q, cache.v_scale, cache.v_zero)
        Tq = k_deq.shape[2]
        t_qb, buf_lenb = per_batch(cache.t_q), per_batch(cache.buf_len)
        s_q = jnp.einsum("bkgm,bktm->bkgt", qf, k_deq) * scale
        pos = jnp.arange(Tq)[None, None, None]
        valid = pos < t_qb
        if window is not None:
            valid &= pos >= (t_qb + buf_lenb - window)
        s_q = jnp.where(valid, s_q, NEG_INF)
        s_b = jnp.einsum("bkgm,bkrm->bkgr", qf, cache.k_buf.astype(jnp.float32)) * scale
        nb = cache.k_buf.shape[2]
        s_b = jnp.where(jnp.arange(nb)[None, None, None] < buf_lenb, s_b, NEG_INF)
        p = jax.nn.softmax(jnp.concatenate([s_q, s_b], axis=-1), axis=-1)
        out = jnp.einsum("bkgt,bktm->bkgm", p[..., :Tq], v_deq)
        out += jnp.einsum("bkgr,bkrm->bkgm", p[..., Tq:], cache.v_buf.astype(jnp.float32))
        return out

    def length(self, cache):
        return cache.t_q + cache.buf_len

    def kv_size_fraction(self, m: int) -> float:
        return (m * self.bits / 8 + 8) / (2 * m)
