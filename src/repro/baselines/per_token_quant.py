"""HF-style per-token KV quantization (the paper's 'Per-Token' baseline).

Every cached vector is quantized independently (asymmetric min/max over its
channels) at ``bits`` precision, with a small residual window of recent
tokens in full precision (HF's `KVQuant`-style residual_length).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.baselines.kivi import _dequant, _quant

Array = jax.Array


class PTQCache(NamedTuple):
    k_q: Array      # (B, KV, T_max, m) uint8
    k_scale: Array  # (B, KV, T_max, 1)
    k_zero: Array
    v_q: Array
    v_scale: Array
    v_zero: Array
    k_buf: Array    # (B, KV, n_b, m)
    v_buf: Array
    t_q: Array
    buf_len: Array
    buf_start: Array


class PerTokenQuantPolicy:
    def __init__(self, bits: int = 4, n_b: int = 128):
        self.bits, self.n_b = bits, n_b

    def init(self, batch, kv_heads, head_dim, t_max):
        tq = max(t_max - self.n_b, 1)
        z8 = jnp.zeros((batch, kv_heads, tq, head_dim), jnp.uint8)
        zs = jnp.zeros((batch, kv_heads, tq, 1), jnp.float32)
        zb = jnp.zeros((batch, kv_heads, self.n_b, head_dim), jnp.bfloat16)
        return PTQCache(z8, zs, zs, z8, zs, zs, zb, zb,
                        jnp.int32(0), jnp.int32(0), jnp.int32(0))

    def prefill(self, cache, K, V, ctx):
        B, KV, T, m = K.shape
        n_q = T - self.n_b
        kq, ks, kz = _quant(K[:, :, :n_q].astype(jnp.float32), self.bits, axis=-1)
        vq, vs, vz = _quant(V[:, :, :n_q].astype(jnp.float32), self.bits, axis=-1)
        upd = lambda a, b: jax.lax.dynamic_update_slice(a, b, (0, 0, 0, 0))
        return cache._replace(
            k_q=upd(cache.k_q, kq), k_scale=upd(cache.k_scale, ks),
            k_zero=upd(cache.k_zero, kz),
            v_q=upd(cache.v_q, vq), v_scale=upd(cache.v_scale, vs),
            v_zero=upd(cache.v_zero, vz),
            k_buf=K[:, :, n_q:].astype(cache.k_buf.dtype),
            v_buf=V[:, :, n_q:].astype(cache.v_buf.dtype),
            t_q=jnp.int32(n_q), buf_len=jnp.int32(self.n_b), buf_start=jnp.int32(0))

    def decode(self, cache, k_t, v_t, ctx):
        n_b = self.n_b
        full = cache.buf_len >= n_b
        old_k = jax.lax.dynamic_slice_in_dim(cache.k_buf, cache.buf_start, 1, axis=2)
        old_v = jax.lax.dynamic_slice_in_dim(cache.v_buf, cache.buf_start, 1, axis=2)
        kq, ks, kz = _quant(old_k.astype(jnp.float32), self.bits, axis=-1)
        vq, vs, vz = _quant(old_v.astype(jnp.float32), self.bits, axis=-1)

        def store(arr, new):
            cur = jax.lax.dynamic_slice(arr, (0, 0, cache.t_q, 0), new.shape)
            return jax.lax.dynamic_update_slice(
                arr, jnp.where(full, new.astype(arr.dtype), cur), (0, 0, cache.t_q, 0))

        cache = cache._replace(
            k_q=store(cache.k_q, kq), k_scale=store(cache.k_scale, ks),
            k_zero=store(cache.k_zero, kz),
            v_q=store(cache.v_q, vq), v_scale=store(cache.v_scale, vs),
            v_zero=store(cache.v_zero, vz),
            t_q=jnp.where(full, cache.t_q + 1, cache.t_q))
        write_pos = jnp.where(full, cache.buf_start, cache.buf_len)
        k_buf = jax.lax.dynamic_update_slice(
            cache.k_buf, k_t[:, :, None].astype(cache.k_buf.dtype), (0, 0, write_pos, 0))
        v_buf = jax.lax.dynamic_update_slice(
            cache.v_buf, v_t[:, :, None].astype(cache.v_buf.dtype), (0, 0, write_pos, 0))
        return cache._replace(
            k_buf=k_buf, v_buf=v_buf,
            buf_len=jnp.where(full, cache.buf_len, cache.buf_len + 1),
            buf_start=jnp.where(full, (cache.buf_start + 1) % n_b, cache.buf_start))

    def attend(self, cache, q, ctx, *, window=None):
        from repro.core.attention import NEG_INF
        B, KV, G, m = q.shape
        qf = q.astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(m))
        k_deq = _dequant(cache.k_q, cache.k_scale, cache.k_zero)
        v_deq = _dequant(cache.v_q, cache.v_scale, cache.v_zero)
        Tq = k_deq.shape[2]
        s_q = jnp.einsum("bkgm,bktm->bkgt", qf, k_deq) * scale
        pos = jnp.arange(Tq)[None, None, None]
        valid = pos < cache.t_q
        if window is not None:
            valid &= pos >= (cache.t_q + cache.buf_len - window)
        s_q = jnp.where(valid, s_q, NEG_INF)
        s_b = jnp.einsum("bkgm,bkrm->bkgr", qf, cache.k_buf.astype(jnp.float32)) * scale
        nb = cache.k_buf.shape[2]
        s_b = jnp.where(jnp.arange(nb)[None, None, None] < cache.buf_len, s_b, NEG_INF)
        p = jax.nn.softmax(jnp.concatenate([s_q, s_b], axis=-1), axis=-1)
        out = jnp.einsum("bkgt,bktm->bkgm", p[..., :Tq], v_deq)
        out += jnp.einsum("bkgr,bkrm->bkgm", p[..., Tq:], cache.v_buf.astype(jnp.float32))
        return out

    def length(self, cache):
        return cache.t_q + cache.buf_len

    def kv_size_fraction(self, m: int) -> float:
        return (m * self.bits / 8 + 8) / (2 * m)
