"""Full-precision cache baseline — re-export of DensePolicy (paper's
'Full Cache' rows) for symmetric imports from benchmarks."""
from repro.models.cache_policy import DenseCache, DensePolicy  # noqa: F401
