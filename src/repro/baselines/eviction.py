"""Score-based token eviction (SnapKV / H2O flavour).

Keeps a fixed budget of cache slots: a running attention-mass score per
cached token (H2O's "heavy hitters") plus a protected window of recent
tokens (SnapKV's observation window). When the cache is full, the lowest-
scoring unprotected token is overwritten.

This is the paper's eviction baseline family — it reaches arbitrarily low
KV sizes but degrades hard on tasks needing full context, and composes badly
with GQA (scores are shared per KV head), which is the paper's Figure-1
observation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.attention import NEG_INF

Array = jax.Array


class EvictionCache(NamedTuple):
    k: Array        # (B, KV, budget, m) bf16
    v: Array
    score: Array    # (B, KV, budget) accumulated attention mass
    pos: Array      # (B, KV, budget) absolute position of each slot (-1 empty)
    length: Array   # (B,) — tokens seen per batch element (not tokens kept)


class EvictionPolicy:
    def __init__(self, budget: int = 512, recent: int = 32):
        self.budget, self.recent = budget, recent

    def init(self, batch, kv_heads, head_dim, t_max):
        b = min(self.budget, t_max)
        return EvictionCache(
            k=jnp.zeros((batch, kv_heads, b, head_dim), jnp.bfloat16),
            v=jnp.zeros((batch, kv_heads, b, head_dim), jnp.bfloat16),
            score=jnp.zeros((batch, kv_heads, b), jnp.float32),
            pos=jnp.full((batch, kv_heads, b), -1, jnp.int32),
            length=jnp.zeros((batch,), jnp.int32))

    def prefill(self, cache, K, V, ctx):
        """SnapKV-style: score prompt tokens by attention mass from the last
        `recent` queries is unavailable here (policy sees only K/V), so we use
        key-norm salience (Devoto et al. 2024: low ||k|| ~ high attention) +
        protected recency."""
        B, KV, T, m = K.shape
        b = cache.k.shape[2]
        sal = -jnp.linalg.norm(K.astype(jnp.float32), axis=-1)   # (B,KV,T)
        recency = jnp.arange(T) >= (T - self.recent)
        sal = jnp.where(recency[None, None], jnp.inf, sal)
        if T <= b:
            pad = b - T
            k = jnp.pad(K.astype(jnp.bfloat16), ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(V.astype(jnp.bfloat16), ((0, 0), (0, 0), (0, pad), (0, 0)))
            pos = jnp.pad(jnp.broadcast_to(jnp.arange(T)[None, None], (B, KV, T)),
                          ((0, 0), (0, 0), (0, pad)), constant_values=-1)
            sc = jnp.pad(jnp.where(jnp.isinf(sal), 0.0, -sal), ((0, 0), (0, 0), (0, pad)))
            return EvictionCache(k, v, sc, pos, jnp.full((B,), T, jnp.int32))
        _, keep = jax.lax.top_k(sal, b)                          # (B,KV,b)
        take = lambda x: jnp.take_along_axis(x, keep[..., None], axis=2)
        pos = keep.astype(jnp.int32)
        sc = jnp.take_along_axis(jnp.where(jnp.isinf(sal), 0.0, -sal), keep, axis=2)
        return EvictionCache(take(K).astype(jnp.bfloat16), take(V).astype(jnp.bfloat16),
                             sc, pos, jnp.full((B,), T, jnp.int32))

    def decode(self, cache, k_t, v_t, ctx, *, active=None, s_cap=None):
        B, KV, bsz, m = cache.k.shape
        act = (jnp.ones((B,), jnp.bool_) if active is None
               else jnp.asarray(active, jnp.bool_))
        # victim = lowest score among unprotected slots (empty slots score -inf)
        protected = cache.pos >= (cache.length[:, None, None] - self.recent)
        eff = jnp.where(cache.pos < 0, -jnp.inf,
                        jnp.where(protected, jnp.inf, cache.score))
        victim = jnp.argmin(eff, axis=-1)                        # (B,KV)
        oh = jax.nn.one_hot(victim, bsz, dtype=jnp.bool_) & act[:, None, None]
        k = jnp.where(oh[..., None], k_t[:, :, None].astype(cache.k.dtype), cache.k)
        v = jnp.where(oh[..., None], v_t[:, :, None].astype(cache.v.dtype), cache.v)
        score = jnp.where(oh, 0.0, cache.score)
        pos = jnp.where(oh, cache.length[:, None, None], cache.pos)
        return EvictionCache(k, v, score, pos, cache.length + act.astype(jnp.int32))

    def attend(self, cache, q, ctx, *, window=None):
        B, KV, G, m = q.shape
        qf = q.astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(jnp.float32(m))
        s = jnp.einsum("bkgm,bktm->bkgt", qf, cache.k.astype(jnp.float32)) * scale
        valid = cache.pos[:, :, None] >= 0
        if window is not None:
            valid &= cache.pos[:, :, None] >= (cache.length[:, None, None, None] - window)
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgt,bktm->bkgm", p, cache.v.astype(jnp.float32))
        # H2O: accumulate attention mass (summed over query-head group)
        # NOTE: attend() is pure; score updates ride through decode() next step
        # in a full H2O impl. We fold the update here by returning out only —
        # the framework treats scores as advisory (prefill salience + recency).
        return out

    def length(self, cache):
        return cache.length

    def kv_size_fraction(self, t_total: int) -> float:
        return min(1.0, self.budget / max(t_total, 1))
