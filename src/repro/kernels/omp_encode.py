"""Fused tile-batched OMP encoder — the prefill-compression hot loop.

``core/omp.py`` is the oracle: a per-vector Cholesky-incremental OMP vmapped
over the batch, running all ``s_max`` ``fori_loop`` iterations even after
every row has hit its ``delta`` / ``s_cap`` stop. This module is the fused
production path behind ``omp_batch(backend="fused")``:

  * **Tile-batched iteration** — the batch is cut into ``tile_b``-row tiles
    and each tile runs ONE iteration loop: the atom selection, the Cholesky
    append (rank-1 row update of the (tile_b, s, s) factor), the pair of
    triangular solves and the ``G[idx, n]`` gathers are all batched over the
    tile, so the factor tile stays resident in VMEM between iterations
    instead of being re-streamed per vector.
  * **Fused selection** — the argmax over atoms goes through
    ``kernels.ops`` dispatch: ``omp_gram_select_op`` (Gram path — Gram rows
    streamed by a scalar-prefetch Pallas kernel, the (B, N) correlation
    matrix never hits HBM) or ``omp_select_op`` on the explicit residual
    (Gram-free path). Off-TPU the jnp oracles run unless ``force_kernel``
    pins the interpret-mode kernel.
  * **Early exit** — the iteration is a ``lax.while_loop`` that stops as
    soon as no row in the tile is still active (``nnz == i`` and
    ``r2 > delta²·‖k‖²`` and ``i < s_cap``). Inactive rows are no-ops inside
    the body, so the early-exited state is bitwise identical to running the
    same body for all ``s_max`` steps (``early_exit=False`` swaps in a
    ``fori_loop`` over the identical body — the always-``s_max`` baseline
    the benchmark measures against). One compile either way, and the output
    contract is the oracle's padded ``OMPResult``.

Per-row ``s_cap`` tiers, ``delta`` early stop, Gram and Gram-free
correlation, and arbitrary leading batch shape all match ``omp_batch``;
tests/test_omp_encode.py pins the differential (idx exact, vals ≤ 2e-5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.omp import OMPResult
from repro.kernels import ops

Array = jax.Array


def _tri_solve(L: Array, b: Array, *, trans: bool = False) -> Array:
    """Batched lower-triangular solve: L (B, s, s), b (B, s)."""
    x = jax.scipy.linalg.solve_triangular(
        L, b[..., None], lower=True, trans=1 if trans else 0)
    return x[..., 0]


def _encode_tile(
    K: Array,                       # (B, m) f32
    D: Array,                       # (m, N) f32
    s_max: int,
    *,
    G: Optional[Array],             # (N, N) f32 or None (Gram-free)
    delta: float,
    eps: float,
    cap: Array,                     # (B,) i32 per-row atom cap
    early_exit: bool,
    force_kernel: bool,
    interpret: Optional[bool],
) -> OMPResult:
    """One token tile through the batched iteration loop."""
    B, m = K.shape
    N = D.shape[1]
    alpha0 = K @ D                                     # (B, N)
    kk = jnp.sum(K * K, axis=-1)                       # (B,)
    thresh2 = (delta * delta) * kk
    pos = jnp.arange(s_max)

    L0 = jnp.broadcast_to(jnp.eye(s_max, dtype=jnp.float32),
                          (B, s_max, s_max))
    state0 = (
        jnp.int32(0),                                  # i
        L0,                                            # Cholesky factor
        jnp.zeros((B, s_max), jnp.int32),              # idx
        jnp.zeros((B, s_max), jnp.float32),            # y
        jnp.zeros((B, N), jnp.bool_),                  # selected
        jnp.zeros((B,), jnp.int32),                    # nnz
        kk,                                            # r2
    )

    def active_rows(i, nnz, r2):
        return (nnz == i) & (r2 > thresh2) & (i < cap)

    def body(state):
        i, L, idx, y, sel, nnz, r2 = state
        active = active_rows(i, nnz, r2)

        # Atom selection — dispatched kernel/oracle per backend. y is zero
        # past the filled prefix so trailing idx slots subtract nothing.
        if G is not None:
            n, _ = ops.omp_gram_select_op(
                alpha0, G, idx, y, sel,
                force_kernel=force_kernel, interpret=interpret)
            g_col = G[n[:, None], idx]                 # (B, s)
            gnn = G[n, n]                              # (B,)
        else:
            atoms = jnp.take(D.T, idx, axis=0)         # (B, s, m)
            r = K - jnp.einsum("bs,bsm->bm", y, atoms)
            n, _ = ops.omp_select_op(
                r, D, sel, force_kernel=force_kernel, interpret=interpret)
            d_n = D[:, n].T                            # (B, m)
            g_col = jnp.einsum("bsm,bm->bs", atoms, d_n)
            gnn = jnp.sum(d_n * d_n, axis=-1)

        # Batched Cholesky append: w = L^{-1} G[idx, n] over the prefix.
        g_col = jnp.where(pos[None, :] < i, g_col, 0.0)
        w = _tri_solve(L, g_col)
        w = jnp.where(pos[None, :] < i, w, 0.0)
        d2 = jnp.maximum(gnn - jnp.sum(w * w, axis=-1), eps)
        row = jnp.where(pos[None, :] < i, w,
                        jnp.where(pos[None, :] == i,
                                  jnp.sqrt(d2)[:, None], 0.0))
        L_new = jax.lax.dynamic_update_slice(L, row[:, None, :], (0, i, 0))
        idx_new = jnp.where(pos[None, :] == i, n[:, None], idx)
        sel_new = sel.at[jnp.arange(B), n].set(True)

        # Solve (L L^T) y = alpha0[idx] on the filled prefix.
        alpha_idx = jnp.take_along_axis(alpha0, idx_new, axis=1)
        rhs = jnp.where(pos[None, :] <= i, alpha_idx, 0.0)
        z = _tri_solve(L_new, rhs)
        z = jnp.where(pos[None, :] <= i, z, 0.0)
        y_new = _tri_solve(L_new, z, trans=True)
        y_new = jnp.where(pos[None, :] <= i, y_new, 0.0)
        r2_new = jnp.maximum(kk - jnp.sum(y_new * alpha_idx, axis=-1), 0.0)

        a1 = active[:, None]
        return (
            i + 1,
            jnp.where(a1[..., None], L_new, L),
            jnp.where(a1, idx_new, idx),
            jnp.where(a1, y_new, y),
            jnp.where(a1, sel_new, sel),
            jnp.where(active, nnz + 1, nnz),
            jnp.where(active, r2_new, r2),
        )

    if early_exit:
        def cond(state):
            i, _, _, _, _, nnz, r2 = state
            return (i < s_max) & jnp.any(active_rows(i, nnz, r2))
        _, _, idx, y, _, nnz, r2 = jax.lax.while_loop(cond, body, state0)
    else:
        _, _, idx, y, _, nnz, r2 = jax.lax.fori_loop(
            0, s_max, lambda _, st: body(st), state0)

    vals = jnp.where(pos[None, :] < nnz[:, None], y, 0.0)
    idx = jnp.where(pos[None, :] < nnz[:, None], idx, 0)
    return OMPResult(vals=vals, idx=idx, nnz=nnz, resid2=r2)


@functools.partial(jax.jit, static_argnames=(
    "s_max", "delta", "eps", "tile_b", "early_exit", "force_kernel",
    "interpret"))
def omp_encode_batch(
    K: Array,
    D: Array,
    s_max: int,
    *,
    G: Optional[Array] = None,
    delta: float = 0.0,
    s_cap: Optional[Array] = None,
    eps: float = 1e-12,
    tile_b: int = 256,
    early_exit: bool = True,
    force_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> OMPResult:
    """Fused tile-batched OMP over ``K`` (..., m) — drop-in for ``omp_batch``.

    ``G=None`` selects the Gram-free correlation (``use_gram=False`` path).
    ``tile_b`` rows share one iteration loop (and one early-exit decision);
    tiles run sequentially via ``lax.map`` so each tile stops at its own
    deepest row. The trailing partial tile is zero-padded — pad rows have
    ``‖k‖ = 0`` so they are never active and are sliced off the outputs.
    """
    batch_shape = K.shape[:-1]
    m = K.shape[-1]
    K32 = K.astype(jnp.float32).reshape(-1, m)
    D32 = D.astype(jnp.float32)
    G32 = None if G is None else G.astype(jnp.float32)
    B = K32.shape[0]
    if s_cap is None:
        cap = jnp.full((B,), s_max, jnp.int32)
    else:
        cap = jnp.broadcast_to(
            jnp.asarray(s_cap, jnp.int32), batch_shape).reshape(-1)

    tb = max(1, min(tile_b, B))
    n_tiles = -(-B // tb)
    pad = n_tiles * tb - B
    if pad:
        K32 = jnp.pad(K32, ((0, pad), (0, 0)))
        cap = jnp.pad(cap, (0, pad))

    encode = functools.partial(
        _encode_tile, D=D32, s_max=s_max, G=G32, delta=float(delta),
        eps=float(eps), early_exit=early_exit, force_kernel=force_kernel,
        interpret=interpret)
    if n_tiles == 1:
        out = encode(K32, cap=cap)
    else:
        out = jax.lax.map(
            lambda t: encode(t[0], cap=t[1]),
            (K32.reshape(n_tiles, tb, m), cap.reshape(n_tiles, tb)))
        out = jax.tree_util.tree_map(
            lambda x: x.reshape((n_tiles * tb,) + x.shape[2:]), out)
    return OMPResult(
        vals=out.vals[:B].reshape(batch_shape + (s_max,)),
        idx=out.idx[:B].reshape(batch_shape + (s_max,)),
        nnz=out.nnz[:B].reshape(batch_shape),
        resid2=out.resid2[:B].reshape(batch_shape),
    )
