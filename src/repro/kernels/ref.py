"""Pure-jnp oracles for the Pallas kernels (the ground truth the kernels are
asserted against across shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sparse_scores_ref(qd: Array, vals: Array, idx: Array) -> Array:
    """qd (N,) f32; vals (T, s); idx (T, s) int -> scores (T,) f32.

    scores[t] = sum_j vals[t, j] * qd[idx[t, j]]
    """
    g = qd[idx.astype(jnp.int32)]                      # (T, s)
    return jnp.sum(g * vals.astype(jnp.float32), axis=-1)


def sparse_values_ref(probs: Array, vals: Array, idx: Array, N: int) -> Array:
    """probs (T,) f32; vals/idx (T, s) -> coefficient accumulator (N,) f32.

    c[n] = sum_{t,j: idx[t,j]==n} probs[t] * vals[t,j]
    """
    contrib = probs[:, None].astype(jnp.float32) * vals.astype(jnp.float32)
    return jnp.zeros((N,), jnp.float32).at[
        idx.astype(jnp.int32).reshape(-1)].add(contrib.reshape(-1))


def omp_corr_ref(D: Array, residual: Array, selected_mask: Array) -> tuple:
    """Fused OMP selection step: c = |D^T r| masked; returns (argmax, max).

    D (m, N) f32; residual (B, m) f32; selected_mask (B, N) bool.
    """
    c = jnp.abs(residual.astype(jnp.float32) @ D.astype(jnp.float32))  # (B, N)
    c = jnp.where(selected_mask, -jnp.inf, c)
    return jnp.argmax(c, axis=-1).astype(jnp.int32), jnp.max(c, axis=-1)
