"""Pure-jnp oracles for the Pallas kernels (the ground truth the kernels are
asserted against across shape/dtype sweeps in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sparse_scores_ref(qd: Array, vals: Array, idx: Array) -> Array:
    """qd (N,) f32; vals (T, s); idx (T, s) int -> scores (T,) f32.

    scores[t] = sum_j vals[t, j] * qd[idx[t, j]]
    """
    g = qd[idx.astype(jnp.int32)]                      # (T, s)
    return jnp.sum(g * vals.astype(jnp.float32), axis=-1)


def sparse_values_ref(probs: Array, vals: Array, idx: Array, N: int) -> Array:
    """probs (T,) f32; vals/idx (T, s) -> coefficient accumulator (N,) f32.

    c[n] = sum_{t,j: idx[t,j]==n} probs[t] * vals[t,j]
    """
    contrib = probs[:, None].astype(jnp.float32) * vals.astype(jnp.float32)
    return jnp.zeros((N,), jnp.float32).at[
        idx.astype(jnp.int32).reshape(-1)].add(contrib.reshape(-1))


def paged_attention_ref(qd: Array, k_vals: Array, k_idx: Array,
                        v_vals: Array, v_idx: Array, page_table: Array,
                        t_c: Array, min_pos: Array, *, N: int,
                        scale: float) -> tuple:
    """Gather-then-mask oracle of the fused paged attention kernel.

    Materialises per-row contiguous views of the pool (exactly what the
    pre-fusion ``paged_attend`` did via ``gather_pages``), computes all
    compressed logits, and reduces them to the same ``(m, l, c)`` carry the
    kernel emits: running max (B,KV,G), softmax mass (B,KV,G), and the
    coefficient accumulator (B,KV,G,N) over positions
    ``min_pos <= pos < t_c``. Rows with no valid positions yield
    ``(NEG_INF, 0, 0)``.
    """
    from repro.core.attention import (
        NEG_INF, compressed_scores, gather_pages, scatter_coeffs,
    )
    g_kv = gather_pages(k_vals, page_table)
    g_ki = gather_pages(k_idx, page_table)
    g_vv = gather_pages(v_vals, page_table)
    g_vi = gather_pages(v_idx, page_table)
    s_c = compressed_scores(qd, g_kv, g_ki, scale=scale)
    T = g_kv.shape[2]
    pos = jnp.arange(T)[None, None, None, :]
    t_cb = jnp.asarray(t_c, jnp.int32)[:, None, None, None]
    mpb = jnp.asarray(min_pos, jnp.int32)[:, None, None, None]
    valid = (pos < t_cb) & (pos >= mpb)
    s_c = jnp.where(valid, s_c, NEG_INF)
    m = jnp.max(s_c, axis=-1)
    p = jnp.where(valid, jnp.exp(s_c - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    c = scatter_coeffs(p, g_vv, g_vi, N)
    return m, l, c


def omp_corr_ref(D: Array, residual: Array, selected_mask: Array) -> tuple:
    """Fused OMP selection step: c = |D^T r| masked; returns (argmax, max).

    D (m, N) f32; residual (B, m) f32; selected_mask (B, N) bool.
    """
    c = jnp.abs(residual.astype(jnp.float32) @ D.astype(jnp.float32))  # (B, N)
    c = jnp.where(selected_mask, -jnp.inf, c)
    return jnp.argmax(c, axis=-1).astype(jnp.int32), jnp.max(c, axis=-1)


def omp_gram_corr_ref(alpha0: Array, G: Array, idx: Array, y: Array,
                      selected_mask: Array) -> tuple:
    """Gram-path OMP selection oracle: gathered ``|alpha0 − Σ y_k·G[idx_k]|``.

    alpha0 (B, N) f32; G (N, N); idx (B, s) int; y (B, s) f32 (zero past the
    filled prefix); selected_mask (B, N) bool -> (argmax (B,) i32, max (B,)).

    This is the gather-then-reduce form the streamed ``omp_gram_argmax``
    kernel exists to avoid: it materialises the (B, s, N) row gather of G
    and the full (B, N) correlation matrix. ``jnp.argmax`` breaks ties to
    the lowest atom index, matching the kernel's strictly-greater merge.
    """
    rows = G.astype(jnp.float32)[idx.astype(jnp.int32)]        # (B, s, N)
    c = alpha0.astype(jnp.float32) - jnp.einsum(
        "bs,bsn->bn", y.astype(jnp.float32), rows)
    c = jnp.where(selected_mask, -jnp.inf, jnp.abs(c))
    return jnp.argmax(c, axis=-1).astype(jnp.int32), jnp.max(c, axis=-1)
