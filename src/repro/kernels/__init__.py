"""Pallas TPU kernels for Lexico's sparse hot paths + jnp oracles.

<name>.py hold the pl.pallas_call kernels with explicit BlockSpec VMEM
tiling; ops.py the backend-dispatching jit wrappers; ref.py the pure-jnp
oracles every kernel is tested against (shape/dtype sweeps + hypothesis).
"""
from repro.kernels.ops import (
    batched_scores, batched_values, omp_select_op, paged_attention_op,
    resolve_dispatch, scores_op, values_op,
)
