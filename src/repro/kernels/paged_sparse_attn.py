"""Pallas TPU kernel: fused paged sparse-attention over the compressed pool.

The decode hot loop computed end to end from packed ``(idx, val)`` codes: the
kernel walks each slot's page table, streams page-sized tiles of the four
sparse stores HBM→VMEM, expands attention scores against the dictionary
projection ``qd = q @ D_k`` (the gather-dot of ``sparse_scores``), folds them
through an online softmax, and scatter-accumulates the probabilities into
dictionary-coefficient space (the segment-adds of ``sparse_values``) — all
inside one ``pallas_call``. Dense K/V and the gathered per-row page copy of
``gather_pages`` never exist: the only HBM traffic is the resident codes
(3s+2 bytes/token), read once.

Layout and grid:

  * grid = ``(B, KV, max_pages * blocks_per_page)`` — the last dimension
    walks one slot's page table in token tiles; TPU grid order is sequential
    with the last dimension fastest, so for each (row, head) the tiles
    arrive in position order and the online-softmax carry is race-free.
  * the page table, ``t_c`` and ``min_pos`` ride in scalar-prefetch SMEM
    (``PrefetchScalarGridSpec``): the pool BlockSpecs index
    ``table[b, i // blocks_per_page]`` directly, so each grid step DMAs
    exactly one page tile of each store — *physical* page placement is
    invisible to the kernel body, which only sees logical positions.
  * null/out-of-range table entries are pre-clamped onto the trash page 0;
    its tiles stream through like any other and are masked by ``pos < t_c``
    (the same contract ``gather_pages`` + ``decode_attention`` rely on).
  * the online-softmax carry — running max ``m`` (G,), mass ``l`` (G,) and
    the coefficient accumulator ``c`` (G, N) — lives in the revisited output
    blocks in VMEM (the ``sparse_values`` accumulation pattern). At the
    paper shape N=4096, G=8 that is 128 KB for ``c`` plus 128 KB for ``qd``
    — comfortably inside VMEM next to four (block_t, s) code tiles.
  * ``block_t`` (tokens per tile, default one full page) may be any value
    ``<= page_size``, divisor or not: a partial tail tile reads pad garbage
    (NaN in interpret mode), so masked lanes are forced to zero values and
    in-range indices before use.

The kernel returns the carry ``(m, l, c)`` rather than finished attention:
the caller merges the full-precision recency buffer as the final online-
softmax block and decodes ``c`` through ``D_v`` on the MXU (see
``repro.core.attention.fused_paged_decode_attention``), exactly mirroring
the flash-decode epilogue of ``decode_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _fused_kernel(tbl_ref, t_c_ref, min_pos_ref,
                  qd_ref, kv_ref, ki_ref, vv_ref, vi_ref,
                  m_ref, l_ref, c_ref, *,
                  page_size: int, block_t: int, blocks_per_page: int,
                  scale: float, G: int, s: int, N: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        # fresh (row, head): reset the online-softmax carry
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    page_i = i // blocks_per_page          # logical page index in the row
    sub = i % blocks_per_page              # tile index inside the page
    pos_in_page = sub * block_t + jnp.arange(block_t)
    pos = page_i * page_size + pos_in_page
    valid = ((pos < t_c_ref[b]) & (pos >= min_pos_ref[b])
             & (pos_in_page < page_size))

    # Sanitize before use: a partial tail tile (block_t not dividing
    # page_size) reads pad garbage, and trash-page codes are arbitrary —
    # masked lanes must carry finite zero values and in-range indices.
    vmask = valid[:, None]
    kvals = jnp.where(vmask, kv_ref[0, 0].astype(jnp.float32), 0.0)
    kidx = jnp.clip(ki_ref[0, 0].astype(jnp.int32), 0, N - 1)
    vvals = jnp.where(vmask, vv_ref[0, 0].astype(jnp.float32), 0.0)
    vidx = jnp.clip(vi_ref[0, 0].astype(jnp.int32), 0, N - 1)

    # G is small and static: unroll query heads, each head re-running the
    # proven single-vector gather-dot / segment-add bodies of
    # sparse_scores / sparse_values.
    for g in range(G):
        qd_g = qd_ref[0, 0, g]                               # (N,) VMEM
        sc = jnp.sum(qd_g[kidx] * kvals, axis=-1) * scale    # (block_t,)
        sc = jnp.where(valid, sc, NEG_INF)
        m_run = m_ref[0, 0, g]
        m_new = jnp.maximum(m_run, jnp.max(sc))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.where(valid, jnp.exp(sc - m_new), 0.0)
        l_ref[0, 0, g] = l_ref[0, 0, g] * alpha + jnp.sum(p)
        c_g = c_ref[0, 0, g] * alpha                         # (N,)
        contrib = p[:, None] * vvals                         # (block_t, s)
        for j in range(s):
            c_g = c_g.at[vidx[:, j]].add(contrib[:, j])
        c_ref[0, 0, g] = c_g
        m_ref[0, 0, g] = m_new


@functools.partial(jax.jit,
                   static_argnames=("N", "scale", "block_t", "interpret"))
def paged_sparse_attention(
    qd: Array,                                  # (B, KV, G, N) f32
    k_vals: Array, k_idx: Array,                # (n_pages, KV, P, s)
    v_vals: Array, v_idx: Array,
    page_table: Array,                          # (B, max_pages) int32
    t_c: Array,                                 # (B,) int32 valid tokens
    min_pos: Array,                             # (B,) int32 window floor; -1 = global
    *,
    N: int,
    scale: float,
    block_t: int | None = None,
    interpret: bool = False,
) -> tuple[Array, Array, Array]:
    """Fused paged attention carry over the compressed pool.

    Returns ``(m, l, c)`` — running max (B, KV, G), softmax mass (B, KV, G)
    and the coefficient accumulator (B, KV, G, N) of every *valid* cache
    position (``min_pos <= pos < t_c`` per row). Rows with no valid
    positions return ``m = NEG_INF, l = 0, c = 0`` — the same carry the
    flash-decode path of ``decode_attention`` starts from, so the caller's
    buffer merge handles them unchanged.

    ``block_t``: tokens per VMEM tile, ``<= page_size``; need not divide it
    (the tail tile is pad-masked). Default: one full page per tile.
    """
    B, KV, G, _ = qd.shape
    n_pages, _, P, s = k_vals.shape
    MP = page_table.shape[1]
    bt = P if block_t is None else min(block_t, P)
    bpp = -(-P // bt)
    grid = (B, KV, MP * bpp)

    def pool_spec():
        # one page tile per grid step, addressed THROUGH the page table
        return pl.BlockSpec(
            (1, 1, bt, s),
            lambda b, k, i, tbl, tc, mp: (tbl[b, i // bpp], k, i % bpp, 0))

    def bcast_spec(shape):
        return pl.BlockSpec(shape, lambda b, k, i, *_: (b, k, 0, 0)[:len(shape)])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,     # page_table, t_c, min_pos
        grid=grid,
        in_specs=[
            bcast_spec((1, 1, G, N)),                        # qd
            pool_spec(), pool_spec(), pool_spec(), pool_spec(),
        ],
        out_specs=[
            bcast_spec((1, 1, G)),                           # m
            bcast_spec((1, 1, G)),                           # l
            bcast_spec((1, 1, G, N)),                        # c
        ],
    )
    kern = functools.partial(
        _fused_kernel, page_size=P, block_t=bt, blocks_per_page=bpp,
        scale=float(scale), G=G, s=s, N=N)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
                   jax.ShapeDtypeStruct((B, KV, G, N), jnp.float32)],
        interpret=interpret,
    )(jnp.clip(jnp.asarray(page_table, jnp.int32), 0, n_pages - 1),
      jnp.asarray(t_c, jnp.int32), jnp.asarray(min_pos, jnp.int32),
      qd.astype(jnp.float32), k_vals, k_idx, v_vals, v_idx)
