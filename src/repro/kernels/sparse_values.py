"""Pallas TPU kernel: compressed-cache value read-out (scatter-accumulate).

The second sparse primitive of Lexico decode: accumulate attention
probabilities into dictionary-coefficient space,

    c[n] += probs[t] * vals[t, j]   for n = idx[t, j],

then one dense (N x m) matmul decodes c through D_v (done outside, on the
MXU). The (N,) accumulator lives in VMEM for the whole kernel (16 KB at
N=4096); token tiles stream through. TPU adaptation notes:

  * TPU has no fast random scatter; inside a tile we materialise the gather-
    free form ``c += one_hot(idx) @ (p*vals)`` as an (s-step) loop of
    segment adds on the VPU — for s<=32 this beats emulated scatter and
    keeps everything (8,128)-tiled.
  * The grid walks token tiles sequentially (single program instance per
    token range, revisiting the same output block) — Pallas guarantees
    sequential grid order on TPU, so the accumulation is race-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _values_kernel(probs_ref, vals_ref, idx_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = probs_ref[...].astype(jnp.float32)            # (T_blk,)
    vals = vals_ref[...].astype(jnp.float32)          # (T_blk, s)
    idx = idx_ref[...].astype(jnp.int32)
    contrib = p[:, None] * vals                       # (T_blk, s)
    N = out_ref.shape[0]
    acc = out_ref[...]
    # s sequential segment-adds (s is small); each is a VPU scatter-free add
    s = vals.shape[1]
    for j in range(s):
        acc = acc.at[idx[:, j]].add(contrib[:, j])
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("N", "block_t", "interpret"))
def sparse_values(probs: Array, vals: Array, idx: Array, *, N: int,
                  block_t: int = 1024, interpret: bool = False) -> Array:
    """probs (T,); vals/idx (T, s) -> coefficient accumulator (N,) f32."""
    T, s = vals.shape
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    grid = (T // block_t,)
    return pl.pallas_call(
        _values_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t,), lambda i: (i,)),
            pl.BlockSpec((block_t, s), lambda i: (i, 0)),
            pl.BlockSpec((block_t, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((N,), lambda i: (0,)),   # same block every step
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(probs.astype(jnp.float32), vals, idx)
