"""Pallas TPU kernel: fused OMP correlation + masked abs-argmax.

The inner step of batched OMP (Algorithm 1 line 3): for a batch of residuals,
``n* = argmax_n |(Dᵀ r)_n|`` excluding already-selected atoms. Fusing the
(m x N) matvec with the masked argmax avoids materialising the (B, N)
correlation matrix in HBM — the block-local max/argmax reduce in VMEM and
only (B,) scalars leave the kernel.

Tiling: grid over (batch tiles x atom tiles). D is streamed as (m, N_blk)
tiles (the MXU does the (B_blk, m) x (m, N_blk) product); a running
(B_blk,) max + argmax pair is carried in the output refs across the atom
grid dimension (sequential on TPU, so the reduction is race-free).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array
NEG = -1e30


def _corr_kernel(r_ref, d_ref, sel_ref, max_ref, arg_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        max_ref[...] = jnp.full_like(max_ref, NEG)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    r = r_ref[...].astype(jnp.float32)                # (B_blk, m)
    d = d_ref[...].astype(jnp.float32)                # (m, N_blk)
    sel = sel_ref[...]                                # (B_blk, N_blk) bool
    c = jnp.abs(jnp.dot(r, d, preferred_element_type=jnp.float32))
    c = jnp.where(sel, NEG, c)
    n_blk = d.shape[1]
    local_arg = jnp.argmax(c, axis=-1)                # (B_blk,)
    local_max = jnp.max(c, axis=-1)
    cur_max = max_ref[...]
    better = local_max > cur_max
    max_ref[...] = jnp.where(better, local_max, cur_max)
    arg_ref[...] = jnp.where(better, (j * n_blk + local_arg).astype(jnp.int32),
                             arg_ref[...])


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def omp_corr_argmax(residual: Array, D: Array, selected: Array, *,
                    block_b: int = 128, block_n: int = 512,
                    interpret: bool = False):
    """residual (B, m); D (m, N); selected (B, N) bool -> (argmax (B,) i32,
    max (B,) f32) of |D^T r| over unselected atoms."""
    B, m = residual.shape
    N = D.shape[1]
    block_b = min(block_b, B)
    block_n = min(block_n, N)
    assert B % block_b == 0 and N % block_n == 0, (B, block_b, N, block_n)
    grid = (B // block_b, N // block_n)
    out_max, out_arg = pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((m, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(residual.astype(jnp.float32), D.astype(jnp.float32), selected)
    return out_arg, out_max
