"""Pallas TPU kernels: fused OMP correlation + masked abs-argmax.

The inner step of batched OMP (Algorithm 1 line 3): for a batch of residuals,
``n* = argmax_n |(Dᵀ r)_n|`` excluding already-selected atoms. Fusing the
correlation with the masked argmax avoids materialising the (B, N)
correlation matrix in HBM — the block-local max/argmax reduce in VMEM and
only (B,) scalars leave the kernel.

Two kernels, one per correlation backend of ``core/omp.py``:

  * ``omp_corr_argmax`` — Gram-free: the (m x N) matvec ``|Dᵀ r|`` fused with
    the masked argmax. Tiled over (batch tiles x atom tiles); D is streamed
    as (m, N_blk) tiles (the MXU does the (B_blk, m) x (m, N_blk) product); a
    running (B_blk,) max + argmax pair is carried in the output refs across
    the atom grid dimension (sequential on TPU, so the reduction is
    race-free). Ragged B / N are padded to the block grid and masked (pad
    rows are sliced off, pad atoms enter as ``selected``).

  * ``omp_gram_argmax`` — the Gram path the serving engine actually uses:
    ``c = alpha0 − Σ_k y_k · G[idx_k, :]`` fused with the masked abs-argmax.
    The selected-atom Gram rows are streamed one (1, N_blk) tile per grid
    step through a scalar-prefetch BlockSpec (``idx`` rides in SMEM and
    addresses G's row directly — the same page-table-walk idiom as
    ``paged_sparse_attn``), so neither the (B, N) correlation matrix nor a
    gathered (B, s, N) copy of G ever hits HBM: the only G traffic is the
    ``B·s`` rows actually subtracted, read once. The running correlation for
    one atom tile accumulates in VMEM scratch across the ``s`` grid steps and
    reduces to the carried (max, argmax) on the last one.

Both kernels mask with a large negative finite (``NEG``) rather than -inf;
since ``|c| >= 0`` for every unselected atom, the masked lanes can never win
the argmax, and ties between equal correlations resolve to the lowest atom
index on every path (``jnp.argmax`` picks the first maximum inside a tile,
and the cross-tile merge is strictly-greater).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG = -1e30


def _corr_kernel(r_ref, d_ref, sel_ref, max_ref, arg_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        max_ref[...] = jnp.full_like(max_ref, NEG)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    r = r_ref[...].astype(jnp.float32)                # (B_blk, m)
    d = d_ref[...].astype(jnp.float32)                # (m, N_blk)
    sel = sel_ref[...]                                # (B_blk, N_blk) bool
    c = jnp.abs(jnp.dot(r, d, preferred_element_type=jnp.float32))
    c = jnp.where(sel, NEG, c)
    n_blk = d.shape[1]
    local_arg = jnp.argmax(c, axis=-1)                # (B_blk,)
    local_max = jnp.max(c, axis=-1)
    cur_max = max_ref[...]
    better = local_max > cur_max
    max_ref[...] = jnp.where(better, local_max, cur_max)
    arg_ref[...] = jnp.where(better, (j * n_blk + local_arg).astype(jnp.int32),
                             arg_ref[...])


def _pad_to(x: Array, axis: int, mult: int, value) -> Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_b", "block_n", "interpret"))
def omp_corr_argmax(residual: Array, D: Array, selected: Array, *,
                    block_b: int = 128, block_n: int = 512,
                    interpret: bool = False):
    """residual (B, m); D (m, N); selected (B, N) bool -> (argmax (B,) i32,
    max (B,) f32) of |D^T r| over unselected atoms.

    B and N may be ragged: the batch is zero-padded to a whole number of
    ``block_b`` tiles (pad rows are sliced off the outputs) and the atom axis
    to ``block_n`` tiles (pad atoms stream through as ``selected`` with zero
    columns, so they can never win the argmax).
    """
    B, m = residual.shape
    N = D.shape[1]
    block_b = min(block_b, B)
    block_n = min(block_n, N)
    r = _pad_to(residual.astype(jnp.float32), 0, block_b, 0.0)
    d = _pad_to(D.astype(jnp.float32), 1, block_n, 0.0)
    sel = _pad_to(_pad_to(selected, 1, block_n, True), 0, block_b, True)
    Bp, Np = sel.shape
    grid = (Bp // block_b, Np // block_n)
    out_max, out_arg = pl.pallas_call(
        _corr_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((m, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp,), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(r, d, sel)
    return out_arg[:B], out_max[:B]


def _gram_kernel(idx_ref, a_ref, g_ref, y_ref, sel_ref, max_ref, arg_ref,
                 acc_ref, *, block_n: int):
    j = pl.program_id(1)
    k = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when((j == 0) & (k == 0))
    def _init():
        max_ref[...] = jnp.full_like(max_ref, NEG)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    @pl.when(k == 0)
    def _load():
        # fresh atom tile: start the running correlation from alpha0
        acc_ref[...] = a_ref[0].astype(jnp.float32)

    y_k = jax.lax.dynamic_index_in_dim(
        y_ref[0].astype(jnp.float32), k, keepdims=False)
    acc_ref[...] = acc_ref[...] - y_k * g_ref[0].astype(jnp.float32)

    @pl.when(k == n_k - 1)
    def _reduce():
        c = jnp.where(sel_ref[0], NEG, jnp.abs(acc_ref[...]))
        local_arg = jnp.argmax(c)
        local_max = jnp.max(c)
        better = local_max > max_ref[0]
        max_ref[0] = jnp.where(better, local_max, max_ref[0])
        arg_ref[0] = jnp.where(
            better, (j * block_n + local_arg).astype(jnp.int32), arg_ref[0])


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def omp_gram_argmax(alpha0: Array, G: Array, idx: Array, y: Array,
                    selected: Array, *, block_n: int = 512,
                    interpret: bool = False):
    """Gram-path OMP selection: streamed ``|alpha0 − Σ_k y_k·G[idx_k]|``.

    alpha0 (B, N) f32; G (N, N); idx (B, s) i32; y (B, s) f32 (zero past the
    filled prefix, so trailing slots subtract nothing); selected (B, N) bool.
    Returns ``(argmax (B,) i32, max (B,) f32)`` over unselected atoms.

    Grid is (B, atom tiles, s): ``idx`` is scalar-prefetched into SMEM and
    drives G's BlockSpec row index, so each step DMAs exactly one
    (1, block_n) Gram-row tile; the correlation accumulates in VMEM scratch
    and only the (B,) max/argmax carry leaves the kernel. N may be ragged
    (pad atoms enter selected with zero G columns).
    """
    B, N = alpha0.shape
    s = idx.shape[1]
    block_n = min(block_n, N)
    a = _pad_to(alpha0.astype(jnp.float32), 1, block_n, 0.0)
    g = _pad_to(G.astype(jnp.float32), 1, block_n, 0.0)
    sel = _pad_to(selected, 1, block_n, True)
    Np = a.shape[1]
    grid = (B, Np // block_n, s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                        # idx
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda b, j, k, idx_ref: (b, j)),
            pl.BlockSpec((1, block_n),
                         lambda b, j, k, idx_ref: (idx_ref[b, k], j)),
            pl.BlockSpec((1, s), lambda b, j, k, idx_ref: (b, 0)),
            pl.BlockSpec((1, block_n), lambda b, j, k, idx_ref: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b, j, k, idx_ref: (b,)),
            pl.BlockSpec((1,), lambda b, j, k, idx_ref: (b,)),
        ],
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
    )
    out_max, out_arg = pl.pallas_call(
        functools.partial(_gram_kernel, block_n=block_n),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.clip(jnp.asarray(idx, jnp.int32), 0, N - 1), a, g,
      y.astype(jnp.float32), sel)
    return out_arg, out_max
