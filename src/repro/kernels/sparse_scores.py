"""Pallas TPU kernel: compressed-cache attention scores (gather-dot).

The decode hot loop of Lexico: for each compressed token t,
``score[t] = sum_j vals[t,j] * qd[idx[t,j]]`` where ``qd = q @ D_k`` (computed
once per query on the MXU). This is the TPU-native replacement of the paper's
cuSPARSE SpMV ``q·D_k·K_csrᵀ``:

  * ``qd`` (N,) stays resident in VMEM for the whole kernel (N=4096 fp32 =
    16 KB — trivially fits) — every block re-reads it for free.
  * tokens are tiled along the grid; each program loads a (T_blk, s) tile of
    vals/idx from HBM into VMEM, gathers qd at the indices with the VPU, and
    writes a (T_blk,) score tile. Arithmetic intensity is ~1 flop/byte —
    memory-bound by design, which is the point: the kernel reads 3s+2 bytes
    per token instead of 2·m (the compression ratio is the speedup bound).
  * T_blk defaults to 1024 tokens: (1024 x s=16) tiles are (8,128)-aligned
    for both the int16 index load and the fp8 value load.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _scores_kernel(qd_ref, vals_ref, idx_ref, out_ref):
    qd = qd_ref[...]                                  # (N,) f32 in VMEM
    vals = vals_ref[...].astype(jnp.float32)          # (T_blk, s)
    idx = idx_ref[...].astype(jnp.int32)              # (T_blk, s)
    g = qd[idx]                                       # VPU gather
    out_ref[...] = jnp.sum(g * vals, axis=-1)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def sparse_scores(qd: Array, vals: Array, idx: Array, *, block_t: int = 1024,
                  interpret: bool = False) -> Array:
    """qd (N,) f32; vals/idx (T, s) -> (T,) f32 scores.

    T must be a multiple of block_t (cache stores are padded at allocation).
    """
    T, s = vals.shape
    N = qd.shape[0]
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    grid = (T // block_t,)
    return pl.pallas_call(
        _scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N,), lambda i: (0,)),                # qd: whole vector
            pl.BlockSpec((block_t, s), lambda i: (i, 0)),      # vals tile
            pl.BlockSpec((block_t, s), lambda i: (i, 0)),      # idx tile
        ],
        out_specs=pl.BlockSpec((block_t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        interpret=interpret,
    )(qd.astype(jnp.float32), vals, idx)
