"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; elsewhere (this CPU container, tests)
they execute in interpret mode or fall back to the pure-jnp oracle — the
wrappers pick per-backend so the serving stack can call one function
everywhere. Batched variants vmap the single-instance kernels over
(B, KV, G) the same way core.attention composes the jnp forms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.omp_corr import omp_corr_argmax
from repro.kernels.sparse_scores import sparse_scores
from repro.kernels.sparse_values import sparse_values

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def scores_op(qd: Array, vals: Array, idx: Array, *, force_kernel: bool = False,
              interpret: bool | None = None) -> Array:
    """(N,), (T,s), (T,s) -> (T,) — kernel on TPU, oracle elsewhere."""
    if _on_tpu() or force_kernel:
        return sparse_scores(qd, vals, idx,
                             interpret=(not _on_tpu()) if interpret is None else interpret)
    return ref.sparse_scores_ref(qd, vals, idx)


def values_op(probs: Array, vals: Array, idx: Array, *, N: int,
              force_kernel: bool = False, interpret: bool | None = None) -> Array:
    if _on_tpu() or force_kernel:
        return sparse_values(probs, vals, idx, N=N,
                             interpret=(not _on_tpu()) if interpret is None else interpret)
    return ref.sparse_values_ref(probs, vals, idx, N)


def omp_select_op(residual: Array, D: Array, selected: Array, *,
                  force_kernel: bool = False, interpret: bool | None = None):
    if _on_tpu() or force_kernel:
        return omp_corr_argmax(residual, D, selected,
                               interpret=(not _on_tpu()) if interpret is None else interpret)
    return ref.omp_corr_ref(D, residual, selected)


def batched_scores(qd: Array, vals: Array, idx: Array, **kw) -> Array:
    """(B,KV,G,N) x (B,KV,T,s) -> (B,KV,G,T) via the kernel."""
    f = functools.partial(scores_op, **kw)
    g = jax.vmap(jax.vmap(lambda q_g, v, i: jax.vmap(lambda q: f(q, v, i))(q_g),
                          in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    return g(qd, vals, idx)


def batched_values(probs: Array, vals: Array, idx: Array, *, N: int, **kw) -> Array:
    """(B,KV,G,T) x (B,KV,T,s) -> (B,KV,G,N) via the kernel."""
    f = functools.partial(values_op, N=N, **kw)
    g = jax.vmap(jax.vmap(lambda p_g, v, i: jax.vmap(lambda p: f(p, v, i))(p_g),
                          in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    return g(probs, vals, idx)
