"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; elsewhere (this CPU container, tests)
they execute in interpret mode or fall back to the pure-jnp oracle — the
wrappers pick per-backend so the serving stack can call one function
everywhere. Batched variants vmap the single-instance kernels over
(B, KV, G) the same way core.attention composes the jnp forms.

Dispatch contract (shared by every op, pinned in
``tests/test_paged_sparse_attn.py::test_dispatch_table``):

    use_kernel = on_tpu OR force_kernel OR interpret is True
    interpret  = (not on_tpu) if interpret is None else interpret

i.e. ``force_kernel=True`` with ``interpret=None`` off-TPU runs the kernel
in interpret mode (it must never silently fall back to the oracle), and an
explicit ``interpret=True`` is itself a request for the kernel. The oracle
path is taken only when nothing asked for the kernel and no TPU is present.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.omp_corr import omp_corr_argmax, omp_gram_argmax
from repro.kernels.paged_sparse_attn import paged_sparse_attention
from repro.kernels.sparse_scores import sparse_scores
from repro.kernels.sparse_values import sparse_values

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_dispatch(force_kernel: bool,
                     interpret: Optional[bool]) -> Tuple[bool, bool]:
    """The one dispatch decision every op shares.

    Returns ``(use_kernel, interpret_mode)``: whether to run the Pallas
    kernel at all, and — when running it — whether in interpret mode.
    ``interpret=None`` means "pick per backend" (native on TPU, interpret
    elsewhere); an explicit ``interpret=True`` opts into the kernel even
    without ``force_kernel``.
    """
    on_tpu = _on_tpu()
    use_kernel = on_tpu or force_kernel or interpret is True
    interp = (not on_tpu) if interpret is None else bool(interpret)
    return use_kernel, interp


def scores_op(qd: Array, vals: Array, idx: Array, *, force_kernel: bool = False,
              interpret: bool | None = None) -> Array:
    """(N,), (T,s), (T,s) -> (T,) — kernel on TPU, oracle elsewhere."""
    use_kernel, interp = resolve_dispatch(force_kernel, interpret)
    if use_kernel:
        return sparse_scores(qd, vals, idx, interpret=interp)
    return ref.sparse_scores_ref(qd, vals, idx)


def values_op(probs: Array, vals: Array, idx: Array, *, N: int,
              force_kernel: bool = False, interpret: bool | None = None) -> Array:
    use_kernel, interp = resolve_dispatch(force_kernel, interpret)
    if use_kernel:
        return sparse_values(probs, vals, idx, N=N, interpret=interp)
    return ref.sparse_values_ref(probs, vals, idx, N)


def omp_select_op(residual: Array, D: Array, selected: Array, *,
                  force_kernel: bool = False, interpret: bool | None = None):
    use_kernel, interp = resolve_dispatch(force_kernel, interpret)
    if use_kernel:
        return omp_corr_argmax(residual, D, selected, interpret=interp)
    return ref.omp_corr_ref(D, residual, selected)


def omp_gram_select_op(alpha0: Array, G: Array, idx: Array, y: Array,
                       selected: Array, *, force_kernel: bool = False,
                       interpret: bool | None = None):
    """Gram-path OMP selection step: ``argmax_n |alpha0 − Σ_k y_k·G[idx_k]|``
    over unselected atoms — streamed kernel on TPU (Gram rows addressed
    through a scalar-prefetch BlockSpec), gathered jnp oracle elsewhere."""
    use_kernel, interp = resolve_dispatch(force_kernel, interpret)
    if use_kernel:
        return omp_gram_argmax(alpha0, G, idx, y, selected, interpret=interp)
    return ref.omp_gram_corr_ref(alpha0, G, idx, y, selected)


def paged_attention_op(
    qd: Array,                                  # (B, KV, G, N)
    k_vals: Array, k_idx: Array,                # (n_pages, KV, P, s)
    v_vals: Array, v_idx: Array,
    page_table: Array,                          # (B, max_pages) int32
    t_c: Array, min_pos: Array,                 # (B,) int32
    *,
    N: int,
    scale: float,
    block_t: Optional[int] = None,
    force_kernel: bool = False,
    interpret: bool | None = None,
) -> Tuple[Array, Array, Array]:
    """Fused paged sparse-attention carry ``(m, l, c)`` — the kernel walks
    the page tables directly; the oracle gathers-then-masks. Both return
    identical carries (to fp32 accumulation-order tolerance), so callers
    merge the recency buffer the same way on every backend."""
    use_kernel, interp = resolve_dispatch(force_kernel, interpret)
    if use_kernel:
        return paged_sparse_attention(
            qd, k_vals, k_idx, v_vals, v_idx, page_table, t_c, min_pos,
            N=N, scale=scale, block_t=block_t, interpret=interp)
    return ref.paged_attention_ref(
        qd, k_vals, k_idx, v_vals, v_idx, page_table, t_c, min_pos,
        N=N, scale=scale)


def batched_scores(qd: Array, vals: Array, idx: Array, **kw) -> Array:
    """(B,KV,G,N) x (B,KV,T,s) -> (B,KV,G,T) via the kernel."""
    f = functools.partial(scores_op, **kw)
    g = jax.vmap(jax.vmap(lambda q_g, v, i: jax.vmap(lambda q: f(q, v, i))(q_g),
                          in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    return g(qd, vals, idx)


def batched_values(probs: Array, vals: Array, idx: Array, *, N: int, **kw) -> Array:
    """(B,KV,G,T) x (B,KV,T,s) -> (B,KV,G,N) via the kernel."""
    f = functools.partial(values_op, N=N, **kw)
    g = jax.vmap(jax.vmap(lambda p_g, v, i: jax.vmap(lambda p: f(p, v, i))(p_g),
                          in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    return g(probs, vals, idx)
