"""Sharded, atomic, async checkpointing (no orbax in the container).

Layout: one ``.npz`` per pytree leaf (path-keyed), plus ``manifest.json``
holding the treedef, shapes, dtypes, and step. Writes go to ``<step>.tmp``
and are atomically renamed to ``<step>`` when complete — a crashed writer
never corrupts the latest checkpoint (fault-tolerance requirement).

Elasticity: leaves are saved as *global* logical arrays (gathered per leaf on
save via ``jax.device_get``) and restored with ``jax.device_put`` against any
target sharding — so a checkpoint taken on a 16x16 mesh restores onto 2x16x16
or a single host unchanged (restore-to-any-mesh). At true multi-host scale
each process would write only its addressable shards; the manifest format
already records per-leaf shape/dtype so that extension is mechanical — the
single-controller container exercises the gather path.

Async: ``CheckpointManager.save(..., blocking=False)`` snapshots to host
memory synchronously (cheap) and writes files on a background thread, so the
train loop resumes immediately (the paper-scale requirement: checkpoint
without stalling the step).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


def save_pytree(tree: Any, directory: str, *, step: int) -> str:
    """Write tree to ``directory/<step>`` atomically. Returns the final path."""
    final = os.path.join(directory, str(step))
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "leaves": {}}
    for path, leaf in leaves_with_paths:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        np.savez_compressed(os.path.join(tmp, key + ".npz"), arr=arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_pytree(template: Any, directory: str, *, step: Optional[int] = None,
                   shardings: Any = None) -> Any:
    """Restore into the structure of ``template``. ``shardings`` (optional,
    same structure) places each leaf on the target mesh — this is the
    elastic-restore path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, str(step))

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                 if shardings is not None else [None] * len(leaves_with_paths))
    out = []
    for (path, leaf), sh in zip(leaves_with_paths, sh_leaves):
        key = _leaf_key(path)
        arr = np.load(os.path.join(d, key + ".npz"))["arr"]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(n) for n in os.listdir(directory)
             if n.isdigit() and os.path.exists(os.path.join(directory, n, "manifest.json"))]
    return max(steps) if steps else None


class CheckpointManager:
    """Retention + async writes + preemption-time emergency saves."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    def save(self, tree: Any, *, step: int, blocking: bool = True):
        if not blocking:
            self.wait()   # one in-flight save at a time
            host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

            def work():
                try:
                    save_pytree(host_tree, self.directory, step=step)
                    self._gc()
                except BaseException as e:   # surfaced on next wait()
                    self._last_error = e

            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
            return
        save_pytree(tree, self.directory, step=step)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise e

    def restore_latest(self, template: Any, shardings: Any = None):
        return restore_pytree(template, self.directory, shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def _gc(self):
        steps = sorted(int(n) for n in os.listdir(self.directory) if n.isdigit())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, str(s)), ignore_errors=True)
