"""Host data pipeline: deterministic, sharded, resumable, prefetching.

Each process feeds only its addressable shard of the global batch (multi-host
pattern); the iterator state is a single step counter, so restoring a
checkpoint restores the exact data order (fault-tolerance requirement).
A background thread prefetches ``prefetch`` batches ahead of the consumer.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from repro.data.synthetic import SyntheticCorpus


class DataPipeline:
    def __init__(self, vocab_size: int, global_batch: int, seq_len: int, *,
                 process_index: int = 0, process_count: int = 1,
                 seed: int = 0, prefetch: int = 2,
                 corpus: Optional[SyntheticCorpus] = None):
        assert global_batch % process_count == 0
        self.local_batch = global_batch // process_count
        self.seq_len = seq_len
        self.process_index = process_index
        self.seed = seed
        self.corpus = corpus or SyntheticCorpus(vocab_size, seed=seed)
        self.step = 0
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic access ------------------------------------------------
    def batch_at(self, step: int) -> dict:
        tokens = self.corpus.sample(
            self.local_batch, self.seq_len,
            seed=step * 1_000_003 + self.process_index)
        return {"tokens": tokens.astype(np.int32),
                "labels": tokens.astype(np.int32)}

    # -- prefetching iterator ------------------------------------------------
    def _producer(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.batch_at(s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.5)
                    break
                except queue.Full:
                    continue
            s += 1

    def start(self, from_step: int = 0):
        self.step = from_step
        self._stop.clear()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        return self

    def __next__(self) -> dict:
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
