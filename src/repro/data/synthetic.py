"""Synthetic corpus for offline training (no internet in the container).

A deterministic Zipfian-bigram language over an arbitrary vocab: token
frequencies follow a Zipf law and transitions follow per-state bigram tables
with topic drift, giving sequences with real low-dimensional structure —
enough for dictionaries to have something to learn (unlike iid-uniform
tokens, whose KV vectors carry no shared subspaces). Plays the WikiText-103
role of the paper for dictionary training; a second generator with different
seed/topic structure stands in for the out-of-domain corpora of Table 1.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np


class SyntheticCorpus:
    def __init__(self, vocab_size: int, *, seed: int = 0, n_topics: int = 16,
                 branch: int = 64, zipf_a: float = 1.2):
        self.vocab_size = vocab_size
        self.rng = np.random.default_rng(seed)
        self.n_topics = n_topics
        self.branch = branch
        # Zipf over the vocab, topic-specific permutations
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        base = ranks ** (-zipf_a)
        base /= base.sum()
        self.topic_perm = np.stack(
            [self.rng.permutation(vocab_size) for _ in range(n_topics)])
        self.base = base
        # per-topic sparse "bigram" jump tables: token t -> branch candidates
        self.jump = self.rng.integers(
            0, vocab_size, size=(n_topics, 256, branch), dtype=np.int64)

    def sample(self, batch: int, seq_len: int, *, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng((seed * 0x9E3779B9) & 0x7FFFFFFF)
        out = np.empty((batch, seq_len), np.int64)
        for b in range(batch):
            topic = rng.integers(self.n_topics)
            perm = self.topic_perm[topic]
            tok = perm[rng.choice(self.vocab_size, p=self.base)]
            for t in range(seq_len):
                out[b, t] = tok
                if rng.random() < 0.15:   # topic-conditioned bigram jump
                    tok = self.jump[topic, tok % 256, rng.integers(self.branch)]
                else:                     # unigram re-draw within topic
                    tok = perm[rng.choice(self.vocab_size, p=self.base)]
                if rng.random() < 0.01:   # topic drift
                    topic = rng.integers(self.n_topics)
                    perm = self.topic_perm[topic]
        return out


def synth_tokens(vocab_size: int, batch: int, seq_len: int, *, seed: int = 0
                 ) -> np.ndarray:
    """One-shot convenience sampler."""
    return SyntheticCorpus(vocab_size, seed=seed).sample(batch, seq_len, seed=seed)
