from repro.data.synthetic import SyntheticCorpus, synth_tokens
from repro.data.pipeline import DataPipeline
