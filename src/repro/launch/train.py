"""Training step + driver: AdamW LM training with remat, clipping, schedules,
fault-tolerance hooks and (optional) int8 error-feedback gradient compression.

``make_train_step(cfg)`` builds the pure step; ``build_train_artifacts``
wires shardings for AOT lowering (dry-run) or live pjit execution.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.optim import adamw_tree_init, adamw_tree_update, clip_by_global_norm, linear_warmup_cosine
from repro.runtime import sharding as shd

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    mu: Any
    nu: Any
    step: Array


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = M.init_params(key, cfg)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return TrainState(params=params,
                      mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, zeros),
                      step=jnp.int32(0))


def make_train_step(cfg: ModelConfig, *, base_lr: float = 3e-4,
                    warmup: int = 200, total_steps: int = 10_000,
                    clip_norm: float = 1.0, remat: bool = True,
                    grad_compress: bool = False):
    schedule = linear_warmup_cosine(base_lr, warmup, total_steps)

    def train_step(state: TrainState, batch: dict) -> Tuple[TrainState, dict]:
        def loss_fn(p):
            return M.lm_loss(p, cfg, batch, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        if grad_compress:
            from repro.runtime.compression import int8_compress_tree
            grads = int8_compress_tree(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(state.step)

        from repro.optim.adam import AdamState
        new_params, opt = adamw_tree_update(
            state.params, grads, AdamState(mu=state.mu, nu=state.nu,
                                           count=state.step),
            lr=lr, weight_decay=0.1)
        new_state = TrainState(params=new_params, mu=opt.mu, nu=opt.nu,
                               step=state.step + 1)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def state_shardings(mesh: Mesh, state_shape: TrainState, cfg: ModelConfig,
                    *, fsdp: bool = True) -> TrainState:
    ps = shd.param_shardings(mesh, state_shape.params, moe=cfg.moe is not None,
                             fsdp=fsdp)
    return TrainState(params=ps,
                      mu=jax.tree.map(lambda s: s, ps),
                      nu=jax.tree.map(lambda s: s, ps),
                      step=NamedSharding(mesh, P()))


def input_specs_train(cfg: ModelConfig, seq_len: int, global_batch: int) -> dict:
    spec = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if cfg.enc_dec:
        frames = min(seq_len, cfg.enc_max_frames)
        spec["frames"] = jax.ShapeDtypeStruct(
            (global_batch, frames, cfg.d_model), jnp.bfloat16)
    return spec


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    shapes = jax.eval_shape(functools.partial(init_train_state, cfg=cfg),
                            jax.random.PRNGKey(0))
    return shapes


def lower_train_step(cfg: ModelConfig, mesh: Mesh, seq_len: int,
                     global_batch: int, *, fsdp: bool = True,
                     remat: bool = True, donate: bool = True):
    """AOT-lower the training step on ShapeDtypeStructs (no allocation)."""
    step = make_train_step(cfg, remat=remat)
    state_shape = abstract_train_state(cfg)
    st_sh = state_shardings(mesh, state_shape, cfg, fsdp=fsdp)
    batch_sh = jax.tree.map(
        lambda _: shd.data_sharding(mesh, batch_size=global_batch),
        input_specs_train(cfg, seq_len, global_batch))
    jitted = jax.jit(
        step,
        in_shardings=(st_sh, batch_sh),
        out_shardings=(st_sh, NamedSharding(mesh, P())),
        donate_argnums=(0,) if donate else (),
    )
    from repro.launch.serve import _mesh_ctx
    with _mesh_ctx(mesh):
        lowered = jitted.lower(state_shape,
                               input_specs_train(cfg, seq_len, global_batch))
    return lowered
