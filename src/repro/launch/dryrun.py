"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines (jax locks device count on first init):
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

import repro.configs as configs
from repro.configs.base import LexicoConfig, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import analyze_compiled, model_flops_for

# cells: every arch runs train_4k / prefill_32k / decode_32k; long_500k only
# for the sub-quadratic archs (SSM / hybrid-SWA) — see DESIGN.md.
LONG_OK = ("hymba-1.5b", "rwkv6-3b")
SKIPS = {(a, "long_500k"): "full-attention arch: 500k decode needs sub-quadratic attention"
         for a in configs.ARCHS if a not in LONG_OK}


def cells():
    for arch in configs.ARCHS:
        for shape in SHAPES:
            if (arch, shape) in SKIPS:
                continue
            yield arch, shape


def _shrink_for_serve(cfg, lex: LexicoConfig, shape: str) -> LexicoConfig:
    """Paper defaults (N=4096, s=16 for ~21% KV, n_b=128)."""
    return lex


def run_cell(arch: str, shape: str, *, multi_pod: bool, variant: str = "baseline",
             s: int = 16) -> dict:
    cfg = configs.get(arch)
    sh = SHAPES[shape]
    seq_len, global_batch, kind = sh["seq_len"], sh["global_batch"], sh["kind"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    mesh_desc = "x".join(str(v) for v in mesh.devices.shape)

    # variant knobs (see EXPERIMENTS.md §Perf). 'baseline' is paper-faithful:
    # compressed cache replicated over 'model', unchunked softmax, fp32 Gram,
    # pjit scatter MoE dispatch. 'opt*' variants turn on the beyond-paper
    # optimizations one at a time for the hillclimb:
    #   opt-seq:   sequence-shard the compressed cache + flash-decode chunks
    #   opt-gram:  bf16 stored Gram
    #   opt-moe:   shard_map zero-dispatch-comm EP
    #   opt:       all of the above
    import dataclasses as _dc
    seq_shard = variant in ("opt", "opt-seq", "opt-smap")
    chunk = 2048 if variant in ("opt", "opt-seq") else None
    gram_dtype = "bfloat16" if variant in ("opt", "opt-gram") else "float32"
    if variant in ("opt", "opt-moe") and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe, dispatch="ep_local"))
    if variant in ("opt", "opt-bf16p"):
        cfg = _dc.replace(cfg, attn_probs_bf16=True)
    lex = LexicoConfig(N=4096, s=s, n_b=128, chunk=chunk, use_gram=True,
                       gram_dtype=gram_dtype)

    # FSDP for params only when TP-16 alone can't fit them
    per_chip_tp = cfg.param_count() * 2 / 16
    fsdp = kind == "train" or per_chip_tp > 6e9

    t0 = time.time()
    if kind == "train":
        from repro.launch.train import lower_train_step
        lowered = lower_train_step(cfg, mesh, seq_len, global_batch, fsdp=True)
    elif kind == "prefill":
        from repro.launch.serve import lower_prefill
        lowered = lower_prefill(cfg, lex, mesh, seq_len, global_batch,
                                seq_shard=seq_shard, fsdp=fsdp)
    else:
        from repro.launch.serve import lower_decode
        policy = None
        if variant in ("opt-smap", "opt") and not cfg.attn_free and cfg.mla is None:
            from repro.core.sharded_decode import SeqShardLexicoPolicy
            policy = SeqShardLexicoPolicy(lex)
        lowered = lower_decode(cfg, lex, mesh, seq_len, global_batch,
                               seq_shard=seq_shard, fsdp=fsdp, policy=policy)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mf = model_flops_for(cfg, kind, seq_len, global_batch)
    rep = analyze_compiled(compiled, arch=arch, shape=shape, mesh_desc=mesh_desc,
                           chips=chips, model_flops=mf)
    ma = compiled.memory_analysis()
    rec = rep.to_json()
    rec.update({
        "variant": variant,
        "s": s,
        "kind": kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "mem": {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", -1)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", -1)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", -1)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", -1)),
        },
    })
    print(compiled.memory_analysis())
    ca = compiled.cost_analysis()
    print({k: v for k, v in (ca[0] if isinstance(ca, list) else ca).items()
           if k in ("flops", "bytes accessed")})
    return rec


def key_of(arch, shape, multi_pod, variant):
    return f"{arch}|{shape}|{'multipod' if multi_pod else 'singlepod'}|{variant}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--s", type=int, default=16)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--sweep", action="store_true",
                    help="run every pending cell in a fresh subprocess each")
    ap.add_argument("--meshes", default="single,multi")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        results = json.load(open(args.out))

    if args.sweep:
        meshes = [m == "multi" for m in args.meshes.split(",")]
        todo = [(a, s, mp) for a, s in cells() for mp in meshes]
        for arch, shape, mp in todo:
            k = key_of(arch, shape, mp, args.variant)
            if k in results and "error" not in results[k]:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--variant", args.variant,
                   "--s", str(args.s), "--out", args.out] + (
                       ["--multi-pod"] if mp else [])
            print(f"=== {k} ===", flush=True)
            r = subprocess.run(cmd, env={**os.environ}, capture_output=True,
                               text=True, timeout=3600)
            if r.returncode != 0:
                results = json.load(open(args.out)) if os.path.exists(args.out) else {}
                results[k] = {"error": (r.stderr or r.stdout)[-2000:]}
                json.dump(results, open(args.out, "w"), indent=1)
                print(f"FAILED {k}: {(r.stderr or '')[-400:]}", flush=True)
            else:
                print(r.stdout[-400:], flush=True)
        # summary
        results = json.load(open(args.out))
        bad = [k for k, v in results.items() if "error" in v]
        print(f"done: {len(results) - len(bad)} ok, {len(bad)} failed")
        for k in bad:
            print("  FAIL", k)
        return

    assert args.arch and args.shape
    k = key_of(args.arch, args.shape, args.multi_pod, args.variant)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   variant=args.variant, s=args.s)
    results = json.load(open(args.out)) if os.path.exists(args.out) else {}
    results[k] = rec
    json.dump(results, open(args.out, "w"), indent=1)
    print(json.dumps({kk: vv for kk, vv in rec.items()
                      if kk in ("compute_s", "memory_s", "collective_s",
                                "bottleneck", "useful_ratio", "compile_s")}))


if __name__ == "__main__":
    main()
