"""Production mesh builders.

Functions (not module-level constants) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} — run via "
            "launch/dryrun.py which forces XLA_FLAGS host device count first")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_mesh(shape, axes):
    """Arbitrary mesh (used by elastic re-meshing and tests)."""
    need = math.prod(shape)
    return jax.make_mesh(tuple(shape), tuple(axes), devices=jax.devices()[:need])


def make_host_mesh():
    """1x1 mesh over the real local device (smoke tests, benchmarks)."""
    return jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
