"""Serving step builders: prefill and decode under pjit, with Lexico (or any
cache policy) and the production sharding layout.

Decode sharding (the interesting part):
  * batch          -> ('pod','data')
  * params         -> TP ('model') + FSDP ('data') — same rules as training
  * compressed cache token axis -> 'model' when ``seq_shard`` (beyond-paper
    sequence-parallel flash-decode: XLA inserts the softmax-stat reductions)
    or replicated when paper-faithful.
  * dictionaries   -> replicated (the paper's universality argument: constant
    memory, shared across batch/requests); Gram rows -> 'model'.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import LexicoConfig, ModelConfig
from repro.models import model as M
from repro.models.cache_policy import CachePolicy, LexicoPolicy
from repro.runtime import sharding as shd


def _mesh_ctx(mesh: Mesh):
    """``jax.set_mesh`` on newer JAX; the Mesh context manager elsewhere."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def bank_shardings(mesh: Mesh, bank, *, shard_gram: bool = True):
    if bank is None:
        return None
    from repro.core.dictionary import DictionaryBank
    d_sh = NamedSharding(mesh, P())           # universal dicts: replicated
    if bank.G is None:
        return DictionaryBank(D=d_sh, G=None)
    g_spec = P(None, None, "model", None) if shard_gram else P()
    return DictionaryBank(D=d_sh, G=NamedSharding(mesh, g_spec))


def serve_state_shardings(mesh: Mesh, state_shape: M.ServeState, *,
                          seq_shard: bool = True) -> M.ServeState:
    seq_axis = "model" if seq_shard else None
    cache_sh = shd.cache_shardings(mesh, state_shape.cache, seq_axis=seq_axis)
    cross_sh = (shd.cache_shardings(mesh, state_shape.cross, seq_axis=seq_axis)
                if state_shape.cross is not None else None)
    # length is (B,) per-slot bookkeeping — follows the batch sharding
    len_sh = (shd.data_sharding(mesh, batch_size=state_shape.length.shape[0])
              if state_shape.length.ndim else NamedSharding(mesh, P()))
    return M.ServeState(cache=cache_sh, length=len_sh, cross=cross_sh)


def input_specs_prefill(cfg: ModelConfig, seq_len: int, global_batch: int) -> dict:
    spec = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)}
    if cfg.enc_dec:
        frames = min(seq_len, cfg.enc_max_frames)
        spec["frames"] = jax.ShapeDtypeStruct(
            (global_batch, frames, cfg.d_model), jnp.bfloat16)
    return spec


def abstract_serve_params(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(M.init_params, cfg=cfg),
                          jax.random.PRNGKey(0))


def abstract_bank(cfg: ModelConfig, lex_cfg: LexicoConfig):
    return jax.eval_shape(
        functools.partial(M.init_dictionary_bank, cfg=cfg, lex_cfg=lex_cfg),
        jax.random.PRNGKey(0))


def lower_prefill(cfg: ModelConfig, lex_cfg: LexicoConfig, mesh: Mesh,
                  seq_len: int, global_batch: int, *,
                  policy: Optional[CachePolicy] = None,
                  seq_shard: bool = True, fsdp: bool = True):
    """AOT-lower prefill (full prompt -> compressed cache + first logits)."""
    policy = policy or (LexicoPolicy(lex_cfg) if not cfg.attn_free else None)
    t_max = seq_len + cfg.num_meta_tokens + 128
    params_shape = abstract_serve_params(cfg)
    bank_shape = abstract_bank(cfg, lex_cfg)
    in_spec = input_specs_prefill(cfg, seq_len, global_batch)

    def fn(params, bank, batch):
        return M.prefill(params, cfg, policy, batch, bank=bank, t_max=t_max)

    out_shape = jax.eval_shape(fn, params_shape, bank_shape, in_spec)
    p_sh = shd.param_shardings(mesh, params_shape, moe=cfg.moe is not None,
                               fsdp=fsdp)
    b_sh = bank_shardings(mesh, bank_shape)
    batch_sh = jax.tree.map(
        lambda _: shd.data_sharding(mesh, batch_size=global_batch), in_spec)
    out_sh = (shd.data_sharding(mesh, batch_size=global_batch),
              serve_state_shardings(mesh, out_shape[1], seq_shard=seq_shard))
    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh, batch_sh),
                     out_shardings=out_sh)
    with _mesh_ctx(mesh):
        return jitted.lower(params_shape, bank_shape, in_spec)


def abstract_decode_state(cfg: ModelConfig, policy: CachePolicy,
                          global_batch: int, t_max: int) -> M.ServeState:
    """ShapeDtypeStruct ServeState for a decode step with a cache of t_max."""
    def mk():
        cache = M.init_serve_cache(cfg, policy, global_batch, t_max)
        cross = None
        if cfg.enc_dec:
            # cross cache over enc_max_frames, stacked per layer
            lex = isinstance(policy, LexicoPolicy)
            B, KV, Tf, hd = (global_batch, cfg.cache_kv_heads,
                             cfg.enc_max_frames, cfg.hd)
            if lex:
                s = policy.cfg.s
                z = jnp.zeros((cfg.num_layers, B, KV, Tf, 0), jnp.bfloat16)
                cross = M.CrossCache(
                    k_vals=jnp.zeros((cfg.num_layers, B, KV, Tf, s), jnp.float8_e4m3fn),
                    k_idx=jnp.zeros((cfg.num_layers, B, KV, Tf, s), jnp.int16),
                    v_vals=jnp.zeros((cfg.num_layers, B, KV, Tf, s), jnp.float8_e4m3fn),
                    v_idx=jnp.zeros((cfg.num_layers, B, KV, Tf, s), jnp.int16),
                    dense_k=z, dense_v=z,
                    length=jnp.zeros((cfg.num_layers,), jnp.int32))
            else:
                zc = jnp.zeros((cfg.num_layers, B, KV, Tf, 0), jnp.float8_e4m3fn)
                zi = jnp.zeros((cfg.num_layers, B, KV, Tf, 0), jnp.int16)
                cross = M.CrossCache(
                    k_vals=zc, k_idx=zi, v_vals=zc, v_idx=zi,
                    dense_k=jnp.zeros((cfg.num_layers, B, KV, Tf, hd), jnp.bfloat16),
                    dense_v=jnp.zeros((cfg.num_layers, B, KV, Tf, hd), jnp.bfloat16),
                    length=jnp.zeros((cfg.num_layers,), jnp.int32))
        return M.ServeState(cache=cache,
                            length=jnp.zeros((global_batch,), jnp.int32),
                            cross=cross)

    return jax.eval_shape(mk)


def lower_decode(cfg: ModelConfig, lex_cfg: LexicoConfig, mesh: Mesh,
                 seq_len: int, global_batch: int, *,
                 policy: Optional[CachePolicy] = None,
                 seq_shard: bool = True, fsdp: bool = True):
    """AOT-lower one decode step with a KV cache of ``seq_len`` tokens."""
    policy = policy or LexicoPolicy(lex_cfg)
    t_max = seq_len + cfg.num_meta_tokens + 128
    params_shape = abstract_serve_params(cfg)
    bank_shape = abstract_bank(cfg, lex_cfg)
    state_shape = abstract_decode_state(cfg, policy, global_batch, t_max)
    tok = jax.ShapeDtypeStruct((global_batch,), jnp.int32)

    def fn(params, bank, state, token):
        return M.decode_step(params, cfg, policy, state, token, bank=bank)

    p_sh = shd.param_shardings(mesh, params_shape, moe=cfg.moe is not None,
                               fsdp=fsdp)
    b_sh = bank_shardings(mesh, bank_shape)
    st_sh = serve_state_shardings(mesh, state_shape, seq_shard=seq_shard)
    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, b_sh, st_sh,
                      shd.data_sharding(mesh, batch_size=global_batch)),
        out_shardings=(shd.data_sharding(mesh, batch_size=global_batch), st_sh),
        donate_argnums=(2,),
    )
    with _mesh_ctx(mesh):
        return jitted.lower(params_shape, bank_shape, state_shape, tok)
