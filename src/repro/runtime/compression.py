"""Gradient compression for bandwidth-constrained meshes.

int8 stochastic-free quantization with **error feedback** (Seide et al.;
Karimireddy et al.): the quantization residual of step t is added back to the
gradient at step t+1, making the compressed optimizer unbiased in the long
run. Under pjit the quantize/dequantize brackets the gradient all-reduce —
XLA then moves int8 (4x fewer bytes) over the 'data'/'pod' axes instead of
fp32. The error buffer is part of the (sharded) train state.

``int8_compress_tree`` is the stateless variant used when the caller does not
carry an error buffer (dictionary-learning loop default).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _q(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g))
    scale = amax / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_compress(g: jax.Array) -> jax.Array:
    q, scale = _q(g.astype(jnp.float32))
    return q.astype(jnp.float32) * scale


def int8_compress_tree(grads: Any) -> Any:
    return jax.tree.map(int8_compress, grads)


def int8_compress_with_feedback(grads: Any, error: Any) -> Tuple[Any, Any]:
    """Returns (compressed grads, new error buffers)."""
    def f(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _q(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [f(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_buffers(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
