"""Fault-tolerance runtime: heartbeats, straggler detection, preemption
handling, and bounded-retry step execution.

On a real multi-pod deployment these hooks attach to the cluster layer
(GKE/Borg preemption notices, per-host heartbeat agents); in this repo the
mechanisms are exercised end-to-end in-process (tests/test_fault_tolerance.py
kills and resumes a training loop) — the policy logic is the deliverable,
the transport is pluggable.

Components:
  * HeartbeatMonitor — per-host step-time tracker; flags stragglers whose
    rolling step time exceeds ``threshold`` x the fleet median (the standard
    mitigation at 1000+ nodes: alert + drain + re-shard around the slow host).
  * PreemptionGuard — installs SIGTERM/SIGINT handlers that request an
    emergency checkpoint at the next step boundary (graceful preemption).
  * run_with_retries — wraps a step function with bounded retry + checkpoint
    restore on failure (covers transient XLA/network faults).
"""
from __future__ import annotations

import signal
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional


class HeartbeatMonitor:
    def __init__(self, *, window: int = 16, threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self._times: Dict[str, deque] = defaultdict(lambda: deque(maxlen=window))

    def record(self, host: str, step_time_s: float):
        self._times[host].append(step_time_s)

    def rolling(self, host: str) -> Optional[float]:
        ts = self._times.get(host)
        return sum(ts) / len(ts) if ts else None

    def stragglers(self) -> List[str]:
        means = {h: self.rolling(h) for h in self._times if self._times[h]}
        if len(means) < 2:
            return []
        vals = sorted(means.values())
        median = vals[len(vals) // 2]
        return [h for h, m in means.items() if m > self.threshold * median]

    def missing(self, expected_hosts, *, now: Optional[float] = None,
                deadline_s: float = 60.0, last_seen: Optional[Dict[str, float]] = None):
        """Hosts that have not heartbeat within the deadline (dead-node list)."""
        last_seen = last_seen or {}
        now = now if now is not None else time.time()
        return [h for h in expected_hosts
                if now - last_seen.get(h, 0.0) > deadline_s]


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the train loop checkpoints and exits at
    the next step boundary instead of dying mid-write."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._signals = signals
        self._installed = False

    def install(self):
        if self._installed:
            return self
        for s in self._signals:
            signal.signal(s, self._handler)
        self._installed = True
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def should_stop(self) -> bool:
        return self.requested


def run_with_retries(step_fn: Callable, state, batch, *, retries: int = 2,
                     on_failure: Optional[Callable] = None):
    """Run one step with bounded retries; ``on_failure(attempt, exc)`` can
    restore state from the last checkpoint (node-failure recovery path)."""
    last = None
    for attempt in range(retries + 1):
        try:
            return step_fn(state, batch)
        except Exception as e:   # noqa: BLE001 — deliberate catch-all boundary
            last = e
            if on_failure is not None:
                state = on_failure(attempt, e) or state
    raise RuntimeError(f"step failed after {retries + 1} attempts") from last
