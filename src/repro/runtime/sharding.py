"""Logical-axis sharding rules (MaxText-style, regex over tree paths).

Parallelism mapping on the production mesh (pod, data, model):
  * ``model``  — tensor parallel (attention heads / MLP hidden / vocab) and
    expert parallel (MoE expert axis), and *sequence parallel* for the
    compressed-cache token axis during decode (beyond-paper optimization).
  * ``data``   — batch data-parallel AND FSDP-style parameter sharding (the
    second-to-last weight axis shards over ``data``; XLA SPMD inserts the
    per-layer all-gathers). Needed to fit the 123B/235B configs.
  * ``pod``    — outer data parallelism across pods (gradient reduction is
    hierarchical: reduce-scatter in-pod then all-reduce across pods, which is
    what XLA emits for a ('pod','data') batch axis).

Rules are (regex over '/'-joined tree path) -> PartitionSpec. First match
wins; default is replicate. Caches get their own rule-set (batch on
('pod','data'), compressed-token axis optionally on 'model').
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Parameter rules. Layer-stacked weights carry a leading (L,) axis => rules
# below include it as the first (None) entry when the path starts 'layers'.
# ---------------------------------------------------------------------------

def param_rules(fsdp: bool = True):
    d = "data" if fsdp else None
    return [
        # embeddings / head
        (r"^embed$",                 P("model", d)),
        (r"^lm_head$",               P(d, "model")),
        (r"^pos_embed$",             P(None, None)),
        (r"^meta$",                  P(None, None)),
        # MoE experts (L, E, d, f) / (L, E, f, d): expert-parallel + FSDP
        (r"mlp/w_(gate|up)$.*",      None),  # placeholder; resolved below by ndim
        # MLA
        (r"attn/w_q$",               P(None, d, "model")),
        (r"attn/w_dkv$",             P(None, d, None)),
        (r"attn/w_uk$",              P(None, d, "model")),
        (r"attn/w_uv$",              P(None, d, "model")),
        (r"attn/kv_norm$",           P(None, None)),
        # attention
        (r"(attn|cross)/w[qkv]$",    P(None, d, "model")),
        (r"(attn|cross)/wo$",        P(None, "model", d)),
        (r"(attn|cross)/[qk]_norm$", P(None, None)),
        # dense MLP
        (r"mlp/(w_gate|w_up)$",      P(None, d, "model")),
        (r"mlp/w_down$",             P(None, "model", d)),
        (r"mlp/shared/(w_gate|w_up)$", P(None, d, "model")),
        (r"mlp/shared/w_down$",      P(None, "model", d)),
        (r"mlp/router$",             P(None, None, None)),
        # mamba
        (r"ssm/w_in$",               P(None, d, "model")),
        (r"ssm/conv_[wb]$",          P(None, None, "model")),
        (r"ssm/x_proj$",             P(None, "model", None)),
        (r"ssm/dt_proj$",            P(None, None, "model")),
        (r"ssm/dt_bias$",            P(None, "model")),
        (r"ssm/A_log$",              P(None, "model", None)),
        (r"ssm/D$",                  P(None, "model")),
        (r"ssm/w_out$",              P(None, "model", d)),
        # rwkv
        (r"rwkv/w_[rkvg]$",          P(None, d, "model")),
        (r"rwkv/w_o$",               P(None, "model", d)),
        (r"rwkv/w_k_cm$",            P(None, d, "model")),
        (r"rwkv/w_v_cm$",            P(None, "model", d)),
        (r"rwkv/w_r_cm$",            P(None, d, "model")),
        (r"rwkv/(w_dec[12]|w_mix[12]|mu.*|w0|u|ln_x_w)$", None),  # small, replicate
    ]


_MOE_EXPERT_RE = re.compile(r"mlp/w_(gate|up|down)$")


def spec_for_param(path_str: str, ndim: int, *, moe: bool, fsdp: bool = True) -> P:
    d = "data" if fsdp else None
    if moe and _MOE_EXPERT_RE.search(path_str) and ndim == 4:
        # (L, E, d_model, f) or (L, E, f, d_model): EP on E, FSDP on dim 2
        return P(None, "model", d, None)
    for pat, spec in param_rules(fsdp):
        if spec is None:
            continue
        if re.search(pat, path_str):
            # trim/extend spec to ndim (layer-stacked tensors already include
            # the leading None; non-stacked (embed) match exactly)
            entries = list(spec)
            if len(entries) < ndim:
                entries = [None] * (ndim - len(entries)) + entries
            if len(entries) > ndim:
                entries = entries[len(entries) - ndim:]
            return P(*entries)
    return P()  # replicate


def param_shardings(mesh: Mesh, params: Any, *, moe: bool, fsdp: bool = True) -> Any:
    def f(path, leaf):
        ps = _path_str(path)
        spec = spec_for_param(ps, leaf.ndim, moe=moe, fsdp=fsdp)
        # drop axes that don't divide
        entries = []
        for dim, ax in zip(leaf.shape, list(spec) + [None] * (leaf.ndim - len(spec))):
            if ax is None:
                entries.append(None)
            else:
                size = mesh.shape[ax] if isinstance(ax, str) else 1
                entries.append(ax if dim % size == 0 and dim >= size else None)
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(f, params)


# ---------------------------------------------------------------------------
# Cache / activation shardings
# ---------------------------------------------------------------------------

def cache_shardings(mesh: Mesh, cache: Any, *, batch_axes=("pod", "data"),
                    seq_axis: Optional[str] = "model") -> Any:
    """Serve-cache shardings. All cache tensors are (L, B, ...); batch on
    ('pod','data'). Per-slot bookkeeping counters are (L, B) and follow the
    batch sharding. Compressed-token axes (T_max slot) go on ``seq_axis``
    (sequence-parallel decode) when set — the paper-faithful baseline uses
    ``seq_axis=None`` (cache replicated over 'model', single-host semantics).
    """
    batch = tuple(a for a in batch_axes if a in mesh.shape)
    batch = batch if len(batch) > 1 else (batch[0] if batch else None)

    def f(path, leaf):
        ps = _path_str(path)
        if leaf.ndim <= 1:
            # scalars / per-layer (L,) bookkeeping: replicate
            return NamedSharding(mesh, P())
        entries = [None] * leaf.ndim
        entries[1] = batch  # (L, B, ...) batch axis
        # token axis of the big compressed stores: k_vals/k_idx/v_vals/v_idx
        # (L, B, KV, T, s) at dim 3; mla vals/idx (L, B, T, s) at dim 2
        if seq_axis is not None and re.search(r"(k_|v_)?(vals|idx|q|scale|zero)$", ps):
            tdim = leaf.ndim - 2
            if tdim >= 2 and leaf.shape[tdim] % mesh.shape[seq_axis] == 0:
                entries[tdim] = seq_axis
        if re.search(r"(dense_k|dense_v)$", ps) and seq_axis is not None:
            tdim = leaf.ndim - 2
            if leaf.shape[tdim] % mesh.shape[seq_axis] == 0:
                entries[tdim] = seq_axis
        # validate divisibility on batch axis
        bdim = 1
        ax = entries[bdim]
        if ax is not None:
            size = (mesh.shape[ax] if isinstance(ax, str)
                    else int(jax.numpy.prod(jax.numpy.array([mesh.shape[a] for a in ax]))))
            if leaf.shape[bdim] % size != 0:
                entries[bdim] = None
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(f, cache)


def data_sharding(mesh: Mesh, *, batch_axes=("pod", "data"),
                  batch_size: Optional[int] = None) -> NamedSharding:
    """Batch sharding over ('pod','data'); axes that don't divide the batch
    are dropped greedily (long_500k has batch=1 — fully replicated)."""
    batch = [a for a in batch_axes if a in mesh.shape]
    if batch_size is not None:
        while batch:
            size = 1
            for a in batch:
                size *= mesh.shape[a]
            if batch_size % size == 0:
                break
            batch.pop()
    if not batch:
        return NamedSharding(mesh, P())
    return NamedSharding(mesh, P(tuple(batch) if len(batch) > 1 else batch[0]))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
