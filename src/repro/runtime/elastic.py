"""Elastic scaling: re-mesh and re-shard after topology changes.

When nodes die (or capacity is added) the job restarts with a different
device count. The flow:

  1. `plan_mesh(n_devices)` picks the largest supported (data, model) grid —
     model-parallel width is kept if possible (weights reshard cheaply along
     data), else the nearest divisor is chosen.
  2. `reshard(tree, shardings)` device_puts every leaf against the new
     shardings (built on the new mesh) — combined with
     checkpoint.restore_pytree this is restore-to-any-mesh (checkpoints store
     global logical arrays).
  3. The data pipeline keys batches by step + process index, so the resumed
     run replays the exact token stream regardless of the new process grid.

Exercised in tests/test_fault_tolerance.py::test_save_restore_across_meshes
(save on one mesh, restore on another, bit-identical logical state).
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax

from repro.launch.mesh import make_mesh


def plan_mesh(n_devices: int, *, prefer_model: int = 16,
              with_pod: bool = False) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (data, model) grid for n_devices, keeping model width if it
    divides; otherwise fall back to the largest power-of-two divisor."""
    model = prefer_model
    while model > 1 and n_devices % model != 0:
        model //= 2
    data = n_devices // model
    if with_pod and data % 2 == 0:
        return (2, data // 2, model), ("pod", "data", "model")
    return (data, model), ("data", "model")


def remesh(n_devices: Optional[int] = None, *, prefer_model: int = 16):
    n = n_devices if n_devices is not None else len(jax.devices())
    shape, axes = plan_mesh(n, prefer_model=prefer_model)
    return make_mesh(shape, axes)


def reshard(tree: Any, shardings: Any) -> Any:
    """Move every leaf to the new shardings (cross-mesh resharding)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda x: x is None)
