"""Lexico core: sparse-coded KV cache compression over universal dictionaries."""
from repro.core.omp import OMPResult, omp_batch, omp_multi_dict, omp_single, reconstruct
from repro.core.dictionary import (
    DictionaryBank, init_bank, init_dictionary, normalize_atoms, project_gradient,
)
from repro.core.dict_learning import (
    DictTrainState, dict_train_init, dict_train_step, relative_error,
)
from repro.core.sparse_cache import (
    LexicoLayerCache, attend, decode_update, init_layer_cache, kv_size_percent,
    paper_kv_bytes, prefill_compress,
)
from repro.core.attention import compressed_scores, compressed_values, decode_attention
from repro.core.adaptive import AdaptiveDict, adaptive_encode, init_adaptive
from repro.core import quant
