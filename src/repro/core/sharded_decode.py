"""Sequence-parallel decode via shard_map (beyond-paper, EXPERIMENTS.md §Perf).

Plain pjit with a token-sharded compressed cache fails on the *write*: a
dynamic-update-slice at (traced) position t_c on a sharded dim makes the SPMD
partitioner all-gather the whole cache every step (measured: 79 GB/step on
mistral-large decode_32k — worse than the replicated baseline's 55 GB).

This module does the update + attention inside one shard_map so the cache
stays shard-local end to end:

  * each 'model' shard owns a contiguous T/|model| slice of the sparse store;
  * the evicted buffer token is OMP-encoded (gram-free — trades abundant
    decode FLOPs for not carrying the N x N Gram) redundantly on every shard
    (it's n_a=1 token), and only the owner shard applies the local-index DUS;
  * attention runs flash-style per shard: local logits -> (m, l, coeff) stats
    -> pmax/psum combine over 'model' -> the replicated recency buffer is
    folded in as the final block. Per-step collectives drop to the O(B·KV·G·N)
    stat psums — no cache-sized transfers at all.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import LexicoConfig
from repro.core import omp as omp_mod
from repro.core.attention import NEG_INF, compressed_scores, scatter_coeffs
from repro.core.sparse_cache import LexicoLayerCache

Array = jax.Array


def _decode_attend_local(cache: LexicoLayerCache, q, k_t, v_t, D_k, D_v,
                         *, s: int, N: int, delta: float,
                         window, model_axis: str = "model",
                         active=None, s_cap=None):
    """shard_map body. cache.{k,v}_{vals,idx} are LOCAL (B,KV,T_loc,s) slices;
    buffers + per-row (B,) counters replicated. Returns (attn_out, new local
    cache)."""
    B, KV, T_loc, _ = cache.k_vals.shape
    n_b = cache.n_b
    b_idx = jnp.arange(B)
    act = (jnp.ones((B,), jnp.bool_) if active is None
           else jnp.asarray(active, jnp.bool_))
    ax = jax.lax.axis_index(model_axis)
    t_off = ax * T_loc
    full = cache.buf_len >= n_b
    evict = full & act

    # --- compress the evictee (replicated tiny work), write on owner only ---
    old_k = cache.k_buf[b_idx, :, cache.buf_start]
    old_v = cache.v_buf[b_idx, :, cache.buf_start]
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)[:, None]
    rk = omp_mod.omp_batch(old_k.astype(jnp.float32), D_k, s, use_gram=False,
                           delta=delta, s_cap=cap)
    rv = omp_mod.omp_batch(old_v.astype(jnp.float32), D_v, s, use_gram=False,
                           delta=delta, s_cap=cap)
    owner = (cache.t_c >= t_off) & (cache.t_c < t_off + T_loc)   # (B,)
    local_pos = jnp.clip(cache.t_c - t_off, 0, T_loc - 1)        # (B,)

    def store(arr, new, dtype):
        cur = arr[b_idx, :, local_pos]                           # (B, KV, s)
        payload = jnp.where((evict & owner)[:, None, None],
                            new.astype(dtype), cur)
        return arr.at[b_idx, :, local_pos].set(payload.astype(arr.dtype))

    k_vals = store(cache.k_vals, rk.vals, cache.k_vals.dtype)
    k_idx = store(cache.k_idx, rk.idx, jnp.int16)
    v_vals = store(cache.v_vals, rv.vals, cache.v_vals.dtype)
    v_idx = store(cache.v_idx, rv.idx, jnp.int16)
    t_c = jnp.where(evict, cache.t_c + 1, cache.t_c)

    # --- ring-write the new token (replicated buffers) ---
    write_pos = jnp.where(full, cache.buf_start, cache.buf_len)

    def ring(buf, x_t):
        cur = buf[b_idx, :, write_pos]
        payload = jnp.where(act[:, None, None], x_t.astype(buf.dtype), cur)
        return buf.at[b_idx, :, write_pos].set(payload)

    k_buf = ring(cache.k_buf, k_t)
    v_buf = ring(cache.v_buf, v_t)
    new_cache = cache._replace(
        k_vals=k_vals, k_idx=k_idx, v_vals=v_vals, v_idx=v_idx,
        k_buf=k_buf, v_buf=v_buf, t_c=t_c,
        buf_len=jnp.where(act & ~full, cache.buf_len + 1, cache.buf_len),
        buf_start=jnp.where(evict, (cache.buf_start + 1) % n_b, cache.buf_start))

    # --- flash attention over the local slice ---
    m_dim = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(m_dim))
    qf = q.astype(jnp.float32)
    qd = jnp.einsum("bkgm,mn->bkgn", qf, D_k.astype(jnp.float32))
    s_loc = compressed_scores(qd, k_vals, k_idx, scale=scale)   # (B,KV,G,T_loc)
    pos = t_off + jnp.arange(T_loc)
    from repro.core.attention import per_batch
    t_cb = per_batch(t_c)
    length = t_cb + per_batch(new_cache.buf_len)
    min_pos = (length - window) if window is not None else jnp.int32(-1)
    valid = (pos[None, None, None, :] < t_cb) & (pos[None, None, None, :] >= min_pos)
    s_loc = jnp.where(valid, s_loc, NEG_INF)
    m_loc = jnp.max(s_loc, axis=-1)
    p_loc = jnp.where(valid, jnp.exp(s_loc - m_loc[..., None]), 0.0)
    l_loc = jnp.sum(p_loc, axis=-1)
    c_loc = scatter_coeffs(p_loc, v_vals, v_idx, D_k.shape[1])  # (B,KV,G,N)

    # combine across shards (the only per-step collectives)
    m_g = jax.lax.pmax(m_loc, model_axis)
    corr = jnp.exp(m_loc - m_g)
    l_g = jax.lax.psum(l_loc * corr, model_axis)
    c_g = jax.lax.psum(c_loc * corr[..., None], model_axis)

    # replicated buffer as the final block
    s_b = jnp.einsum("bkgm,bkrm->bkgr", qf, k_buf.astype(jnp.float32)) * scale
    s_b = jnp.where(jnp.arange(n_b)[None, None, None, :] < per_batch(new_cache.buf_len),
                    s_b, NEG_INF)
    m_f = jnp.maximum(m_g, jnp.max(s_b, axis=-1))
    alpha = jnp.exp(m_g - m_f)
    p_b = jnp.exp(s_b - m_f[..., None])
    l_f = l_g * alpha + jnp.sum(p_b, axis=-1)
    out = jnp.einsum("bkgn,mn->bkgm", c_g * alpha[..., None],
                     D_v.astype(jnp.float32))
    out = out + jnp.einsum("bkgr,bkrm->bkgm", p_b, v_buf.astype(jnp.float32))
    return out / l_f[..., None], new_cache


class SeqShardLexicoPolicy:
    """LexicoPolicy variant whose decode+attend run fused inside shard_map
    with a token-sharded cache. Falls back to unsharded math off-mesh."""

    def __init__(self, cfg: LexicoConfig):
        self.cfg = cfg

    # prefill/init identical to LexicoPolicy
    def init(self, batch, kv_heads, head_dim, t_max):
        from repro.models.cache_policy import LexicoPolicy
        return LexicoPolicy(self.cfg).init(batch, kv_heads, head_dim, t_max)

    def prefill(self, cache, K, V, ctx):
        from repro.models.cache_policy import LexicoPolicy
        return LexicoPolicy(self.cfg).prefill(cache, K, V, ctx)

    def length(self, cache):
        return cache.t_c + cache.buf_len

    def decode_attend(self, cache: LexicoLayerCache, q, k_t, v_t, ctx, *,
                      window=None, active=None,
                      s_cap=None) -> Tuple[Array, LexicoLayerCache]:
        from repro.core.sparse_cache import PagedLexicoLayerCache
        if isinstance(cache, PagedLexicoLayerCache):
            # the shard_map body owns a contiguous T/|model| stripe per shard;
            # a shared page pool has no such stripe to own. Paged serving
            # shards by replica (one pool per data-parallel replica), not by
            # token — see ROADMAP "multi-host request routing".
            raise NotImplementedError(
                "SeqShardLexicoPolicy requires the contiguous cache layout; "
                "paged pools shard per-replica, not per-token")
        D_k, D_v = ctx[0], ctx[1]
        from repro.models.model import _abstract_mesh
        am = _abstract_mesh()
        if (am is None or "model" not in am.axis_names
                or cache.k_vals.shape[2] % am.shape["model"] != 0):
            # off-mesh fallback: single-shard semantics
            from repro.core import sparse_cache as sc
            new_cache = sc.decode_update(cache, k_t, v_t, D_k, D_v, s=self.cfg.s,
                                         use_gram=False, delta=self.cfg.delta,
                                         active=active, s_cap=s_cap)
            out = sc.attend(new_cache, q, D_k, D_v, N=self.cfg.N,
                            chunk=self.cfg.chunk, window=window)
            return out, new_cache

        B = q.shape[0]
        act = (jnp.ones((B,), jnp.bool_) if active is None
               else jnp.asarray(active, jnp.bool_))
        cap = (jnp.full((B,), self.cfg.s, jnp.int32) if s_cap is None
               else jnp.asarray(s_cap, jnp.int32))
        body = lambda c, qq, kk, vv, dk, dv, aa, cc: _decode_attend_local(
            c, qq, kk, vv, dk, dv, s=self.cfg.s, N=self.cfg.N,
            delta=self.cfg.delta, window=window, active=aa, s_cap=cc)
        batch_axes = tuple(a for a in ("pod", "data") if a in am.axis_names)
        bspec = (batch_axes if len(batch_axes) > 1 else batch_axes[0]) \
            if batch_axes and q.shape[0] % math.prod(
                am.shape[a] for a in batch_axes) == 0 else None
        ctr = P(bspec)   # per-row (B,) bookkeeping follows the batch sharding
        cache_specs = LexicoLayerCache(
            k_vals=P(bspec, None, "model", None), k_idx=P(bspec, None, "model", None),
            v_vals=P(bspec, None, "model", None), v_idx=P(bspec, None, "model", None),
            k_buf=P(bspec, None, None, None), v_buf=P(bspec, None, None, None),
            t_c=ctr, buf_len=ctr, buf_start=ctr)
        vec = P(bspec, None, None)
        out, new_cache = shard_map(
            body, mesh=am,
            in_specs=(cache_specs, P(bspec, None, None, None), vec, vec, P(), P(),
                      ctr, ctr),
            out_specs=(P(bspec, None, None, None), cache_specs),
            check_rep=False,
        )(cache, q, k_t, v_t, D_k, D_v, act, cap)
        return out, new_cache
