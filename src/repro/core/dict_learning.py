"""Dictionary learning (paper §3.3, Figure 4).

Alternating scheme: OMP (fixed D) produces the sparse codes y, then one
gradient step on D for the loss ``||k - D y||^2`` with the codes held fixed
(stop-gradient through OMP — exactly the paper's procedure). Gradients are
projected to the tangent space of the unit sphere per atom, updated with Adam
+ cosine decay, and atoms are renormalised.

The loop is data-parallel: KV batches are sharded over the ``data`` mesh axis
and the gradient is mean-reduced (pjit inserts the all-reduce). An optional
int8 error-feedback gradient compressor (runtime.compression) can wrap the
reduction for bandwidth-constrained meshes.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import omp as omp_mod
from repro.core.dictionary import normalize_atoms, project_gradient
from repro.optim.adam import AdamState, adam_init, adam_update

Array = jax.Array


class DictTrainState(NamedTuple):
    D: Array            # (..., m, N) — arbitrary leading dict axes (L, 2)
    opt: AdamState
    step: Array         # scalar int32


def dict_train_init(D: Array) -> DictTrainState:
    return DictTrainState(D=D, opt=adam_init(D), step=jnp.int32(0))


def reconstruction_loss(D: Array, vals: Array, idx: Array, K: Array) -> Array:
    """Mean squared reconstruction error given fixed codes (vals, idx).

    Works for a single dictionary (D (m,N), idx (B,s)) and for stacked banks
    (D (..,m,N), idx (..,B,s)) — the gather must pair each leading dict axis
    with its own index slice (take_along_axis, not take)."""
    Dx = D[..., None, :, :]                              # (.., 1, m, N)
    ix = idx[..., :, None, :].astype(jnp.int32)          # (.., B, 1, s)
    ix = jnp.broadcast_to(ix, ix.shape[:-3] + (ix.shape[-3], D.shape[-2], ix.shape[-1]))
    atoms = jnp.take_along_axis(Dx, ix, axis=-1)         # (.., B, m, s)
    rec = jnp.einsum("...bs,...bms->...bm", vals, atoms)
    return jnp.mean(jnp.sum((K - rec) ** 2, axis=-1))


@functools.partial(jax.jit, static_argnames=("s", "use_gram", "lr_schedule_len"))
def dict_train_step(
    state: DictTrainState,
    K: Array,
    *,
    s: int,
    base_lr: float = 1e-4,
    lr_schedule_len: int = 10_000,
    use_gram: bool = True,
) -> Tuple[DictTrainState, dict]:
    """One dictionary-learning step.

    K: (..., B, m) KV vectors with leading axes matching state.D's dict axes
       (e.g. (L, 2, B, m) for a full bank) — or (B, m) for a single dict.
    """
    D = state.D.astype(jnp.float32)
    Kf = K.astype(jnp.float32)

    # --- encode with fixed D (no gradient through OMP) ---
    if D.ndim == 2:
        res = omp_mod.omp_batch(Kf, D, s, use_gram=use_gram)
    else:
        dict_shape = D.shape[:-2]
        Df = D.reshape((-1,) + D.shape[-2:])
        Kfl = Kf.reshape((Df.shape[0], -1, Kf.shape[-1]))
        res = omp_mod.omp_multi_dict(Kfl, Df, s, use_gram=use_gram)
        res = omp_mod.OMPResult(
            vals=res.vals.reshape(dict_shape + (-1, s)),
            idx=res.idx.reshape(dict_shape + (-1, s)),
            nnz=res.nnz.reshape(dict_shape + (-1,)),
            resid2=res.resid2.reshape(dict_shape + (-1,)),
        )
    vals = jax.lax.stop_gradient(res.vals)
    idx = jax.lax.stop_gradient(res.idx)

    # --- gradient step on D with codes fixed ---
    loss, grad = jax.value_and_grad(reconstruction_loss)(D, vals, idx, Kf)
    grad = project_gradient(D, grad)

    # cosine decay
    frac = jnp.minimum(state.step.astype(jnp.float32) / lr_schedule_len, 1.0)
    lr = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))

    new_D, new_opt = adam_update(D, grad, state.opt, lr=lr)
    new_D = normalize_atoms(new_D)

    rel_err = jnp.sqrt(res.resid2) / (jnp.linalg.norm(Kf, axis=-1) + 1e-12)
    metrics = {
        "loss": loss,
        "rel_err_mean": jnp.mean(rel_err),
        "rel_err_std": jnp.std(rel_err),
        "lr": lr,
        "mean_nnz": jnp.mean(res.nnz.astype(jnp.float32)),
    }
    return DictTrainState(D=new_D.astype(state.D.dtype), opt=new_opt, step=state.step + 1), metrics


def relative_error(D: Array, K: Array, s: int, *, use_gram: bool = True, delta: float = 0.0) -> Array:
    """Per-vector relative reconstruction error (Table 1 metric).

    Delegates the ``sqrt(resid2)/||k||`` normalisation to
    ``omp.relative_residual`` — the same helper the serving-time quality
    telemetry uses, so offline Table-1 numbers and live telemetry agree
    exactly on the same dictionary/inputs.
    """
    res = omp_mod.omp_batch(K.astype(jnp.float32), D.astype(jnp.float32), s,
                            use_gram=use_gram, delta=delta)
    return omp_mod.relative_residual(res.resid2, K)
