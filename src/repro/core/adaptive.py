"""Adaptive dictionary growth (paper §4.2.4).

Start from the universal dictionary occupying the first ``n_base`` columns of a
fixed-capacity array D (m, N_total); the tail columns are empty slots. When a
vector's OMP approximation misses the relative-error threshold δ, the vector
itself (normalised) is appended as a new atom and its code is the 1-sparse
(new-slot-index, ℓ2-norm) pair. Growth is sequential over the batch (the atom
added for vector i is visible to vector i+1) — implemented as a lax.scan so
the whole thing stays jittable with static shapes.

Grown atoms are input-specific, so their storage counts toward the KV-size
budget (the paper's accounting) — ``adaptive_extra_bytes`` reports it.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import omp as omp_mod

Array = jax.Array


class AdaptiveDict(NamedTuple):
    D: Array        # (m, N_total); columns >= n_used are zero
    n_base: Array   # scalar int32 — universal atoms
    n_used: Array   # scalar int32 — total atoms in use


def init_adaptive(D_universal: Array, capacity: int) -> AdaptiveDict:
    m, n_base = D_universal.shape
    D = jnp.zeros((m, capacity), jnp.float32).at[:, :n_base].set(
        D_universal.astype(jnp.float32))
    return AdaptiveDict(D=D, n_base=jnp.int32(n_base), n_used=jnp.int32(n_base))


def adaptive_encode(
    ad: AdaptiveDict, K: Array, *, s: int, delta: float,
) -> Tuple[AdaptiveDict, omp_mod.OMPResult]:
    """Encode a batch K (B, m); grow the dictionary on threshold misses."""
    capacity = ad.D.shape[1]

    def step(carry, k):
        D, n_used = carry
        res = omp_mod.omp_single(k.astype(jnp.float32), D, s, delta=delta)
        norm = jnp.linalg.norm(k)
        fail = jnp.logical_and(jnp.sqrt(res.resid2) > delta * norm,
                               n_used < capacity)
        atom = (k / (norm + 1e-12)).astype(jnp.float32)
        D_new = jnp.where(fail, D.at[:, n_used].set(atom), D)
        vals = jnp.where(fail, jnp.zeros_like(res.vals).at[0].set(norm), res.vals)
        idx = jnp.where(fail, jnp.zeros_like(res.idx).at[0].set(n_used), res.idx)
        nnz = jnp.where(fail, 1, res.nnz)
        r2 = jnp.where(fail, 0.0, res.resid2)
        return (D_new, n_used + fail.astype(jnp.int32)), omp_mod.OMPResult(vals, idx, nnz, r2)

    (D_fin, n_fin), res = jax.lax.scan(step, (ad.D, ad.n_used), K)
    return ad._replace(D=D_fin, n_used=n_fin), res


def adaptive_extra_bytes(ad: AdaptiveDict, dtype_bytes: int = 2) -> Array:
    """Bytes of grown (non-universal) atoms — charged to the KV budget."""
    return (ad.n_used - ad.n_base) * ad.D.shape[0] * dtype_bytes
