"""Coefficient codecs for the sparse codes (paper step 3: 8-bit values).

The paper stores CSR values in FP8 (E4M3) and indices as int16, for a payload
of ``3s + 2`` bytes per vector. JAX has native ``float8_e4m3fn`` — we use it
directly as the storage dtype. An int8 + per-vector-scale codec is provided as
an alternative (useful on hardware without fp8 gathers).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class QuantizedCode(NamedTuple):
    vals: Array  # storage dtype (f8e4m3 / int8 / bf16 / fp32)
    idx: Array   # int16 (N <= 65536) or int32
    scale: Array  # per-vector scale (only used by int8 codec; 1.0 otherwise)


def encode_fp8(vals: Array, idx: Array) -> QuantizedCode:
    return QuantizedCode(
        vals=vals.astype(jnp.float8_e4m3fn),
        idx=idx.astype(jnp.int16),
        scale=jnp.ones(vals.shape[:-1], jnp.float32),
    )


def encode_int8(vals: Array, idx: Array, eps: float = 1e-12) -> QuantizedCode:
    amax = jnp.max(jnp.abs(vals), axis=-1, keepdims=True)
    scale = (amax / 127.0 + eps).astype(jnp.float32)
    q = jnp.clip(jnp.round(vals / scale), -127, 127).astype(jnp.int8)
    return QuantizedCode(vals=q, idx=idx.astype(jnp.int16), scale=scale[..., 0])


def encode_fp16(vals: Array, idx: Array) -> QuantizedCode:
    return QuantizedCode(
        vals=vals.astype(jnp.bfloat16),
        idx=idx.astype(jnp.int16),
        scale=jnp.ones(vals.shape[:-1], jnp.float32),
    )


_ENCODERS = {"fp8": encode_fp8, "int8": encode_int8, "fp16": encode_fp16}
VAL_BYTES = {"fp8": 1, "int8": 1, "fp16": 2}


def encode(vals: Array, idx: Array, codec: str = "fp8") -> QuantizedCode:
    return _ENCODERS[codec](vals, idx)


def decode_vals(code: QuantizedCode) -> Array:
    v = code.vals.astype(jnp.float32)
    if code.vals.dtype == jnp.int8:
        v = v * code.scale[..., None]
    return v


def payload_bytes(s: int, codec: str = "fp8") -> int:
    """Per-vector payload: s values + s int16 indices + 2-byte offset
    (paper's ``3s + 2`` for the fp8 codec)."""
    return VAL_BYTES[codec] * s + 2 * s + 2


def kv_size_fraction(s: int, m: int, codec: str = "fp8", fp_bytes: int = 2) -> float:
    """Fraction of the full-precision per-vector footprint (paper: 1.17s% at m=128)."""
    return payload_bytes(s, codec) / (fp_bytes * m)
