"""LexicoCache: the compressed KV cache pytree + update logic (Algorithm 2).

TPU adaptation of the paper's CSR layout: a *padded fixed-s dense* layout —
``vals (B, KV, T_max, s)`` in a storage dtype (fp8-e4m3 by default),
``idx (B, KV, T_max, s)`` int16, plus per-token ``nnz`` for δ-terminated rows.
Static shapes keep the whole serving step jittable/pjit-able; the recency
buffer is a ring so the eviction path is one dynamic-slice per step.

All fields carry a leading layer axis when stacked into a model cache
(``jax.lax.scan`` over layers consumes/produces one layer's slice).

Memory accounting: ``paper_bytes_per_vector = 3s+2`` (fp8 codec) — the number
we report KV-size %, matching the paper; ``array_bytes`` reports the actual
padded-layout footprint.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import omp as omp_mod
from repro.core import quant
from repro.core.attention import decode_attention

Array = jax.Array


class LexicoLayerCache(NamedTuple):
    """Cache for one attention layer (or one (L,...) stack of layers)."""

    k_vals: Array   # (B, KV, T_max, s) storage dtype
    k_idx: Array    # (B, KV, T_max, s) int16
    v_vals: Array
    v_idx: Array
    k_buf: Array    # (B, KV, n_b, m) bf16 ring buffer
    v_buf: Array
    t_c: Array      # (B,) int32 — valid compressed tokens per batch element
    buf_len: Array  # (B,) int32 — valid buffer entries per batch element
    buf_start: Array  # (B,) int32 — ring head (oldest entry) per batch element

    @property
    def T_max(self) -> int:
        return self.k_vals.shape[-2]

    @property
    def n_b(self) -> int:
        return self.k_buf.shape[-2]

    @property
    def s(self) -> int:
        return self.k_vals.shape[-1]


def init_layer_cache(
    batch: int, kv_heads: int, head_dim: int, *,
    t_max: int, n_b: int, s: int,
    val_dtype=jnp.float8_e4m3fn, buf_dtype=jnp.bfloat16,
) -> LexicoLayerCache:
    zv = jnp.zeros((batch, kv_heads, t_max, s), val_dtype)
    zi = jnp.zeros((batch, kv_heads, t_max, s), jnp.int16)
    zb = jnp.zeros((batch, kv_heads, n_b, head_dim), buf_dtype)
    zc = jnp.zeros((batch,), jnp.int32)
    return LexicoLayerCache(
        k_vals=zv, k_idx=zi, v_vals=zv, v_idx=zi,
        k_buf=zb, v_buf=zb,
        t_c=zc, buf_len=zc, buf_start=zc,
    )


def _encode_store(vals: Array, idx: Array, val_dtype) -> Tuple[Array, Array]:
    if val_dtype == jnp.int8:
        code = quant.encode_int8(vals, idx)
        # int8 codec folds the scale into the values for storage-free decode:
        # we instead store fp8 by default; int8-with-scale is exercised in
        # benchmarks via quant.encode directly.
        return code.vals, code.idx
    return vals.astype(val_dtype), idx.astype(jnp.int16)


def prefill_compress(
    cache: LexicoLayerCache,
    K: Array, V: Array,          # (B, KV, T, m) full-precision K/V of the prompt
    D_k: Array, D_v: Array,      # (m, N)
    *,
    s: int,
    use_gram: bool = True,
    delta: float = 0.0,
    G_k=None, G_v=None,
    s_cap: Optional[Array] = None,
) -> LexicoLayerCache:
    """Compress a prefilled prompt into the cache (Algorithm 2, Prefilling).

    The last n_b tokens go to the buffer; the first T-n_b are OMP-compressed.
    Assumes T >= n_b and T - n_b <= T_max.
    ``s_cap`` (B,) optionally caps the per-request sparsity tier below ``s``.
    """
    B, KV, T, m = K.shape
    n_b = cache.n_b
    n_comp = T - n_b
    k_head, k_tail = K[:, :, :n_comp], K[:, :, n_comp:]
    v_head, v_tail = V[:, :, :n_comp], V[:, :, n_comp:]
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)[:, None, None]

    rk = omp_mod.omp_batch(k_head.astype(jnp.float32), D_k, s, use_gram=use_gram,
                           delta=delta, G=G_k, s_cap=cap)
    rv = omp_mod.omp_batch(v_head.astype(jnp.float32), D_v, s, use_gram=use_gram,
                           delta=delta, G=G_v, s_cap=cap)
    kv, ki = _encode_store(rk.vals, rk.idx, cache.k_vals.dtype)
    vv, vi = _encode_store(rv.vals, rv.idx, cache.v_vals.dtype)

    def put(store, new):
        return jax.lax.dynamic_update_slice(store, new, (0, 0, 0, 0))

    fill = lambda v: jnp.full((B,), v, jnp.int32)
    return cache._replace(
        k_vals=put(cache.k_vals, kv), k_idx=put(cache.k_idx, ki),
        v_vals=put(cache.v_vals, vv), v_idx=put(cache.v_idx, vi),
        k_buf=k_tail.astype(cache.k_buf.dtype),
        v_buf=v_tail.astype(cache.v_buf.dtype),
        t_c=fill(n_comp), buf_len=fill(n_b), buf_start=fill(0),
    )


def decode_update(
    cache: LexicoLayerCache,
    k_t: Array, v_t: Array,      # (B, KV, m) new token K/V (RoPE already applied)
    D_k: Array, D_v: Array,
    *,
    s: int,
    use_gram: bool = True,
    delta: float = 0.0,
    G_k=None, G_v=None,
    active: Optional[Array] = None,
    s_cap: Optional[Array] = None,
) -> LexicoLayerCache:
    """Insert the new token; if the buffer is full, OMP-compress the oldest
    entry into the sparse store first (Algorithm 2, Decoding, n_a = 1).

    Bookkeeping is per batch element: every row has its own ``t_c``,
    ``buf_len`` and ring head, so heterogeneous-length requests advance
    independently inside one jitted step.
    ``active`` (B,) bool: rows set False are left untouched (idle slots of the
    continuous-batching pool). ``s_cap`` (B,) caps the per-row sparsity tier.
    """
    B, KV, m = k_t.shape
    n_b = cache.n_b
    b_idx = jnp.arange(B)
    act = (jnp.ones((B,), jnp.bool_) if active is None
           else jnp.asarray(active, jnp.bool_))
    full = cache.buf_len >= n_b

    # --- compress the oldest buffer slot if evicting ---
    old_k = cache.k_buf[b_idx, :, cache.buf_start]          # (B, KV, m)
    old_v = cache.v_buf[b_idx, :, cache.buf_start]
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)[:, None]
    rk = omp_mod.omp_batch(old_k.astype(jnp.float32), D_k, s, use_gram=use_gram,
                           delta=delta, G=G_k, s_cap=cap)
    rv = omp_mod.omp_batch(old_v.astype(jnp.float32), D_v, s, use_gram=use_gram,
                           delta=delta, G=G_v, s_cap=cap)
    kv, ki = _encode_store(rk.vals, rk.idx, cache.k_vals.dtype)
    vv, vi = _encode_store(rv.vals, rv.idx, cache.v_vals.dtype)

    # per-row write positions; rows that aren't evicting (or are idle) get
    # their current contents written back (read-select-write, no full select)
    t_w = jnp.clip(cache.t_c, 0, cache.T_max - 1)
    evict = full & act

    def maybe_store(store, new):
        cur = store[b_idx, :, t_w]                          # (B, KV, s)
        payload = jnp.where(evict[:, None, None], new.astype(store.dtype), cur)
        return store.at[b_idx, :, t_w].set(payload)

    k_vals = maybe_store(cache.k_vals, kv)
    k_idx = maybe_store(cache.k_idx, ki)
    v_vals = maybe_store(cache.v_vals, vv)
    v_idx = maybe_store(cache.v_idx, vi)
    t_c = jnp.where(evict, cache.t_c + 1, cache.t_c)

    # --- write the new token into the ring ---
    write_pos = jnp.where(full, cache.buf_start, cache.buf_len)

    def ring_write(buf, x_t):
        cur = buf[b_idx, :, write_pos]                      # (B, KV, m)
        payload = jnp.where(act[:, None, None], x_t.astype(buf.dtype), cur)
        return buf.at[b_idx, :, write_pos].set(payload)

    k_buf = ring_write(cache.k_buf, k_t)
    v_buf = ring_write(cache.v_buf, v_t)
    buf_start = jnp.where(evict, (cache.buf_start + 1) % n_b, cache.buf_start)
    buf_len = jnp.where(act & ~full, cache.buf_len + 1, cache.buf_len)

    return cache._replace(
        k_vals=k_vals, k_idx=k_idx, v_vals=v_vals, v_idx=v_idx,
        k_buf=k_buf, v_buf=v_buf, t_c=t_c, buf_len=buf_len, buf_start=buf_start)


def attend(
    cache: LexicoLayerCache,
    q: Array,                    # (B, KV, G, m)
    D_k: Array, D_v: Array,
    *,
    N: int,
    chunk: Optional[int] = None,
    window=None,
) -> Array:
    """Eq. 7 attention over the cache (buffer already contains the new token)."""
    return decode_attention(
        q,
        cache.k_vals, cache.k_idx, cache.v_vals, cache.v_idx,
        cache.k_buf, cache.v_buf, D_k, D_v,
        t_c=cache.t_c, buf_len=cache.buf_len, N=N, chunk=chunk, window=window)


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------

def paper_kv_bytes(t_c: int, n_b: int, s: int, m: int, *, codec: str = "fp8",
                   fp_bytes: int = 2) -> int:
    """Paper accounting: compressed tokens at 3s+2 B/vector + buffer at full
    precision. Per (head, K+V) pair of vectors."""
    return 2 * (t_c * quant.payload_bytes(s, codec) + n_b * m * fp_bytes)


def kv_size_percent(t_c: int, n_b: int, s: int, m: int, **kw) -> float:
    total = t_c + n_b
    full = 2 * total * m * kw.get("fp_bytes", 2)
    return 100.0 * paper_kv_bytes(t_c, n_b, s, m, **kw) / full


def array_bytes(cache: LexicoLayerCache) -> int:
    return sum(x.size * x.dtype.itemsize for x in
               [cache.k_vals, cache.k_idx, cache.v_vals, cache.v_idx,
                cache.k_buf, cache.v_buf])
