"""LexicoCache: the compressed KV cache pytree + update logic (Algorithm 2).

TPU adaptation of the paper's CSR layout: a *padded fixed-s dense* layout —
``vals (B, KV, T_max, s)`` in a storage dtype (fp8-e4m3 by default),
``idx (B, KV, T_max, s)`` int16, plus per-token ``nnz`` for δ-terminated rows.
Static shapes keep the whole serving step jittable/pjit-able; the recency
buffer is a ring so the eviction path is one dynamic-slice per step.

Two storage layouts share one compression/bookkeeping core:

  * ``LexicoLayerCache`` — one contiguous ``(B, KV, T_max, s)`` stripe per
    batch row. Simple, but a serving pool pays the full padded stripe for
    every slot regardless of fill.
  * ``PagedLexicoLayerCache`` — a *shared* page pool ``(n_pages, KV,
    page_size, s)`` plus a per-row page table ``(B, max_pages)`` int32.
    Rows own only the pages their ``t_c`` actually covers, so a pool's real
    footprint tracks the paper's 3s+2 accounting instead of the padded
    worst case. Page ids come from the host-side allocator in
    ``repro.serving.pages``; id 0 is the reserved null/trash page (writes by
    rows without a live destination are clamped onto it and never read).

The contiguous layout stays fully supported — it is the differential-test
oracle for the paged one (``tests/test_paged_cache.py``).

All fields carry a leading layer axis when stacked into a model cache
(``jax.lax.scan`` over layers consumes/produces one layer's slice).

Memory accounting: ``paper_bytes_per_vector = 3s+2`` (fp8 codec) — the number
we report KV-size %, matching the paper; ``array_bytes`` reports the actual
padded-layout footprint, ``paged_array_bytes`` the shared-pool footprint.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import omp as omp_mod
from repro.core import quant
from repro.core.attention import decode_attention

Array = jax.Array


class LexicoLayerCache(NamedTuple):
    """Cache for one attention layer (or one (L,...) stack of layers)."""

    k_vals: Array   # (B, KV, T_max, s) storage dtype
    k_idx: Array    # (B, KV, T_max, s) int16
    v_vals: Array
    v_idx: Array
    k_buf: Array    # (B, KV, n_b, m) bf16 ring buffer
    v_buf: Array
    t_c: Array      # (B,) int32 — valid compressed tokens per batch element
    buf_len: Array  # (B,) int32 — valid buffer entries per batch element
    buf_start: Array  # (B,) int32 — ring head (oldest entry) per batch element

    @property
    def T_max(self) -> int:
        return self.k_vals.shape[-2]

    @property
    def n_b(self) -> int:
        return self.k_buf.shape[-2]

    @property
    def s(self) -> int:
        return self.k_vals.shape[-1]


def init_layer_cache(
    batch: int, kv_heads: int, head_dim: int, *,
    t_max: int, n_b: int, s: int,
    val_dtype=jnp.float8_e4m3fn, buf_dtype=jnp.bfloat16,
) -> LexicoLayerCache:
    """Zero-initialised contiguous cache: ``(B, KV, t_max, s)`` sparse
    stores (``t_max`` = compressed capacity, buffer excluded), ``(B, KV,
    n_b, head_dim)`` ring buffers, and ``(B,)`` int32 counters."""
    zv = jnp.zeros((batch, kv_heads, t_max, s), val_dtype)
    zi = jnp.zeros((batch, kv_heads, t_max, s), jnp.int16)
    zb = jnp.zeros((batch, kv_heads, n_b, head_dim), buf_dtype)
    zc = jnp.zeros((batch,), jnp.int32)
    return LexicoLayerCache(
        k_vals=zv, k_idx=zi, v_vals=zv, v_idx=zi,
        k_buf=zb, v_buf=zb,
        t_c=zc, buf_len=zc, buf_start=zc,
    )


class PagedLexicoLayerCache(NamedTuple):
    """Paged cache for one attention layer (or one (L,...) stack).

    The four sparse stores are a page pool shared by every batch row;
    ``page_table[b, i]`` names the pool page holding row ``b``'s compressed
    tokens ``[i*page_size, (i+1)*page_size)``. Entry 0 = unallocated (the
    null page). Buffers and counters stay per-row, identical to the
    contiguous layout.
    """

    k_vals: Array      # (n_pages, KV, page_size, s) storage dtype
    k_idx: Array       # (n_pages, KV, page_size, s) int16
    v_vals: Array
    v_idx: Array
    page_table: Array  # (B, max_pages) int32; 0 = null/unallocated
    k_buf: Array       # (B, KV, n_b, m) bf16 ring buffer
    v_buf: Array
    t_c: Array         # (B,) int32 — valid compressed tokens per batch element
    buf_len: Array     # (B,) int32
    buf_start: Array   # (B,) int32 — ring head per batch element

    @property
    def n_pages(self) -> int:
        return self.k_vals.shape[0]

    @property
    def page_size(self) -> int:
        return self.k_vals.shape[-2]

    @property
    def max_pages(self) -> int:
        return self.page_table.shape[-1]

    @property
    def T_max(self) -> int:
        """Per-row capacity of the page table (tokens)."""
        return self.max_pages * self.page_size

    @property
    def n_b(self) -> int:
        return self.k_buf.shape[-2]

    @property
    def s(self) -> int:
        return self.k_vals.shape[-1]


def init_paged_layer_cache(
    batch: int, kv_heads: int, head_dim: int, *,
    n_pages: int, page_size: int, max_pages: int, n_b: int, s: int,
    val_dtype=jnp.float8_e4m3fn, buf_dtype=jnp.bfloat16,
) -> PagedLexicoLayerCache:
    """Zero-initialised paged cache: a shared ``(n_pages, KV, page_size,
    s)`` pool (page 0 = null/trash), an all-null ``(B, max_pages)`` int32
    page table, per-row ``(B, KV, n_b, head_dim)`` ring buffers and ``(B,)``
    int32 counters."""
    zv = jnp.zeros((n_pages, kv_heads, page_size, s), val_dtype)
    zi = jnp.zeros((n_pages, kv_heads, page_size, s), jnp.int16)
    zb = jnp.zeros((batch, kv_heads, n_b, head_dim), buf_dtype)
    zc = jnp.zeros((batch,), jnp.int32)
    return PagedLexicoLayerCache(
        k_vals=zv, k_idx=zi, v_vals=zv, v_idx=zi,
        page_table=jnp.zeros((batch, max_pages), jnp.int32),
        k_buf=zb, v_buf=zb, t_c=zc, buf_len=zc, buf_start=zc,
    )


def _page_dest(page_table: Array, pos: Array, page_size: int, n_pages: int):
    """Map per-row token positions (B,) to (page (B,), offset (B,)).

    Null/out-of-range table entries are clamped onto the trash page 0, which
    is never read — attention masks by ``t_c`` — so a row without a live
    destination can still issue its (no-op) write inside the shared step.
    """
    pos = jnp.asarray(pos, jnp.int32)
    slot_idx = jnp.clip(pos // page_size, 0, page_table.shape[-1] - 1)
    pg = jnp.take_along_axis(page_table, slot_idx[:, None], axis=1)[:, 0]
    return jnp.clip(pg, 0, n_pages - 1), pos % page_size


def _encode_store(vals: Array, idx: Array, val_dtype) -> Tuple[Array, Array]:
    if val_dtype == jnp.int8:
        code = quant.encode_int8(vals, idx)
        # int8 codec folds the scale into the values for storage-free decode:
        # we instead store fp8 by default; int8-with-scale is exercised in
        # benchmarks via quant.encode directly.
        return code.vals, code.idx
    return vals.astype(val_dtype), idx.astype(jnp.int16)


def _compress_prompt_head(cache, K, V, D_k, D_v, *, s, use_gram, delta,
                          G_k, G_v, s_cap, start=0, omp_backend="ref",
                          return_quality=False):
    """Shared prefill core: OMP-encode prompt positions ``[start, T - n_b)``.

    Args:
      cache: either cache layout (only ``n_b`` and store dtypes are read).
      K, V: ``(B, KV, T, m)`` full-precision prompt K/V (RoPE applied).
      s_cap: optional ``(B,)`` per-row sparsity caps (``<= s``).
      start: static Python int — first compressed position to encode. Prefix
        sharing restarts prefill here: positions ``[0, start)`` are already
        held as shared pages, so their OMP is skipped entirely. OMP is
        per-vector independent, so the tail codes are bitwise identical to
        the same positions of a full (``start=0``) encode.
      omp_backend: encoder implementation for the prompt-head OMP — see
        ``omp_batch(backend=)``. Prefill is the OMP-dominated phase; decode's
        single-evictee encode stays on the default path.
      return_quality: static bool — also return the per-position quality aux
        (see below) instead of discarding ``resid2``/``nnz``.

    Returns ``(kv, ki, vv, vi, k_tail, v_tail, n_comp, qual)`` — encoded
    sparse stores for positions ``[start, n_comp)`` (shape ``(B, KV,
    n_comp-start, s)``) plus the ``(B, KV, n_b, m)`` buffer tail —
    identically for both storage layouts, so the layouts can only differ in
    *where* codes land. ``start >= n_comp`` (everything shared) returns
    ``None`` stores. ``qual`` is ``None`` unless ``return_quality``; then a
    dict of ``(B, KV, n_comp-start)`` arrays — ``k_rel``/``v_rel`` (relative
    residual via ``omp.relative_residual``) and ``k_nnz``/``v_nnz`` (int32
    effective sparsity = OMP iterations actually run) — zero-length on the
    last axis when everything was shared.
    """
    B, KV, T, m = K.shape
    n_b = cache.n_b
    n_comp = T - n_b
    start = int(start)
    if start < 0:
        raise ValueError(f"start must be >= 0, got {start}")
    k_tail, v_tail = K[:, :, n_comp:], V[:, :, n_comp:]
    if start >= n_comp:       # fully shared prefix: nothing left to encode
        qual = None
        if return_quality:
            qual = {"k_rel": jnp.zeros((B, KV, 0), jnp.float32),
                    "k_nnz": jnp.zeros((B, KV, 0), jnp.int32),
                    "v_rel": jnp.zeros((B, KV, 0), jnp.float32),
                    "v_nnz": jnp.zeros((B, KV, 0), jnp.int32)}
        return None, None, None, None, k_tail, v_tail, n_comp, qual
    k_head = K[:, :, start:n_comp].astype(jnp.float32)
    v_head = V[:, :, start:n_comp].astype(jnp.float32)
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)[:, None, None]

    rk = omp_mod.omp_batch(k_head, D_k, s, use_gram=use_gram,
                           delta=delta, G=G_k, s_cap=cap, backend=omp_backend)
    rv = omp_mod.omp_batch(v_head, D_v, s, use_gram=use_gram,
                           delta=delta, G=G_v, s_cap=cap, backend=omp_backend)
    kv, ki = _encode_store(rk.vals, rk.idx, cache.k_vals.dtype)
    vv, vi = _encode_store(rv.vals, rv.idx, cache.v_vals.dtype)
    qual = None
    if return_quality:
        qual = {"k_rel": omp_mod.relative_residual(rk.resid2, k_head),
                "k_nnz": rk.nnz.astype(jnp.int32),
                "v_rel": omp_mod.relative_residual(rv.resid2, v_head),
                "v_nnz": rv.nnz.astype(jnp.int32)}
    return kv, ki, vv, vi, k_tail, v_tail, n_comp, qual


def prefill_compress(
    cache: LexicoLayerCache,
    K: Array, V: Array,          # (B, KV, T, m) full-precision K/V of the prompt
    D_k: Array, D_v: Array,      # (m, N)
    *,
    s: int,
    use_gram: bool = True,
    delta: float = 0.0,
    G_k=None, G_v=None,
    s_cap: Optional[Array] = None,
    start: int = 0,
    omp_backend: str = "ref",
    return_quality: bool = False,
):
    """Compress a prefilled prompt into the cache (Algorithm 2, Prefilling).

    Args:
      cache: ``LexicoLayerCache`` to fill (typically freshly initialised).
      K, V: ``(B, KV, T, m)`` full-precision prompt K/V (RoPE applied).
      D_k, D_v: ``(m, N)`` dictionaries.
      s_cap: optional ``(B,)`` int32 per-request sparsity tiers (``<= s``).
      start: static int — restart offset in compressed-position space.
        Positions ``[0, start)`` are left untouched (a prefix-sharing caller
        already holds their codes elsewhere); only ``[start, T - n_b)`` are
        OMP-encoded and written. ``start=0`` is the full prefill.
      omp_backend: prompt-head encoder — see ``omp_batch(backend=)``.
      return_quality: static bool — also return the encode-quality aux
        (``_compress_prompt_head``'s ``qual`` dict) instead of discarding
        ``resid2``/``nnz``. The cache contents are identical either way.

    The last ``n_b`` tokens go to the ring buffer; positions ``[start,
    T - n_b)`` are OMP-compressed into the sparse stores. Bookkeeping
    (``t_c = T - n_b``, full buffer) is set as if the whole prompt were
    compressed — the skipped prefix is the caller's responsibility.
    Assumes ``T >= n_b`` and ``T - n_b <= T_max``.

    Returns the updated ``LexicoLayerCache`` (or ``(cache, qual)`` when
    ``return_quality``).
    """
    B = K.shape[0]
    kv, ki, vv, vi, k_tail, v_tail, n_comp, qual = _compress_prompt_head(
        cache, K, V, D_k, D_v, s=s, use_gram=use_gram, delta=delta,
        G_k=G_k, G_v=G_v, s_cap=s_cap, start=start, omp_backend=omp_backend,
        return_quality=return_quality)

    def put(store, new):
        return jax.lax.dynamic_update_slice(store, new, (0, 0, int(start), 0))

    stores = {}
    if kv is not None:
        stores = dict(k_vals=put(cache.k_vals, kv), k_idx=put(cache.k_idx, ki),
                      v_vals=put(cache.v_vals, vv), v_idx=put(cache.v_idx, vi))
    fill = lambda v: jnp.full((B,), v, jnp.int32)
    out = cache._replace(
        k_buf=k_tail.astype(cache.k_buf.dtype),
        v_buf=v_tail.astype(cache.v_buf.dtype),
        t_c=fill(n_comp), buf_len=fill(cache.n_b), buf_start=fill(0),
        **stores,
    )
    return (out, qual) if return_quality else out


def scatter_into_pages(pool: Array, page_table: Array, dense: Array,
                       *, start: int = 0) -> Array:
    """Write a contiguous (B, KV, T, ·) block into the shared page pool at
    token positions ``[start, start+T)`` of each row's page table.

    Rows whose table doesn't cover a position write onto the trash page 0
    (masked out of every read by ``t_c``).
    """
    B, KV, T, _ = dense.shape
    n_pages, _, P, _ = pool.shape
    t = start + jnp.arange(T)
    slot_idx = jnp.clip(t // P, 0, page_table.shape[-1] - 1)
    pg = jnp.clip(page_table[:, slot_idx], 0, n_pages - 1)   # (B, T)
    off = jnp.broadcast_to(t % P, (B, T))
    payload = jnp.moveaxis(dense.astype(pool.dtype), 1, 2)   # (B, T, KV, ·)
    return pool.at[pg, :, off].set(payload)


def paged_prefill_compress(
    cache: PagedLexicoLayerCache,
    K: Array, V: Array,
    D_k: Array, D_v: Array,
    *,
    s: int,
    use_gram: bool = True,
    delta: float = 0.0,
    G_k=None, G_v=None,
    s_cap: Optional[Array] = None,
    start: int = 0,
    omp_backend: str = "ref",
    return_quality: bool = False,
):
    """Paged twin of :func:`prefill_compress` (restartable).

    The caller owns page placement: every row's ``page_table`` must already
    name pages covering positions ``[start, T - n_b)`` (the serving engine
    installs rows via ``repro.serving.slots``; tests build them directly).
    ``start`` (static int, page-aligned in the sharing flow) skips encoding
    of an already-shared prefix — table entries below ``start // page_size``
    are never written, so they may alias pages owned by other rows.
    Encoding is bit-identical to the contiguous path — only the scatter
    destination differs. ``return_quality`` returns ``(cache, qual)`` with
    the same quality aux as :func:`prefill_compress`.
    """
    B = K.shape[0]
    kv, ki, vv, vi, k_tail, v_tail, n_comp, qual = _compress_prompt_head(
        cache, K, V, D_k, D_v, s=s, use_gram=use_gram, delta=delta,
        G_k=G_k, G_v=G_v, s_cap=s_cap, start=start, omp_backend=omp_backend,
        return_quality=return_quality)

    stores = {}
    if kv is not None:
        table = cache.page_table
        stores = dict(
            k_vals=scatter_into_pages(cache.k_vals, table, kv, start=start),
            k_idx=scatter_into_pages(cache.k_idx, table, ki, start=start),
            v_vals=scatter_into_pages(cache.v_vals, table, vv, start=start),
            v_idx=scatter_into_pages(cache.v_idx, table, vi, start=start))
    fill = lambda v: jnp.full((B,), v, jnp.int32)
    out = cache._replace(
        k_buf=k_tail.astype(cache.k_buf.dtype),
        v_buf=v_tail.astype(cache.v_buf.dtype),
        t_c=fill(n_comp), buf_len=fill(cache.n_b), buf_start=fill(0),
        **stores,
    )
    return (out, qual) if return_quality else out


def decode_update(
    cache: LexicoLayerCache,
    k_t: Array, v_t: Array,      # (B, KV, m) new token K/V (RoPE already applied)
    D_k: Array, D_v: Array,
    *,
    s: int,
    use_gram: bool = True,
    delta: float = 0.0,
    G_k=None, G_v=None,
    active: Optional[Array] = None,
    s_cap: Optional[Array] = None,
    return_quality: bool = False,
):
    """Insert the new token; if the buffer is full, OMP-compress the oldest
    entry into the sparse store first (Algorithm 2, Decoding, n_a = 1).

    Bookkeeping is per batch element: every row has its own ``t_c``,
    ``buf_len`` and ring head, so heterogeneous-length requests advance
    independently inside one jitted step.
    ``active`` (B,) bool: rows set False are left untouched (idle slots of the
    continuous-batching pool). ``s_cap`` (B,) caps the per-row sparsity tier.
    ``return_quality`` returns ``(cache, qual)`` with the evictee-encode
    quality aux (see ``_compress_evictee``); the cache is identical either way.
    """
    kv, ki, vv, vi, act, full, evict, qual = _compress_evictee(
        cache, k_t, D_k, D_v, s=s, use_gram=use_gram, delta=delta,
        G_k=G_k, G_v=G_v, active=active, s_cap=s_cap,
        return_quality=return_quality)
    B = k_t.shape[0]
    b_idx = jnp.arange(B)

    # per-row write positions; rows that aren't evicting (or are idle) get
    # their current contents written back (read-select-write, no full select)
    t_w = jnp.clip(cache.t_c, 0, cache.T_max - 1)

    def maybe_store(store, new):
        cur = store[b_idx, :, t_w]                          # (B, KV, s)
        payload = jnp.where(evict[:, None, None], new.astype(store.dtype), cur)
        return store.at[b_idx, :, t_w].set(payload)

    out = cache._replace(
        k_vals=maybe_store(cache.k_vals, kv), k_idx=maybe_store(cache.k_idx, ki),
        v_vals=maybe_store(cache.v_vals, vv), v_idx=maybe_store(cache.v_idx, vi),
        **_ring_append(cache, k_t, v_t, act, full, evict))
    return (out, qual) if return_quality else out


def _compress_evictee(cache, k_t, D_k, D_v, *, s, use_gram, delta, G_k, G_v,
                      active, s_cap, return_quality=False):
    """Shared decode core: OMP-encode the oldest ring-buffer entry.

    Returns the encoded stores plus the (act, full, evict) row masks and a
    quality aux; both storage layouts consume these, differing only in the
    write destination. ``qual`` is ``None`` unless ``return_quality``; then a
    dict of ``(B, KV)`` arrays (``k_rel``/``v_rel``/``k_nnz``/``v_nnz``, same
    semantics as the prefill aux) plus ``wrote`` — the (B,) evict mask, since
    the encode runs unconditionally for every row but only rows whose buffer
    was full *and* active actually wrote the code. This closes the decode-path
    quality blind spot without changing what is computed: the ``resid2``/
    ``nnz`` the encode already produced simply stop being discarded.
    """
    B = k_t.shape[0]
    b_idx = jnp.arange(B)
    act = (jnp.ones((B,), jnp.bool_) if active is None
           else jnp.asarray(active, jnp.bool_))
    full = cache.buf_len >= cache.n_b

    old_k = cache.k_buf[b_idx, :, cache.buf_start].astype(jnp.float32)  # (B, KV, m)
    old_v = cache.v_buf[b_idx, :, cache.buf_start].astype(jnp.float32)
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)[:, None]
    rk = omp_mod.omp_batch(old_k, D_k, s, use_gram=use_gram,
                           delta=delta, G=G_k, s_cap=cap)
    rv = omp_mod.omp_batch(old_v, D_v, s, use_gram=use_gram,
                           delta=delta, G=G_v, s_cap=cap)
    kv, ki = _encode_store(rk.vals, rk.idx, cache.k_vals.dtype)
    vv, vi = _encode_store(rv.vals, rv.idx, cache.v_vals.dtype)
    evict = full & act
    qual = None
    if return_quality:
        qual = {"k_rel": omp_mod.relative_residual(rk.resid2, old_k),
                "k_nnz": rk.nnz.astype(jnp.int32),
                "v_rel": omp_mod.relative_residual(rv.resid2, old_v),
                "v_nnz": rv.nnz.astype(jnp.int32),
                "wrote": evict}
    return kv, ki, vv, vi, act, full, evict, qual


def _ring_append(cache, k_t, v_t, act, full, evict) -> dict:
    """Shared decode core: ring-write the new token + advance the counters."""
    B = k_t.shape[0]
    b_idx = jnp.arange(B)
    write_pos = jnp.where(full, cache.buf_start, cache.buf_len)

    def ring_write(buf, x_t):
        cur = buf[b_idx, :, write_pos]                      # (B, KV, m)
        payload = jnp.where(act[:, None, None], x_t.astype(buf.dtype), cur)
        return buf.at[b_idx, :, write_pos].set(payload)

    return dict(
        k_buf=ring_write(cache.k_buf, k_t),
        v_buf=ring_write(cache.v_buf, v_t),
        t_c=jnp.where(evict, cache.t_c + 1, cache.t_c),
        buf_start=jnp.where(evict, (cache.buf_start + 1) % cache.n_b,
                            cache.buf_start),
        buf_len=jnp.where(act & ~full, cache.buf_len + 1, cache.buf_len))


def paged_decode_update(
    cache: PagedLexicoLayerCache,
    k_t: Array, v_t: Array,
    D_k: Array, D_v: Array,
    *,
    s: int,
    use_gram: bool = True,
    delta: float = 0.0,
    G_k=None, G_v=None,
    active: Optional[Array] = None,
    s_cap: Optional[Array] = None,
    return_quality: bool = False,
):
    """Paged twin of :func:`decode_update`.

    The evicted token lands at position ``t_c`` of the row's page table —
    always inside the row's *tail page*, so a decode append touches one
    (page, offset) cell of the shared pool. Rows that aren't evicting write
    their current contents back (evicting rows own their destination page
    exclusively; non-evicting rows resolve to the trash page or their own
    cell, so same-payload writes are the only possible collisions).
    ``return_quality`` returns ``(cache, qual)`` exactly as
    :func:`decode_update` does.
    """
    kv, ki, vv, vi, act, full, evict, qual = _compress_evictee(
        cache, k_t, D_k, D_v, s=s, use_gram=use_gram, delta=delta,
        G_k=G_k, G_v=G_v, active=active, s_cap=s_cap,
        return_quality=return_quality)

    t_w = jnp.clip(cache.t_c, 0, cache.T_max - 1)
    pg, off = _page_dest(cache.page_table, t_w, cache.page_size, cache.n_pages)

    def maybe_store(pool, new):
        cur = pool[pg, :, off]                              # (B, KV, s)
        payload = jnp.where(evict[:, None, None], new.astype(pool.dtype), cur)
        return pool.at[pg, :, off].set(payload)

    out = cache._replace(
        k_vals=maybe_store(cache.k_vals, kv), k_idx=maybe_store(cache.k_idx, ki),
        v_vals=maybe_store(cache.v_vals, vv), v_idx=maybe_store(cache.v_idx, vi),
        **_ring_append(cache, k_t, v_t, act, full, evict))
    return (out, qual) if return_quality else out


def attend(
    cache: LexicoLayerCache,
    q: Array,                    # (B, KV, G, m)
    D_k: Array, D_v: Array,
    *,
    N: int,
    chunk: Optional[int] = None,
    window=None,
) -> Array:
    """Eq. 7 attention over the cache (buffer already contains the new
    token).

    Args:
      q: ``(B, KV, G, m)`` query heads (G = query groups per KV head).
      D_k, D_v: ``(m, N)`` dictionaries; ``N`` atoms.
      chunk: optional score-chunking width; ``window``: sliding window.

    Returns ``(B, KV, G, m)`` attention output; positions ``>= t_c`` per
    row carry NEG_INF logits and cannot contribute.
    """
    return decode_attention(
        q,
        cache.k_vals, cache.k_idx, cache.v_vals, cache.v_idx,
        cache.k_buf, cache.v_buf, D_k, D_v,
        t_c=cache.t_c, buf_len=cache.buf_len, N=N, chunk=chunk, window=window)


def paged_attend(
    cache: PagedLexicoLayerCache,
    q: Array,
    D_k: Array, D_v: Array,
    *,
    N: int,
    chunk: Optional[int] = None,
    window=None,
    fused: bool = False,
    fused_force_kernel: bool = False,
    fused_block_t: Optional[int] = None,
) -> Array:
    """Eq. 7 attention over the paged cache: gather each row's pages into a
    per-row contiguous view, then run the same masked softmax — positions
    beyond ``t_c`` (including anything a null table entry resolved to) carry
    NEG_INF logits, so garbage in gathered padding can't contribute.

    ``fused=True`` skips the gather entirely: the compressed half runs
    through :func:`repro.core.attention.fused_paged_decode_attention`, whose
    Pallas kernel walks the page tables in-place (dense K/V and the gathered
    page copy never hit HBM). Same math, online-softmax accumulation order —
    tokens identical in practice, logits equal to fp32 tolerance.
    ``fused_force_kernel=True`` additionally forces the Pallas kernel (in
    interpret mode off-TPU) instead of the jnp oracle — parity tests and
    TPU-shaped benchmarking."""
    from repro.core.attention import fused_paged_decode_attention, gather_pages
    if fused:
        return fused_paged_decode_attention(
            q,
            cache.k_vals, cache.k_idx, cache.v_vals, cache.v_idx,
            cache.page_table, cache.k_buf, cache.v_buf, D_k, D_v,
            t_c=cache.t_c, buf_len=cache.buf_len, N=N, window=window,
            block_t=fused_block_t, force_kernel=fused_force_kernel)
    return decode_attention(
        q,
        gather_pages(cache.k_vals, cache.page_table),
        gather_pages(cache.k_idx, cache.page_table),
        gather_pages(cache.v_vals, cache.page_table),
        gather_pages(cache.v_idx, cache.page_table),
        cache.k_buf, cache.v_buf, D_k, D_v,
        t_c=cache.t_c, buf_len=cache.buf_len, N=N, chunk=chunk, window=window)


# ---------------------------------------------------------------------------
# page-level tier transfer (host-memory swap: repro.serving.swap)
# ---------------------------------------------------------------------------

def extract_page(cache: PagedLexicoLayerCache, page) -> Tuple[Array, Array,
                                                              Array, Array]:
    """Slice one pool page's four sparse stores out of the shared pool — the
    device half of a page *demotion* to the host tier.

    Works on a single layer ``(n_pages, KV, page_size, s)`` pool or an
    (L,)-stacked one ``(L, n_pages, KV, page_size, s)``; ``page`` is a
    traced int32, so one jitted trace serves every page id (same pattern as
    the slot splices in ``repro.serving.slots``). The returned arrays keep
    the singleton page axis so :func:`inject_page` can splice them back.
    """
    page = jnp.asarray(page, jnp.int32)
    axis = cache.k_vals.ndim - 4

    def take(store):
        return jax.lax.dynamic_slice_in_dim(store, page, 1, axis=axis)

    return (take(cache.k_vals), take(cache.k_idx),
            take(cache.v_vals), take(cache.v_idx))


def inject_page(cache: PagedLexicoLayerCache, page, k_vals: Array,
                k_idx: Array, v_vals: Array,
                v_idx: Array) -> PagedLexicoLayerCache:
    """Write one page's four sparse stores into the pool at ``page`` — the
    device half of a page *promotion* from the host tier.

    Exact inverse of :func:`extract_page`: the arrays are stored verbatim in
    the pool dtypes, so a demote→promote round trip is bitwise. Callers must
    never target the null/trash page 0 with live data — ``page`` is traced,
    so that is enforced host-side (``repro.serving.swap``).
    """
    page = jnp.asarray(page, jnp.int32)
    axis = cache.k_vals.ndim - 4

    def put(store, new):
        return jax.lax.dynamic_update_slice_in_dim(
            store, new.astype(store.dtype), page, axis=axis)

    return cache._replace(
        k_vals=put(cache.k_vals, k_vals), k_idx=put(cache.k_idx, k_idx),
        v_vals=put(cache.v_vals, v_vals), v_idx=put(cache.v_idx, v_idx))


# ---------------------------------------------------------------------------
# layout conversion (differential-test harness + slot migration)
# ---------------------------------------------------------------------------

def to_paged(cache: LexicoLayerCache, page_table: Array,
             n_pages: int, page_size: int) -> PagedLexicoLayerCache:
    """Re-lay a contiguous cache out onto a page pool through ``page_table``.

    Every row's table must cover its ``t_c`` tokens; the stripe's padding
    beyond the last table entry lands on the trash page.
    """
    page_table = jnp.asarray(page_table, jnp.int32)
    B, KV, T_max, s = cache.k_vals.shape

    def pool_of(store):
        pool = jnp.zeros((n_pages, KV, page_size, s), store.dtype)
        return scatter_into_pages(pool, page_table, store)

    return PagedLexicoLayerCache(
        k_vals=pool_of(cache.k_vals), k_idx=pool_of(cache.k_idx),
        v_vals=pool_of(cache.v_vals), v_idx=pool_of(cache.v_idx),
        page_table=page_table, k_buf=cache.k_buf, v_buf=cache.v_buf,
        t_c=cache.t_c, buf_len=cache.buf_len, buf_start=cache.buf_start)


def to_contiguous(cache: PagedLexicoLayerCache) -> LexicoLayerCache:
    """Gather a paged cache back into the contiguous layout
    (T_max = max_pages * page_size; positions beyond t_c are garbage, exactly
    like the contiguous layout's own padding)."""
    from repro.core.attention import gather_pages
    return LexicoLayerCache(
        k_vals=gather_pages(cache.k_vals, cache.page_table),
        k_idx=gather_pages(cache.k_idx, cache.page_table),
        v_vals=gather_pages(cache.v_vals, cache.page_table),
        v_idx=gather_pages(cache.v_idx, cache.page_table),
        k_buf=cache.k_buf, v_buf=cache.v_buf,
        t_c=cache.t_c, buf_len=cache.buf_len, buf_start=cache.buf_start)


# ---------------------------------------------------------------------------
# memory accounting
# ---------------------------------------------------------------------------

def paper_kv_bytes(t_c: int, n_b: int, s: int, m: int, *, codec: str = "fp8",
                   fp_bytes: int = 2) -> int:
    """Paper accounting: compressed tokens at 3s+2 B/vector + buffer at full
    precision. Per (head, K+V) pair of vectors."""
    return 2 * (t_c * quant.payload_bytes(s, codec) + n_b * m * fp_bytes)


def kv_size_percent(t_c: int, n_b: int, s: int, m: int, **kw) -> float:
    """Compressed-cache size as % of the dense bf16 cache for the same
    ``t_c + n_b`` tokens (the paper's KV size % columns)."""
    total = t_c + n_b
    if total == 0:
        # empty cache: 0 compressed bytes of 0 dense bytes — report 0%, not
        # a ZeroDivisionError (hit by freshly cleared serving slots)
        return 0.0
    full = 2 * total * m * kw.get("fp_bytes", 2)
    return 100.0 * paper_kv_bytes(t_c, n_b, s, m, **kw) / full


def array_bytes(cache) -> int:
    """Actual padded-layout footprint. For a paged cache this is the whole
    shared pool + tables + buffers (what the device really holds)."""
    leaves = [cache.k_vals, cache.k_idx, cache.v_vals, cache.v_idx,
              cache.k_buf, cache.v_buf]
    if isinstance(cache, PagedLexicoLayerCache):
        leaves.append(cache.page_table)
    return sum(x.size * x.dtype.itemsize for x in leaves)


def page_store_bytes(kv_heads: int, page_size: int, s: int, *,
                     val_bytes: int = 1, idx_bytes: int = 2) -> int:
    """Array bytes one pool page holds across the four sparse stores
    (K and V, values + indices)."""
    return 2 * kv_heads * page_size * s * (val_bytes + idx_bytes)


def slot_resident_bytes(n_pages_held: int, *, kv_heads: int, page_size: int,
                        s: int, n_b: int, m: int, val_bytes: int = 1,
                        idx_bytes: int = 2, buf_bytes: int = 2) -> int:
    """Real per-layer footprint of one slot under paged storage: the pages it
    holds plus its full-precision ring buffers (K and V)."""
    return (n_pages_held * page_store_bytes(kv_heads, page_size, s,
                                            val_bytes=val_bytes,
                                            idx_bytes=idx_bytes)
            + 2 * kv_heads * n_b * m * buf_bytes)
