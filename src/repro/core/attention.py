"""Compressed-path attention math (paper §3.4, Eq. 7 and Algorithm 2).

The two sparse primitives:

  * ``compressed_scores``  — pre-softmax logits of queries against the sparse
    key cache: the query is first projected into coefficient space
    (``qd = q @ D_k``, O(N·m) once per query) and the per-token score is the
    s-sparse dot ``sum_j vals[t,j] * qd[idx[t,j]]`` (O(s) per token). This is
    the TPU-native analogue of the paper's ``q·D_k·K_csrᵀ`` SpMV.

  * ``compressed_values``  — attention read-out through the sparse value
    cache: probabilities are scatter-accumulated into coefficient space
    (``c[n] += p[t]·vals[t,j]`` for ``n = idx[t,j]``, O(T·s)) and decoded with
    one dense matmul ``c @ D_vᵀ`` (O(N·m)) — the paper's ``(a·V_csr)·D_vᵀ``.

``decode_attention`` composes them with the full-precision recency buffer into
the Eq. 7 joint softmax. Two execution modes:

  * ``chunk=None`` — the paper-faithful layout: all compressed logits are
    materialised, one softmax (what the PyTorch reference does).
  * ``chunk=C``    — beyond-paper *flash-decode* over the compressed cache:
    online-softmax scan over token chunks, with the value accumulator kept in
    coefficient space (N floats/query, decoded once at the end). Peak memory
    drops from O(T·s) per query-head to O(C·s + N).

Both have Pallas kernel twins in ``repro.kernels``; these jnp forms double as
the kernels' oracles. GQA layout everywhere: (B, KV, G, ·) — G query heads
share one KV head.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def gather_pages(pool: Array, page_table: Array) -> Array:
    """Materialise per-row contiguous views of a shared page pool.

    ``pool`` (n_pages, KV, P, ·) + ``page_table`` (B, max_pages) int32 →
    (B, KV, max_pages·P, ·). Null (0) and out-of-range entries clamp onto
    page 0, whose contents are garbage by design — callers must mask reads by
    ``t_c`` (``decode_attention`` already does). This is the read half of the
    paged layout: attention gathers pages, then masks.
    """
    pg = jnp.clip(page_table, 0, pool.shape[0] - 1)
    g = pool[pg]                                   # (B, MP, KV, P, ·)
    B, MP = page_table.shape
    _, KV, P = pool.shape[:3]
    g = jnp.moveaxis(g, 2, 1)                      # (B, KV, MP, P, ·)
    return g.reshape((B, KV, MP * P) + pool.shape[3:])


def per_batch(x) -> Array:
    """Lift a bookkeeping counter to broadcast against (B, KV, G, T) logits.

    Scalars pass through (legacy lockstep batches); (B,) per-slot counters —
    the continuous-batching layout — become (B, 1, 1, 1).
    """
    x = jnp.asarray(x)
    return x.reshape((-1, 1, 1, 1)) if x.ndim == 1 else x


def compressed_scores(qd: Array, vals: Array, idx: Array, *, scale) -> Array:
    """Logits (B,KV,G,T) of pre-projected queries qd (B,KV,G,N) against the
    sparse key cache vals/idx (B,KV,T,s)."""
    v = vals.astype(jnp.float32)
    g = jnp.take_along_axis(
        qd.astype(jnp.float32)[:, :, :, None, :],  # (B,KV,G,1,N)
        idx.astype(jnp.int32)[:, :, None, :, :],   # (B,KV,1,T,s)
        axis=-1,
    )  # (B,KV,G,T,s)
    return jnp.einsum("bkgts,bkts->bkgt", g, v) * scale


def scatter_coeffs(probs: Array, vals: Array, idx: Array, N: int) -> Array:
    """Coefficient-space accumulation c (B,KV,G,N): c[n] += p[t]·vals[t,j]."""
    contrib = probs.astype(jnp.float32)[..., None] * vals.astype(jnp.float32)[:, :, None, :, :]
    flat_idx = jnp.broadcast_to(idx.astype(jnp.int32)[:, :, None, :, :], contrib.shape)
    B, KV, G = contrib.shape[:3]
    c0 = jnp.zeros((B, KV, G, N), jnp.float32)
    return jax.vmap(jax.vmap(jax.vmap(
        lambda cc, ii, vv: cc.at[ii.reshape(-1)].add(vv.reshape(-1))
    )))(c0, flat_idx, contrib)


def compressed_values(probs: Array, vals: Array, idx: Array, D_v: Array, N: int) -> Array:
    """Attention output contribution (B,KV,G,m) of the compressed tokens."""
    c = scatter_coeffs(probs, vals, idx, N)
    return jnp.einsum("bkgn,mn->bkgm", c, D_v.astype(jnp.float32))


def fused_paged_decode_attention(
    q: Array,                         # (B, KV, G, m) new-token queries
    k_vals: Array, k_idx: Array,      # page pool (n_pages, KV, P, s)
    v_vals: Array, v_idx: Array,
    page_table: Array,                # (B, max_pages) int32
    k_buf: Array, v_buf: Array,       # (B, KV, n_b, m) full-precision buffer
    D_k: Array, D_v: Array,           # (m, N)
    *,
    t_c: Array,                       # int32 valid compressed tokens: scalar or (B,)
    buf_len: Array,                   # int32 valid buffer entries: scalar or (B,)
    N: int,
    window: Optional[Array] = None,
    block_t: Optional[int] = None,
    force_kernel: bool = False,
    interpret: Optional[bool] = None,
) -> Array:
    """Eq. 7 attention computed *directly* from the paged sparse codes.

    The fused twin of ``paged_attend``'s gather-then-mask read: the
    compressed half runs through ``repro.kernels.ops.paged_attention_op``
    (Pallas kernel on TPU / forced interpret; gather-free-semantics jnp
    oracle elsewhere), which walks the page tables and returns the online-
    softmax carry ``(m, l, c)`` — dense K/V and the per-row gathered page
    copy never materialise. This epilogue then folds the full-precision
    recency buffer in as the final online-softmax block and decodes the
    coefficient accumulator through ``D_v``, exactly the flash-decode
    epilogue of :func:`decode_attention`. Returns (B, KV, G, m) float32.
    """
    from repro.kernels import ops as kernel_ops

    m = q.shape[-1]
    scale = 1.0 / math.sqrt(m)
    qf = q.astype(jnp.float32)
    qd = jnp.einsum("bkgm,mn->bkgn", qf, D_k.astype(jnp.float32))
    B = q.shape[0]
    buf_lenb = per_batch(buf_len)
    t_c_row = jnp.broadcast_to(jnp.asarray(t_c, jnp.int32).reshape(-1), (B,))
    if window is not None:
        length = t_c_row + jnp.broadcast_to(
            jnp.asarray(buf_len, jnp.int32).reshape(-1), (B,))
        min_pos = length - jnp.asarray(window, jnp.int32)
    else:
        min_pos = jnp.full((B,), -1, jnp.int32)

    m_run, l_run, c_acc = kernel_ops.paged_attention_op(
        qd, k_vals, k_idx, v_vals, v_idx, page_table, t_c_row, min_pos,
        N=N, scale=scale, block_t=block_t, force_kernel=force_kernel,
        interpret=interpret)

    # --- recency buffer as the final online-softmax block ---
    s_b = jnp.einsum("bkgm,bkrm->bkgr", qf, k_buf.astype(jnp.float32)) * scale
    n_b = s_b.shape[-1]
    s_b = jnp.where(jnp.arange(n_b)[None, None, None, :] < buf_lenb, s_b, NEG_INF)
    m_new = jnp.maximum(m_run, jnp.max(s_b, axis=-1))
    alpha = jnp.exp(m_run - m_new)
    p_b = jnp.exp(s_b - m_new[..., None])
    l_fin = l_run * alpha + jnp.sum(p_b, axis=-1)
    out_b = jnp.einsum("bkgr,bkrm->bkgm", p_b, v_buf.astype(jnp.float32))
    out_c = jnp.einsum("bkgn,mn->bkgm", c_acc * alpha[..., None],
                       D_v.astype(jnp.float32))
    # empty slots (t_c == buf_len == 0) have zero mass; keep them finite
    return (out_c + out_b) / jnp.maximum(l_fin, 1e-30)[..., None]


def decode_attention(
    q: Array,                         # (B, KV, G, m) new-token queries
    k_vals: Array, k_idx: Array,      # compressed keys   (B, KV, T, s)
    v_vals: Array, v_idx: Array,      # compressed values (B, KV, T, s)
    k_buf: Array, v_buf: Array,       # (B, KV, n_b, m) full-precision buffer
    D_k: Array, D_v: Array,           # (m, N)
    *,
    t_c: Array,                       # int32 valid compressed tokens: scalar or (B,)
    buf_len: Array,                   # int32 valid buffer entries: scalar or (B,)
    N: int,
    chunk: Optional[int] = None,
    window: Optional[Array] = None,   # sliding-window width (tokens); None = global
) -> Array:
    """One-token attention over [compressed cache || buffer] (Eq. 7).

    The caller has already appended the new token's k/v to the buffer
    (Algorithm 2 lines 15-16). Returns (B, KV, G, m) in float32.
    ``t_c``/``buf_len`` may be per-batch-element (B,) — heterogeneous slot
    lengths in the continuous-batching engine — or legacy scalars.
    ``window``: only cache positions >= length - window attend (compressed
    token t sits at absolute position t; buffer entries are always the most
    recent tokens, assumed inside any window >= n_b).
    """
    m = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(m))
    qf = q.astype(jnp.float32)
    qd = jnp.einsum("bkgm,mn->bkgn", qf, D_k.astype(jnp.float32))
    T = k_vals.shape[2]
    t_cb, buf_lenb = per_batch(t_c), per_batch(buf_len)
    length = t_cb + buf_lenb
    min_pos = (length - window) if window is not None else jnp.int32(-1)

    # --- buffer logits (always dense, small) ---
    s_b = jnp.einsum("bkgm,bkrm->bkgr", qf, k_buf.astype(jnp.float32)) * scale
    n_b = s_b.shape[-1]
    s_b = jnp.where(jnp.arange(n_b)[None, None, None, :] < buf_lenb, s_b, NEG_INF)

    if chunk is None or chunk >= T:
        # Paper-faithful: materialise all compressed logits, single softmax.
        s_c = compressed_scores(qd, k_vals, k_idx, scale=scale)
        pos = jnp.arange(T)[None, None, None, :]
        s_c = jnp.where((pos < t_cb) & (pos >= min_pos), s_c, NEG_INF)
        s_all = jnp.concatenate([s_c, s_b], axis=-1)
        p = jax.nn.softmax(s_all, axis=-1)
        p_c, p_b = p[..., :T], p[..., T:]
        out_c = compressed_values(p_c, v_vals, v_idx, D_v, N)
        out_b = jnp.einsum("bkgr,bkrm->bkgm", p_b, v_buf.astype(jnp.float32))
        return out_c + out_b

    # --- flash-decode: online softmax over T chunks, coeff-space values ---
    # (remainder tokens are handled as a final partial block)
    n_chunks = T // chunk
    rem = T - n_chunks * chunk
    B, KV, G = qd.shape[:3]

    def block(carry, kv_c, ki_c, vv_c, vi_c, base):
        m_run, l_run, c_acc = carry
        s_chk = compressed_scores(qd, kv_c, ki_c, scale=scale)       # (B,KV,G,C)
        pos = base + jnp.arange(kv_c.shape[2])
        valid = (pos[None, None, None, :] < t_cb) & (pos[None, None, None, :] >= min_pos)
        s_chk = jnp.where(valid, s_chk, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s_chk, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s_chk - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        c_new = c_acc * alpha[..., None] + scatter_coeffs(p, vv_c, vi_c, N)
        return (m_new, l_new, c_new)

    def to_chunks(x):  # (B,KV,T,s) -> (n_chunks, B,KV,C,s)
        return jnp.moveaxis(x[:, :, :n_chunks * chunk].reshape(
            B, KV, n_chunks, chunk, -1), 2, 0)

    init = (jnp.full((B, KV, G), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, G), jnp.float32),
            jnp.zeros((B, KV, G, N), jnp.float32))
    if n_chunks:
        xs = (to_chunks(k_vals), to_chunks(k_idx), to_chunks(v_vals),
              to_chunks(v_idx), jnp.arange(n_chunks) * chunk)
        carry, _ = jax.lax.scan(
            lambda c, x: (block(c, *x), None), init, xs)
    else:
        carry = init
    if rem:
        carry = block(carry, k_vals[:, :, -rem:], k_idx[:, :, -rem:],
                      v_vals[:, :, -rem:], v_idx[:, :, -rem:],
                      jnp.int32(n_chunks * chunk))
    m_run, l_run, c_acc = carry

    # --- buffer as the final block ---
    m_new = jnp.maximum(m_run, jnp.max(s_b, axis=-1))
    alpha = jnp.exp(m_run - m_new)
    p_b = jnp.exp(s_b - m_new[..., None])
    l_fin = l_run * alpha + jnp.sum(p_b, axis=-1)
    out_b = jnp.einsum("bkgr,bkrm->bkgm", p_b, v_buf.astype(jnp.float32))
    out_c = jnp.einsum("bkgn,mn->bkgm", c_acc * alpha[..., None], D_v.astype(jnp.float32))
    # empty slots (t_c == buf_len == 0) have zero mass; keep them finite
    return (out_c + out_b) / jnp.maximum(l_fin, 1e-30)[..., None]
