"""Batched Orthogonal Matching Pursuit (OMP) in pure JAX.

This is the sparse encoder of Lexico (paper §3.2, Appendix A). We implement the
Cholesky-incremental variant (OMP v0 of Zhu et al. 2020): the Gram matrix of the
selected atoms is factorised incrementally, so each iteration costs one
correlation pass + O(i^2) triangular solves instead of a fresh least squares.

Shapes are static (fixed ``s_max`` iterations, padded index/value slots) so the
whole encoder jits, vmaps over vectors, and vmaps again over (layer x K/V)
dictionaries — the batched-over-dictionaries extension described in the paper.

Two correlation backends:
  * ``use_gram=True``  — precomputed ``G = D^T D`` (paper's path). Residual
    correlations are ``alpha0 - G[:, I] @ y`` (O(N*i) per iter). G may be
    sharded row-wise over the ``model`` mesh axis at scale.
  * ``use_gram=False`` — Gram-free: ``D^T (k - D y)`` (O(N*m) per iter). Cheaper
    in memory, used when N is large and G doesn't pay for itself.

Early termination (paper §4.2.1): iterations stop *logically* once the relative
residual ``||r|| <= delta * ||k||`` — further slots stay zero and ``nnz`` records
the effective sparsity. Because OMP is greedy, the truncated code equals the
code OMP would have produced with smaller s (paper's observation).
"""
from __future__ import annotations

import functools
import weakref
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# --------------------------------------------------------------------------
# Gram cache: G = DᵀD keyed on dictionary identity.
#
# Dictionaries are long-lived (the serving engine holds one bank for its
# whole lifetime; benchmarks reuse one trained D across sweeps) but several
# callers — benchmarks/latency.py, benchmarks/threshold_ablation.py,
# core/dict_learning.py — historically passed ``G=None`` and silently paid
# the N²·m recompute on every call. ``gram_for`` materialises the Gram once
# per concrete dictionary object and holds it behind a weakref, so dropping
# the dictionary drops its Gram. Tracers (callers already under jit/vmap)
# can't be host-cached and compute G inline, exactly as before.
# --------------------------------------------------------------------------
_GRAM_CACHE: dict = {}
_GRAM_STATS = {"hits": 0, "misses": 0}


def gram_for(D: Array) -> Array:
    """Return ``DᵀD`` in fp32, cached per concrete dictionary object."""
    if isinstance(D, jax.core.Tracer):
        Df = D.astype(jnp.float32)
        return Df.T @ Df
    key = id(D)
    ent = _GRAM_CACHE.get(key)
    if ent is not None and ent[0]() is D:
        _GRAM_STATS["hits"] += 1
        return ent[1]
    _GRAM_STATS["misses"] += 1
    Df = jnp.asarray(D).astype(jnp.float32)
    G = Df.T @ Df
    try:
        wr = weakref.ref(D, lambda _r, _k=key: _GRAM_CACHE.pop(_k, None))
    except TypeError:
        return G  # unweakreffable inputs just aren't cached
    _GRAM_CACHE[key] = (wr, G)
    return G


def gram_cache_info() -> dict:
    """Cache observability for tests/benchmarks: size + hit/miss counters."""
    return {"size": len(_GRAM_CACHE), **_GRAM_STATS}


def clear_gram_cache() -> None:
    _GRAM_CACHE.clear()
    _GRAM_STATS.update(hits=0, misses=0)


class OMPResult(NamedTuple):
    """Padded sparse code for a batch of vectors.

    vals:  (..., s_max) float32 coefficients (zeros past nnz)
    idx:   (..., s_max) int32 dictionary indices (zeros past nnz, masked by vals)
    nnz:   (...,) int32 effective sparsity per vector
    resid2: (...,) float32 squared residual norm at termination
    """

    vals: Array
    idx: Array
    nnz: Array
    resid2: Array


def _tri_solve(L: Array, b: Array, *, lower: bool, trans: bool = False) -> Array:
    """Triangular solve on a padded (s,s) factor whose unused diag is 1."""
    return jax.scipy.linalg.solve_triangular(L, b, lower=lower, trans=1 if trans else 0)


def omp_single(
    k: Array,
    D: Array,
    s_max: int,
    *,
    G: Optional[Array] = None,
    delta: float = 0.0,
    eps: float = 1e-12,
    s_cap: Optional[Array] = None,
) -> OMPResult:
    """OMP for a single vector ``k`` (m,) against dictionary ``D`` (m, N).

    If ``G`` (N, N) is given it is used for residual correlations (paper's
    Cholesky path); otherwise correlations are recomputed from D.
    ``delta`` is the relative-error early-stop threshold (0 disables).
    ``s_cap`` (scalar int32) caps the number of atoms below ``s_max`` — since
    OMP is greedy with a fresh LS refit per step, stopping at ``s_cap`` yields
    exactly the code of an ``s=s_cap`` run (per-request sparsity tiers ride on
    one compiled s_max-shaped encoder).
    """
    m, N = D.shape
    k = k.astype(jnp.float32)
    D = D.astype(jnp.float32)
    alpha0 = D.T @ k  # (N,)
    kk = jnp.dot(k, k)
    thresh2 = (delta * delta) * kk
    cap = jnp.int32(s_max) if s_cap is None else jnp.asarray(s_cap, jnp.int32)

    # Padded state. L starts as identity so triangular solves on the full
    # (s,s) factor are exact for the filled prefix and inert elsewhere.
    L0 = jnp.eye(s_max, dtype=jnp.float32)
    idx0 = jnp.zeros((s_max,), jnp.int32)
    y0 = jnp.zeros((s_max,), jnp.float32)
    sel0 = jnp.zeros((N,), jnp.bool_)
    state0 = (L0, idx0, y0, sel0, jnp.int32(0), kk)

    def body(i, state):
        L, idx, y, sel, nnz, r2 = state
        active = jnp.logical_and(i == nnz, r2 > thresh2) & (i < cap)

        # Residual correlations c = D^T r.
        if G is not None:
            # alpha0 - G[:, idx] @ y   (gather i columns; padded y zeros are inert
            # only if gathered columns for unused slots contribute 0 — enforce by
            # masking y, which is already zero past nnz).
            c = alpha0 - (G[:, idx] @ y)
        else:
            c = alpha0 - D.T @ (D[:, idx] @ y)
        c = jnp.where(sel, -jnp.inf, jnp.abs(c))
        n = jnp.argmax(c).astype(jnp.int32)

        # Cholesky append: w = L^{-1} G[idx, n] over the filled prefix.
        if G is not None:
            g_col = G[idx, n]
        else:
            g_col = D[:, idx].T @ D[:, n]
        pos = jnp.arange(s_max)
        g_col = jnp.where(pos < i, g_col, 0.0)
        w = _tri_solve(L, g_col, lower=True)
        w = jnp.where(pos < i, w, 0.0)
        gnn = (G[n, n] if G is not None else jnp.dot(D[:, n], D[:, n]))
        d2 = jnp.maximum(gnn - jnp.dot(w, w), eps)
        d = jnp.sqrt(d2)
        L_new = L.at[i, :].set(jnp.where(pos < i, w, jnp.where(pos == i, d, 0.0)))
        idx_new = idx.at[i].set(n)
        sel_new = sel.at[n].set(True)

        # Solve (L L^T) y = alpha0[idx] on the filled prefix.
        rhs = jnp.where(pos <= i, alpha0[idx_new], 0.0)
        z = _tri_solve(L_new, rhs, lower=True)
        z = jnp.where(pos <= i, z, 0.0)
        y_new = _tri_solve(L_new, z, lower=True, trans=True)
        y_new = jnp.where(pos <= i, y_new, 0.0)

        # Residual norm^2 = ||k||^2 - y . alpha0[idx].
        r2_new = jnp.maximum(kk - jnp.dot(y_new, alpha0[idx_new]), 0.0)

        return (
            jnp.where(active, L_new, L),
            jnp.where(active, idx_new, idx),
            jnp.where(active, y_new, y),
            jnp.where(active, sel_new, sel),
            jnp.where(active, nnz + 1, nnz),
            jnp.where(active, r2_new, r2),
        )

    L, idx, y, sel, nnz, r2 = jax.lax.fori_loop(0, s_max, body, state0)
    pos = jnp.arange(s_max)
    vals = jnp.where(pos < nnz, y, 0.0)
    idx = jnp.where(pos < nnz, idx, 0)
    return OMPResult(vals=vals, idx=idx, nnz=nnz, resid2=r2)


def omp_batch(
    K: Array,
    D: Array,
    s_max: int,
    *,
    use_gram: bool = True,
    delta: float = 0.0,
    G: Optional[Array] = None,
    s_cap: Optional[Array] = None,
    backend: str = "ref",
    tile_b: int = 256,
) -> OMPResult:
    """Batched OMP: ``K`` (..., m) against a single dictionary ``D`` (m, N).

    ``G``: optional precomputed Gram (paper precomputes it offline — at decode
    time recomputing N^2 m dominates everything else, so serving threads the
    stored Gram through). If None and use_gram, G comes from the per-
    dictionary cache (``gram_for``) — callers that don't thread G pay the
    N²·m materialisation once per dictionary, not once per call.

    ``s_cap``: optional per-vector atom cap, broadcastable to ``K.shape[:-1]``
    (per-request sparsity tiers in the serving engine).

    ``backend`` selects the encoder implementation (identical padded-output
    contract; tests pin idx exact / vals ≤ 2e-5 across them):
      * ``"ref"`` — this module's vmapped per-vector Cholesky OMP (oracle).
      * ``"fused"`` — ``kernels.omp_encode``: tile-batched iteration
        (``tile_b`` rows per loop) with ``lax.while_loop`` early exit and
        Pallas selection kernels via ``kernels.ops`` dispatch (kernels run
        natively on TPU, jnp oracles elsewhere).
      * ``"fused_kernel"`` — fused with the selection kernels forced on
        (interpret mode off-TPU); parity/CI path.
    """
    if G is None and use_gram:
        G = gram_for(D)
    if backend != "ref":
        if backend not in ("fused", "fused_kernel"):
            raise ValueError(f"unknown omp backend: {backend!r}")
        from repro.kernels.omp_encode import omp_encode_batch
        return omp_encode_batch(
            K, D, s_max, G=G if use_gram else None, delta=delta, s_cap=s_cap,
            tile_b=tile_b, force_kernel=(backend == "fused_kernel"))
    return _omp_batch_ref(
        K, D, s_max, use_gram=use_gram, delta=delta, G=G, s_cap=s_cap)


@functools.partial(jax.jit, static_argnames=("s_max", "use_gram", "delta"))
def _omp_batch_ref(
    K: Array,
    D: Array,
    s_max: int,
    *,
    use_gram: bool = True,
    delta: float = 0.0,
    G: Optional[Array] = None,
    s_cap: Optional[Array] = None,
) -> OMPResult:
    """The vmapped per-vector encoder — ``omp_batch(backend="ref")``."""
    if G is None and use_gram:
        G = D.astype(jnp.float32).T @ D.astype(jnp.float32)
    batch_shape = K.shape[:-1]
    flat = K.reshape((-1, K.shape[-1]))
    if s_cap is None:
        out = jax.vmap(lambda k: omp_single(k, D, s_max, G=G, delta=delta))(flat)
    else:
        cap_flat = jnp.broadcast_to(
            jnp.asarray(s_cap, jnp.int32), batch_shape).reshape(-1)
        out = jax.vmap(
            lambda k, c: omp_single(k, D, s_max, G=G, delta=delta, s_cap=c)
        )(flat, cap_flat)
    return OMPResult(
        vals=out.vals.reshape(batch_shape + (s_max,)),
        idx=out.idx.reshape(batch_shape + (s_max,)),
        nnz=out.nnz.reshape(batch_shape),
        resid2=out.resid2.reshape(batch_shape),
    )


@functools.partial(jax.jit, static_argnames=("s_max", "use_gram", "delta"))
def omp_multi_dict(
    K: Array,
    D: Array,
    s_max: int,
    *,
    use_gram: bool = True,
    delta: float = 0.0,
) -> OMPResult:
    """OMP batched over dictionaries too: ``K`` (d, B, m), ``D`` (d, m, N).

    This is the paper's "extra batch dimension ... parallel processing across
    multiple dictionaries" — e.g. d = num_layers * 2 (K and V dictionaries).
    """
    return jax.vmap(lambda k, dd: omp_batch(k, dd, s_max, use_gram=use_gram, delta=delta))(K, D)


def relative_residual(resid2: Array, k: Array, *, eps: float = 1e-12) -> Array:
    """Relative reconstruction error ``sqrt(resid2) / (||k|| + eps)``.

    The Table-1 quality metric, shared by the offline evaluator
    (``core.dict_learning.relative_error``) and the serving-time quality
    telemetry (``serving/obs/quality.py``) so the two report the *same*
    number on the same inputs. ``resid2`` is ``OMPResult.resid2`` (any batch
    shape); ``k`` the matching original vectors (..., m).
    """
    r2 = jnp.maximum(jnp.asarray(resid2, jnp.float32), 0.0)
    norm = jnp.linalg.norm(jnp.asarray(k, jnp.float32), axis=-1)
    return jnp.sqrt(r2) / (norm + eps)


def reconstruct(res: OMPResult, D: Array) -> Array:
    """Decode a padded sparse code back to dense vectors: sum_j vals_j * D[:, idx_j]."""
    atoms = jnp.take(D, res.idx, axis=1)  # (m, ..., s)
    atoms = jnp.moveaxis(atoms, 0, -1)  # (..., s, m)
    return jnp.einsum("...s,...sm->...m", res.vals.astype(jnp.float32), atoms)
