"""Naive reference OMP (numpy, per-vector least squares) — the oracle.

Matches Algorithm 1 in the paper verbatim: each iteration picks the atom with
max |correlation to the residual| and re-solves the restricted least squares
from scratch. O(s * (Nm + m s^2)) — slow, only for tests/benchmarks.
"""
from __future__ import annotations

import numpy as np


def omp_ref(k: np.ndarray, D: np.ndarray, s: int, delta: float = 0.0):
    """Returns (vals[s], idx[s], nnz, resid2) padded like core.omp.OMPResult."""
    k = np.asarray(k, np.float64)
    D = np.asarray(D, np.float64)
    m, N = D.shape
    sel: list[int] = []
    y = np.zeros(0)
    r = k.copy()
    kk = float(k @ k)
    for _ in range(s):
        if r @ r <= (delta * delta) * kk:
            break
        c = np.abs(D.T @ r)
        c[sel] = -np.inf
        n = int(np.argmax(c))
        sel.append(n)
        Dsub = D[:, sel]
        y, *_ = np.linalg.lstsq(Dsub, k, rcond=None)
        r = k - Dsub @ y
    vals = np.zeros(s)
    idx = np.zeros(s, np.int64)
    vals[: len(sel)] = y
    idx[: len(sel)] = sel
    return vals, idx, len(sel), float(r @ r)


def omp_ref_batch(K: np.ndarray, D: np.ndarray, s: int, delta: float = 0.0):
    outs = [omp_ref(k, D, s, delta) for k in K.reshape(-1, K.shape[-1])]
    vals = np.stack([o[0] for o in outs]).reshape(K.shape[:-1] + (s,))
    idx = np.stack([o[1] for o in outs]).reshape(K.shape[:-1] + (s,))
    nnz = np.array([o[2] for o in outs]).reshape(K.shape[:-1])
    r2 = np.array([o[3] for o in outs]).reshape(K.shape[:-1])
    return vals, idx, nnz, r2
