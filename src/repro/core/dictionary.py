"""Lexico dictionaries: init, unit-norm constraint, tangent-projected gradients.

A dictionary is a plain array ``D (m, N)`` with unit-norm atoms (columns).
The paper (§3.3) enforces the constraint by removing any gradient component
parallel to each atom before the update, then we renormalise for drift.

``DictionaryBank`` stacks the per-(layer, role) dictionaries of a model:
``D (L, 2, m, N)`` with role 0 = key, 1 = value — this is the unit that
``omp_multi_dict`` consumes and that serving replicates across the mesh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
KEY_ROLE, VALUE_ROLE = 0, 1


def init_dictionary(key: jax.Array, m: int, N: int, dtype=jnp.float32) -> Array:
    """Uniform(-1/sqrt(N), 1/sqrt(N)) init (PyTorch linear default, per paper),
    then unit-normalise the atoms."""
    bound = 1.0 / jnp.sqrt(N)
    D = jax.random.uniform(key, (m, N), dtype, minval=-bound, maxval=bound)
    return normalize_atoms(D)


def normalize_atoms(D: Array, eps: float = 1e-8) -> Array:
    return D / (jnp.linalg.norm(D, axis=-2, keepdims=True) + eps)


def project_gradient(D: Array, grad: Array) -> Array:
    """Remove the component of each atom's gradient parallel to the atom."""
    parallel = jnp.sum(grad * D, axis=-2, keepdims=True) * D
    return grad - parallel


class DictionaryBank(NamedTuple):
    """Stacked dictionaries for a model: D (num_layers, roles, m, N).

    ``G`` optionally holds the precomputed Grams (num_layers, roles, N, N) —
    the paper's Cholesky OMP consumes G; serving threads the stored Gram
    through instead of recomputing N²m per step. Rows of G shard over the
    ``model`` mesh axis at scale."""

    D: Array
    G: Array | None = None

    @property
    def num_layers(self) -> int:
        return self.D.shape[0]

    @property
    def m(self) -> int:
        return self.D.shape[2]

    @property
    def N(self) -> int:
        return self.D.shape[3]

    def layer(self, i: int):
        """(D_k, D_v) for layer i."""
        return self.D[i, KEY_ROLE], self.D[i, VALUE_ROLE]

    def flat(self) -> Array:
        """(L*2, m, N) view for omp_multi_dict."""
        return self.D.reshape((-1,) + self.D.shape[2:])


def init_bank(key: jax.Array, num_layers: int, m: int, N: int, dtype=jnp.float32) -> DictionaryBank:
    keys = jax.random.split(key, num_layers * 2)
    D = jnp.stack([init_dictionary(k, m, N, dtype) for k in keys])
    return DictionaryBank(D=D.reshape(num_layers, 2, m, N))


def storage_bytes(N: int, m: int, num_layers: int, dtype_bytes: int = 2) -> int:
    """Constant model-side storage the dictionaries add (paper: 16.8MB for
    N=1024 on a 7B/8B model)."""
    return num_layers * 2 * m * N * dtype_bytes
