"""hymba-1.5b [hybrid] — parallel attn + mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer runs attention heads and Mamba (selective-SSM) heads in parallel
on the same input and fuses (mean of per-path normed outputs). Most layers
use sliding-window attention; layers {0, mid, last} stay global. Hymba's 128
meta tokens are learnable prefix embeddings prepended to the sequence.
Lexico compresses the attention path's KV; the SSM state is O(1) per layer.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    sliding_window=1024, global_attn_layers=(0, 15, 31),
    parallel_ssm=True, ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    num_meta_tokens=128,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, param_dtype="float32",
        sliding_window=16, global_attn_layers=(0,),
        parallel_ssm=True, ssm=SSMConfig(state_dim=4, conv_width=4, expand=2),
        num_meta_tokens=4,
    )
