"""mistral-large-123b [dense] [hf:mistralai/Mistral-Large-Instruct-2407].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=28672, vocab_size=32768,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke", family="dense",
        num_layers=3, d_model=96, num_heads=6, num_kv_heads=2,
        d_ff=224, vocab_size=256, param_dtype="float32",
    )
