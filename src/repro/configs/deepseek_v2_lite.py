"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512 [arXiv:2405.04434].

27L d_model=2048 16H d_ff=1408 (per expert) vocab=102400, MoE with 2 shared +
64 routed experts top-6 (the assignment sheet lists both "64e top-6" and
"2 shared+160 routed"; the published V2-Lite is 64 routed + 2 shared, top-6 —
we use that; see DESIGN.md). MLA: kv_lora_rank=512, rope_head_dim=64,
nope/v head dims 128. V2-Lite has a dense-FFN first layer; we keep all layers
MoE for the scan-uniform stack (shared experts provide the dense path — noted
in DESIGN.md).

Lexico note: the cached vector is the MLA latent (c_kv ‖ k_rope), dim 576 —
the dictionary lives in that space and query-side MLA absorption composes
with the qD trick (see models/mla.py).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102_400,
    rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128, q_lora_rank=None),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
                  d_ff_shared=1408),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=96, vocab_size=256, param_dtype="float32",
        mla=MLAConfig(kv_lora_rank=32, rope_head_dim=16, nope_head_dim=16,
                      v_head_dim=16, q_lora_rank=None),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, num_shared=1,
                      d_ff_shared=96, capacity_factor=4.0),
    )
