"""Config system: architecture + technique + run configs.

Every assigned architecture gets a module in this package exporting
``CONFIG: ModelConfig`` (the exact assigned shape) and ``smoke_config()``
(a reduced same-family config for CPU tests). ``repro.configs.get(name)``
resolves either.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0          # hidden dim of the shared-expert MLP
    router_dtype: str = "float32"
    # Expert-bucket capacity = tokens*top_k/E * capacity_factor. Overflow is
    # dropped (standard at scale; makes routing weakly non-causal). Tests and
    # decode paths use a dropless factor (= num_experts/top_k upper bound).
    capacity_factor: float = 1.25
    # 'sort' = pjit scatter dispatch (baseline); 'ep_local' = shard_map
    # zero-dispatch-comm EP (beyond-paper; see models/moe.py + §Perf)
    dispatch: str = "sort"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None   # None = dense q projection (V2-Lite)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None       # default ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    qk_norm: bool = False
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    act: str = "swiglu"                     # swiglu | gelu
    use_rope: bool = True                   # whisper: learned/sinusoidal instead
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None    # SWA width (hymba non-global layers)
    global_attn_layers: Tuple[int, ...] = ()  # layers exempt from SWA
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    attn_free: bool = False                 # rwkv6: no attention at all
    parallel_ssm: bool = False              # hymba: attn + ssm heads in parallel
    enc_dec: bool = False                   # whisper
    enc_layers: int = 0
    enc_max_frames: int = 1500
    num_meta_tokens: int = 0                # hymba meta tokens (learnable prefix)
    max_seq_len: int = 131_072
    param_dtype: str = "bfloat16"
    # store attention probabilities in bf16 inside the blocked kernel-stream
    # (halves the dominant T^2 HBM traffic of long prefill; §Perf)
    attn_probs_bf16: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def cached_vector_dim(self) -> int:
        """Dim of the vector Lexico compresses per cached token.

        MLA caches one latent (c_kv ‖ k_rope) per token; everything else
        caches per-KV-head k/v of head_dim."""
        if self.mla is not None:
            return self.mla.kv_lora_rank + self.mla.rope_head_dim
        return self.hd

    @property
    def cache_kv_heads(self) -> int:
        return 1 if self.mla is not None else self.num_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once if tied)."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        hd, H, KV = self.hd, self.num_heads, self.num_kv_heads
        if self.rwkv is not None:
            att = d * d * 4 + 3 * d * self.rwkv.decay_lora  # r,k,v,o + loras (approx)
            ffn = 2 * d * self.d_ff + self.d_ff * d
            core = L * (att + ffn)
        else:
            if self.mla is not None:
                c = self.mla
                att = (d * H * (c.nope_head_dim + c.rope_head_dim)
                       + d * (c.kv_lora_rank + c.rope_head_dim)
                       + c.kv_lora_rank * H * (c.nope_head_dim + c.v_head_dim)
                       + H * c.v_head_dim * d)
            else:
                att = d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.moe is not None:
                e = self.moe
                mult = 3 if self.act == "swiglu" else 2
                ffn = (e.num_experts * mult * d * e.d_ff_expert
                       + e.num_shared * mult * d * max(e.d_ff_shared, e.d_ff_expert)
                       + d * e.num_experts)
            else:
                mult = 3 if self.act == "swiglu" else 2
                ffn = mult * d * f
            ssm = 0
            if self.ssm is not None:
                di = self.ssm.expand * d
                ssm = 2 * d * di + di * self.ssm.conv_width + di * (2 * self.ssm.state_dim) + di * d
            core = L * (att + ffn + ssm)
        emb = V * d * (1 if self.tie_embeddings else 2)
        enc = 0
        if self.enc_dec:
            enc = self.enc_layers * (4 * d * d + (3 if self.act == "swiglu" else 2) * d * f)
            core += L * 2 * d * d  # cross-attn kv/out approx
        return core + emb + enc

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        mult = 3 if self.act == "swiglu" else 2
        full_ffn = e.num_experts * mult * self.d_model * e.d_ff_expert
        act_ffn = (e.top_k + e.num_shared) * mult * self.d_model * e.d_ff_expert
        return self.param_count() - self.num_layers * (full_ffn - act_ffn)


@dataclasses.dataclass(frozen=True)
class LexicoConfig:
    """Technique config (paper defaults: N=4096, n_b=128, n_a=1, fp8 codec)."""
    gram_dtype: str = "float32"   # 'bfloat16' halves stored-Gram traffic
    N: int = 4096
    s: int = 16
    n_b: int = 128
    n_a: int = 1
    delta: float = 0.0            # 0 = fixed sparsity; >0 = error-threshold mode
    codec: str = "fp8"            # fp8 | int8 | fp16
    use_gram: bool = True
    chunk: Optional[int] = 2048   # flash-decode chunk; None = paper-faithful
    enabled: bool = True

    @property
    def val_dtype(self):
        return {"fp8": jnp.float8_e4m3fn, "int8": jnp.int8, "fp16": jnp.bfloat16}[self.codec]


SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}
