"""whisper-tiny [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865. The mel/conv frontend is a
STUB per the assignment: input_specs() provides precomputed frame embeddings
(B, T_frames, d_model). Whisper uses LayerNorm + GELU, learned positional
embeddings on the decoder, sinusoidal on the encoder, no RoPE.
Lexico compresses the decoder self-attention cache and the (once-computed)
cross-attention KV.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    norm="layernorm", act="gelu", use_rope=False,
    enc_dec=True, enc_layers=4, enc_max_frames=1500,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, norm="layernorm", act="gelu", use_rope=False,
        enc_dec=True, enc_layers=2, enc_max_frames=32, param_dtype="float32",
    )
