"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. StarCoder2 uses
LayerNorm + GELU MLPs (not RMSNorm/SwiGLU).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    norm="layernorm", act="gelu", rope_theta=100_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, norm="layernorm", act="gelu",
        param_dtype="float32",
    )
