"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936,
MoE 128e top-8, qk-norm. No shared experts (Qwen3 MoE).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    head_dim=128,
    d_ff=1536, vocab_size=151_936, qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536, num_shared=0),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=96, vocab_size=256, qk_norm=True, param_dtype="float32",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96, num_shared=0,
                      capacity_factor=4.0),
    )
