"""chameleon-34b [vlm] — early-fusion, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The VQ image
tokenizer is a stub per the assignment: image patches arrive as token ids in
the shared vocab (early fusion), so the backbone is a decoder-only
transformer with qk-norm (Chameleon's stability fix).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=65536, qk_norm=True,
    rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, qk_norm=True, param_dtype="float32",
    )
