"""Architecture registry: ``get(name)`` / ``get_smoke(name)`` / ``ARCHS``."""
from __future__ import annotations

import importlib

from repro.configs.base import LexicoConfig, MLAConfig, ModelConfig, MoEConfig, RWKVConfig, SSMConfig, SHAPES

_MODULES = {
    "chameleon-34b": "chameleon_34b",
    "qwen3-0.6b": "qwen3_0_6b",
    "llama3.2-1b": "llama3_2_1b",
    "mistral-large-123b": "mistral_large_123b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "hymba-1.5b": "hymba_1_5b",
    "whisper-tiny": "whisper_tiny",
    "rwkv6-3b": "rwkv6_3b",
}
ARCHS = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).smoke_config()


__all__ = ["ARCHS", "SHAPES", "get", "get_smoke", "ModelConfig", "LexicoConfig",
           "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig"]
