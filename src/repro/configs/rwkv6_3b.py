"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536. Head dim 64
(40 heads). No KV cache exists — Lexico is inapplicable (recorded in
DESIGN.md §Arch-applicability); the serve path carries the constant-size
wkv state, so long_500k decode runs at O(1) memory per token.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    attn_free=True, rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
    norm="layernorm",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, param_dtype="float32",
        attn_free=True, rwkv=RWKVConfig(head_dim=16, decay_lora=8, mix_lora=8),
        norm="layernorm",
    )
