"""Multi-head Latent Attention (DeepSeek-V2) with Lexico over the latents.

MLA caches one vector per token: the low-rank latent ``c_kv`` (kv_lora_rank)
concatenated with the shared RoPE key ``k_pe`` (rope_head_dim). With query-side
absorption (fold W_uk into the query) the decode score is

    score = (q_nope·W_ukᵀ) · c_kv + q_pe · k_pe = q_eff · (c_kv ‖ k_pe)

so the *cached vector itself* is what attention dots against — which means
Lexico composes perfectly: one dictionary over R^{kv_lora+rope} encodes the
latent, the qD trick works on ``q_eff``, and the value read-out decodes the
probability-weighted *coefficients* back through D[:kv_lora] before the W_uv
up-projection. One OMP per token total (vs two for standard K/V caches).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.core import omp as omp_mod
from repro.core.attention import NEG_INF, compressed_scores, scatter_coeffs
from repro.models.attention import blocked_attention
from repro.models.layers import dense_init, rmsnorm
from repro.models.rope import apply_rope

Array = jax.Array


def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    c = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    qd = c.nope_head_dim + c.rope_head_dim
    return {
        "w_q": dense_init(ks[0], d, H * qd, dtype),
        "w_dkv": dense_init(ks[1], d, c.kv_lora_rank + c.rope_head_dim, dtype),
        "kv_norm": jnp.ones((c.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[2], c.kv_lora_rank, H * c.nope_head_dim, dtype),
        "w_uv": dense_init(ks[3], c.kv_lora_rank, H * c.v_head_dim, dtype),
        "w_o": dense_init(ks[4], H * c.v_head_dim, d, dtype),
    }


def _project(p: dict, x: Array, cfg: ModelConfig, positions: Array):
    """Shared q / latent computation. x (B, T, d) -> q_nope (B,T,H,nope),
    q_pe (B,T,H,rope), latent (B,T, kv_lora+rope) with RoPE+norm applied.

    ``positions``: (T,) shared across the batch (train/prefill), or (B, T)
    per batch element (decode with heterogeneous slot lengths)."""
    c = cfg.mla
    B, T, d = x.shape
    H = cfg.num_heads
    q = (x @ p["w_q"]).reshape(B, T, H, c.nope_head_dim + c.rope_head_dim)
    q_nope, q_pe = q[..., :c.nope_head_dim], q[..., c.nope_head_dim:]
    pos_q = positions if positions.ndim == 1 else positions[:, None]  # (B,1,T)
    q_pe = apply_rope(jnp.moveaxis(q_pe, 2, 1), pos_q, cfg.rope_theta)
    q_pe = jnp.moveaxis(q_pe, 1, 2)

    dkv = x @ p["w_dkv"]
    c_kv = rmsnorm(dkv[..., :c.kv_lora_rank], p["kv_norm"])
    k_pe = dkv[..., c.kv_lora_rank:]
    k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
    latent = jnp.concatenate([c_kv, k_pe], axis=-1)   # (B, T, kv_lora+rope)
    return q_nope, q_pe, latent


def mla_train_forward(p: dict, x: Array, cfg: ModelConfig, positions: Array) -> Array:
    """Training / prefill attention (non-absorbed, flash-blocked). Returns
    (attn_out (B,T,d), latent (B,T,lat_dim)) — latent is what prefill caches."""
    c = cfg.mla
    B, T, d = x.shape
    H = cfg.num_heads
    q_nope, q_pe, latent = _project(p, x, cfg, positions)
    c_kv, k_pe = latent[..., :c.kv_lora_rank], latent[..., c.kv_lora_rank:]

    k_nope = (c_kv @ p["w_uk"]).reshape(B, T, H, c.nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, T, H, c.v_head_dim)
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, T, H, c.rope_head_dim))

    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)   # (B,T,H,qd)
    k_full = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    # layout (B, KV=H, G=1, T, hd)
    qx = jnp.moveaxis(q_full, 2, 1)[:, :, None]
    kx = jnp.moveaxis(k_full, 2, 1)
    vx = jnp.moveaxis(v, 2, 1)
    out = blocked_attention(qx, kx, vx, causal=True)[:, :, 0]   # (B,H,T,v_hd)
    out = jnp.moveaxis(out, 1, 2).reshape(B, T, H * c.v_head_dim)
    return out @ p["w_o"], latent


class MLACache(NamedTuple):
    """Lexico-compressed latent cache. One code per token (no separate K/V)."""
    vals: Array      # (B, T_max, s) storage dtype
    idx: Array       # (B, T_max, s) int16
    buf: Array       # (B, n_b, lat_dim) bf16
    t_c: Array       # (B,) int32
    buf_len: Array   # (B,) int32
    buf_start: Array  # (B,) int32


def init_mla_cache(batch: int, lat_dim: int, *, t_max: int, n_b: int, s: int,
                   val_dtype=jnp.float8_e4m3fn, buf_dtype=jnp.bfloat16) -> MLACache:
    zc = jnp.zeros((batch,), jnp.int32)
    return MLACache(
        vals=jnp.zeros((batch, t_max, s), val_dtype),
        idx=jnp.zeros((batch, t_max, s), jnp.int16),
        buf=jnp.zeros((batch, n_b, lat_dim), buf_dtype),
        t_c=zc, buf_len=zc, buf_start=zc)


def mla_prefill_compress(cache: MLACache, latent: Array, D: Array, *, s: int,
                         use_gram: bool = True, delta: float = 0.0, G=None,
                         s_cap=None) -> MLACache:
    B, T, lat = latent.shape
    n_b = cache.buf.shape[1]
    n_comp = T - n_b
    head, tail = latent[:, :n_comp], latent[:, n_comp:]
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)[:, None]
    r = omp_mod.omp_batch(head.astype(jnp.float32), D, s, use_gram=use_gram,
                          delta=delta, G=G, s_cap=cap)
    B = latent.shape[0]
    vals = jax.lax.dynamic_update_slice(
        cache.vals, r.vals.astype(cache.vals.dtype), (0, 0, 0))
    idx = jax.lax.dynamic_update_slice(
        cache.idx, r.idx.astype(jnp.int16), (0, 0, 0))
    fill = lambda v: jnp.full((B,), v, jnp.int32)
    return cache._replace(vals=vals, idx=idx, buf=tail.astype(cache.buf.dtype),
                          t_c=fill(n_comp), buf_len=fill(n_b),
                          buf_start=fill(0))


def mla_decode_update(cache: MLACache, latent_t: Array, D: Array, *, s: int,
                      use_gram: bool = True, delta: float = 0.0, G=None,
                      active=None, s_cap=None) -> MLACache:
    """latent_t (B, lat_dim): append to ring; compress evictee (n_a = 1).
    Per-row bookkeeping: see ``sparse_cache.decode_update``."""
    B, lat = latent_t.shape
    n_b = cache.buf.shape[1]
    b_idx = jnp.arange(B)
    act = (jnp.ones((B,), jnp.bool_) if active is None
           else jnp.asarray(active, jnp.bool_))
    full = cache.buf_len >= n_b
    evict = full & act
    old = cache.buf[b_idx, cache.buf_start]                 # (B, lat)
    cap = None if s_cap is None else jnp.asarray(s_cap, jnp.int32)
    r = omp_mod.omp_batch(old.astype(jnp.float32), D, s, use_gram=use_gram,
                          delta=delta, G=G, s_cap=cap)

    t_w = jnp.clip(cache.t_c, 0, cache.vals.shape[1] - 1)

    def store(arr, new):
        cur = arr[b_idx, t_w]                               # (B, s)
        payload = jnp.where(evict[:, None], new.astype(arr.dtype), cur)
        return arr.at[b_idx, t_w].set(payload)

    vals = store(cache.vals, r.vals)
    idx = store(cache.idx, r.idx.astype(jnp.int16))
    t_c = jnp.where(evict, cache.t_c + 1, cache.t_c)
    write_pos = jnp.where(full, cache.buf_start, cache.buf_len)
    cur = cache.buf[b_idx, write_pos]
    buf = cache.buf.at[b_idx, write_pos].set(
        jnp.where(act[:, None], latent_t.astype(cache.buf.dtype), cur))
    return cache._replace(
        vals=vals, idx=idx, buf=buf, t_c=t_c,
        buf_len=jnp.where(act & ~full, cache.buf_len + 1, cache.buf_len),
        buf_start=jnp.where(evict, (cache.buf_start + 1) % n_b, cache.buf_start))


def mla_decode_step(
    p: dict, cache: MLACache, x_t: Array, cfg: ModelConfig, position: Array,
    D: Array, *, N: int, s: int, use_gram: bool = True, delta: float = 0.0,
    chunk: Optional[int] = None, G=None, active=None, s_cap=None,
) -> Tuple[Array, MLACache]:
    """One decode step: project, insert the latent (Algorithm 2 order —
    the new token attends to itself via the buffer), absorbed attention.

    x_t (B, d); position scalar or (B,). Returns (attn_out (B, d), new cache)."""
    c = cfg.mla
    B, d = x_t.shape
    H = cfg.num_heads
    position = jnp.asarray(position)
    pos_bt = (position[:, None] if position.ndim == 1
              else jnp.broadcast_to(position[None, None], (B, 1)))   # (B, 1)
    q_nope, q_pe, latent = _project(p, x_t[:, None], cfg, pos_bt)
    q_nope, q_pe = q_nope[:, 0], q_pe[:, 0]        # (B,H,nope), (B,H,rope)
    cache = mla_decode_update(cache, latent[:, 0], D, s=s,
                              use_gram=use_gram, delta=delta, G=G,
                              active=active, s_cap=s_cap)

    # absorption: q_lat = q_nope @ W_uk^T  (per head)
    w_uk = p["w_uk"].reshape(c.kv_lora_rank, H, c.nope_head_dim)
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    q_eff = jnp.concatenate([q_lat, q_pe.astype(jnp.float32)], axis=-1)  # (B,H,lat_dim)
    scale = 1.0 / jnp.sqrt(jnp.float32(c.nope_head_dim + c.rope_head_dim))

    # layout (B, KV=1, G=H, ·)
    qd = jnp.einsum("bhl,ln->bhn", q_eff, D.astype(jnp.float32))[:, None]  # (B,1,H,N)
    from repro.core.attention import per_batch
    t_cb, buf_lenb = per_batch(cache.t_c), per_batch(cache.buf_len)
    s_c = compressed_scores(qd, cache.vals[:, None], cache.idx[:, None], scale=scale)
    T = cache.vals.shape[1]
    s_c = jnp.where(jnp.arange(T)[None, None, None, :] < t_cb, s_c, NEG_INF)

    buf = cache.buf.astype(jnp.float32)            # (B, n_b, lat)
    s_b = jnp.einsum("bhl,brl->bhr", q_eff, buf)[:, None] * scale
    n_b = buf.shape[1]
    s_b = jnp.where(jnp.arange(n_b)[None, None, None, :] < buf_lenb, s_b, NEG_INF)

    pfull = jax.nn.softmax(jnp.concatenate([s_c, s_b], axis=-1), axis=-1)
    p_c, p_b = pfull[..., :T], pfull[..., T:]

    # value read-out: accumulate coefficients, decode through D[:kv_lora], W_uv
    coeff = scatter_coeffs(p_c, cache.vals[:, None], cache.idx[:, None], N)  # (B,1,H,N)
    lat_acc = jnp.einsum("bhn,ln->bhl", coeff[:, 0], D[:c.kv_lora_rank].astype(jnp.float32))
    lat_acc = lat_acc + jnp.einsum("bhr,brl->bhl", p_b[:, 0], buf[..., :c.kv_lora_rank])
    w_uv = p["w_uv"].reshape(c.kv_lora_rank, H, c.v_head_dim)
    out = jnp.einsum("bhl,lhv->bhv", lat_acc, w_uv.astype(jnp.float32))
    out = out.reshape(B, H * c.v_head_dim).astype(x_t.dtype)
    return out @ p["w_o"], cache
