from repro.models.model import (
    init_params, forward_train, lm_loss, prefill, decode_step, init_serve_cache,
)
