"""Blocked (flash-style) attention for training / prefill.

Double-blocked online-softmax attention in pure jnp: the query axis is split
into chunks (lax.map), and for each query chunk an inner lax.scan walks KV
chunks accumulating (m, l, acc) — standard flash recurrence. Peak memory is
O(Cq*Ck) per (batch, head) instead of O(T^2). Supports causal masking,
sliding windows (hymba), and non-causal encoders (whisper).

GQA layout: q (B, KV, G, T, hd), k/v (B, KV, T, hd).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def blocked_attention(
    q: Array, k: Array, v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    q_offset: int = 0,
    remat_blocks: bool = True,
    probs_bf16: bool = False,
) -> Array:
    """Returns (B, KV, G, T_q, hd) in q.dtype.

    ``q_offset``: absolute position of q[..., 0, :] relative to k[..., 0, :]
    (used when queries are a suffix of the cached sequence).

    ``remat_blocks``: checkpoint each kv block — the backward pass recomputes
    the block's (Cq x Ck) probabilities from the carried statistics instead
    of saving them. Without it, training saves O(T^2) probabilities per layer
    (flash-backward-style memory fix; see EXPERIMENTS.md §Perf).
    """
    B, KV, G, Tq, hd = q.shape
    hd_v = v.shape[-1]           # may differ from hd (MLA)
    Tk = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def _pick(T, c):
        """Largest divisor of T that is <= c (chunk must tile T exactly)."""
        c = min(c, T)
        while T % c != 0:
            c -= 1
        return c

    q_chunk = _pick(Tq, q_chunk)
    kv_chunk = _pick(Tk, kv_chunk)
    nq, nk = Tq // q_chunk, Tk // kv_chunk

    qf = (q.astype(jnp.float32) * scale).reshape(B, KV, G, nq, q_chunk, hd)
    kc = jnp.moveaxis(k.astype(jnp.float32).reshape(B, KV, nk, kv_chunk, hd), 2, 0)
    vc = jnp.moveaxis(v.astype(jnp.float32).reshape(B, KV, nk, kv_chunk, hd_v), 2, 0)

    def q_block(args):
        qb, qi = args                                   # (B,KV,G,Cq,hd), scalar
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, xs):
            m_run, l_run, acc = carry
            kb, vb, ki = xs
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            if probs_bf16:
                # bf16 score/prob tensors: halves the dominant (Cq x Ck) HBM
                # traffic of long prefill; stats stay f32 (see §Perf)
                s = jnp.einsum("bkgqh,bkch->bkgqc", qb.astype(jnp.bfloat16),
                               kb.astype(jnp.bfloat16),
                               preferred_element_type=jnp.bfloat16)
                s = s.astype(jnp.float32)
            else:
                s = jnp.einsum("bkgqh,bkch->bkgqc", qb, kb)  # (B,KV,G,Cq,Ck)
            mask = jnp.ones((q_chunk, kv_chunk), jnp.bool_)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            if probs_bf16:
                p = p.astype(jnp.bfloat16)
                vb = vb.astype(jnp.bfloat16)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p, vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, hd_v), jnp.float32))
        step = jax.checkpoint(kv_step) if remat_blocks else kv_step
        (m_run, l_run, acc), _ = jax.lax.scan(
            step, init, (kc, vc, jnp.arange(nk)))
        return acc / jnp.maximum(l_run, 1e-30)[..., None]

    out = jax.lax.map(q_block, (jnp.moveaxis(qf, 3, 0), jnp.arange(nq)))
    out = jnp.moveaxis(out, 0, 3).reshape(B, KV, G, Tq, hd_v)
    return out.astype(q.dtype)


def dense_decode_attention(
    q: Array,              # (B, KV, G, hd) single new token
    k_cache: Array, v_cache: Array,   # (B, KV, T, hd)
    *,
    length: Array,         # int32 valid cache entries — scalar or (B,)
    window: Optional[int] = None,
) -> Array:
    """Full-precision decode attention (baseline / buffer-only path)."""
    from repro.core.attention import per_batch
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bkgh,bkth->bkgt", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    T = k_cache.shape[2]
    length = per_batch(length)
    pos = jnp.arange(T)
    valid = pos[None, None, None, :] < length
    if window is not None:
        valid &= pos[None, None, None, :] >= (length - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgt,bkth->bkgh", p, v_cache.astype(jnp.float32))
