"""Pluggable KV-cache policies.

The serving stack treats the KV cache as a policy object with four methods —
``init / prefill / decode / attend`` — so Lexico, full-precision, KIVI-style
quantization, and eviction baselines all run through the *same* model code
(this is how the paper's comparison tables are produced, and how a deployment
would switch policies per request class).

All caches are per-layer pytrees with static shapes; the model stacks them
along a leading layer axis and scans. ``ctx`` carries per-layer extras (the
Lexico dictionaries ``(D_k, D_v)``); policies that don't need it ignore it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Protocol

import jax
import jax.numpy as jnp

from repro.configs.base import LexicoConfig
from repro.core import sparse_cache as sc

Array = jax.Array


class CachePolicy(Protocol):
    """``decode`` accepts ``active`` (B,) bool — rows set False must be left
    unchanged (idle slots of the continuous-batching pool) — and may accept
    ``s_cap`` (B,) per-request sparsity tiers (Lexico only). ``length`` is
    per batch element: (B,) int32."""

    def init(self, batch: int, kv_heads: int, head_dim: int, t_max: int) -> Any: ...
    def prefill(self, cache: Any, K: Array, V: Array, ctx: Any) -> Any: ...
    def decode(self, cache: Any, k_t: Array, v_t: Array, ctx: Any, *,
               active: Optional[Array] = None, s_cap: Optional[Array] = None) -> Any: ...
    def attend(self, cache: Any, q: Array, ctx: Any, *, window=None) -> Array: ...
    def length(self, cache: Any) -> Array: ...


# ---------------------------------------------------------------------------
# Lexico (the paper)
# ---------------------------------------------------------------------------

class LexicoPolicy:
    """The paper's policy: OMP sparse codes + recency buffer.

    ``omp_backend`` selects the prefill encoder implementation (see
    ``repro.core.omp.omp_batch(backend=)``): ``"ref"`` (default, vmapped
    oracle), ``"fused"`` (tile-batched early-exit encoder, Pallas selection
    on TPU) or ``"fused_kernel"`` (selection kernels forced, interpret mode
    off-TPU). Decode's single-evictee encode always uses the ref path — its
    batch is one vector per slot and the vmap form is already optimal there.
    """

    def __init__(self, cfg: LexicoConfig, *, omp_backend: str = "ref"):
        self.cfg = cfg
        self.omp_backend = omp_backend

    def init(self, batch, kv_heads, head_dim, t_max):
        c = self.cfg
        return sc.init_layer_cache(
            batch, kv_heads, head_dim,
            t_max=max(t_max - c.n_b, 1), n_b=c.n_b, s=c.s, val_dtype=c.val_dtype)

    @staticmethod
    def _unpack(ctx):
        if len(ctx) == 4:
            return ctx
        D_k, D_v = ctx
        return D_k, D_v, None, None

    def prefill(self, cache, K, V, ctx, *, s_cap=None, start=0,
                return_quality=False):
        """Compress prompt K/V ``(B, KV, T, m)`` into ``cache``.

        ``s_cap`` (B,) caps per-row sparsity tiers; ``start`` (static int)
        restarts compression at that compressed position (prefix sharing) —
        positions below it are left untouched. ``return_quality`` (static
        bool) returns ``(cache, qual)`` with the encode-quality aux (see
        ``sc.prefill_compress``); cache contents are identical either way.
        """
        D_k, D_v, G_k, G_v = self._unpack(ctx)
        return sc.prefill_compress(cache, K, V, D_k, D_v, s=self.cfg.s,
                                   use_gram=self.cfg.use_gram, delta=self.cfg.delta,
                                   G_k=G_k, G_v=G_v, s_cap=s_cap, start=start,
                                   omp_backend=self.omp_backend,
                                   return_quality=return_quality)

    def decode(self, cache, k_t, v_t, ctx, *, active=None, s_cap=None,
               return_quality=False):
        D_k, D_v, G_k, G_v = self._unpack(ctx)
        return sc.decode_update(cache, k_t, v_t, D_k, D_v, s=self.cfg.s,
                                use_gram=self.cfg.use_gram, delta=self.cfg.delta,
                                G_k=G_k, G_v=G_v, active=active, s_cap=s_cap,
                                return_quality=return_quality)

    def attend(self, cache, q, ctx, *, window=None):
        D_k, D_v = ctx[0], ctx[1]
        return sc.attend(cache, q, D_k, D_v, N=self.cfg.N,
                         chunk=self.cfg.chunk, window=window)

    def length(self, cache):
        return cache.t_c + cache.buf_len


class PagedLexicoPolicy:
    """Lexico over paged slot storage (``sc.PagedLexicoLayerCache``).

    Same OMP encoder and attention math as :class:`LexicoPolicy`; only the
    sparse-store layout differs — a shared ``(n_pages, KV, page_size, s)``
    pool plus per-row page tables, so a serving pool's real footprint is the
    pages actually held, not ``B`` padded stripes. Page placement is host
    business (``repro.serving.pages`` + ``repro.serving.slots``); this policy
    only reads/writes through whatever tables the cache carries.

    ``prefill`` scatters through the cache's *existing* page tables — callers
    must install row tables first. The serving engine never uses it: it
    prefills at B=1 through the contiguous oracle and splices pages in via
    ``slots.write_slot_paged``.
    """

    def __init__(self, cfg: LexicoConfig, *, n_pages: int, page_size: int,
                 fused: bool = False, fused_force_kernel: bool = False,
                 omp_backend: str = "ref"):
        self.cfg = cfg
        self.n_pages = n_pages
        self.page_size = page_size
        # fused=True: attend computes directly from the packed pool codes via
        # the paged sparse-attention kernel path (no gather_pages copy);
        # fused_force_kernel additionally pins the Pallas kernel (interpret
        # mode off-TPU) instead of the jnp oracle.
        self.fused = fused
        self.fused_force_kernel = fused_force_kernel
        # prefill encoder backend — same contract as LexicoPolicy
        self.omp_backend = omp_backend

    def max_pages_for(self, t_max: int) -> int:
        """Page-table width covering a slot of ``t_max`` tokens (t_max - n_b
        compressed positions; the rest live in the ring buffer)."""
        t_comp = max(t_max - self.cfg.n_b, 1)
        return -(-t_comp // self.page_size)

    def init(self, batch, kv_heads, head_dim, t_max):
        c = self.cfg
        return sc.init_paged_layer_cache(
            batch, kv_heads, head_dim, n_pages=self.n_pages,
            page_size=self.page_size, max_pages=self.max_pages_for(t_max),
            n_b=c.n_b, s=c.s, val_dtype=c.val_dtype)

    _unpack = staticmethod(LexicoPolicy._unpack)

    def prefill(self, cache, K, V, ctx, *, s_cap=None, start=0,
                return_quality=False):
        """Paged twin of :meth:`LexicoPolicy.prefill`: scatters through the
        cache's existing page tables. ``start`` must be page-aligned when the
        skipped prefix aliases pages owned by other rows."""
        D_k, D_v, G_k, G_v = self._unpack(ctx)
        return sc.paged_prefill_compress(
            cache, K, V, D_k, D_v, s=self.cfg.s, use_gram=self.cfg.use_gram,
            delta=self.cfg.delta, G_k=G_k, G_v=G_v, s_cap=s_cap, start=start,
            omp_backend=self.omp_backend, return_quality=return_quality)

    def decode(self, cache, k_t, v_t, ctx, *, active=None, s_cap=None,
               return_quality=False):
        D_k, D_v, G_k, G_v = self._unpack(ctx)
        return sc.paged_decode_update(
            cache, k_t, v_t, D_k, D_v, s=self.cfg.s, use_gram=self.cfg.use_gram,
            delta=self.cfg.delta, G_k=G_k, G_v=G_v, active=active, s_cap=s_cap,
            return_quality=return_quality)

    def attend(self, cache, q, ctx, *, window=None):
        D_k, D_v = ctx[0], ctx[1]
        return sc.paged_attend(cache, q, D_k, D_v, N=self.cfg.N,
                               chunk=self.cfg.chunk, window=window,
                               fused=self.fused,
                               fused_force_kernel=self.fused_force_kernel)

    def length(self, cache):
        return cache.t_c + cache.buf_len


# ---------------------------------------------------------------------------
# Full-precision baseline
# ---------------------------------------------------------------------------

class DenseCache(NamedTuple):
    k: Array       # (B, KV, T_max, hd)
    v: Array
    length: Array  # (B,) int32


class DensePolicy:
    """FP16/BF16 full cache — the paper's 'Full Cache' row."""

    def __init__(self, dtype=jnp.bfloat16):
        self.dtype = dtype

    def init(self, batch, kv_heads, head_dim, t_max):
        z = jnp.zeros((batch, kv_heads, t_max, head_dim), self.dtype)
        return DenseCache(k=z, v=z, length=jnp.zeros((batch,), jnp.int32))

    def prefill(self, cache, K, V, ctx):
        B, _, T, _ = K.shape
        k = jax.lax.dynamic_update_slice(cache.k, K.astype(self.dtype), (0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, V.astype(self.dtype), (0, 0, 0, 0))
        return DenseCache(k=k, v=v, length=jnp.full((B,), T, jnp.int32))

    def decode(self, cache, k_t, v_t, ctx, *, active=None, s_cap=None):
        B = k_t.shape[0]
        b_idx = jnp.arange(B)
        act = (jnp.ones((B,), jnp.bool_) if active is None
               else jnp.asarray(active, jnp.bool_))
        pos = jnp.clip(cache.length, 0, cache.k.shape[2] - 1)

        def put(buf, x_t):
            cur = buf[b_idx, :, pos]
            payload = jnp.where(act[:, None, None], x_t.astype(self.dtype), cur)
            return buf.at[b_idx, :, pos].set(payload)

        return DenseCache(k=put(cache.k, k_t), v=put(cache.v, v_t),
                          length=cache.length + act.astype(jnp.int32))

    def attend(self, cache, q, ctx, *, window=None):
        from repro.models.attention import dense_decode_attention
        return dense_decode_attention(q, cache.k, cache.v,
                                      length=cache.length, window=window)

    def length(self, cache):
        return cache.length


def make_policy(name: str, lex_cfg: Optional[LexicoConfig] = None, **kw) -> CachePolicy:
    if name == "lexico":
        return LexicoPolicy(lex_cfg or LexicoConfig())
    if name == "lexico_paged":
        return PagedLexicoPolicy(lex_cfg or LexicoConfig(), **kw)
    if name == "dense":
        return DensePolicy(**kw)
    # quantization / eviction baselines
    from repro.baselines import kivi, per_token_quant, eviction
    if name == "kivi":
        return kivi.KIVIPolicy(**kw)
    if name == "per_token":
        return per_token_quant.PerTokenQuantPolicy(**kw)
    if name == "eviction":
        return eviction.EvictionPolicy(**kw)
    raise KeyError(name)
