"""Rotary position embeddings (half-rotation convention, llama-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., T, hd), positions (T,) or broadcastable to x[..., :, 0]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
