"""Composable model zoo: init / train-forward / prefill / decode for all ten
assigned architectures, with Lexico (or any CachePolicy) as the serving cache.

Design rules:
  * scan-over-layers everywhere — per-layer params/caches/dicts are stacked on
    a leading (L,) axis and consumed as lax.scan xs, so HLO size (and compile
    time) is O(1) in depth. Layer-varying behaviour (hymba's global-attention
    layers) rides along as an (L,) flag array.
  * pure functions over param pytrees; dtypes from cfg.param_dtype.
  * one code path per family: attention-stack (dense/vlm/moe/hybrid),
    MLA (deepseek), RWKV (attn-free), enc-dec (whisper).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LexicoConfig, ModelConfig
from repro.core.attention import NEG_INF, compressed_scores, scatter_coeffs
from repro.core.dictionary import DictionaryBank
from repro.core import omp as omp_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import blocked_attention
from repro.models.cache_policy import CachePolicy, DensePolicy, LexicoPolicy
from repro.models.layers import (
    dense_init, embed_init, mlp_apply, mlp_init, norm_apply, norm_init, rmsnorm,
    sinusoidal_pos,
)
from repro.models.rope import apply_rope

Array = jax.Array
BIG_WINDOW = jnp.int32(1 << 30)


def _abstract_mesh():
    """Version-tolerant current-mesh lookup (None when no mesh is active).

    ``jax.sharding.get_abstract_mesh`` only exists on newer JAX; older
    releases keep it in ``jax._src.mesh`` and return an empty tuple when no
    mesh context is set.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        try:
            from jax._src.mesh import get_abstract_mesh as get  # type: ignore
        except ImportError:
            return None
    try:
        am = get()
    except Exception:
        return None
    if am is None or not hasattr(am, "axis_names"):
        return None
    if getattr(am, "empty", False) or not am.axis_names:
        return None
    return am


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def shard_hint(x: Array, *entries) -> Array:
    """Activation-sharding constraint that is a no-op outside a mesh context.

    Without explicit activation hints XLA's sharding propagation can decide to
    replicate the batch across the 'data' axis (observed: the embedding gather
    output loses the batch sharding and the whole backbone runs replicated —
    16x the memory/flops per device). Entries use axis names; axes missing
    from the active mesh, or that don't divide the dim, are dropped.
    """
    am = _abstract_mesh()
    if am is None:
        return x
    names = set(am.axis_names)

    def ok(axes, dim):
        axes = axes if isinstance(axes, tuple) else (axes,)
        axes = tuple(a for a in axes if a in names)
        if not axes:
            return None
        size = 1
        for a in axes:
            size *= am.shape[a]
        if dim % size != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    cleaned = [None if e is None else ok(e, x.shape[i])
               for i, e in enumerate(entries)]
    if all(c is None for c in cleaned):
        return x
    from jax.sharding import PartitionSpec as _P
    return jax.lax.with_sharding_constraint(x, _P(*cleaned))


BATCH_AXES = ("pod", "data")


# ===========================================================================
# Parameter init
# ===========================================================================

def _init_attn(key, cfg: ModelConfig, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, KV * hd, dtype),
        "wv": dense_init(ks[2], d, KV * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _init_layer(key, cfg: ModelConfig, dtype, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": norm_init(cfg.norm, cfg.d_model, dtype),
                         "ln2": norm_init(cfg.norm, cfg.d_model, dtype)}
    if cfg.rwkv is not None:
        p["rwkv"] = ssm_mod.rwkv_init(ks[0], cfg, dtype)
        return p
    if cfg.mla is not None:
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    if cfg.parallel_ssm:
        p["ssm"] = ssm_mod.mamba_init(ks[1], cfg, dtype)
        p["attn_out_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["ssm_out_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.moe is not None:
        p["mlp"] = moe_mod.moe_init(ks[2], cfg.d_model, cfg.moe, cfg.act, dtype)
    else:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    if cross:
        p["ln_cross"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["cross"] = _init_attn(ks[3], cfg, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = _dtype(cfg)
    k_emb, k_layers, k_head, k_enc, k_meta, k_pos = jax.random.split(key, 6)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype, cross=cfg.enc_dec))(layer_keys)
    params: Dict[str, Any] = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.num_meta_tokens:
        params["meta"] = (jax.random.normal(k_meta, (cfg.num_meta_tokens, cfg.d_model),
                                            jnp.float32) * 0.02).astype(dtype)
    if cfg.enc_dec:
        enc_keys = jax.random.split(k_enc, cfg.enc_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: _init_layer(k, cfg, dtype))(enc_keys),
            "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        }
        params["pos_embed"] = (jax.random.normal(k_pos, (cfg.max_seq_len if cfg.max_seq_len
                                                         < 65536 else 65536, cfg.d_model),
                                                 jnp.float32) * 0.02).astype(dtype)
    return params


def init_dictionary_bank(key, cfg: ModelConfig, lex_cfg: LexicoConfig) -> Optional[DictionaryBank]:
    """Per-layer dictionaries sized for what this arch actually caches.
    When ``lex_cfg.use_gram``, the Grams are precomputed and stored (the
    paper's offline Cholesky setup)."""
    if cfg.attn_free or not lex_cfg.enabled:
        return None
    from repro.core.dictionary import init_dictionary
    roles = 1 if cfg.mla is not None else 2
    m = cfg.cached_vector_dim
    keys = jax.random.split(key, cfg.num_layers * roles)
    D = jax.vmap(lambda k: init_dictionary(k, m, lex_cfg.N))(keys)
    D = D.reshape(cfg.num_layers, roles, m, lex_cfg.N)
    G = None
    if lex_cfg.use_gram:
        G = jnp.einsum("lrmn,lrmp->lrnp", D, D).astype(
            jnp.dtype(lex_cfg.gram_dtype))
    return DictionaryBank(D=D, G=G)


# ===========================================================================
# Attention sublayer (sequence form, GQA + qk-norm + RoPE)
# ===========================================================================

def _qkv_seq(lp: dict, cfg: ModelConfig, x: Array, positions: Array):
    B, T, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    q = (x @ lp["wq"]).reshape(B, T, KV, G, hd)
    k = (x @ lp["wk"]).reshape(B, T, KV, hd)
    v = (x @ lp["wv"]).reshape(B, T, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, lp["q_norm"])
        k = rmsnorm(k, lp["k_norm"])
    q = jnp.transpose(q, (0, 2, 3, 1, 4))          # (B,KV,G,T,hd)
    k = jnp.transpose(k, (0, 2, 1, 3))             # (B,KV,T,hd)
    v = jnp.transpose(v, (0, 2, 1, 3))
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_seq(lp: dict, cfg: ModelConfig, x: Array, positions: Array,
             window=None, *, causal: bool = True,
             kv_override: Optional[Tuple[Array, Array]] = None) -> Tuple[Array, Array, Array]:
    """Full-sequence attention sublayer. Returns (out (B,T,d), k, v)."""
    B, T, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q, k, v = _qkv_seq(lp, cfg, x, positions)
    if kv_override is not None:
        k, v = kv_override
    out = blocked_attention(q, k, v, causal=causal, window=window,
                            probs_bf16=cfg.attn_probs_bf16)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, T, H * hd)
    return out @ lp["wo"], k, v


def _qkv_step(lp: dict, cfg: ModelConfig, x_t: Array, position: Array):
    """Single-token QKV. ``position``: scalar (lockstep batch) or (B,)
    per-slot absolute positions (continuous batching)."""
    B, d = x_t.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    q = (x_t @ lp["wq"]).reshape(B, KV, G, hd)
    k = (x_t @ lp["wk"]).reshape(B, KV, hd)
    v = (x_t @ lp["wv"]).reshape(B, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, lp["q_norm"])
        k = rmsnorm(k, lp["k_norm"])
    if cfg.use_rope:
        pos = jnp.asarray(position)
        if pos.ndim == 1:
            pos_q, pos_k = pos.reshape(B, 1, 1, 1), pos.reshape(B, 1, 1)
        else:
            pos_q = pos_k = pos[None]
        q = apply_rope(q[..., None, :], pos_q, cfg.rope_theta)[..., 0, :]
        k = apply_rope(k[..., None, :], pos_k, cfg.rope_theta)[..., 0, :]
    return q, k, v


# ===========================================================================
# Cross-attention with a compressed static KV (whisper decode path)
# ===========================================================================

class CrossCache(NamedTuple):
    """Static (built-once) encoder KV for whisper decode. Exactly one of the
    compressed (``*_vals/*_idx``) or dense (``dense_*``) sides has nonzero
    trailing dim — the branch is resolved from *shapes* so it stays static."""
    k_vals: Array   # (B, KV, T_enc, s) compressed, or (..., 0) when dense
    k_idx: Array
    v_vals: Array
    v_idx: Array
    dense_k: Array  # (B, KV, T_enc, hd) dense, or (..., 0) when compressed
    dense_v: Array
    length: Array

    @property
    def compressed(self) -> bool:
        return self.dense_k.shape[-1] == 0

    @classmethod
    def build(cls, K, V, D_k, D_v, *, s, use_gram, compressed: bool):
        if compressed:
            rk = omp_mod.omp_batch(K.astype(jnp.float32), D_k, s, use_gram=use_gram)
            rv = omp_mod.omp_batch(V.astype(jnp.float32), D_v, s, use_gram=use_gram)
            z = jnp.zeros(K.shape[:3] + (0,), jnp.bfloat16)
            return cls(rk.vals.astype(jnp.float8_e4m3fn), rk.idx.astype(jnp.int16),
                       rv.vals.astype(jnp.float8_e4m3fn), rv.idx.astype(jnp.int16),
                       z, z, jnp.int32(K.shape[2]))
        zi = jnp.zeros(K.shape[:3] + (0,), jnp.int16)
        zv = jnp.zeros(K.shape[:3] + (0,), jnp.float8_e4m3fn)
        return cls(zv, zi, zv, zi, K.astype(jnp.bfloat16), V.astype(jnp.bfloat16),
                   jnp.int32(K.shape[2]))


def cross_attend_step(lp: dict, cfg: ModelConfig, x_t: Array, cc: CrossCache,
                      D_k, D_v, N: int) -> Array:
    """Single-token cross-attention against the (compressed) encoder KV."""
    B, d = x_t.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    q = (x_t @ lp["wq"]).reshape(B, KV, G, hd).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    if cc.compressed:
        qd = jnp.einsum("bkgm,mn->bkgn", q, D_k.astype(jnp.float32))
        s_c = compressed_scores(qd, cc.k_vals, cc.k_idx, scale=scale)
        T = cc.k_vals.shape[2]
        s_c = jnp.where(jnp.arange(T)[None, None, None] < cc.length, s_c, NEG_INF)
        p = jax.nn.softmax(s_c, axis=-1)
        coeff = scatter_coeffs(p, cc.v_vals, cc.v_idx, N)
        out = jnp.einsum("bkgn,mn->bkgm", coeff, D_v.astype(jnp.float32))
    else:
        s_c = jnp.einsum("bkgm,bktm->bkgt", q, cc.dense_k.astype(jnp.float32)) * scale
        T = cc.dense_k.shape[2]
        s_c = jnp.where(jnp.arange(T)[None, None, None] < cc.length, s_c, NEG_INF)
        p = jax.nn.softmax(s_c, axis=-1)
        out = jnp.einsum("bkgt,bktm->bkgm", p, cc.dense_v.astype(jnp.float32))
    out = out.reshape(B, H * hd).astype(x_t.dtype)
    return out @ lp["wo"]


def cross_attend_seq(lp: dict, cfg: ModelConfig, x: Array, enc_out: Array) -> Array:
    """Full-precision cross-attention for training / prefill (non-causal)."""
    B, T, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    q = (x @ lp["wq"]).reshape(B, T, KV, G, hd)
    k = (enc_out @ lp["wk"]).reshape(B, -1, KV, hd)
    v = (enc_out @ lp["wv"]).reshape(B, -1, KV, hd)
    q = jnp.transpose(q, (0, 2, 3, 1, 4))
    k = jnp.transpose(k, (0, 2, 1, 3))
    v = jnp.transpose(v, (0, 2, 1, 3))
    out = blocked_attention(q, k, v, causal=False)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, T, H * hd)
    return out @ lp["wo"], k, v


# ===========================================================================
# Layer bodies (sequence + step), shared by train / prefill / decode
# ===========================================================================

def _ffn(lp: dict, cfg: ModelConfig, h: Array) -> Array:
    if cfg.moe is not None:
        if cfg.moe.dispatch == "ep_local":
            return moe_mod.moe_apply_sharded(lp["mlp"], h, cfg.moe, cfg.act)
        return moe_mod.moe_apply(lp["mlp"], h, cfg.moe, cfg.act)
    return mlp_apply(lp["mlp"], h, cfg.act)


def _fuse_parallel(lp: dict, attn_out: Array, ssm_out: Array) -> Array:
    return 0.5 * (rmsnorm(attn_out, lp["attn_out_norm"])
                  + rmsnorm(ssm_out, lp["ssm_out_norm"]))


def layer_seq(lp: dict, cfg: ModelConfig, x: Array, positions: Array,
              window, ssm_state=None, *, causal=True, enc_out=None):
    """One transformer layer over a full sequence.

    Returns (x, (k, v), new_ssm_state) — k/v are the post-RoPE cache entries.
    """
    h = norm_apply(cfg.norm, x, lp["ln1"])
    if cfg.mla is not None:
        attn_out, latent = mla_mod.mla_train_forward(lp["attn"], h, cfg, positions)
        kv = latent          # MLA caches the latent
    else:
        attn_out, k, v = attn_seq(lp["attn"], cfg, h, positions, window, causal=causal)
        kv = (k, v)
    new_ssm = None
    if cfg.parallel_ssm:
        ssm_out, new_ssm = ssm_mod.mamba_forward(lp["ssm"], h, cfg, ssm_state)
        attn_out = _fuse_parallel(lp, attn_out, ssm_out)
    x = x + attn_out
    cross_kv = None
    if enc_out is not None:
        hc = norm_apply(cfg.norm, x, lp["ln_cross"])
        c_out, ck, cv = cross_attend_seq(lp["cross"], cfg, hc, enc_out)
        x = x + c_out
        cross_kv = (ck, cv)
    h2 = norm_apply(cfg.norm, x, lp["ln2"])
    x = x + _ffn(lp, cfg, h2)
    return x, kv, new_ssm, cross_kv


# ===========================================================================
# Public API: train forward
# ===========================================================================

def _encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper encoder over stubbed frame embeddings (B, T_f, d)."""
    x = frames.astype(_dtype(cfg))
    x = x + sinusoidal_pos(frames.shape[1], cfg.d_model, x.dtype)[None]
    positions = jnp.arange(frames.shape[1])

    def body(h, lp):
        h, _, _, _ = layer_seq(lp, cfg, h, positions, None, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return norm_apply(cfg.norm, x, params["encoder"]["final_norm"])


def _window_arr(cfg: ModelConfig) -> Optional[Array]:
    """(L,) per-layer window widths, or None if the arch is fully global."""
    if cfg.sliding_window is None:
        return None
    w = jnp.full((cfg.num_layers,), cfg.sliding_window, jnp.int32)
    for i in cfg.global_attn_layers:
        w = w.at[i].set(BIG_WINDOW)
    return w


def _embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def _unembed(params, cfg, x):
    x = norm_apply(cfg.norm, x, params["final_norm"])
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]


def forward_train(params: dict, cfg: ModelConfig, batch: dict,
                  *, remat: bool = False) -> Array:
    """Teacher-forced logits (B, T, vocab). batch: {'tokens', ['frames']}."""
    hidden = forward_hidden(params, cfg, batch, remat=remat)
    return _unembed(params, cfg, hidden)


def forward_hidden(params: dict, cfg: ModelConfig, batch: dict,
                   *, remat: bool = False) -> Array:
    """Backbone hidden states (B, T, d) before final norm / unembedding.
    Hymba meta tokens are prepended internally and stripped from the output.
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    n_meta = cfg.num_meta_tokens
    if n_meta:
        meta = jnp.broadcast_to(params["meta"][None], (B, n_meta, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    x = shard_hint(x, BATCH_AXES, None, None)
    Ttot = x.shape[1]
    positions = jnp.arange(Ttot)
    enc_out = _encode(params, cfg, batch["frames"]) if cfg.enc_dec else None
    if cfg.enc_dec:
        x = x + params["pos_embed"][:Ttot][None].astype(x.dtype)
    windows = _window_arr(cfg)

    if cfg.rwkv is not None:
        state = ssm_mod.init_rwkv_state(B, cfg)
        stacked_state = jax.tree.map(
            lambda s: jnp.broadcast_to(s[None], (cfg.num_layers,) + s.shape),
            state)

        def body(h, xs):
            lp, st = xs
            h, _ = ssm_mod.rwkv_block_seq(lp["rwkv"], h, cfg, st,
                                          lp["ln1"], lp["ln2"], cfg.norm)
            return h, None

        f = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(f, x, (params["layers"], stacked_state))
        return x

    ssm0 = (ssm_mod.init_mamba_state(B, cfg) if cfg.parallel_ssm else None)

    def body(h, xs):
        lp, win = xs
        w = None if windows is None else win
        h = shard_hint(h, BATCH_AXES, None, None)
        h, _, _, _ = layer_seq(lp, cfg, h, positions, w,
                               ssm_state=ssm0, enc_out=enc_out)
        return shard_hint(h, BATCH_AXES, None, None), None

    xs = (params["layers"],
          windows if windows is not None else jnp.zeros((cfg.num_layers,), jnp.int32))
    f = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(f, x, xs)
    return x[:, n_meta:] if n_meta else x


def lm_loss(params: dict, cfg: ModelConfig, batch: dict, *, remat: bool = False,
            loss_chunk: int = 512):
    """Next-token cross entropy; label -1 positions are masked.

    The CE is computed in sequence chunks (scan) so the full (B, T, vocab)
    logits tensor never materialises — at 150k vocab that tensor dominates
    training memory otherwise (this took the llama train cell from 175 GB of
    XLA temps per device to fitting in HBM; see EXPERIMENTS.md §Perf).
    """
    hidden = forward_hidden(params, cfg, batch, remat=remat)   # (B, T, d)
    labels = batch["labels"]
    B, T, d = hidden.shape
    hidden = hidden[:, :-1]
    labels = labels[:, 1:]

    n = T - 1
    chunk = min(loss_chunk, n)
    n_chunks = n // chunk
    rem = n - n_chunks * chunk

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def ce(h_chunk, l_chunk):
        logits = norm_apply(cfg.norm, h_chunk, params["final_norm"]) @ head
        logits = shard_hint(logits.astype(jnp.float32), BATCH_AXES, None, "model")
        mask = l_chunk >= 0
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_chunk, 0)[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        h_chunk, l_chunk = xs
        tot, cnt = ce(h_chunk, l_chunk)
        return (carry[0] + tot, carry[1] + cnt), None

    hs = jnp.moveaxis(hidden[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk), 1, 0)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ls))
    if rem:
        t2, c2 = ce(hidden[:, -rem:], labels[:, -rem:])
        tot, cnt = tot + t2, cnt + c2
    return tot / jnp.maximum(cnt, 1)


# ===========================================================================
# Public API: serving (prefill + decode) with a pluggable cache policy
# ===========================================================================

class ServeState(NamedTuple):
    cache: Any        # stacked per-layer cache pytree
    length: Array     # (B,) int32 — tokens in cache per slot (incl. meta tokens)
    cross: Any = None  # whisper: stacked CrossCache


def _is_lexico(policy) -> bool:
    """True for any policy speaking the Lexico sparse-code format — the
    contiguous ``LexicoPolicy``, the paged variant, and the shard_map fused
    one all carry a ``LexicoConfig`` as ``.cfg`` (the serving paths key
    format decisions off this, not off a concrete class)."""
    return isinstance(getattr(policy, "cfg", None), LexicoConfig)


def _dict_ctx(cfg: ModelConfig, bank: Optional[DictionaryBank], D_slice, G_slice):
    """Per-layer dictionary context: (D_k, D_v[, G_k, G_v]) — or for MLA the
    single latent dictionary (D[, G])."""
    if bank is None:
        return None
    has_G = bank.G is not None
    if cfg.mla is not None:
        return (D_slice[0], G_slice[0]) if has_G else (D_slice[0], None)
    if has_G:
        return (D_slice[0], D_slice[1], G_slice[0], G_slice[1])
    return (D_slice[0], D_slice[1], None, None)


def init_serve_cache(cfg: ModelConfig, policy: CachePolicy, batch: int,
                     t_max: int) -> Any:
    """Stacked (L,) cache pytree for the decoder stack.

    Layout is the policy's business: ``PagedLexicoPolicy`` yields one shared
    page pool per layer (leaves without a batch axis) plus per-row tables —
    the scan over layers is identical either way.
    """
    L = cfg.num_layers
    if cfg.rwkv is not None:
        st = ssm_mod.init_rwkv_state(batch, cfg)
        return jax.tree.map(lambda s: jnp.stack([s] * L), st)
    if cfg.mla is not None:
        lex: LexicoPolicy = policy  # MLA serving requires the Lexico policy
        c = lex.cfg
        one = mla_mod.init_mla_cache(batch, cfg.cached_vector_dim,
                                     t_max=max(t_max - c.n_b, 1), n_b=c.n_b, s=c.s,
                                     val_dtype=c.val_dtype)
        cache = jax.tree.map(lambda s: jnp.stack([s] * L), one)
    else:
        one = policy.init(batch, cfg.cache_kv_heads, cfg.hd, t_max)
        cache = jax.tree.map(lambda s: jnp.stack([s] * L), one)
    if cfg.parallel_ssm:
        st = ssm_mod.init_mamba_state(batch, cfg)
        ssm = jax.tree.map(lambda s: jnp.stack([s] * L), st)
        return {"attn": cache, "ssm": ssm}
    return cache


def prefill(params: dict, cfg: ModelConfig, policy: CachePolicy, batch: dict,
            *, bank: Optional[DictionaryBank], t_max: int,
            s_cap: Optional[Array] = None,
            compress_start: int = 0,
            collect_quality: bool = False):
    """Run the prompt, build the (compressed) cache.

    Args:
      batch: ``{"tokens": (B, T) int32[, "frames": ...]}``.
      s_cap: ``(B,)`` int32 per-request sparsity tiers (Lexico policies only).
      compress_start: static int — restart the cache *compression* at this
        compressed position (prefix sharing: the skipped prefix's codes are
        already held as shared pages). The transformer forward always runs
        over the whole prompt — only the OMP encode is skipped — so logits
        and the encoded tail are bitwise identical to a ``compress_start=0``
        run. Lexico attention-stack policies only.
      collect_quality: static bool — additionally return the layer-stacked
        encode-quality aux (``k_rel``/``v_rel``/``k_nnz``/``v_nnz``, each
        ``(L, B, KV, n_encoded)``) as a third output. The aux rides the
        existing scan as extra ys, so logits and cache stay bitwise identical
        and no extra trace is introduced. Lexico attention-stack only.

    Returns ``(last-token logits (B, vocab), ServeState)`` where the state's
    ``length`` is ``(B,)`` (meta tokens included) — plus the quality aux dict
    when ``collect_quality``.
    """
    if collect_quality and (cfg.rwkv is not None or cfg.mla is not None
                            or not _is_lexico(policy)):
        raise NotImplementedError(
            "collect_quality covers attention-stack Lexico policies only")
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    n_meta = cfg.num_meta_tokens
    if n_meta:
        meta = jnp.broadcast_to(params["meta"][None], (B, n_meta, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    x = shard_hint(x, BATCH_AXES, None, None)
    Ttot = x.shape[1]
    positions = jnp.arange(Ttot)
    enc_out = _encode(params, cfg, batch["frames"]) if cfg.enc_dec else None
    if cfg.enc_dec:
        x = x + params["pos_embed"][:Ttot][None].astype(x.dtype)
    windows = _window_arr(cfg)
    L = cfg.num_layers
    bank_D = bank.D if bank is not None else jnp.zeros((L, 1))
    bank_G = (bank.G if (bank is not None and bank.G is not None)
              else jnp.zeros((L, 1)))
    cache0 = init_serve_cache(cfg, policy, B, t_max)

    if cfg.rwkv is not None:
        def body(h, xs):
            lp, st = xs
            h, new_st = ssm_mod.rwkv_block_seq(lp["rwkv"], h, cfg, st,
                                               lp["ln1"], lp["ln2"], cfg.norm)
            return h, new_st

        x, new_state = jax.lax.scan(body, x, (params["layers"], cache0))
        logits = _unembed(params, cfg, x[:, -1])
        return logits, ServeState(cache=new_state,
                                  length=jnp.full((B,), Ttot, jnp.int32))

    attn_cache0 = cache0["attn"] if cfg.parallel_ssm else cache0
    ssm_cache0 = cache0["ssm"] if cfg.parallel_ssm else None

    def body(h, xs):
        lp, win, Dl, Gl, cache_l, ssm_l = xs
        w = None if windows is None else win
        ssm_in = ssm_l if cfg.parallel_ssm else None
        h = shard_hint(h, BATCH_AXES, None, None)
        h, kv, new_ssm, cross_kv = layer_seq(lp, cfg, h, positions, w,
                                             ssm_state=ssm_in, enc_out=enc_out)
        ctx = _dict_ctx(cfg, bank, Dl, Gl)
        qaux = None
        if cfg.mla is not None:
            if compress_start:
                raise NotImplementedError(
                    "prefix sharing (compress_start) covers attention-stack "
                    "Lexico caches; the MLA latent cache has no paged layout")
            new_cache = mla_mod.mla_prefill_compress(
                cache_l, kv, ctx[0], s=policy.cfg.s, use_gram=policy.cfg.use_gram,
                delta=policy.cfg.delta, G=ctx[1], s_cap=s_cap)
        elif collect_quality:
            new_cache, qaux = policy.prefill(cache_l, kv[0], kv[1], ctx,
                                             s_cap=s_cap, start=compress_start,
                                             return_quality=True)
        elif compress_start:
            new_cache = policy.prefill(cache_l, kv[0], kv[1], ctx,
                                       s_cap=s_cap, start=compress_start)
        elif s_cap is not None:
            new_cache = policy.prefill(cache_l, kv[0], kv[1], ctx, s_cap=s_cap)
        else:
            new_cache = policy.prefill(cache_l, kv[0], kv[1], ctx)
        cross_c = None
        if cfg.enc_dec:
            compressed = _is_lexico(policy)
            ck, cv = cross_kv
            cross_c = CrossCache.build(
                ck, cv, ctx[0] if ctx else None, ctx[1] if ctx else None,
                s=policy.cfg.s if compressed else 0,
                use_gram=getattr(policy.cfg, "use_gram", True) if compressed else True,
                compressed=compressed)
        outs = (new_cache, new_ssm, cross_c, qaux)
        return h, outs

    xs = (params["layers"],
          windows if windows is not None else jnp.zeros((cfg.num_layers,), jnp.int32),
          bank_D, bank_G, attn_cache0, ssm_cache0 if cfg.parallel_ssm else
          jnp.zeros((cfg.num_layers,), jnp.int32))
    x, (new_cache, new_ssm, cross_c, qaux) = jax.lax.scan(body, x, xs)
    logits = _unembed(params, cfg, x[:, -1])
    cache_out = {"attn": new_cache, "ssm": new_ssm} if cfg.parallel_ssm else new_cache
    state = ServeState(cache=cache_out,
                       length=jnp.full((B,), Ttot, jnp.int32),
                       cross=cross_c)
    if collect_quality:
        return logits, state, qaux
    return logits, state


def decode_step(params: dict, cfg: ModelConfig, policy: CachePolicy,
                state: ServeState, token: Array,
                *, bank: Optional[DictionaryBank],
                active: Optional[Array] = None,
                s_cap: Optional[Array] = None,
                collect_quality: bool = False):
    """One autoregressive step. token (B,) int32 -> (logits (B,V), state).

    ``active`` (B,) bool: slots set False are carried through unchanged (their
    cache, counters and length don't advance) — the continuous-batching
    engine decodes a partially-occupied slot pool with one compiled step.
    ``s_cap`` (B,) int32: per-request sparsity tiers (Lexico policies only).
    ``collect_quality`` (static bool): additionally return the layer-stacked
    evictee-encode quality aux (``k_rel``/``v_rel``/``k_nnz``/``v_nnz`` each
    ``(L, B, KV)`` plus the ``(L, B)`` ``wrote`` mask) as a third output —
    the decode-path quality signal, riding the existing scan as extra ys so
    logits/cache stay bitwise identical within the same single trace. Lexico
    attention-stack policies only (not the fused ``decode_attend`` path).
    """
    if collect_quality and (cfg.rwkv is not None or cfg.mla is not None
                            or hasattr(policy, "decode_attend")
                            or not _is_lexico(policy)):
        raise NotImplementedError(
            "collect_quality covers attention-stack Lexico policies only")
    B = token.shape[0]
    x = _embed_tokens(params, cfg, token)           # (B, d)
    x = shard_hint(x, BATCH_AXES, None)
    position = state.length                          # (B,)
    step_inc = (jnp.ones((B,), jnp.int32) if active is None
                else jnp.asarray(active, jnp.bool_).astype(jnp.int32))
    if cfg.enc_dec:
        # decoder position excludes encoder frames; length counts decoder tokens
        x = x + params["pos_embed"][position].astype(x.dtype)
    windows = _window_arr(cfg)
    bank_D = bank.D if bank is not None else jnp.zeros((cfg.num_layers, 1))
    bank_G = (bank.G if (bank is not None and bank.G is not None)
              else jnp.zeros((cfg.num_layers, 1)))

    if cfg.rwkv is not None:
        def body(h, xs):
            lp, st = xs
            h, new_st = ssm_mod.rwkv_block_step(lp["rwkv"], h, cfg, st,
                                                lp["ln1"], lp["ln2"], cfg.norm)
            return h, new_st

        x, new_state = jax.lax.scan(body, x, (params["layers"], state.cache))
        return _unembed(params, cfg, x), ServeState(cache=new_state,
                                                    length=state.length + step_inc)

    attn_cache = state.cache["attn"] if cfg.parallel_ssm else state.cache
    ssm_cache = state.cache["ssm"] if cfg.parallel_ssm else None

    def body(h, xs):
        lp, win, Dl, Gl, cache_l, ssm_l, cross_l = xs
        w = None if windows is None else win
        ctx = _dict_ctx(cfg, bank, Dl, Gl)
        h = shard_hint(h, BATCH_AXES, None)
        hn = norm_apply(cfg.norm, h, lp["ln1"])
        new_ssm = None
        qaux = None
        if cfg.mla is not None:
            attn_out, new_cache = mla_mod.mla_decode_step(
                lp["attn"], cache_l, hn, cfg, position, ctx[0],
                N=policy.cfg.N, s=policy.cfg.s, use_gram=policy.cfg.use_gram,
                delta=policy.cfg.delta, chunk=policy.cfg.chunk, G=ctx[1],
                active=active, s_cap=s_cap)
        else:
            q, k_t, v_t = _qkv_step(lp["attn"], cfg, hn, position)
            w_eff = win if windows is not None else None
            if hasattr(policy, "decode_attend"):
                # fused sequence-parallel update+attend (shard_map path)
                att, new_cache = policy.decode_attend(cache_l, q, k_t, v_t, ctx,
                                                      window=w_eff, active=active,
                                                      s_cap=s_cap)
            elif collect_quality:
                new_cache, qaux = policy.decode(cache_l, k_t, v_t, ctx,
                                                active=active, s_cap=s_cap,
                                                return_quality=True)
                att = policy.attend(new_cache, q, ctx, window=w_eff)
            else:
                new_cache = policy.decode(cache_l, k_t, v_t, ctx,
                                          active=active, s_cap=s_cap)
                att = policy.attend(new_cache, q, ctx, window=w_eff)
            H, hd = cfg.num_heads, cfg.hd
            attn_out = att.reshape(B, H * hd).astype(h.dtype) @ lp["attn"]["wo"]
        if cfg.parallel_ssm:
            ssm_out, new_ssm = ssm_mod.mamba_step(lp["ssm"], hn, cfg, ssm_l)
            attn_out = _fuse_parallel(lp, attn_out, ssm_out)
        h = h + attn_out
        if cfg.enc_dec:
            hc = norm_apply(cfg.norm, h, lp["ln_cross"])
            h = h + cross_attend_step(lp["cross"], cfg, hc, cross_l,
                                      ctx[0] if ctx else None,
                                      ctx[1] if ctx else None,
                                      policy.cfg.N if _is_lexico(policy) else 0)
        h2 = norm_apply(cfg.norm, h, lp["ln2"])
        h = h + _ffn(lp, cfg, h2)
        return h, (new_cache, new_ssm, qaux)

    L = cfg.num_layers
    xs = (params["layers"],
          windows if windows is not None else jnp.zeros((L,), jnp.int32),
          bank_D, bank_G, attn_cache,
          ssm_cache if cfg.parallel_ssm else jnp.zeros((L,), jnp.int32),
          state.cross if cfg.enc_dec else jnp.zeros((L,), jnp.int32))
    x, (new_cache, new_ssm, qaux) = jax.lax.scan(body, x, xs)
    logits = _unembed(params, cfg, x)
    cache_out = ({"attn": new_cache, "ssm": new_ssm} if cfg.parallel_ssm
                 else new_cache)
    new_state = ServeState(cache=cache_out, length=state.length + step_inc,
                           cross=state.cross)
    if collect_quality:
        return logits, new_state, qaux
    return logits, new_state
