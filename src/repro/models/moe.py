"""Mixture-of-Experts FFN (top-k routing, optional shared experts).

Sort-based dropless-with-capacity dispatch (Megablocks-flavoured, the
standard production shape): token×expert assignments are argsorted by expert
id, bucketed into an (E, C, d) buffer (overflow dropped against capacity
``C = ceil(tokens*top_k/E * capacity_factor)``), run through a grouped einsum
(``(E,C,d) x (E,d,f)``), and scattered back weighted by router probabilities.

Sharding: the expert axis of the weights and of the (E, C, d) buffer maps to
the ``model`` mesh axis (EP); XLA lowers the gather/scatter across the sharded
axis into the all-to-all pair of a classic MoE dispatch/combine.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init

Array = jax.Array


def moe_init(key, d: int, cfg: MoEConfig, act: str, dtype) -> dict:
    ks = jax.random.split(key, 5)
    E, f = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * d**-0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (E, f, d), jnp.float32) * f**-0.5).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[3], (E, d, f), jnp.float32) * d**-0.5).astype(dtype)
    if cfg.num_shared:
        f_sh = (cfg.d_ff_shared or cfg.d_ff_expert) * cfg.num_shared
        p["shared"] = mlp_init(ks[4], d, f_sh, act, dtype)
    return p


def moe_apply(p: dict, x: Array, cfg: MoEConfig, act: str,
              *, capacity_factor: Optional[float] = None) -> Array:
    """x (..., d) -> (..., d). Flattens all leading axes into a token axis."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    S, E, k = xt.shape[0], cfg.num_experts, cfg.top_k
    capacity_factor = capacity_factor if capacity_factor is not None else cfg.capacity_factor

    logits = (xt.astype(jnp.float32) @ p["router"])          # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                   # (S, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalise over top-k

    # --- dispatch: sort (token, slot) pairs by expert ---
    flat_e = top_e.reshape(-1)                               # (S*k,)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(S), k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    p_sorted = flat_p[order]

    C = max(1, math.ceil(S * k / E * capacity_factor))
    # position within the expert bucket
    same = jnp.cumsum(jnp.ones_like(e_sorted), axis=0) - 1
    start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    slot = same - start[e_sorted]
    keep = slot < C

    buf = jnp.zeros((E * C, d), xt.dtype)
    dest = jnp.where(keep, e_sorted * C + slot, E * C)       # OOB drop
    buf = buf.at[dest.astype(jnp.int32)].set(xt[tok_sorted], mode="drop")
    buf = buf.reshape(E, C, d)

    # --- grouped expert MLP ---
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)

    # --- combine: gather back and weight by router prob ---
    gathered = jnp.where(keep[:, None], out_buf[jnp.clip(dest, 0, E * C - 1).astype(jnp.int32)], 0.0)
    contrib = gathered.astype(jnp.float32) * p_sorted[:, None]
    out = jnp.zeros((S, d), jnp.float32).at[tok_sorted].add(contrib)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xt, act).astype(jnp.float32)
    return out.astype(x.dtype).reshape(orig_shape)


def moe_apply_ep_local(p_local: dict, x_local: Array, cfg: MoEConfig, act: str,
                       *, model_axis: str = "model",
                       fsdp_axis: Optional[str] = "data",
                       capacity_factor: Optional[float] = None) -> Array:
    """EP dispatch body — runs INSIDE shard_map.

    Beyond-paper optimization for the collective-bound MoE training cells
    (EXPERIMENTS.md §Perf): tokens are replicated across the 'model' axis
    (standard TP activation layout), so each model shard can serve its local
    E/|model| experts with **zero dispatch communication** — it masks the
    global top-k assignments to its local expert range, buckets locally, and
    the only collective is one psum of the (tokens, d) output over 'model'
    (the same all-reduce a dense TP MLP pays). This replaces the
    scatter-into-sharded-buffer dispatch that XLA lowers into TB-scale
    all-reduces.

    ``p_local`` weights arrive as local (E_loc, d/|fsdp|, f) shards; the FSDP
    axis is all-gathered here (per layer, transient) like XLA would.
    """
    capacity_factor = capacity_factor if capacity_factor is not None else cfg.capacity_factor
    orig_shape = x_local.shape
    d = x_local.shape[-1]
    xt = x_local.reshape(-1, d)
    S, E, k = xt.shape[0], cfg.num_experts, cfg.top_k

    def gather_w(w):
        if fsdp_axis is None:
            return w
        return jax.lax.all_gather(w, fsdp_axis, axis=1, tiled=True)

    w_up = gather_w(p_local["w_up"])
    w_down = jax.lax.all_gather(p_local["w_down"], fsdp_axis, axis=2, tiled=True) \
        if fsdp_axis is not None else p_local["w_down"]
    w_gate = gather_w(p_local["w_gate"]) if "w_gate" in p_local else None
    E_loc = w_up.shape[0]
    e_lo = jax.lax.axis_index(model_axis) * E_loc

    logits = (xt.astype(jnp.float32) @ p_local["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)
    flat_p = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(S), k)
    local = (flat_e >= e_lo) & (flat_e < e_lo + E_loc)
    loc_e = jnp.where(local, flat_e - e_lo, E_loc)          # E_loc = drop bin
    order = jnp.argsort(loc_e, stable=True)
    e_sorted = loc_e[order]
    tok_sorted = flat_tok[order]
    p_sorted = jnp.where(local[order], flat_p[order], 0.0)

    C = max(1, math.ceil(S * k / E * capacity_factor))
    same = jnp.cumsum(jnp.ones_like(e_sorted)) - 1
    start = jnp.searchsorted(e_sorted, jnp.arange(E_loc + 1), side="left")
    slot = same - start[jnp.minimum(e_sorted, E_loc)]
    keep = (slot < C) & (e_sorted < E_loc)
    dest = jnp.where(keep, e_sorted * C + slot, E_loc * C)
    buf = jnp.zeros((E_loc * C, d), xt.dtype)
    buf = buf.at[dest.astype(jnp.int32)].set(xt[tok_sorted], mode="drop")
    buf = buf.reshape(E_loc, C, d)

    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) \
            * jnp.einsum("ecd,edf->ecf", buf, w_up)
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, w_up))
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * C, d)

    gathered = jnp.where(keep[:, None],
                         out_buf[jnp.clip(dest, 0, E_loc * C - 1).astype(jnp.int32)], 0.0)
    contrib = gathered.astype(jnp.float32) * p_sorted[:, None]
    out = jnp.zeros((S, d), jnp.float32).at[tok_sorted].add(contrib)
    out = jax.lax.psum(out, model_axis)          # the only EP collective
    return out.astype(x_local.dtype).reshape(orig_shape)


def moe_apply_sharded(p: dict, x: Array, cfg: MoEConfig, act: str) -> Array:
    """shard_map wrapper around ``moe_apply_ep_local``. Falls back to the
    plain dispatch when no 'model' mesh axis is active (smoke tests)."""
    am = jax.sharding.get_abstract_mesh()
    if am is None or am.empty or "model" not in am.axis_names:
        return moe_apply(p, x, cfg, act)
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    batch_axes = tuple(a for a in ("pod", "data") if a in am.axis_names)
    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    fsdp = "data" if "data" in am.axis_names else None
    x_spec = P(*([bspec] + [None] * (x.ndim - 1))) \
        if x.shape[0] % max(1, math.prod(am.shape[a] for a in batch_axes)) == 0 \
        else P(*([None] * x.ndim))
    w_specs = {
        "router": P(None, None),
        "w_up": P("model", fsdp, None),
        "w_down": P("model", None, fsdp),
    }
    if "w_gate" in p:
        w_specs["w_gate"] = P("model", fsdp, None)
    shared = p.get("shared")
    p_experts = {k_: v for k_, v in p.items() if k_ != "shared"}

    out = shard_map(
        lambda pl, xl: moe_apply_ep_local(pl, xl, cfg, act, fsdp_axis=fsdp),
        mesh=am, in_specs=(w_specs, x_spec), out_specs=x_spec,
        check_rep=False,
    )(p_experts, x)
    if shared is not None:
        out = out + mlp_apply(shared, x.reshape(-1, x.shape[-1]), act).reshape(x.shape)
    return out


def aux_load_balance_loss(logits: Array, top_e: Array, num_experts: int) -> Array:
    """Switch-style load-balancing auxiliary loss (fraction * probability)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    S = logits.shape[0]
    frac = jnp.zeros((num_experts,)).at[top_e.reshape(-1)].add(1.0) / top_e.size
    imp = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(frac * imp)
