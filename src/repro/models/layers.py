"""Shared layer primitives (pure functions over param dicts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rmsnorm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: Array, w: Array, b: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            + b.astype(jnp.float32)).astype(x.dtype)


def norm_apply(cfg_norm: str, x: Array, p: dict) -> Array:
    if cfg_norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


def norm_init(cfg_norm: str, d: int, dtype) -> dict:
    if cfg_norm == "layernorm":
        return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype)}


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None) -> Array:
    scale = scale if scale is not None else (d_in ** -0.5)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def mlp_init(key, d: int, f: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"w_gate": dense_init(ks[0], d, f, dtype),
                "w_up": dense_init(ks[1], d, f, dtype),
                "w_down": dense_init(ks[2], f, d, dtype)}
    return {"w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype)}


def mlp_apply(p: dict, x: Array, act: str) -> Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def sinusoidal_pos(T: int, d: int, dtype=jnp.float32) -> Array:
    pos = jnp.arange(T)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10_000.0) * dim / (d // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
