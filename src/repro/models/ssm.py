"""State-space sequence mixers: Mamba-style selective SSM (for Hymba's
parallel heads) and RWKV6 "Finch" (data-dependent decay linear attention).

Both expose a (sequence-scan, single-step) pair so training/prefill and
decode share weights and exact math. States are O(1) in sequence length —
these are the sub-quadratic paths that make ``long_500k`` runnable.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array


# ===========================================================================
# Mamba-style selective SSM
# ===========================================================================

class MambaState(NamedTuple):
    h: Array           # (B, d_inner, d_state)
    conv: Array        # (B, conv_width-1, d_inner) — trailing inputs


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt_rank = s.dt_rank or max(1, d // 16)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "w_in": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, di), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * s.state_dim, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[4], di, d, dtype),
    }


def init_mamba_state(batch: int, cfg: ModelConfig) -> MambaState:
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return MambaState(h=jnp.zeros((batch, di, s.state_dim), jnp.float32),
                      conv=jnp.zeros((batch, s.conv_width - 1, di), jnp.float32))


def _mamba_core(p: dict, xs: Array, z: Array, h0: Array, cfg: ModelConfig
                ) -> Tuple[Array, Array]:
    """xs (B, T, di) post-conv inputs; returns (y (B,T,di), h_T)."""
    s = cfg.ssm
    dt_rank = s.dt_rank or max(1, cfg.d_model // 16)
    proj = xs @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"]
                         + p["dt_bias"].astype(jnp.float32))          # (B,T,di)
    Bmat = proj[..., dt_rank:dt_rank + s.state_dim].astype(jnp.float32)
    Cmat = proj[..., dt_rank + s.state_dim:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                      # (di, n)

    def step(h, t):
        dt_t, B_t, C_t, x_t = t
        dA = jnp.exp(dt_t[..., None] * A)                             # (B,di,n)
        h = dA * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xsf = xs.astype(jnp.float32)
    (hT, ys) = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bmat, 1, 0),
         jnp.moveaxis(Cmat, 1, 0), jnp.moveaxis(xsf, 1, 0)))
    ys = jnp.moveaxis(ys, 0, 1)                                       # (B,T,di)
    y = ys + p["D"].astype(jnp.float32) * xsf
    return (y * jax.nn.silu(z.astype(jnp.float32))), hT


def mamba_forward(p: dict, x: Array, cfg: ModelConfig,
                  state: MambaState) -> Tuple[Array, MambaState]:
    """Sequence form. x (B, T, d) -> (out (B, T, d), new state)."""
    s = cfg.ssm
    B, T, d = x.shape
    xz = x @ p["w_in"]
    xi, z = jnp.split(xz, 2, axis=-1)                                 # (B,T,di)
    # causal depthwise conv over time, seeded by the carried conv state
    pad = jnp.concatenate([state.conv.astype(xi.dtype), xi], axis=1)  # (B,T+cw-1,di)
    cw = s.conv_width
    conv = sum(pad[:, i:i + T] * p["conv_w"][i] for i in range(cw)) + p["conv_b"]
    xs = jax.nn.silu(conv)
    y, hT = _mamba_core(p, xs, z, state.h, cfg)
    new_conv = pad[:, T:].astype(jnp.float32) if cw > 1 else state.conv
    out = y.astype(x.dtype) @ p["w_out"]
    return out, MambaState(h=hT, conv=new_conv)


def mamba_step(p: dict, x_t: Array, cfg: ModelConfig,
               state: MambaState) -> Tuple[Array, MambaState]:
    """Single decode step. x_t (B, d)."""
    out, st = mamba_forward(p, x_t[:, None], cfg, state)
    return out[:, 0], st


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================

class RWKVState(NamedTuple):
    S: Array          # (B, H, hd, hd) wkv state
    x_tm: Array       # (B, d) previous input of time-mix
    x_cm: Array       # (B, d) previous input of channel-mix


def rwkv_init(key, cfg: ModelConfig, dtype) -> dict:
    r = cfg.rwkv
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    H = d // r.head_dim
    return {
        # time-mix
        "mu_base": (jax.random.uniform(ks[0], (d,), jnp.float32)).astype(dtype),
        "mu": (jax.random.uniform(ks[1], (5, d), jnp.float32)).astype(dtype),
        "w_mix1": dense_init(ks[2], d, 5 * r.mix_lora, dtype, scale=1e-2),
        "w_mix2": (jax.random.normal(ks[3], (5, r.mix_lora, d), jnp.float32) * 1e-2).astype(dtype),
        "w_r": dense_init(ks[4], d, d, dtype),
        "w_k": dense_init(ks[5], d, d, dtype),
        "w_v": dense_init(ks[6], d, d, dtype),
        "w_g": dense_init(ks[7], d, d, dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_dec1": dense_init(ks[8], d, r.decay_lora, dtype, scale=1e-2),
        "w_dec2": dense_init(ks[9], r.decay_lora, d, dtype, scale=1e-2),
        "u": jnp.zeros((d,), jnp.float32),
        "ln_x_w": jnp.ones((r.head_dim,), dtype),
        "w_o": dense_init(ks[10], d, d, dtype),
        # channel-mix
        "mu_k_cm": (jax.random.uniform(ks[11], (d,), jnp.float32)).astype(dtype),
        "mu_r_cm": jnp.zeros((d,), dtype),
        "w_k_cm": dense_init(jax.random.fold_in(key, 99), d, f, dtype),
        "w_v_cm": dense_init(jax.random.fold_in(key, 98), f, d, dtype),
        "w_r_cm": dense_init(jax.random.fold_in(key, 97), d, d, dtype),
    }


def init_rwkv_state(batch: int, cfg: ModelConfig) -> RWKVState:
    r = cfg.rwkv
    d = cfg.d_model
    H = d // r.head_dim
    return RWKVState(S=jnp.zeros((batch, H, r.head_dim, r.head_dim), jnp.float32),
                     x_tm=jnp.zeros((batch, d), jnp.float32),
                     x_cm=jnp.zeros((batch, d), jnp.float32))


def _groupnorm_heads(x: Array, w: Array, eps: float = 64e-5) -> Array:
    """Per-head layernorm of (B, H, hd)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w.astype(x.dtype)


def rwkv_time_mix_step(p: dict, x_t: Array, cfg: ModelConfig, S: Array,
                       x_prev: Array) -> Tuple[Array, Array]:
    """One token of RWKV6 time-mix. x_t (B, d) fp32. Returns (out, new S)."""
    r = cfg.rwkv
    d = cfg.d_model
    H, hd = d // r.head_dim, r.head_dim
    B = x_t.shape[0]
    delta = x_prev - x_t
    xx = x_t + delta * p["mu_base"].astype(jnp.float32)
    dyn = jnp.tanh(xx @ p["w_mix1"]).reshape(B, 5, -1)                # (B,5,lora)
    dyn = jnp.einsum("bfl,fld->bfd", dyn, p["w_mix2"].astype(jnp.float32))
    mix = p["mu"].astype(jnp.float32)[None] + dyn                     # (B,5,d)
    x_w, x_k, x_v, x_r, x_g = [x_t + delta * mix[:, i] for i in range(5)]

    rv = x_r @ p["w_r"]
    kv = x_k @ p["w_k"]
    vv = x_v @ p["w_v"]
    gv = jax.nn.silu(x_g @ p["w_g"])
    w_dec = jnp.exp(-jnp.exp(
        p["w0"] + jnp.tanh(x_w @ p["w_dec1"]) @ p["w_dec2"].astype(jnp.float32)))

    rh = rv.reshape(B, H, hd).astype(jnp.float32)
    kh = kv.reshape(B, H, hd).astype(jnp.float32)
    vh = vv.reshape(B, H, hd).astype(jnp.float32)
    wh = w_dec.reshape(B, H, hd)
    uh = p["u"].reshape(H, hd)

    kv_outer = jnp.einsum("bhi,bhj->bhij", kh, vh)
    o = jnp.einsum("bhi,bhij->bhj", rh, S + uh[None, :, :, None] * kv_outer)
    S_new = wh[..., None] * S + kv_outer
    o = _groupnorm_heads(o, p["ln_x_w"]).reshape(B, d)
    return (o * gv.astype(jnp.float32)) @ p["w_o"], S_new


def rwkv_channel_mix_step(p: dict, x_t: Array, x_prev: Array) -> Array:
    delta = x_prev - x_t
    x_k = x_t + delta * p["mu_k_cm"].astype(jnp.float32)
    x_r = x_t + delta * p["mu_r_cm"].astype(jnp.float32)
    k = jnp.square(jax.nn.relu(x_k @ p["w_k_cm"]))
    return jax.nn.sigmoid(x_r @ p["w_r_cm"]) * (k @ p["w_v_cm"])


def rwkv_block_seq(p: dict, x: Array, cfg: ModelConfig, state: RWKVState,
                   ln1: dict, ln2: dict, norm_kind: str) -> Tuple[Array, RWKVState]:
    """Full RWKV layer over a sequence. x (B, T, d). Residuals included."""
    from repro.models.layers import norm_apply
    B, T, d = x.shape

    def step(carry, x_t):
        S, x_tm, x_cm, = carry
        h = x_t.astype(jnp.float32)
        hn = norm_apply(norm_kind, h, ln1).astype(jnp.float32)
        att, S = rwkv_time_mix_step(p, hn, cfg, S, x_tm)
        h = h + att
        hn2 = norm_apply(norm_kind, h, ln2).astype(jnp.float32)
        ffn = rwkv_channel_mix_step(p, hn2, x_cm)
        h = h + ffn
        return (S, hn, hn2), h

    (S, x_tm, x_cm), ys = jax.lax.scan(
        step, (state.S, state.x_tm, state.x_cm), jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), RWKVState(S=S, x_tm=x_tm, x_cm=x_cm)


def rwkv_block_step(p: dict, x_t: Array, cfg: ModelConfig, state: RWKVState,
                    ln1: dict, ln2: dict, norm_kind: str) -> Tuple[Array, RWKVState]:
    out, st = rwkv_block_seq(p, x_t[:, None], cfg, state, ln1, ln2, norm_kind)
    return out[:, 0], st
