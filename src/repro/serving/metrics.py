"""Engine metrics: throughput, occupancy, KV bytes in flight, queue latency.

Host-side counters sampled once per engine step — no device syncs beyond
what the step already does. ``kv_bytes_in_flight`` uses the paper's exact
accounting over the *current* per-slot token counts (not the projected
completion-time bytes the scheduler reserves), so the gap between the two is
the admission controller's safety margin. ``kv_bytes_resident`` is what the
same slots *hold* in their storage layout — pages actually bound under paged
storage (deduplicated: a shared page counts once no matter how many slots
alias it), full padded stripes under contiguous — i.e. the capacity a
right-sized pool must provision; resident-vs-paper is the fragmentation cost
of the storage layout.

Prefix sharing adds admission-time counters: trie hits/misses, pages
aliased / copied-on-write, compressed positions whose prefill OMP was
skipped, and the paper-accounting bytes deduplicated by aliasing.

Tiered storage (``repro.serving.swap``) adds the two-tier counters: pages
demoted to / promoted from the host tier, ``host_bytes_resident`` sampled
per step (the host tier's real footprint — ``kv_bytes_resident`` stays
device-only, so the two never double-count a page), and
``promote_stall_steps`` — slot-steps lost waiting for a swapped page's
device residency (the latency cost oversubscription pays).

Since the observability layer (``repro.serving.obs``), ``EngineMetrics`` is
a façade over a labeled :class:`~repro.serving.obs.registry.MetricsRegistry`
— every counter is a registry family (so it exports as Prometheus text and
carries per-tier label breakdowns), while the legacy attribute surface
(``metrics.pages_demoted`` etc.) is preserved as read-only properties and
``to_dict()`` keeps every pre-existing key byte-compatible. Two timing
fixes ride along: the throughput clock starts lazily on the first step or
admission (``setup_s`` — engine construction and jit setup — is reported
separately), and the first-trace compile time of the prefill/decode entry
points accumulates in ``compile_s`` so ``tokens_per_s_ex_compile`` measures
steady-state throughput.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

from repro.serving.obs.registry import MetricsRegistry, percentile

# step() phases instrumented by the engine, in execution order
PHASES = ("admit", "prepare_slots", "decode_dispatch", "host_sync",
          "consume_logits", "trim")


def _summary(samples: List[float]) -> Dict[str, float]:
    """count/mean/p50/p99/max summary of one phase's timings (p999 once
    enough samples exist for the tail to be distinguishable from max)."""
    if not samples:
        return {"count": 0, "mean": 0.0, "p50": 0.0, "p99": 0.0, "max": 0.0}
    out = {"count": len(samples),
           "mean": sum(samples) / len(samples),
           "p50": percentile(samples, 0.50),
           "p99": percentile(samples, 0.99),
           "max": max(samples)}
    if len(samples) >= 1000:
        out["p999"] = percentile(samples, 0.999)
    return out


class EngineMetrics:
    """Aggregates one engine's serving counters; ``to_dict`` summarizes.

    Counters live in ``self.registry`` (Prometheus-exportable, labeled);
    the legacy int-attribute surface is read-only properties over it.
    ``*_samples`` lists hold one entry per pooled decode step.
    """

    def __init__(self) -> None:
        self.registry = MetricsRegistry()
        r = self.registry
        self._steps = r.counter("lexico_steps_total",
                                "pooled decode steps executed")
        self._prefills = r.counter("lexico_prefills_total",
                                   "requests admitted (prefill splices)")
        self._tokens = r.counter("lexico_tokens_generated_total",
                                 "tokens sampled across all requests")
        self._prompt_tokens = r.counter(
            "lexico_prompt_tokens_total", "prompt tokens consumed")
        self._prefill_compressed = r.counter(
            "lexico_prefill_tokens_compressed_total",
            "compressed positions OMP-encoded at prefill")
        self._prefill_skipped = r.counter(
            "lexico_prefill_tokens_skipped_total",
            "compressed positions skipped via prefix sharing")
        self._completed = r.counter("lexico_requests_completed_total",
                                    "requests retired")
        self._rejections = r.counter(
            "lexico_admission_rejections_total",
            "head-of-line admission reservation failures")
        # prefix sharing (admission-time)
        self._prefix_hits = r.counter("lexico_prefix_hits_total",
                                      "admissions that shared a prefix")
        self._prefix_misses = r.counter("lexico_prefix_misses_total",
                                        "admissions with no shared prefix")
        self._pages_aliased = r.counter("lexico_pages_aliased_total",
                                        "pool pages aliased into new slots")
        self._pages_copied = r.counter("lexico_pages_copied_total",
                                       "copy-on-write boundary-page copies")
        self._bytes_deduped = r.counter("lexico_bytes_deduped_total",
                                        "paper-accounting bytes deduplicated")
        self._prefix_evicted = r.counter(
            "lexico_prefix_pages_evicted_total",
            "prefix-cache pages destructively evicted")
        # tiered storage (host-memory swap)
        self._demoted = r.counter("lexico_pages_demoted_total",
                                  "pages moved device -> host tier")
        self._promoted = r.counter("lexico_pages_promoted_total",
                                   "pages moved host -> device tier")
        self._stalls = r.counter("lexico_promote_stall_steps_total",
                                 "slot-steps stalled on promotion")
        # timing
        self._compile_s = r.counter(
            "lexico_compile_seconds_total",
            "time spent inside first-trace compilation of jitted entry points")
        self._queue_latency = r.histogram(
            "lexico_queue_latency_seconds",
            "submit -> admission latency per request")
        # the throughput clock: construction time is remembered, but
        # elapsed_s runs from the FIRST step/admission so engine setup and
        # jit tracing never pollute tokens_per_s
        self.created_at: float = time.perf_counter()
        self.started_at: Optional[float] = None

        # set by the engine when ObsConfig(quality=True): the
        # QualityRecorder whose summary() block rides to_dict(); None keeps
        # the snapshot schema quality-free (and byte-compatible)
        self.quality = None

        self.occupancy_samples: List[int] = []
        self.kv_bytes_samples: List[int] = []
        self.kv_bytes_resident_samples: List[int] = []
        self.pages_in_use_samples: List[int] = []
        self.shared_pages_samples: List[int] = []
        self.host_bytes_samples: List[int] = []
        self.queue_latency_s: List[float] = []
        self.phase_times: Dict[str, List[float]] = {}

    # ------------------------------------------------- legacy read surface
    @property
    def steps(self) -> int:
        return int(self._steps.value)

    @property
    def prefills(self) -> int:
        return int(self._prefills.value)

    @property
    def tokens_generated(self) -> int:
        return int(self._tokens.value)

    @property
    def prompt_tokens_processed(self) -> int:
        return int(self._prompt_tokens.value)

    @property
    def prefill_tokens_compressed(self) -> int:
        return int(self._prefill_compressed.value)

    @property
    def prefill_tokens_skipped(self) -> int:
        return int(self._prefill_skipped.value)

    @property
    def requests_completed(self) -> int:
        return int(self._completed.value)

    @property
    def admission_rejections(self) -> int:
        return int(self._rejections.value)

    @property
    def prefix_hits(self) -> int:
        return int(self._prefix_hits.value)

    @property
    def prefix_misses(self) -> int:
        return int(self._prefix_misses.value)

    @property
    def pages_aliased(self) -> int:
        return int(self._pages_aliased.value)

    @property
    def pages_copied(self) -> int:
        return int(self._pages_copied.value)

    @property
    def bytes_deduped(self) -> int:
        return int(self._bytes_deduped.value)

    @property
    def prefix_pages_evicted(self) -> int:
        return int(self._prefix_evicted.value)

    @property
    def pages_demoted(self) -> int:
        return int(self._demoted.value)

    @property
    def pages_promoted(self) -> int:
        return int(self._promoted.value)

    @property
    def promote_stall_steps(self) -> int:
        return int(self._stalls.value)

    @property
    def compile_s(self) -> float:
        return self._compile_s.value

    # ------------------------------------------------------------- clocks
    def start_clock(self) -> None:
        """Start the throughput clock (idempotent) — called on the first
        engine step / admission, NOT at construction, so ``elapsed_s`` and
        ``tokens_per_s`` exclude setup; ``setup_s`` reports that gap."""
        if self.started_at is None:
            self.started_at = time.perf_counter()

    @property
    def elapsed_s(self) -> float:
        if self.started_at is None:
            return 0.0
        return time.perf_counter() - self.started_at

    @property
    def setup_s(self) -> float:
        """Construction -> first step/admission gap (0 until the clock
        starts): engine setup the old always-on clock silently charged to
        throughput."""
        if self.started_at is None:
            return 0.0
        return self.started_at - self.created_at

    def record_compile(self, seconds: float) -> None:
        """One jitted entry point's first-trace compilation finished inside
        a timed region — accounted separately so steady-state throughput
        (``tokens_per_s_ex_compile``) is measurable on short runs."""
        self._compile_s.inc(seconds)

    def record_phase(self, name: str, seconds: float) -> None:
        """One engine-step phase's wall time (see :data:`PHASES`)."""
        self.phase_times.setdefault(name, []).append(seconds)
        self.registry.histogram("lexico_step_phase_seconds",
                                "engine.step() phase wall time",
                                phase=name).observe(seconds)

    # ----------------------------------------------------------- recording
    def sample_step(self, *, occupancy: int, kv_bytes_in_flight: int,
                    kv_bytes_resident: int = 0, pages_in_use: int = 0,
                    shared_pages: int = 0, host_bytes_resident: int = 0) -> None:
        """Record one pooled decode step.

        ``shared_pages``: physical pages currently referenced by >= 2
        holders among live slots (the dedup the prefix cache is buying
        right now). ``host_bytes_resident``: bytes the host swap tier holds
        right now (device-resident bytes live in ``kv_bytes_resident``).
        """
        self.start_clock()
        self._steps.inc()
        self.occupancy_samples.append(occupancy)
        self.kv_bytes_samples.append(kv_bytes_in_flight)
        self.kv_bytes_resident_samples.append(kv_bytes_resident)
        self.pages_in_use_samples.append(pages_in_use)
        self.shared_pages_samples.append(shared_pages)
        self.host_bytes_samples.append(host_bytes_resident)
        r = self.registry
        r.gauge("lexico_slot_occupancy", "active slots").set(occupancy)
        r.gauge("lexico_kv_bytes_in_flight",
                "paper-accounting bytes held by active slots"
                ).set(kv_bytes_in_flight)
        r.gauge("lexico_kv_bytes_resident",
                "layout bytes resident, by tier",
                tier="device").set(kv_bytes_resident)
        r.gauge("lexico_kv_bytes_resident",
                "layout bytes resident, by tier",
                tier="host").set(host_bytes_resident)
        r.gauge("lexico_pages_in_use", "pool pages allocated").set(pages_in_use)
        r.gauge("lexico_shared_pages",
                "physical pages with >= 2 holders").set(shared_pages)

    def record_token(self, tier: int) -> None:
        """One token sampled by a slot whose request runs sparsity ``tier``
        (the per-tier breakdown is the registry's labeled family)."""
        self._tokens.inc()
        self.registry.counter("lexico_tier_tokens_generated_total",
                              "tokens sampled, by sparsity tier",
                              tier=tier).inc()

    def record_prompt_tokens(self, n: int) -> None:
        self._prompt_tokens.inc(n)

    def record_prefill_compressed(self, n: int) -> None:
        self._prefill_compressed.inc(n)

    def record_swap(self, *, demoted: int = 0, promoted: int = 0,
                    stalls: int = 0) -> None:
        """Tier traffic of one engine step: pages moved device->host /
        host->device, plus slots that stalled waiting for residency."""
        self._demoted.inc(demoted)
        self._promoted.inc(promoted)
        self._stalls.inc(stalls)

    def record_admission(self, queue_latency_s: float) -> None:
        """One request spliced into a slot (``queue_latency_s`` = time from
        submission to admission)."""
        self.start_clock()
        self._prefills.inc()
        self.queue_latency_s.append(queue_latency_s)
        self._queue_latency.observe(queue_latency_s)

    def record_rejection(self) -> None:
        """One head-of-line admission failure (request stays queued)."""
        self._rejections.inc()

    def record_prefix_share(self, *, aliased: int, copied: int,
                            skipped_codes: int, bytes_deduped: int) -> None:
        """One admission's sharing outcome (no-op counters stay at zero when
        sharing is off)."""
        if aliased or copied or skipped_codes:
            self._prefix_hits.inc()
        else:
            self._prefix_misses.inc()
        self._pages_aliased.inc(aliased)
        self._pages_copied.inc(copied)
        self._prefill_skipped.inc(skipped_codes)
        self._bytes_deduped.inc(bytes_deduped)

    def record_prefix_evict(self, freed: int, unpinned: int) -> None:
        """One destructive prefix-cache eviction pass (``freed`` pages back
        on the free list, ``unpinned`` index pins dropped)."""
        self._prefix_evicted.inc(unpinned)

    def record_completion(self, tier: Optional[int] = None) -> None:
        self._completed.inc()
        if tier is not None:
            self.registry.counter("lexico_tier_requests_completed_total",
                                  "requests retired, by sparsity tier",
                                  tier=tier).inc()

    # -------------------------------------------------------------- export
    def to_prometheus(self) -> str:
        """Prometheus text exposition of the whole registry."""
        return self.registry.to_prometheus()

    def to_dict(self) -> Dict:
        """Summary dict: rates, means and peaks over the run so far.

        Every key that predates the observability layer is preserved with
        identical semantics; the new keys (percentiles, phase timers,
        setup/compile split) are appended after them.
        """
        el = max(self.elapsed_s, 1e-9)
        occ = self.occupancy_samples or [0]
        kvb = self.kv_bytes_samples or [0]
        res = self.kv_bytes_resident_samples or [0]
        pgs = self.pages_in_use_samples or [0]
        shr = self.shared_pages_samples or [0]
        hst = self.host_bytes_samples or [0]
        lat = self.queue_latency_s or [0.0]
        lookups = self.prefix_hits + self.prefix_misses
        el_ex_compile = max(el - self.compile_s, 1e-9)
        out = {
            "elapsed_s": el,
            "steps": self.steps,
            "prefills": self.prefills,
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens_processed": self.prompt_tokens_processed,
            "tokens_per_s": self.tokens_generated / el,
            "decode_tokens_per_step": (self.tokens_generated / self.steps
                                       if self.steps else 0.0),
            "slot_occupancy_mean": sum(occ) / len(occ),
            "slot_occupancy_peak": max(occ),
            "kv_bytes_in_flight_mean": sum(kvb) / len(kvb),
            "kv_bytes_in_flight_peak": max(kvb),
            "kv_bytes_resident_mean": sum(res) / len(res),
            "kv_bytes_resident_peak": max(res),
            "pages_in_use_peak": max(pgs),
            "queue_latency_s_mean": sum(lat) / len(lat),
            "queue_latency_s_max": max(lat),
            # prefix sharing
            "prefill_tokens_compressed": self.prefill_tokens_compressed,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "shared_page_hit_rate": (self.prefix_hits / lookups
                                     if lookups else 0.0),
            "pages_aliased": self.pages_aliased,
            "pages_copied": self.pages_copied,
            "bytes_deduped": self.bytes_deduped,
            "shared_pages_peak": max(shr),
            # tiered storage (host-memory swap)
            "pages_demoted": self.pages_demoted,
            "pages_promoted": self.pages_promoted,
            "promote_stall_steps": self.promote_stall_steps,
            "host_bytes_resident_mean": sum(hst) / len(hst),
            "host_bytes_resident_peak": max(hst),
        }
        # observability additions (appended — pre-existing keys above are
        # byte-compatible with the pre-obs engine)
        out["queue_latency_s_p50"] = percentile(self.queue_latency_s, 0.50)
        out["queue_latency_s_p99"] = percentile(self.queue_latency_s, 0.99)
        if len(self.queue_latency_s) >= 1000:
            out["queue_latency_s_p999"] = percentile(self.queue_latency_s,
                                                     0.999)
        out["phase_times"] = {name: _summary(samples)
                              for name, samples in self.phase_times.items()}
        out["admission_rejections"] = self.admission_rejections
        out["setup_s"] = self.setup_s
        out["compile_s"] = self.compile_s
        out["tokens_per_s_ex_compile"] = self.tokens_generated / el_ex_compile
        if self.quality is not None:
            out["quality"] = self.quality.summary()
        return out


def _wmean(snaps: List[Dict], key: str, weights: List[float]) -> float:
    pairs = [(s[key], w) for s, w in zip(snaps, weights) if key in s]
    total = sum(w for _, w in pairs)
    if total <= 0:
        vals = [v for v, _ in pairs]
        return sum(vals) / len(vals) if vals else 0.0
    return sum(v * w for v, w in pairs) / total


def _merge_phase(summaries: List[Dict]) -> Dict[str, float]:
    """Pool one phase's per-replica count/mean/p50/p99/max summaries."""
    counts = [s.get("count", 0) for s in summaries]
    n = sum(counts)
    out = {"count": n,
           "mean": (sum(s["mean"] * c for s, c in zip(summaries, counts)) / n
                    if n else 0.0)}
    for q in ("p50", "p99"):
        out[q] = (sum(s[q] * c for s, c in zip(summaries, counts)) / n
                  if n else 0.0)
    out["max"] = max((s.get("max", 0.0) for s in summaries), default=0.0)
    if any("p999" in s for s in summaries):
        have = [(s, c) for s, c in zip(summaries, counts) if "p999" in s]
        hn = sum(c for _, c in have)
        out["p999"] = (sum(s["p999"] * c for s, c in have) / hn
                       if hn else 0.0)
    return out


def merge_snapshots(snaps: List[Dict]) -> Dict:
    """Combine per-replica ``EngineMetrics.to_dict()`` snapshots into one
    fleet-level dict with the **same key schema** as a single engine's.

    Semantics per metric class: counters sum; peaks and wall-clock gauges
    take the max (never summed — per-replica peaks at different instants
    don't coexist); per-step means pool step-weighted; per-admission
    latency stats pool prefill-weighted (percentiles approximately — pool
    raw samples for exact fleet percentiles); rates are *recomputed* from
    the merged numerators/denominators, never averaged. ``tokens_per_s`` =
    total tokens / slowest replica's elapsed — the fleet's aggregate
    throughput under concurrent replicas. ``tokens_per_s_ex_compile``
    subtracts the summed compile time: replicas compiled in one process
    compile sequentially, so total compile wall time is the sum.
    """
    if not snaps:
        raise ValueError("merge_snapshots needs at least one snapshot")
    steps = [float(s.get("steps", 0)) for s in snaps]
    prefills = [float(s.get("prefills", 0)) for s in snaps]
    out: Dict = {}
    out["elapsed_s"] = max(s["elapsed_s"] for s in snaps)
    for k in ("steps", "prefills", "requests_completed", "tokens_generated",
              "prompt_tokens_processed"):
        out[k] = sum(s[k] for s in snaps)
    el = max(out["elapsed_s"], 1e-9)
    out["tokens_per_s"] = out["tokens_generated"] / el
    out["decode_tokens_per_step"] = (out["tokens_generated"] / out["steps"]
                                     if out["steps"] else 0.0)
    out["slot_occupancy_mean"] = _wmean(snaps, "slot_occupancy_mean", steps)
    out["slot_occupancy_peak"] = max(s["slot_occupancy_peak"] for s in snaps)
    out["kv_bytes_in_flight_mean"] = _wmean(
        snaps, "kv_bytes_in_flight_mean", steps)
    out["kv_bytes_in_flight_peak"] = max(
        s["kv_bytes_in_flight_peak"] for s in snaps)
    out["kv_bytes_resident_mean"] = _wmean(
        snaps, "kv_bytes_resident_mean", steps)
    out["kv_bytes_resident_peak"] = max(
        s["kv_bytes_resident_peak"] for s in snaps)
    out["pages_in_use_peak"] = max(s["pages_in_use_peak"] for s in snaps)
    out["queue_latency_s_mean"] = _wmean(
        snaps, "queue_latency_s_mean", prefills)
    out["queue_latency_s_max"] = max(
        s["queue_latency_s_max"] for s in snaps)
    for k in ("prefill_tokens_compressed", "prefill_tokens_skipped",
              "prefix_hits", "prefix_misses"):
        out[k] = sum(s[k] for s in snaps)
    lookups = out["prefix_hits"] + out["prefix_misses"]
    out["shared_page_hit_rate"] = (out["prefix_hits"] / lookups
                                   if lookups else 0.0)
    for k in ("pages_aliased", "pages_copied", "bytes_deduped"):
        out[k] = sum(s[k] for s in snaps)
    out["shared_pages_peak"] = max(s["shared_pages_peak"] for s in snaps)
    for k in ("pages_demoted", "pages_promoted", "promote_stall_steps"):
        out[k] = sum(s[k] for s in snaps)
    out["host_bytes_resident_mean"] = _wmean(
        snaps, "host_bytes_resident_mean", steps)
    out["host_bytes_resident_peak"] = max(
        s["host_bytes_resident_peak"] for s in snaps)
    out["queue_latency_s_p50"] = _wmean(
        snaps, "queue_latency_s_p50", prefills)
    out["queue_latency_s_p99"] = _wmean(
        snaps, "queue_latency_s_p99", prefills)
    if any("queue_latency_s_p999" in s for s in snaps):
        out["queue_latency_s_p999"] = _wmean(
            snaps, "queue_latency_s_p999", prefills)
    phases: Dict[str, List[Dict]] = {}
    for s in snaps:
        for name, summary in s.get("phase_times", {}).items():
            phases.setdefault(name, []).append(summary)
    out["phase_times"] = {name: _merge_phase(v) for name, v in phases.items()}
    out["admission_rejections"] = sum(s["admission_rejections"] for s in snaps)
    out["setup_s"] = sum(s["setup_s"] for s in snaps)
    out["compile_s"] = sum(s["compile_s"] for s in snaps)
    el_ex = max(el - out["compile_s"], 1e-9)
    out["tokens_per_s_ex_compile"] = out["tokens_generated"] / el_ex
    quality_blocks = [s["quality"] for s in snaps if s.get("quality")]
    if quality_blocks:
        from repro.serving.obs.quality import merge_quality_blocks
        out["quality"] = merge_quality_blocks(quality_blocks)
    return out
