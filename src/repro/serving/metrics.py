"""Engine metrics: throughput, occupancy, KV bytes in flight, queue latency.

Host-side counters sampled once per engine step — no device syncs beyond
what the step already does. ``kv_bytes_in_flight`` uses the paper's exact
accounting over the *current* per-slot token counts (not the projected
completion-time bytes the scheduler reserves), so the gap between the two is
the admission controller's safety margin. ``kv_bytes_resident`` is what the
same slots *hold* in their storage layout — pages actually bound under paged
storage (deduplicated: a shared page counts once no matter how many slots
alias it), full padded stripes under contiguous — i.e. the capacity a
right-sized pool must provision; resident-vs-paper is the fragmentation cost
of the storage layout.

Prefix sharing adds admission-time counters: trie hits/misses, pages
aliased / copied-on-write, compressed positions whose prefill OMP was
skipped, and the paper-accounting bytes deduplicated by aliasing.

Tiered storage (``repro.serving.swap``) adds the two-tier counters: pages
demoted to / promoted from the host tier, ``host_bytes_resident`` sampled
per step (the host tier's real footprint — ``kv_bytes_resident`` stays
device-only, so the two never double-count a page), and
``promote_stall_steps`` — slot-steps lost waiting for a swapped page's
device residency (the latency cost oversubscription pays).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class EngineMetrics:
    """Aggregates one engine's serving counters; ``to_dict`` summarizes.

    Counter fields are plain ints bumped by the engine; ``*_samples`` lists
    hold one entry per pooled decode step.
    """
    started_at: float = dataclasses.field(default_factory=time.perf_counter)
    steps: int = 0
    prefills: int = 0
    tokens_generated: int = 0
    prompt_tokens_processed: int = 0
    # compressed positions OMP-encoded at prefill vs skipped via sharing
    prefill_tokens_compressed: int = 0
    prefill_tokens_skipped: int = 0
    requests_completed: int = 0
    # prefix sharing (admission-time)
    prefix_hits: int = 0
    prefix_misses: int = 0
    pages_aliased: int = 0
    pages_copied: int = 0
    bytes_deduped: int = 0
    # tiered storage (host-memory swap)
    pages_demoted: int = 0
    pages_promoted: int = 0
    promote_stall_steps: int = 0
    occupancy_samples: List[int] = dataclasses.field(default_factory=list)
    kv_bytes_samples: List[int] = dataclasses.field(default_factory=list)
    kv_bytes_resident_samples: List[int] = dataclasses.field(default_factory=list)
    pages_in_use_samples: List[int] = dataclasses.field(default_factory=list)
    shared_pages_samples: List[int] = dataclasses.field(default_factory=list)
    host_bytes_samples: List[int] = dataclasses.field(default_factory=list)
    queue_latency_s: List[float] = dataclasses.field(default_factory=list)

    def sample_step(self, *, occupancy: int, kv_bytes_in_flight: int,
                    kv_bytes_resident: int = 0, pages_in_use: int = 0,
                    shared_pages: int = 0, host_bytes_resident: int = 0) -> None:
        """Record one pooled decode step.

        ``shared_pages``: physical pages currently referenced by >= 2
        holders among live slots (the dedup the prefix cache is buying
        right now). ``host_bytes_resident``: bytes the host swap tier holds
        right now (device-resident bytes live in ``kv_bytes_resident``).
        """
        self.steps += 1
        self.occupancy_samples.append(occupancy)
        self.kv_bytes_samples.append(kv_bytes_in_flight)
        self.kv_bytes_resident_samples.append(kv_bytes_resident)
        self.pages_in_use_samples.append(pages_in_use)
        self.shared_pages_samples.append(shared_pages)
        self.host_bytes_samples.append(host_bytes_resident)

    def record_swap(self, *, demoted: int = 0, promoted: int = 0,
                    stalls: int = 0) -> None:
        """Tier traffic of one engine step: pages moved device->host /
        host->device, plus slots that stalled waiting for residency."""
        self.pages_demoted += demoted
        self.pages_promoted += promoted
        self.promote_stall_steps += stalls

    def record_admission(self, queue_latency_s: float) -> None:
        """One request spliced into a slot (``queue_latency_s`` = time from
        submission to admission)."""
        self.prefills += 1
        self.queue_latency_s.append(queue_latency_s)

    def record_prefix_share(self, *, aliased: int, copied: int,
                            skipped_codes: int, bytes_deduped: int) -> None:
        """One admission's sharing outcome (no-op counters stay at zero when
        sharing is off)."""
        if aliased or copied or skipped_codes:
            self.prefix_hits += 1
        else:
            self.prefix_misses += 1
        self.pages_aliased += aliased
        self.pages_copied += copied
        self.prefill_tokens_skipped += skipped_codes
        self.bytes_deduped += bytes_deduped

    def record_completion(self) -> None:
        self.requests_completed += 1

    @property
    def elapsed_s(self) -> float:
        return time.perf_counter() - self.started_at

    def to_dict(self) -> Dict:
        """Summary dict: rates, means and peaks over the run so far."""
        el = max(self.elapsed_s, 1e-9)
        occ = self.occupancy_samples or [0]
        kvb = self.kv_bytes_samples or [0]
        res = self.kv_bytes_resident_samples or [0]
        pgs = self.pages_in_use_samples or [0]
        shr = self.shared_pages_samples or [0]
        hst = self.host_bytes_samples or [0]
        lat = self.queue_latency_s or [0.0]
        lookups = self.prefix_hits + self.prefix_misses
        return {
            "elapsed_s": el,
            "steps": self.steps,
            "prefills": self.prefills,
            "requests_completed": self.requests_completed,
            "tokens_generated": self.tokens_generated,
            "prompt_tokens_processed": self.prompt_tokens_processed,
            "tokens_per_s": self.tokens_generated / el,
            "decode_tokens_per_step": (self.tokens_generated / self.steps
                                       if self.steps else 0.0),
            "slot_occupancy_mean": sum(occ) / len(occ),
            "slot_occupancy_peak": max(occ),
            "kv_bytes_in_flight_mean": sum(kvb) / len(kvb),
            "kv_bytes_in_flight_peak": max(kvb),
            "kv_bytes_resident_mean": sum(res) / len(res),
            "kv_bytes_resident_peak": max(res),
            "pages_in_use_peak": max(pgs),
            "queue_latency_s_mean": sum(lat) / len(lat),
            "queue_latency_s_max": max(lat),
            # prefix sharing
            "prefill_tokens_compressed": self.prefill_tokens_compressed,
            "prefill_tokens_skipped": self.prefill_tokens_skipped,
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "shared_page_hit_rate": (self.prefix_hits / lookups
                                     if lookups else 0.0),
            "pages_aliased": self.pages_aliased,
            "pages_copied": self.pages_copied,
            "bytes_deduped": self.bytes_deduped,
            "shared_pages_peak": max(shr),
            # tiered storage (host-memory swap)
            "pages_demoted": self.pages_demoted,
            "pages_promoted": self.pages_promoted,
            "promote_stall_steps": self.promote_stall_steps,
            "host_bytes_resident_mean": sum(hst) / len(hst),
            "host_bytes_resident_peak": max(hst),
        }
