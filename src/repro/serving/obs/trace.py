"""Request-lifecycle tracing in Chrome/Perfetto trace-event format.

One ``TraceRecorder`` per engine accumulates trace events in memory and
serialises them as the Chrome ``traceEvents`` JSON that both
``chrome://tracing`` and https://ui.perfetto.dev load directly.

Track layout
------------
* ``tid 0`` — the engine track.  Every ``engine.step()`` phase (admit,
  prepare_slots, decode_dispatch, host_sync, consume_logits, trim) is a
  complete ("X") event; pool-level instants (demote, promote,
  prefix_evict, reject) land here too.
* ``tid rid+1`` — one track per request.  The outer ``request`` span
  covers submit→retire, with a ``queued`` child span (submit→admission),
  a ``prefill`` complete event, one ``decode`` complete event per engine
  step the request participated in, and instants for page aliasing, CoW
  copies, and promote stalls.

Counter ("C") tracks ride the engine track when quality telemetry is on
(``ObsConfig(quality=True)``): ``prefill_rel_residual`` per admission and
``encode_rel_residual`` / ``encode_nnz`` per decode step with at least one
evictee write, each with ``k``/``v`` series — Perfetto renders them as
stacked time-series lanes above the spans.

Timestamps are ``time.perf_counter`` deltas from recorder construction,
scaled to microseconds as the format requires.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

__all__ = ["TraceRecorder", "ENGINE_TID"]

ENGINE_TID = 0
_PID = 1


class TraceRecorder:
    """Accumulates Chrome trace events; all emit methods are O(1) appends."""

    def __init__(self, process_name: str = "lexico-serving") -> None:
        self._t0 = time.perf_counter()
        self._named: set = set()
        self.events: List[Dict] = [
            {"name": "process_name", "ph": "M", "pid": _PID, "tid": ENGINE_TID,
             "args": {"name": process_name}},
        ]
        self.declare_thread(ENGINE_TID, "engine")

    # -- helpers ----------------------------------------------------------
    def _ts(self, t: Optional[float] = None) -> float:
        if t is None:
            t = time.perf_counter()
        return (t - self._t0) * 1e6

    def declare_thread(self, tid: int, name: str) -> None:
        if tid in self._named:
            return
        self._named.add(tid)
        self.events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                            "tid": tid, "args": {"name": name}})

    # -- span emission ----------------------------------------------------
    def begin(self, name: str, tid: int, **args: object) -> None:
        ev: Dict = {"name": name, "ph": "B", "pid": _PID, "tid": tid,
                    "ts": self._ts()}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def end(self, name: str, tid: int, **args: object) -> None:
        ev: Dict = {"name": name, "ph": "E", "pid": _PID, "tid": tid,
                    "ts": self._ts()}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def complete(self, name: str, tid: int, t_start: float, t_end: float,
                 **args: object) -> None:
        """Complete ("X") event from absolute perf_counter endpoints."""
        ev: Dict = {"name": name, "ph": "X", "pid": _PID, "tid": tid,
                    "ts": self._ts(t_start),
                    "dur": max(t_end - t_start, 0.0) * 1e6}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, tid: int, **args: object) -> None:
        ev: Dict = {"name": name, "ph": "i", "pid": _PID, "tid": tid,
                    "ts": self._ts(), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, tid: int, **values: float) -> None:
        """Counter ("C") sample: each kwarg becomes a series on the
        ``name`` counter track (Perfetto draws them as a time series)."""
        self.events.append({"name": name, "ph": "C", "pid": _PID, "tid": tid,
                            "ts": self._ts(), "args": dict(values)})

    # -- export -----------------------------------------------------------
    def to_chrome_trace(self) -> Dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def __len__(self) -> int:
        return len(self.events)
