"""Labeled metrics registry: Counter / Gauge / Histogram families.

The serving engine's ``EngineMetrics`` is a façade over one of these
registries.  A *family* is a named metric with a fixed set of label keys;
each distinct label-value combination materialises one instrument.  The
design goals, in order:

  1. **Cheap on the hot path.**  ``Counter.inc`` is one float add;
     ``Histogram.observe`` is one list append.  No locks (the engine is
     single-threaded), no string formatting until export time.
  2. **Prometheus-compatible export.**  ``to_prometheus()`` emits the text
     exposition format; histograms are exported as summaries (quantiles
     computed at scrape time from the raw samples — sample counts here are
     small enough that we keep them all rather than pre-bucketing).
  3. **Stable snapshots.**  ``snapshot()`` returns a flat dict for JSON
     emission from benchmarks.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
]


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of ``samples`` (q in [0, 1]); 0.0 if empty."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    k = max(int(math.ceil(q * len(xs))) - 1, 0)
    return float(xs[min(k, len(xs) - 1)])


class Counter:
    """Monotonically non-decreasing value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Gauge:
    """Point-in-time value (set, not accumulated)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Raw-sample histogram; quantiles are computed at export time."""

    __slots__ = ("samples",)

    def __init__(self) -> None:
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def total(self) -> float:
        return float(sum(self.samples))

    def percentile(self, q: float) -> float:
        return percentile(self.samples, q)


_KINDS = {Counter: "counter", Gauge: "gauge", Histogram: "summary"}

LabelKey = Tuple[Tuple[str, str], ...]


class _Family:
    __slots__ = ("name", "kind", "help", "label_keys", "instruments")

    def __init__(self, name: str, kind: str, help_: str,
                 label_keys: Tuple[str, ...]) -> None:
        self.name = name
        self.kind = kind
        self.help = help_
        self.label_keys = label_keys
        self.instruments: Dict[LabelKey, object] = {}


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt_labels(labels: LabelKey, extra: Optional[Tuple[str, str]] = None) -> str:
    parts = [f'{k}="{_escape(str(v))}"' for k, v in labels]
    if extra is not None:
        parts.append(f'{extra[0]}="{extra[1]}"')
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class MetricsRegistry:
    """Ordered collection of metric families keyed by name."""

    QUANTILES = (0.5, 0.9, 0.99)

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}

    # -- instrument constructors ------------------------------------------
    def counter(self, name: str, help_: str = "", **labels: object) -> Counter:
        return self._instrument(Counter, name, help_, labels)

    def gauge(self, name: str, help_: str = "", **labels: object) -> Gauge:
        return self._instrument(Gauge, name, help_, labels)

    def histogram(self, name: str, help_: str = "", **labels: object) -> Histogram:
        return self._instrument(Histogram, name, help_, labels)

    def _instrument(self, cls, name: str, help_: str, labels: Dict[str, object]):
        fam = self._families.get(name)
        keys = tuple(sorted(labels))
        if fam is None:
            fam = _Family(name, _KINDS[cls], help_, keys)
            self._families[name] = fam
        else:
            if fam.kind != _KINDS[cls]:
                raise TypeError(
                    f"metric {name!r} already registered as {fam.kind}")
            if fam.label_keys != keys:
                raise ValueError(
                    f"metric {name!r} label keys {fam.label_keys} != {keys}")
        key: LabelKey = tuple(sorted((k, str(v)) for k, v in labels.items()))
        inst = fam.instruments.get(key)
        if inst is None:
            inst = cls()
            fam.instruments[key] = inst
        return inst

    # -- introspection ----------------------------------------------------
    def families(self) -> List[str]:
        return list(self._families)

    def get(self, name: str, **labels: object):
        """Return an existing instrument or None (never creates)."""
        fam = self._families.get(name)
        if fam is None:
            return None
        key: LabelKey = tuple(sorted((k, str(v)) for k, v in labels.items()))
        return fam.instruments.get(key)

    # -- export -----------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name{label="v"}: value}`` dict for JSON emission."""
        out: Dict[str, float] = {}
        for fam in self._families.values():
            for key, inst in fam.instruments.items():
                base = fam.name + _fmt_labels(key)
                if isinstance(inst, Histogram):
                    out[base + "_count"] = float(inst.count)
                    out[base + "_sum"] = inst.total
                    for q in self.QUANTILES:
                        out[f"{base}_p{int(q * 100)}"] = inst.percentile(q)
                else:
                    out[base] = inst.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (histograms exported as summaries)."""
        lines: List[str] = []
        for fam in self._families.values():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            for key, inst in fam.instruments.items():
                if isinstance(inst, Histogram):
                    for q in self.QUANTILES:
                        labels = _fmt_labels(key, ("quantile", str(q)))
                        lines.append(
                            f"{fam.name}{labels} {_fmt_value(inst.percentile(q))}")
                    base = _fmt_labels(key)
                    lines.append(f"{fam.name}_sum{base} {_fmt_value(inst.total)}")
                    lines.append(f"{fam.name}_count{base} {inst.count}")
                else:
                    lines.append(
                        f"{fam.name}{_fmt_labels(key)} {_fmt_value(inst.value)}")
        return "\n".join(lines) + "\n"
