"""Compression-quality telemetry: streaming residual/nnz sketches, page
quality tags, and dictionary-drift detection.

Lexico's bet is that a universal dictionary keeps reconstruction error low
across inputs. The encoder already computes the evidence — ``OMPResult.resid2``
(squared residual) and ``nnz`` (iterations actually run before the delta
target) — and until now the serving stack discarded both. This module is the
aggregation side of that signal:

* ``StreamingHist`` — a fixed-bin histogram sketch with *exact* integer-count
  merge (associative/commutative), bounded-error quantiles (right bin edge,
  so at most one bin width above the empirical quantile for in-range data),
  and NaN/under/overflow accounting. Serializable, so snapshots merge across
  a replica fleet.
* ``QualityRecorder`` — per-(layer, role, phase, tier) residual and nnz
  sketches plus delta-attainment counters, fed by the engine from the
  prefill and decode encode paths. Exposes Prometheus families through the
  shared :class:`~repro.serving.obs.registry.MetricsRegistry` and a
  ``summary()`` block that rides ``EngineMetrics.to_dict()``.
* ``PageQuality`` — the per-page tag (count / mean / max relative residual,
  mean nnz) stamped at encode and carried by the allocator and host store
  across alias, CoW, demote and promote.
* ``DriftMonitor`` — total-variation distance between the live residual
  distribution and a frozen calibration baseline: the dictionary-staleness
  signal (ROADMAP item 5). Score ≈ 0 on calibration-like traffic; → 1 as
  live residuals stop looking like the baseline.
* ``merge_quality_blocks`` — fleet merge used by
  ``metrics.merge_snapshots`` / ``router.quality_summary``; exact for every
  counter because the underlying sketches merge exactly.

Everything here is plain numpy on host — nothing is jitted, nothing imports
jax. The device side only threads ``(resid2, nnz)`` out of existing encodes
(see ``core/sparse_cache.py``), so enabling quality telemetry changes no
compiled computation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "StreamingHist",
    "PageQuality",
    "DriftMonitor",
    "QualityRecorder",
    "merge_quality_blocks",
    "layer_table_from_block",
]

# Default sketch layout for relative residuals: rel = sqrt(resid2)/||k|| is
# ~always in [0, 1); 1.5 leaves headroom for pathological vectors without
# wasting resolution, and 64 bins bounds quantile error at ~0.023.
REL_BINS = 64
REL_HI = 1.5

_ROLES = ("k", "v")


class StreamingHist:
    """Fixed-bin streaming histogram with exact merge and bounded quantiles.

    ``n_bins`` uniform bins over ``[lo, hi)`` plus underflow/overflow buckets
    and a NaN counter. All counts are integers, so :meth:`merge` is exact —
    associative and commutative — which is what lets per-replica snapshots
    combine into a fleet view without approximation error. ``quantile``
    returns the right edge of the bin holding the requested rank: an upper
    bound on the empirical quantile, tight to one bin width for in-range
    values (the overflow bucket reports the exactly-tracked max).
    """

    __slots__ = ("lo", "hi", "n_bins", "counts", "underflow", "overflow",
                 "nan_count", "vmin", "vmax", "total_sum")

    def __init__(self, lo: float, hi: float, n_bins: int):
        if not hi > lo:
            raise ValueError(f"need hi > lo, got [{lo}, {hi})")
        if n_bins < 1:
            raise ValueError(f"need n_bins >= 1, got {n_bins}")
        self.lo = float(lo)
        self.hi = float(hi)
        self.n_bins = int(n_bins)
        self.counts = np.zeros(self.n_bins, np.int64)
        self.underflow = 0
        self.overflow = 0
        self.nan_count = 0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.total_sum = 0.0

    @property
    def count(self) -> int:
        """Finite observations recorded (NaNs are counted separately)."""
        return self.underflow + self.overflow + int(self.counts.sum())

    @property
    def mean(self) -> float:
        n = self.count
        return self.total_sum / n if n else math.nan

    def add(self, values: Any) -> None:
        a = np.asarray(values, np.float64).ravel()
        if a.size == 0:
            return
        nan = np.isnan(a)
        n_nan = int(nan.sum())
        if n_nan:
            self.nan_count += n_nan
            a = a[~nan]
        if a.size == 0:
            return
        self.vmin = min(self.vmin, float(a.min()))
        self.vmax = max(self.vmax, float(a.max()))
        self.total_sum += float(a.sum())
        scaled = (a - self.lo) / (self.hi - self.lo) * self.n_bins
        # clip before the int cast so +/-inf land in the flow buckets instead
        # of wrapping through undefined float->int64 conversion
        idx = np.clip(np.floor(scaled), -1, self.n_bins).astype(np.int64)
        self.underflow += int((idx < 0).sum())
        self.overflow += int((idx >= self.n_bins).sum())
        inr = idx[(idx >= 0) & (idx < self.n_bins)]
        if inr.size:
            self.counts += np.bincount(inr, minlength=self.n_bins)

    def _check_layout(self, other: "StreamingHist") -> None:
        if (self.lo, self.hi, self.n_bins) != (other.lo, other.hi, other.n_bins):
            raise ValueError(
                f"bin layout mismatch: [{self.lo},{self.hi})x{self.n_bins} vs "
                f"[{other.lo},{other.hi})x{other.n_bins}")

    def merge(self, other: "StreamingHist") -> "StreamingHist":
        """Exact combined histogram (new object; neither input mutated)."""
        self._check_layout(other)
        out = StreamingHist(self.lo, self.hi, self.n_bins)
        out.counts = self.counts + other.counts
        out.underflow = self.underflow + other.underflow
        out.overflow = self.overflow + other.overflow
        out.nan_count = self.nan_count + other.nan_count
        out.vmin = min(self.vmin, other.vmin)
        out.vmax = max(self.vmax, other.vmax)
        out.total_sum = self.total_sum + other.total_sum
        return out

    def quantile(self, q: float) -> float:
        """Upper bound on the empirical q-quantile (NaN if empty).

        In-range ranks resolve to the right edge of their bin; the underflow
        bucket resolves to ``lo`` and the overflow bucket to the exact
        observed max.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        n = self.count
        if n == 0:
            return math.nan
        rank = min(n - 1, max(0, int(math.ceil(q * n)) - 1))
        if rank < self.underflow:
            return self.lo
        c = self.underflow
        width = (self.hi - self.lo) / self.n_bins
        for i in range(self.n_bins):
            c += int(self.counts[i])
            if rank < c:
                edge = self.lo + (i + 1) * width
                return min(edge, self.vmax)
        return self.vmax

    def distance(self, other: "StreamingHist") -> float:
        """Total-variation distance between the normalized histograms, in
        [0, 1]. NaN if either side is empty."""
        self._check_layout(other)
        n1, n2 = self.count, other.count
        if n1 == 0 or n2 == 0:
            return math.nan
        p = np.concatenate(([self.underflow], self.counts, [self.overflow])) / n1
        q = np.concatenate(([other.underflow], other.counts, [other.overflow])) / n2
        return float(0.5 * np.abs(p - q).sum())

    def to_dict(self) -> Dict[str, Any]:
        return {
            "lo": self.lo, "hi": self.hi, "n_bins": self.n_bins,
            "counts": [int(c) for c in self.counts],
            "underflow": int(self.underflow), "overflow": int(self.overflow),
            "nan_count": int(self.nan_count),
            "vmin": self.vmin, "vmax": self.vmax, "sum": self.total_sum,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "StreamingHist":
        h = cls(d["lo"], d["hi"], d["n_bins"])
        counts = np.asarray(d["counts"], np.int64)
        if counts.shape != (h.n_bins,):
            raise ValueError(f"counts shape {counts.shape} != ({h.n_bins},)")
        h.counts = counts.copy()
        h.underflow = int(d["underflow"])
        h.overflow = int(d["overflow"])
        h.nan_count = int(d["nan_count"])
        h.vmin = float(d["vmin"])
        h.vmax = float(d["vmax"])
        h.total_sum = float(d["sum"])
        return h


@dataclasses.dataclass
class PageQuality:
    """Per-page quality tag: running stats over every (layer, head, role)
    encode whose code landed on the page.

    Stamped by the engine at prefill admission, updated on every decode
    evictee write, copied on CoW, and carried by value across demote /
    promote (the host store holds it while the page lives on the host tier).
    Aliased pages share one tag — the codes are physically shared, so the
    quality is too.
    """
    count: int = 0
    rel_sum: float = 0.0
    rel_max: float = 0.0
    nnz_sum: int = 0

    def add(self, rel: Any, nnz: Any) -> None:
        r = np.asarray(rel, np.float64).ravel()
        z = np.asarray(nnz, np.int64).ravel()
        if r.size == 0:
            return
        self.count += int(r.size)
        self.rel_sum += float(r.sum())
        self.rel_max = max(self.rel_max, float(r.max()))
        self.nnz_sum += int(z.sum())

    @property
    def rel_mean(self) -> float:
        return self.rel_sum / self.count if self.count else 0.0

    @property
    def nnz_mean(self) -> float:
        return self.nnz_sum / self.count if self.count else 0.0

    def merge(self, other: "PageQuality") -> "PageQuality":
        return PageQuality(
            count=self.count + other.count,
            rel_sum=self.rel_sum + other.rel_sum,
            rel_max=max(self.rel_max, other.rel_max),
            nnz_sum=self.nnz_sum + other.nnz_sum,
        )

    def copy(self) -> "PageQuality":
        return dataclasses.replace(self)

    def to_event(self) -> Dict[str, Any]:
        """Fields for a ``page_quality`` journal event."""
        return {
            "count": int(self.count),
            "rel_mean": float(self.rel_mean),
            "rel_max": float(self.rel_max),
            "nnz_mean": float(self.nnz_mean),
        }


class DriftMonitor:
    """Dictionary-staleness signal: live residual distribution vs a frozen
    calibration baseline.

    The baseline is a :class:`StreamingHist` of relative residuals captured
    on calibration traffic (or loaded from a saved snapshot). ``score`` is
    the total-variation distance in [0, 1]: ≈ 0 when live traffic encodes as
    well as calibration did, approaching 1 when the residual mass has moved —
    the trigger for retraining/hot-swapping the dictionary (ROADMAP item 5).
    """

    def __init__(self, baseline: StreamingHist):
        if baseline.count == 0:
            raise ValueError("drift baseline histogram is empty")
        self.baseline = baseline

    def score(self, live: StreamingHist) -> float:
        return live.distance(self.baseline)

    def to_dict(self) -> Dict[str, Any]:
        return {"baseline": self.baseline.to_dict()}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "DriftMonitor":
        return cls(StreamingHist.from_dict(d["baseline"]))


def _hist_stats(h: StreamingHist) -> Dict[str, Any]:
    if h.count == 0:
        return {"count": 0, "mean": None, "p50": None, "p99": None, "max": None}
    return {
        "count": int(h.count),
        "mean": float(h.mean),
        "p50": float(h.quantile(0.5)),
        "p99": float(h.quantile(0.99)),
        "max": float(h.vmax),
    }


class QualityRecorder:
    """Host-side aggregator for live encode-quality telemetry.

    One per engine when ``ObsConfig(quality=True)``; holds a
    :class:`StreamingHist` pair (relative residual, nnz) per
    ``(layer, role, phase, tier)`` plus delta-attainment counters per tier.
    The engine feeds it numpy views of the quality aux returned by the
    jitted prefill/decode functions; nothing here touches jax.
    """

    def __init__(self, n_layers: int, s_max: int, *, registry: Any = None,
                 rel_hi: float = REL_HI, rel_bins: int = REL_BINS):
        self.n_layers = int(n_layers)
        self.s_max = int(s_max)
        self.registry = registry
        self.rel_hi = float(rel_hi)
        self.rel_bins = int(rel_bins)
        # key: (layer, role, phase, tier)
        self._rel: Dict[Tuple[int, str, str, int], StreamingHist] = {}
        self._nnz: Dict[Tuple[int, str, str, int], StreamingHist] = {}
        # tier -> [encodes, delta_attained]
        self._tier_counts: Dict[int, List[int]] = {}
        self._drift: Optional[DriftMonitor] = None
        # decode-path deferral: the hot loop appends (rel, nnz) slices per
        # (role, tier) here and the sketch fold happens lazily on access —
        # per-step numpy overhead on (L, 1, KV)-sized arrays costs more than
        # the decode dispatch tolerates (see the quality-gate 2% budget)
        self._pending: Dict[Tuple[str, int],
                            List[Tuple[np.ndarray, np.ndarray]]] = {}
        self._pending_steps = 0

    # -- recording ---------------------------------------------------------

    def _record(self, *, phase: str, layer: int, role: str, tier: int,
                rel: np.ndarray, nnz: np.ndarray, cap: int) -> Tuple[int, int]:
        key = (layer, role, phase, tier)
        h = self._rel.get(key)
        if h is None:
            h = self._rel[key] = StreamingHist(0.0, self.rel_hi, self.rel_bins)
        h.add(rel)
        hn = self._nnz.get(key)
        if hn is None:
            # one unit-width bin per nnz value 0..s_max => exact counts
            hn = self._nnz[key] = StreamingHist(0.0, float(self.s_max + 1),
                                                self.s_max + 1)
        hn.add(nnz)
        n = int(nnz.size)
        attained = int((np.asarray(nnz, np.int64) < cap).sum())
        tc = self._tier_counts.setdefault(int(tier), [0, 0])
        tc[0] += n
        tc[1] += attained
        return n, attained

    def _emit_registry(self, phase: str, role: str, n: int, attained: int,
                       rel_mean: float) -> None:
        # one registry touch per (phase, role) per engine call — NOT per
        # layer; the family labels don't carry the layer, so batching the
        # increments keeps the hot-loop cost flat in n_layers
        if self.registry is None or n == 0:
            return
        self.registry.counter(
            "lexico_quality_encodes_total",
            "Sparse-code encodes observed by quality telemetry.",
            phase=phase, role=role).inc(n)
        self.registry.counter(
            "lexico_quality_delta_attained_total",
            "Encodes that met the delta target before the sparsity cap.",
            phase=phase, role=role).inc(attained)
        self.registry.gauge(
            "lexico_quality_rel_residual_mean",
            "Mean relative residual of the latest encode batch.",
            phase=phase, role=role).set(rel_mean)

    def record_prefill(self, aux: Mapping[str, np.ndarray], *, tier: int) -> None:
        """Record one admission's prefill encode quality.

        ``aux`` arrays are layer-stacked: ``k_rel``/``v_rel``/``k_nnz``/
        ``v_nnz`` of shape (L, B, KV, n) where n is the number of compressed
        positions (0 when the whole head was shared-prefix-skipped).
        """
        k_rel = np.asarray(aux["k_rel"])
        if k_rel.size == 0:
            return
        cap = min(int(tier), self.s_max)
        arrs = {k: np.asarray(aux[k]) for k in ("k_rel", "k_nnz", "v_rel", "v_nnz")}
        for role in _ROLES:
            n = att = 0
            for layer in range(k_rel.shape[0]):
                dn, da = self._record(
                    phase="prefill", layer=layer, role=role, tier=int(tier),
                    rel=arrs[f"{role}_rel"][layer],
                    nnz=arrs[f"{role}_nnz"][layer], cap=cap)
                n += dn
                att += da
            self._emit_registry("prefill", role, n, att,
                                float(arrs[f"{role}_rel"].mean()))

    def record_decode(self, aux: Mapping[str, np.ndarray], *,
                      tiers: np.ndarray) -> None:
        """Record one decode step's evictee encode quality.

        ``aux`` arrays are (L, B, KV); ``aux["wrote"]`` is (L, B) (identical
        across layers) marking slots whose evictee was actually encoded and
        written this step — rows with a non-full recency buffer or an
        inactive slot ran the encode as a masked no-op and are excluded.
        ``tiers`` is the per-slot (B,) sparsity-tier vector.
        """
        wrote = np.asarray(aux["wrote"])
        w = np.asarray(wrote[0] if wrote.ndim == 2 else wrote, bool)
        rows = np.nonzero(w)[0]
        if rows.size == 0:
            return
        tiers = np.asarray(tiers)
        arrs = {k: np.asarray(aux[k]) for k in ("k_rel", "k_nnz", "v_rel", "v_nnz")}
        for role in _ROLES:
            n = att = 0
            for t in np.unique(tiers[rows]):
                sel = rows[tiers[rows] == t]
                cap = min(int(t), self.s_max)
                rel = arrs[f"{role}_rel"][:, sel]          # (L, |sel|, KV)
                nnz = arrs[f"{role}_nnz"][:, sel]
                self._pending.setdefault((role, int(t)), []).append((rel, nnz))
                dn = int(nnz.size)
                da = int((nnz < cap).sum())
                tc = self._tier_counts.setdefault(int(t), [0, 0])
                tc[0] += dn
                tc[1] += da
                n += dn
                att += da
            self._emit_registry("decode", role, n, att,
                                float(arrs[f"{role}_rel"][:, rows].mean()))
        self._pending_steps += 1
        if self._pending_steps >= 512:      # bound deferred memory
            self._flush()

    def _flush(self) -> None:
        """Fold deferred decode-path slices into the per-layer sketches.

        Concatenating a tier's backlog first means each histogram sees one
        large array instead of one tiny array per step — identical counts
        (StreamingHist.add is order-insensitive), amortized numpy overhead.
        """
        pending, self._pending = self._pending, {}
        self._pending_steps = 0
        for (role, tier), blocks in pending.items():
            rel = np.concatenate([r for r, _ in blocks], axis=1)
            nnz = np.concatenate([z for _, z in blocks], axis=1)
            for layer in range(rel.shape[0]):
                key = (layer, role, "decode", tier)
                h = self._rel.get(key)
                if h is None:
                    h = self._rel[key] = StreamingHist(0.0, self.rel_hi,
                                                       self.rel_bins)
                h.add(rel[layer])
                hn = self._nnz.get(key)
                if hn is None:
                    hn = self._nnz[key] = StreamingHist(
                        0.0, float(self.s_max + 1), self.s_max + 1)
                hn.add(nnz[layer])

    # -- aggregation -------------------------------------------------------

    def _merged(self, table: Mapping[Tuple[int, str, str, int], StreamingHist],
                lo: float, hi: float, bins: int, *,
                layer: Optional[int] = None, role: Optional[str] = None,
                phase: Optional[str] = None,
                tier: Optional[int] = None) -> StreamingHist:
        if self._pending:
            self._flush()
        out = StreamingHist(lo, hi, bins)
        for (l, r, p, t), h in table.items():
            if layer is not None and l != layer:
                continue
            if role is not None and r != role:
                continue
            if phase is not None and p != phase:
                continue
            if tier is not None and t != tier:
                continue
            out = out.merge(h)
        return out

    def rel_hist(self, **filt: Any) -> StreamingHist:
        """Merged relative-residual sketch over the selected keys."""
        return self._merged(self._rel, 0.0, self.rel_hi, self.rel_bins, **filt)

    def nnz_hist(self, **filt: Any) -> StreamingHist:
        """Merged nnz sketch over the selected keys."""
        return self._merged(self._nnz, 0.0, float(self.s_max + 1),
                            self.s_max + 1, **filt)

    @property
    def encodes(self) -> int:
        return sum(c for c, _ in self._tier_counts.values())

    @property
    def delta_attained(self) -> int:
        return sum(a for _, a in self._tier_counts.values())

    # -- drift -------------------------------------------------------------

    def set_baseline(self) -> None:
        """Freeze the current aggregate residual distribution as the
        calibration baseline."""
        self._drift = DriftMonitor(self.rel_hist())

    def load_baseline(self, d: Mapping[str, Any]) -> None:
        """Load a baseline from :meth:`baseline_dict` output."""
        self._drift = DriftMonitor(StreamingHist.from_dict(d))

    def baseline_dict(self) -> Optional[Dict[str, Any]]:
        return None if self._drift is None else self._drift.baseline.to_dict()

    def drift_score(self) -> Optional[float]:
        """TV distance of live residuals vs the baseline; None until both a
        baseline and live data exist."""
        if self._drift is None:
            return None
        live = self.rel_hist()
        if live.count == 0:
            return None
        return self._drift.score(live)

    # -- export ------------------------------------------------------------

    def layer_table(self) -> List[Dict[str, Any]]:
        """Per-layer residual/nnz stats, for human-facing printouts."""
        rows = []
        for layer in range(self.n_layers):
            row: Dict[str, Any] = {"layer": layer}
            for role in _ROLES:
                rh = self.rel_hist(layer=layer, role=role)
                nh = self.nnz_hist(layer=layer, role=role)
                row[f"{role}_rel_mean"] = rh.mean if rh.count else math.nan
                row[f"{role}_rel_p99"] = rh.quantile(0.99)
                row[f"{role}_rel_max"] = rh.vmax if rh.count else math.nan
                row[f"{role}_nnz_mean"] = nh.mean if nh.count else math.nan
            rows.append(row)
        return rows

    def summary(self) -> Dict[str, Any]:
        """The ``quality`` block appended to ``EngineMetrics.to_dict()``.

        Carries the full per-layer sketches (as dicts) so fleet merges via
        :func:`merge_quality_blocks` stay exact.
        """
        encodes = self.encodes
        attained = self.delta_attained
        per_layer = []
        for layer in range(self.n_layers):
            per_layer.append({
                "layer": layer,
                "k_rel": self.rel_hist(layer=layer, role="k").to_dict(),
                "v_rel": self.rel_hist(layer=layer, role="v").to_dict(),
                "k_nnz": self.nnz_hist(layer=layer, role="k").to_dict(),
                "v_nnz": self.nnz_hist(layer=layer, role="v").to_dict(),
            })
        return {
            "encodes": int(encodes),
            "delta_attained": int(attained),
            "delta_attained_rate": attained / encodes if encodes else 0.0,
            "tiers": {str(t): {"encodes": int(c), "delta_attained": int(a)}
                      for t, (c, a) in sorted(self._tier_counts.items())},
            "rel_residual": _hist_stats(self.rel_hist()),
            "nnz": _hist_stats(self.nnz_hist()),
            "drift_score": self.drift_score(),
            "per_layer": per_layer,
        }


def _merge_hists(dicts: Sequence[Mapping[str, Any]]) -> StreamingHist:
    h = StreamingHist.from_dict(dicts[0])
    for d in dicts[1:]:
        h = h.merge(StreamingHist.from_dict(d))
    return h


def merge_quality_blocks(blocks: Sequence[Mapping[str, Any]]) -> Dict[str, Any]:
    """Merge per-engine ``quality`` snapshot blocks into one fleet block.

    Counters sum exactly; distribution stats are recomputed from the merged
    per-layer sketches (exact, because :meth:`StreamingHist.merge` is exact);
    ``drift_score`` is the worst (max) per-replica score — one stale replica
    should surface, not be averaged away.
    """
    blocks = [b for b in blocks if b]
    if not blocks:
        return {}
    tiers: Dict[str, Dict[str, int]] = {}
    for b in blocks:
        for t, d in b.get("tiers", {}).items():
            cur = tiers.setdefault(t, {"encodes": 0, "delta_attained": 0})
            cur["encodes"] += int(d["encodes"])
            cur["delta_attained"] += int(d["delta_attained"])
    encodes = sum(d["encodes"] for d in tiers.values())
    attained = sum(d["delta_attained"] for d in tiers.values())

    n_layers = max(len(b.get("per_layer", [])) for b in blocks)
    per_layer: List[Dict[str, Any]] = []
    rel_all: Optional[StreamingHist] = None
    nnz_all: Optional[StreamingHist] = None
    for layer in range(n_layers):
        entry: Dict[str, Any] = {"layer": layer}
        for key in ("k_rel", "v_rel", "k_nnz", "v_nnz"):
            h = _merge_hists([b["per_layer"][layer][key] for b in blocks
                              if layer < len(b.get("per_layer", []))])
            entry[key] = h.to_dict()
            if key.endswith("_rel"):
                rel_all = h if rel_all is None else rel_all.merge(h)
            else:
                nnz_all = h if nnz_all is None else nnz_all.merge(h)
        per_layer.append(entry)

    drifts = [b["drift_score"] for b in blocks if b.get("drift_score") is not None]
    empty = {"count": 0, "mean": None, "p50": None, "p99": None, "max": None}
    return {
        "encodes": int(encodes),
        "delta_attained": int(attained),
        "delta_attained_rate": attained / encodes if encodes else 0.0,
        "tiers": {t: dict(d) for t, d in sorted(tiers.items())},
        "rel_residual": _hist_stats(rel_all) if rel_all is not None else dict(empty),
        "nnz": _hist_stats(nnz_all) if nnz_all is not None else dict(empty),
        "drift_score": max(drifts) if drifts else None,
        "per_layer": per_layer,
    }


def layer_table_from_block(block: Mapping[str, Any]) -> List[Dict[str, Any]]:
    """Rebuild :meth:`QualityRecorder.layer_table` rows from a (possibly
    fleet-merged) ``quality`` snapshot block."""
    rows = []
    for entry in block.get("per_layer", []):
        row: Dict[str, Any] = {"layer": int(entry["layer"])}
        for role in _ROLES:
            rh = StreamingHist.from_dict(entry[f"{role}_rel"])
            nh = StreamingHist.from_dict(entry[f"{role}_nnz"])
            row[f"{role}_rel_mean"] = rh.mean if rh.count else math.nan
            row[f"{role}_rel_p99"] = rh.quantile(0.99)
            row[f"{role}_rel_max"] = rh.vmax if rh.count else math.nan
            row[f"{role}_nnz_mean"] = nh.mean if nh.count else math.nan
        rows.append(row)
    return rows
