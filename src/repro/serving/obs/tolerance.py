"""Bounded-error differential harness: compare a test run against a
reference run and gate on explicit tolerances.

Every serving feature so far is held to *bitwise* token identity against a
feature-off oracle. Deliberately lossy features — cold-tier recompression
(ROADMAP item 4), dictionary hot-swap — break that gate by design, so they
need the next-best contract: a quantified diff (logit max-abs, KL, top-k
overlap, first divergent token) plus a :class:`ToleranceGate` that turns the
diff into a pass/fail with named violations.

The contract the gate enforces:

* a **lossless** run (same computation twice) produces an all-zero
  :class:`DiffReport` and passes any gate;
* an injected lossy perturbation — e.g. :func:`int8_requantize_cache`, which
  round-trips stored sparse-code values through the int8 codec — produces a
  nonzero report that a tight gate *flags* with human-readable violations.

Pure numpy at import time; :func:`int8_requantize_cache` imports jax lazily
so this module stays cheap for host-only consumers (CI artifact checks,
offline journal analysis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "DiffReport",
    "ToleranceGate",
    "compare_logits",
    "token_divergence",
    "diff_runs",
    "int8_requantize_cache",
]


def _softmax(x: np.ndarray) -> np.ndarray:
    x = x - x.max(axis=-1, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=-1, keepdims=True)


@dataclasses.dataclass(frozen=True)
class DiffReport:
    """Quantified difference between a test run and a reference run.

    ``first_divergent_token`` is the earliest position where the two runs'
    emitted tokens differ (-1 = identical; a length mismatch diverges at the
    shorter length). All other fields aggregate over compared positions.
    """
    n_positions: int
    max_abs: float               # max |ref_logit - test_logit| anywhere
    mean_kl: float               # mean KL(softmax(ref) || softmax(test))
    max_kl: float
    topk_overlap: float          # mean fraction of ref top-k kept in test top-k
    first_divergent_token: int   # -1 = token streams identical

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def compare_logits(ref: Any, test: Any, *, k: int = 5,
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-position diff metrics between two logit sequences.

    ``ref``/``test`` are (T, V) (a single (V,) row is treated as T=1).
    Returns ``(max_abs, kl, topk_overlap)``, each of shape (T,).
    """
    ref = np.atleast_2d(np.asarray(ref, np.float64))
    test = np.atleast_2d(np.asarray(test, np.float64))
    if ref.shape != test.shape:
        raise ValueError(f"logit shape mismatch: {ref.shape} vs {test.shape}")
    max_abs = np.abs(ref - test).max(axis=-1)
    p = _softmax(ref)
    q = _softmax(test)
    kl = np.sum(p * (np.log(p + 1e-12) - np.log(q + 1e-12)), axis=-1)
    k = min(int(k), ref.shape[-1])
    ref_top = np.argsort(-ref, axis=-1)[:, :k]
    test_top = np.argsort(-test, axis=-1)[:, :k]
    overlap = np.array([len(set(a.tolist()) & set(b.tolist())) / k
                        for a, b in zip(ref_top, test_top)], np.float64)
    return max_abs, kl, overlap


def token_divergence(ref_tokens: Any, test_tokens: Any) -> int:
    """First position where the token streams differ; -1 if identical.

    A length mismatch counts as divergence at the shorter length.
    """
    a = np.asarray(ref_tokens).ravel()
    b = np.asarray(test_tokens).ravel()
    n = min(a.size, b.size)
    neq = np.nonzero(a[:n] != b[:n])[0]
    if neq.size:
        return int(neq[0])
    if a.size != b.size:
        return n
    return -1


def diff_runs(ref_logits: Any, test_logits: Any,
              ref_tokens: Any = None, test_tokens: Any = None, *,
              k: int = 5) -> DiffReport:
    """Build a :class:`DiffReport` from two runs' logits (and optionally
    their emitted token streams)."""
    max_abs, kl, overlap = compare_logits(ref_logits, test_logits, k=k)
    div = -1
    if ref_tokens is not None and test_tokens is not None:
        div = token_divergence(ref_tokens, test_tokens)
    return DiffReport(
        n_positions=int(max_abs.size),
        max_abs=float(max_abs.max()),
        mean_kl=float(kl.mean()),
        max_kl=float(kl.max()),
        topk_overlap=float(overlap.mean()),
        first_divergent_token=div,
    )


@dataclasses.dataclass(frozen=True)
class ToleranceGate:
    """Pass/fail contract over a :class:`DiffReport`.

    Defaults are fully permissive; a caller opts into each bound. ``check``
    returns the list of violated bounds (empty = pass) so a failed gate can
    report *why* — the API a lossy cold tier wires into its promotion path.
    """
    max_abs: float = math.inf
    max_mean_kl: float = math.inf
    min_topk_overlap: float = 0.0
    require_token_match: bool = False

    def check(self, report: DiffReport) -> List[str]:
        v: List[str] = []
        if report.max_abs > self.max_abs:
            v.append(f"max_abs {report.max_abs:.3e} > {self.max_abs:.3e}")
        if report.mean_kl > self.max_mean_kl:
            v.append(f"mean_kl {report.mean_kl:.3e} > {self.max_mean_kl:.3e}")
        if report.topk_overlap < self.min_topk_overlap:
            v.append(f"topk_overlap {report.topk_overlap:.3f} < "
                     f"{self.min_topk_overlap:.3f}")
        if self.require_token_match and report.first_divergent_token != -1:
            v.append(f"tokens diverge at position {report.first_divergent_token}")
        return v

    def ok(self, report: DiffReport) -> bool:
        return not self.check(report)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def int8_requantize_cache(cache: Any) -> Any:
    """Injected lossy perturbation: round-trip a Lexico cache's stored code
    values through the int8 codec (``core/quant.py``).

    Works on any cache NamedTuple exposing ``k_vals``/``k_idx``/``v_vals``/
    ``v_idx`` (contiguous or paged, single-layer or layer-stacked). Values
    are decoded to fp32, requantized to int8 with a per-vector scale, decoded
    again, and cast back to the original storage dtype. Assumes a scale-free
    storage codec (fp8/fp16); the int8 *storage* codec keeps its scale
    outside the vals array and is not supported here. Note the fp8 grid is
    coarser than a per-vector-scaled int8, so the roundtrip only perturbs
    fp16 (and wider) storage — use ``codec="fp16"`` to inject a visible
    error.
    """
    import jax.numpy as jnp
    from repro.core import quant

    def rq(vals: Any, idx: Any) -> Any:
        code = quant.encode_int8(vals.astype(jnp.float32), idx)
        return quant.decode_vals(code).astype(vals.dtype)

    return cache._replace(
        k_vals=rq(cache.k_vals, cache.k_idx),
        v_vals=rq(cache.v_vals, cache.v_idx),
    )
