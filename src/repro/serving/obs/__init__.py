"""Serving observability: tracing, metrics registry, event journal, roofline.

Four orthogonal instruments over the continuous-batching engine, each
documented in ``docs/observability.md``:

  * :mod:`~repro.serving.obs.registry` — labeled Counter/Gauge/Histogram
    families with Prometheus text exposition; ``EngineMetrics`` is a façade
    over one registry.
  * :mod:`~repro.serving.obs.trace` — request-lifecycle span trees in
    Chrome/Perfetto trace-event JSON (``--trace out.json`` anywhere the
    engine runs).
  * :mod:`~repro.serving.obs.journal` — append-only JSONL journal of
    slot/page lifecycle transitions plus a post-hoc replay invariant
    checker (refcount conservation, leaks, two-tier balance).
  * :mod:`~repro.serving.obs.roofline` — AOT roofline of the engine's
    compiled decode/prefill hot loop via ``repro.roofline``.
  * :mod:`~repro.serving.obs.quality` — compression-quality telemetry:
    streaming residual/nnz histograms per layer/role/phase/tier
    (:class:`QualityRecorder`), per-page quality tags
    (:class:`PageQuality`), and dictionary-drift scoring against a
    calibration baseline (:class:`DriftMonitor`).
  * :mod:`~repro.serving.obs.tolerance` — bounded-error differential
    harness: logit max-abs/KL/top-k-overlap diffs between runs
    (:func:`diff_runs`) gated by :class:`ToleranceGate`, plus the
    :func:`int8_requantize_cache` lossy perturbation used to prove the
    gate trips.

Tracing, journaling, and quality telemetry are opt-in per engine via
:class:`ObsConfig` (``EngineConfig(obs=ObsConfig(trace=True))``); when
disabled the engine carries no recording state at all — every emission
site is behind an ``is not None`` check. Phase timers and the metrics
registry are always on (a handful of ``perf_counter`` calls per step).
"""
from __future__ import annotations

import dataclasses

from repro.serving.obs.quality import (
    DriftMonitor,
    PageQuality,
    QualityRecorder,
    StreamingHist,
    layer_table_from_block,
    merge_quality_blocks,
)
from repro.serving.obs.tolerance import (
    DiffReport,
    ToleranceGate,
    compare_logits,
    diff_runs,
    int8_requantize_cache,
    token_divergence,
)
from repro.serving.obs.journal import (
    EventJournal, JournalViolation, replay_check, replay_check_multi,
)
from repro.serving.obs.registry import (
    Counter, Gauge, Histogram, MetricsRegistry, percentile,
)
from repro.serving.obs.trace import ENGINE_TID, TraceRecorder

__all__ = [
    "ObsConfig",
    "TraceRecorder",
    "ENGINE_TID",
    "EventJournal",
    "JournalViolation",
    "replay_check",
    "replay_check_multi",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "percentile",
    "engine_decode_roofline",
    "engine_prefill_roofline",
    "StreamingHist",
    "PageQuality",
    "DriftMonitor",
    "QualityRecorder",
    "merge_quality_blocks",
    "layer_table_from_block",
    "DiffReport",
    "ToleranceGate",
    "compare_logits",
    "token_divergence",
    "diff_runs",
    "int8_requantize_cache",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Per-engine observability switches (static over an engine's lifetime).

    ``trace``: record a request-lifecycle span tree + engine phase spans
    into a :class:`TraceRecorder` (``engine.tracer``), exportable as
    Chrome/Perfetto JSON. ``journal``: record every slot/page lifecycle
    transition into an :class:`EventJournal` (``engine.journal``) for
    post-hoc invariant replay. ``quality``: record per-encode compression
    quality (relative residual, nnz, delta attainment) into a
    :class:`QualityRecorder` (``engine.quality``), stamp per-page quality
    tags, and emit ``page_quality`` journal events when journaling is
    also on. All default off; a default-constructed engine records
    nothing and pays nothing — with ``quality=False`` the compiled
    prefill/decode functions don't even return the quality aux.
    """
    trace: bool = False
    journal: bool = False
    quality: bool = False


def engine_decode_roofline(*args, **kwargs):
    """Lazy re-export of :func:`repro.serving.obs.roofline.engine_decode_roofline`
    (the roofline bridge imports jax at module load; keep it off the cheap
    registry/journal import path)."""
    from repro.serving.obs.roofline import engine_decode_roofline as fn
    return fn(*args, **kwargs)


def engine_prefill_roofline(*args, **kwargs):
    """Lazy re-export of :func:`repro.serving.obs.roofline.engine_prefill_roofline`."""
    from repro.serving.obs.roofline import engine_prefill_roofline as fn
    return fn(*args, **kwargs)
