"""Append-only structured event journal of slot/page lifecycle transitions.

The fuzz harness (``tests/test_slot_lifecycle_fuzz.py``) proves the pool's
refcount invariants *in-process*; the journal turns those invariants into an
artifact any run can produce and any later process can re-check.  The
allocator and the host tier each carry an optional ``journal`` attribute
(``None`` by default — a single ``is not None`` branch per operation, zero
cost when disabled); when set, every tier transition appends one dict.

Event schema (one JSON object per line in the saved JSONL):

==================  =====================================================
``ev``              fields
==================  =====================================================
``page_alloc``      ``page`` (device id, refcount enters at 1)
``page_incref``     ``page``, ``refs`` (count *after*)
``page_decref``     ``page``, ``refs`` (count *after*; 0 = freed)
``page_demote``     ``page``, ``refs`` (whole count transferred host-side)
``page_promote``    ``page``, ``refs`` (count transferred back)
``host_put``        ``hid``, ``refs`` (host tier admits a demoted page)
``host_incref``     ``hid``, ``refs`` (count after)
``host_decref``     ``hid``, ``refs`` (count after; 0 = dropped)
``host_pop``        ``hid``, ``refs`` (host tier releases for promotion)
``submit``          ``rid``
``admit``           ``rid``, ``slot``, ``pages``, ``aliased``
``stall``           ``rid``, ``slot`` (promote-stall: pool too full)
``retire``          ``rid``, ``slot``
``reject``          ``rid`` (admission reservation check failed)
==================  =====================================================

Every event also carries a monotonically increasing ``seq``.
:func:`replay_check` replays a journal and reports every invariant
violation it finds — refcount conservation, double alloc/free, use after
free, tier-transfer mismatches, and end-of-trace leaks on either tier.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter as _Multiset
from typing import Dict, Iterable, List, Sequence

__all__ = ["EventJournal", "JournalViolation", "replay_check"]


class EventJournal:
    """In-memory append-only journal; one dict per lifecycle event."""

    def __init__(self) -> None:
        self.events: List[Dict] = []
        self._seq = 0

    def emit(self, ev: str, **fields: object) -> None:
        rec: Dict = {"seq": self._seq, "ev": ev}
        rec.update(fields)
        self._seq += 1
        self.events.append(rec)

    def __len__(self) -> int:
        return len(self.events)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")

    @staticmethod
    def load(path: str) -> List[Dict]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]


@dataclasses.dataclass(frozen=True)
class JournalViolation:
    """One invariant breach found by :func:`replay_check`."""
    seq: int          # offending event's seq (-1 = end-of-trace check)
    kind: str         # e.g. "double-free", "device-leak"
    detail: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[seq {self.seq}] {self.kind}: {self.detail}"


def replay_check(events: Iterable[Dict]) -> List[JournalViolation]:
    """Replay a journal and return every invariant violation (empty = clean).

    Checks, in replay order:

      * device-tier refcount conservation: ``page_incref``/``page_decref``
        on live pages only, with the recorded post-count matching the
        replayed count (a divergence means events were lost or tampered);
      * no double alloc, no double free, no demote/incref after free;
      * host-tier twin of the above over handles;
      * tier-transfer balance: every ``page_demote`` pairs with a
        ``host_put`` carrying the identical transferred refcount, every
        ``page_promote`` with a ``host_pop`` (multiset match — ordering
        within a transfer is not constrained);
      * end-of-trace leaks: any page or handle still live when the journal
        ends.
    """
    device: Dict[int, int] = {}
    host: Dict[int, int] = {}
    demote_refs: _Multiset = _Multiset()
    put_refs: _Multiset = _Multiset()
    promote_refs: _Multiset = _Multiset()
    pop_refs: _Multiset = _Multiset()
    out: List[JournalViolation] = []

    def bad(seq: int, kind: str, detail: str) -> None:
        out.append(JournalViolation(seq=seq, kind=kind, detail=detail))

    for e in events:
        seq = int(e.get("seq", -1))
        ev = e["ev"]
        if ev == "page_alloc":
            page = e["page"]
            if page == 0:
                bad(seq, "null-page-alloc", "page 0 is the trash page")
            elif page in device:
                bad(seq, "double-alloc", f"page {page} already live")
            else:
                device[page] = 1
        elif ev == "page_incref":
            page = e["page"]
            if page not in device:
                bad(seq, "incref-after-free", f"page {page} not live")
            else:
                device[page] += 1
                if "refs" in e and e["refs"] != device[page]:
                    bad(seq, "refcount-divergence",
                        f"page {page}: journal says {e['refs']}, "
                        f"replay says {device[page]}")
        elif ev == "page_decref":
            page = e["page"]
            if page not in device:
                bad(seq, "double-free", f"page {page} not live")
            else:
                device[page] -= 1
                if "refs" in e and e["refs"] != device[page]:
                    bad(seq, "refcount-divergence",
                        f"page {page}: journal says {e['refs']}, "
                        f"replay says {device[page]}")
                if device[page] == 0:
                    del device[page]
        elif ev == "page_demote":
            page, refs = e["page"], e["refs"]
            if page not in device:
                bad(seq, "demote-after-free", f"page {page} not live")
            else:
                if device[page] != refs:
                    bad(seq, "refcount-divergence",
                        f"page {page}: demote transferred {refs}, "
                        f"replay holds {device[page]}")
                del device[page]
            demote_refs[refs] += 1
        elif ev == "page_promote":
            page, refs = e["page"], e["refs"]
            if page in device:
                bad(seq, "promote-onto-live-page", f"page {page} already live")
            if refs < 1:
                bad(seq, "bad-refcount", f"promote with refs={refs}")
            device[page] = refs
            promote_refs[refs] += 1
        elif ev == "host_put":
            hid, refs = e["hid"], e["refs"]
            if hid in host:
                bad(seq, "host-double-put", f"handle {hid} already resident")
            if refs < 1:
                bad(seq, "bad-refcount", f"host_put with refs={refs}")
            host[hid] = refs
            put_refs[refs] += 1
        elif ev == "host_incref":
            hid = e["hid"]
            if hid not in host:
                bad(seq, "host-incref-after-free", f"handle {hid} not resident")
            else:
                host[hid] += 1
                if "refs" in e and e["refs"] != host[hid]:
                    bad(seq, "refcount-divergence",
                        f"handle {hid}: journal says {e['refs']}, "
                        f"replay says {host[hid]}")
        elif ev == "host_decref":
            hid = e["hid"]
            if hid not in host:
                bad(seq, "host-double-free", f"handle {hid} not resident")
            else:
                host[hid] -= 1
                if "refs" in e and e["refs"] != host[hid]:
                    bad(seq, "refcount-divergence",
                        f"handle {hid}: journal says {e['refs']}, "
                        f"replay says {host[hid]}")
                if host[hid] == 0:
                    del host[hid]
        elif ev == "host_pop":
            hid, refs = e["hid"], e["refs"]
            if hid not in host:
                bad(seq, "host-pop-missing", f"handle {hid} not resident")
            else:
                if host[hid] != refs:
                    bad(seq, "refcount-divergence",
                        f"handle {hid}: pop transferred {refs}, "
                        f"replay holds {host[hid]}")
                del host[hid]
            pop_refs[refs] += 1
        # submit/admit/stall/retire/reject are context, not invariants

    if demote_refs != put_refs:
        bad(-1, "tier-transfer-mismatch",
            f"demote refcounts {dict(demote_refs)} != "
            f"host_put refcounts {dict(put_refs)}")
    if promote_refs != pop_refs:
        bad(-1, "tier-transfer-mismatch",
            f"promote refcounts {dict(promote_refs)} != "
            f"host_pop refcounts {dict(pop_refs)}")
    for page, refs in sorted(device.items()):
        bad(-1, "device-leak", f"page {page} still holds {refs} ref(s)")
    for hid, refs in sorted(host.items()):
        bad(-1, "host-leak", f"handle {hid} still holds {refs} ref(s)")
    return out
