"""Append-only structured event journal of slot/page lifecycle transitions.

The fuzz harness (``tests/test_slot_lifecycle_fuzz.py``) proves the pool's
refcount invariants *in-process*; the journal turns those invariants into an
artifact any run can produce and any later process can re-check.  The
allocator and the host tier each carry an optional ``journal`` attribute
(``None`` by default — a single ``is not None`` branch per operation, zero
cost when disabled); when set, every tier transition appends one dict.

Event schema (one JSON object per line in the saved JSONL):

==================  =====================================================
``ev``              fields
==================  =====================================================
``page_alloc``      ``page`` (device id, refcount enters at 1)
``page_incref``     ``page``, ``refs`` (count *after*)
``page_decref``     ``page``, ``refs`` (count *after*; 0 = freed)
``page_demote``     ``page``, ``refs`` (whole count transferred host-side)
``page_promote``    ``page``, ``refs`` (count transferred back)
``host_put``        ``hid``, ``refs`` (host tier admits a demoted page)
``host_incref``     ``hid``, ``refs`` (count after)
``host_decref``     ``hid``, ``refs`` (count after; 0 = dropped)
``host_pop``        ``hid``, ``refs`` (host tier releases for promotion)
``submit``          ``rid``
``admit``           ``rid``, ``slot``, ``pages``, ``aliased``
``stall``           ``rid``, ``slot`` (promote-stall: pool too full)
``retire``          ``rid``, ``slot``
``reject``          ``rid`` (admission reservation check failed)
``prefix_publish``  ``path`` (hex chain digest: prefix-index pin created)
``prefix_drop``     ``path`` (pin released — evict/trim/clear)
``page_quality``    ``page``, ``count``, ``rel_mean``, ``rel_max``,
                    ``nnz_mean`` (encode-quality tag stamped/updated on a
                    live device page — admission, page seal, or promote)
==================  =====================================================

A multi-replica deployment adds the **router log** (one journal for the
whole fleet): ``route`` (``rid``, ``replica``, ``policy``, ``hit_pages``)
plus the ``GlobalPrefixView``'s mirror of every replica's prefix pins —
``view_publish`` / ``view_drop`` (``replica``, ``path``).

Every event also carries a monotonically increasing ``seq``.
:func:`replay_check` replays a journal and reports every invariant
violation it finds — refcount conservation, double alloc/free, use after
free, tier-transfer mismatches, and end-of-trace leaks on either tier.
:func:`replay_check_multi` replays per-replica journals against the router
log and adds the cross-replica invariants (single admission per request,
route/admit agreement, view/index consistency).
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter as _Multiset
from typing import Dict, Iterable, List, Sequence

__all__ = ["EventJournal", "JournalViolation", "replay_check",
           "replay_check_multi"]


class EventJournal:
    """In-memory append-only journal; one dict per lifecycle event."""

    def __init__(self) -> None:
        self.events: List[Dict] = []
        self._seq = 0

    def emit(self, ev: str, **fields: object) -> None:
        rec: Dict = {"seq": self._seq, "ev": ev}
        rec.update(fields)
        self._seq += 1
        self.events.append(rec)

    def __len__(self) -> int:
        return len(self.events)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")

    @staticmethod
    def load(path: str) -> List[Dict]:
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]


@dataclasses.dataclass(frozen=True)
class JournalViolation:
    """One invariant breach found by :func:`replay_check`."""
    seq: int          # offending event's seq (-1 = end-of-trace check)
    kind: str         # e.g. "double-free", "device-leak"
    detail: str

    def __str__(self) -> str:  # pragma: no cover - repr convenience
        return f"[seq {self.seq}] {self.kind}: {self.detail}"


def replay_check(events: Iterable[Dict]) -> List[JournalViolation]:
    """Replay a journal and return every invariant violation (empty = clean).

    Checks, in replay order:

      * device-tier refcount conservation: ``page_incref``/``page_decref``
        on live pages only, with the recorded post-count matching the
        replayed count (a divergence means events were lost or tampered);
      * no double alloc, no double free, no demote/incref after free;
      * host-tier twin of the above over handles;
      * tier-transfer balance: every ``page_demote`` pairs with a
        ``host_put`` carrying the identical transferred refcount, every
        ``page_promote`` with a ``host_pop`` (multiset match — ordering
        within a transfer is not constrained);
      * ``page_quality`` tags land only on live device pages (never the
        null page, never a freed page) and carry sane statistics
        (``count >= 1``, ``0 <= rel_mean <= rel_max``, all finite);
      * end-of-trace leaks: any page or handle still live when the journal
        ends.
    """
    device: Dict[int, int] = {}
    host: Dict[int, int] = {}
    demote_refs: _Multiset = _Multiset()
    put_refs: _Multiset = _Multiset()
    promote_refs: _Multiset = _Multiset()
    pop_refs: _Multiset = _Multiset()
    out: List[JournalViolation] = []

    def bad(seq: int, kind: str, detail: str) -> None:
        out.append(JournalViolation(seq=seq, kind=kind, detail=detail))

    for e in events:
        seq = int(e.get("seq", -1))
        ev = e["ev"]
        if ev == "page_alloc":
            page = e["page"]
            if page == 0:
                bad(seq, "null-page-alloc", "page 0 is the trash page")
            elif page in device:
                bad(seq, "double-alloc", f"page {page} already live")
            else:
                device[page] = 1
        elif ev == "page_incref":
            page = e["page"]
            if page not in device:
                bad(seq, "incref-after-free", f"page {page} not live")
            else:
                device[page] += 1
                if "refs" in e and e["refs"] != device[page]:
                    bad(seq, "refcount-divergence",
                        f"page {page}: journal says {e['refs']}, "
                        f"replay says {device[page]}")
        elif ev == "page_decref":
            page = e["page"]
            if page not in device:
                bad(seq, "double-free", f"page {page} not live")
            else:
                device[page] -= 1
                if "refs" in e and e["refs"] != device[page]:
                    bad(seq, "refcount-divergence",
                        f"page {page}: journal says {e['refs']}, "
                        f"replay says {device[page]}")
                if device[page] == 0:
                    del device[page]
        elif ev == "page_demote":
            page, refs = e["page"], e["refs"]
            if page not in device:
                bad(seq, "demote-after-free", f"page {page} not live")
            else:
                if device[page] != refs:
                    bad(seq, "refcount-divergence",
                        f"page {page}: demote transferred {refs}, "
                        f"replay holds {device[page]}")
                del device[page]
            demote_refs[refs] += 1
        elif ev == "page_promote":
            page, refs = e["page"], e["refs"]
            if page in device:
                bad(seq, "promote-onto-live-page", f"page {page} already live")
            if refs < 1:
                bad(seq, "bad-refcount", f"promote with refs={refs}")
            device[page] = refs
            promote_refs[refs] += 1
        elif ev == "host_put":
            hid, refs = e["hid"], e["refs"]
            if hid in host:
                bad(seq, "host-double-put", f"handle {hid} already resident")
            if refs < 1:
                bad(seq, "bad-refcount", f"host_put with refs={refs}")
            host[hid] = refs
            put_refs[refs] += 1
        elif ev == "host_incref":
            hid = e["hid"]
            if hid not in host:
                bad(seq, "host-incref-after-free", f"handle {hid} not resident")
            else:
                host[hid] += 1
                if "refs" in e and e["refs"] != host[hid]:
                    bad(seq, "refcount-divergence",
                        f"handle {hid}: journal says {e['refs']}, "
                        f"replay says {host[hid]}")
        elif ev == "host_decref":
            hid = e["hid"]
            if hid not in host:
                bad(seq, "host-double-free", f"handle {hid} not resident")
            else:
                host[hid] -= 1
                if "refs" in e and e["refs"] != host[hid]:
                    bad(seq, "refcount-divergence",
                        f"handle {hid}: journal says {e['refs']}, "
                        f"replay says {host[hid]}")
                if host[hid] == 0:
                    del host[hid]
        elif ev == "host_pop":
            hid, refs = e["hid"], e["refs"]
            if hid not in host:
                bad(seq, "host-pop-missing", f"handle {hid} not resident")
            else:
                if host[hid] != refs:
                    bad(seq, "refcount-divergence",
                        f"handle {hid}: pop transferred {refs}, "
                        f"replay holds {host[hid]}")
                del host[hid]
            pop_refs[refs] += 1
        elif ev == "page_quality":
            page = e["page"]
            if page == 0:
                bad(seq, "quality-null-page",
                    "quality tag on page 0 (the trash page)")
            elif page not in device:
                bad(seq, "quality-on-dead-page", f"page {page} not live")
            count = e.get("count", 1)
            rel_mean = e.get("rel_mean", 0.0)
            rel_max = e.get("rel_max", rel_mean)
            nnz_mean = e.get("nnz_mean", 0.0)
            finite = all(isinstance(x, (int, float)) and x == x
                         and abs(x) != float("inf")
                         for x in (count, rel_mean, rel_max, nnz_mean))
            if not finite:
                bad(seq, "bad-quality-value",
                    f"page {page}: non-finite quality fields")
            elif count < 1 or rel_mean < 0 or rel_max < rel_mean - 1e-9:
                bad(seq, "bad-quality-value",
                    f"page {page}: count={count} rel_mean={rel_mean} "
                    f"rel_max={rel_max}")
        # submit/admit/stall/retire/reject are context, not invariants

    if demote_refs != put_refs:
        bad(-1, "tier-transfer-mismatch",
            f"demote refcounts {dict(demote_refs)} != "
            f"host_put refcounts {dict(put_refs)}")
    if promote_refs != pop_refs:
        bad(-1, "tier-transfer-mismatch",
            f"promote refcounts {dict(promote_refs)} != "
            f"host_pop refcounts {dict(pop_refs)}")
    for page, refs in sorted(device.items()):
        bad(-1, "device-leak", f"page {page} still holds {refs} ref(s)")
    for hid, refs in sorted(host.items()):
        bad(-1, "host-leak", f"handle {hid} still holds {refs} ref(s)")
    return out


def replay_check_multi(replica_events: Dict[object, Sequence[Dict]],
                       router_events: Iterable[Dict]) -> List[JournalViolation]:
    """Cross-replica replay: per-replica journals + the router's log.

    ``replica_events`` maps replica id -> that engine's journal (the full
    per-replica :func:`replay_check` runs on each, violations prefixed with
    the replica id). ``router_events`` is the router's admission log:
    ``route`` events (``rid``, ``replica``) plus the
    :class:`~repro.serving.prefix.GlobalPrefixView`'s ``view_publish`` /
    ``view_drop`` events (``replica``, ``path``).

    Cross-replica invariants, on top of the per-replica ones:

      * each ``rid`` routed at most once (``duplicate-route``) and admitted
        on at most one replica across the fleet (``duplicate-admission``);
      * every admission was routed, and to the replica that admitted it
        (``unrouted-admission`` / ``route-mismatch``);
      * the view's lifecycle is sane: no double publish, no drop of an
        unknown entry (``view-double-publish`` / ``view-drop-missing``);
      * end of trace: each replica's live prefix pins (its journal's
        ``prefix_publish`` minus ``prefix_drop``) equal exactly the paths
        the view holds for it — a resident chunk the view doesn't know
        about is ``view-missing-path`` (routing can never find it), a view
        entry the replica no longer backs is ``view-stale-path`` (a view
        entry outlived its index pin).
    """
    out: List[JournalViolation] = []

    def bad(seq: int, kind: str, detail: str) -> None:
        out.append(JournalViolation(seq=seq, kind=kind, detail=detail))

    routed: Dict[object, object] = {}       # rid -> replica
    view_live: Dict[object, set] = {}       # replica -> live paths
    for e in router_events:
        seq = int(e.get("seq", -1))
        ev = e["ev"]
        if ev == "route":
            rid = e["rid"]
            if rid in routed:
                bad(seq, "duplicate-route",
                    f"rid {rid} routed to replica {e['replica']} after "
                    f"replica {routed[rid]}")
            else:
                routed[rid] = e["replica"]
        elif ev == "view_publish":
            live = view_live.setdefault(e["replica"], set())
            if e["path"] in live:
                bad(seq, "view-double-publish",
                    f"replica {e['replica']} path {e['path']}")
            live.add(e["path"])
        elif ev == "view_drop":
            live = view_live.setdefault(e["replica"], set())
            if e["path"] not in live:
                bad(seq, "view-drop-missing",
                    f"replica {e['replica']} path {e['path']}")
            live.discard(e["path"])

    admitted: Dict[object, object] = {}     # rid -> replica
    for replica, events in replica_events.items():
        for v in replay_check(events):
            bad(v.seq, v.kind, f"replica {replica}: {v.detail}")
        live_paths: set = set()
        for e in events:
            seq = int(e.get("seq", -1))
            ev = e["ev"]
            if ev == "admit":
                rid = e["rid"]
                if rid in admitted:
                    bad(seq, "duplicate-admission",
                        f"rid {rid} admitted on replica {replica} after "
                        f"replica {admitted[rid]}")
                else:
                    admitted[rid] = replica
                if rid not in routed:
                    bad(seq, "unrouted-admission",
                        f"rid {rid} admitted on replica {replica} with no "
                        "route event")
                elif routed[rid] != replica:
                    bad(seq, "route-mismatch",
                        f"rid {rid} routed to replica {routed[rid]} but "
                        f"admitted on replica {replica}")
            elif ev == "prefix_publish":
                live_paths.add(e["path"])
            elif ev == "prefix_drop":
                live_paths.discard(e["path"])
        known = view_live.get(replica, set())
        for path in sorted(live_paths - known):
            bad(-1, "view-missing-path",
                f"replica {replica} caches {path} but the view doesn't "
                "know it")
        for path in sorted(known - live_paths):
            bad(-1, "view-stale-path",
                f"view entry {path} outlived replica {replica}'s pin")
    return out
