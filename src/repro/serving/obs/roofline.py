"""Roofline analysis of the engine's compiled decode/prefill hot loop.

``repro.roofline.analysis`` already turns a compiled (AOT) module into
roofline terms; this bridge points it at a *live engine's* jitted entry
points.  The engine's decode step is one compiled trace for the whole pool,
so lowering it once with abstract (shape/dtype-only) stand-ins for the live
arrays yields exactly the module every ``engine.step()`` dispatches — the
predicted bytes/FLOPs side of the achieved-vs-predicted comparison the
serving benchmark emits (the achieved side is the measured
``decode_dispatch`` + ``host_sync`` phase time).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.roofline.analysis import (
    HW, V5E, RooflineReport, analyze_compiled, model_flops_for,
)

__all__ = ["engine_decode_roofline", "engine_prefill_roofline"]


def _abstract(tree):
    """Shape/dtype skeleton of a pytree of arrays (lowering needs no data)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        tree)


def engine_decode_roofline(eng, *, hw: HW = V5E) -> RooflineReport:
    """AOT-compile the engine's pooled decode step and report its roofline.

    Lowering uses the engine's real params/state shapes, so the analyzed
    module is byte-identical to the one the hot loop dispatches (jit caches
    by abstract signature).  ``model_flops`` counts one useful token per
    slot — the full-pool upper bound; partial occupancy lowers the useful
    ratio, never the module cost.
    """
    B = eng.engine_cfg.n_slots
    lowered = eng._decode_fn.lower(
        _abstract(eng.params), _abstract(eng.bank), _abstract(eng.state),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.bool_),
        jax.ShapeDtypeStruct((B,), jnp.int32))
    compiled = lowered.compile()
    return analyze_compiled(
        compiled, arch=getattr(eng.cfg, "arch", "decoder"),
        shape=f"decode[B={B},t_max={eng.engine_cfg.t_max},"
              f"layout={eng.engine_cfg.layout}]",
        mesh_desc="1x1", chips=1,
        model_flops=model_flops_for(eng.cfg, "decode", 1, B, steps=1),
        hw=hw)


def engine_prefill_roofline(eng, bucket: int, *, tier: Optional[int] = None,
                            hw: HW = V5E) -> RooflineReport:
    """AOT-compile one prefill bucket (``compress_start=0``) and report its
    roofline — the admission-path complement of the decode report."""
    lowered = eng._prefill_fn.lower(
        _abstract(eng.params), _abstract(eng.bank),
        jax.ShapeDtypeStruct((1, bucket), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        0)
    compiled = lowered.compile()
    return analyze_compiled(
        compiled, arch=getattr(eng.cfg, "arch", "decoder"),
        shape=f"prefill[bucket={bucket}]", mesh_desc="1x1", chips=1,
        model_flops=model_flops_for(eng.cfg, "prefill", bucket, 1),
        hw=hw)
