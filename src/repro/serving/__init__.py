"""Continuous-batching serving over Lexico cache slots.

One universal dictionary bank + one fixed pool of per-request cache slots
serve many heterogeneous requests concurrently: the vectorized (B,) cache
bookkeeping lets each slot advance independently inside one compiled decode
step, the scheduler packs requests against a global KV-byte budget using the
paper's exact ``3s + 2`` bytes/vector accounting, and per-request sparsity
tiers ride on a per-row atom cap inside the shared OMP encoder.

Slot storage is pluggable (``EngineConfig.layout``): the contiguous
per-slot stripe, or paged storage — a shared page pool + per-slot page
tables (``pages.py`` allocator, ``slots.py`` device splices) whose admission
and footprint are page-granular instead of ``t_max``-padded. On top of the
paged layout, ``EngineConfig(share_prefixes=True)`` turns on copy-on-write
prefix sharing (``prefix.py``): requests with a common page-aligned prompt
prefix alias one set of physical pages and skip the prefix's prefill OMP.
``EngineConfig(swap=SwapConfig(...))`` adds tiered storage (``swap.py``): a
host-memory mirror cold pages demote into (and promote back from, bitwise)
under policy control, so the device pool's capacity becomes a latency
tradeoff instead of a hard admission ceiling.

Observability (``obs/``, docs/observability.md): request-lifecycle tracing
(Chrome/Perfetto JSON), always-on step-phase timers, a labeled
Prometheus-exportable metrics registry behind ``EngineMetrics``, a
page-lifecycle event journal with a post-hoc replay invariant checker, and
roofline analysis of the compiled decode/prefill hot loop — all opt-in per
engine via ``EngineConfig(obs=ObsConfig(...))``.

Scale-out (``router.py``, docs/routing.md): ``ReplicaRouter`` fronts N
engine replicas — one dictionary bank shared by reference, everything
stateful per-replica — with a pluggable routing policy (round-robin,
least-loaded, prefix-affinity) scoring each request's expected prefix-page
hits from a cross-replica ``GlobalPrefixView`` against load skew.

See docs/serving.md and docs/tiered_memory.md for the full subsystem design.
"""
from repro.serving.engine import ContinuousBatchingEngine, EngineConfig
from repro.serving.metrics import EngineMetrics, merge_snapshots
from repro.serving.obs import ObsConfig
from repro.serving.pages import (
    NULL_PAGE, PageAllocator, PagePoolExhausted, RefcountOverflow,
    pages_needed,
)
from repro.serving.prefix import (
    GlobalPrefixView, PrefixIndex, SharePlan, prefix_paths,
)
from repro.serving.router import (
    LeastLoadedPolicy, PrefixAffinityPolicy, ReplicaRouter, ReplicaSnapshot,
    RoundRobinPolicy, RoutingPolicy, make_policy,
)
from repro.serving.scheduler import (
    FCFSScheduler, Request, request_kv_bytes, request_kv_bytes_paged,
    request_page_count,
)
from repro.serving.slots import SlotInfo, SlotPool
from repro.serving.swap import (
    HostPageStore, HostTierFull, PageHandle, SwapConfig, SwapManager,
    SwapPolicy,
)

__all__ = [
    "ContinuousBatchingEngine", "EngineConfig", "EngineMetrics",
    "FCFSScheduler", "GlobalPrefixView", "HostPageStore", "HostTierFull",
    "LeastLoadedPolicy", "NULL_PAGE",
    "ObsConfig", "PageAllocator", "PageHandle", "PagePoolExhausted",
    "PrefixAffinityPolicy", "PrefixIndex",
    "RefcountOverflow", "ReplicaRouter", "ReplicaSnapshot", "Request",
    "RoundRobinPolicy", "RoutingPolicy", "SharePlan", "SlotInfo", "SlotPool",
    "SwapConfig", "SwapManager", "SwapPolicy", "make_policy",
    "merge_snapshots", "pages_needed", "prefix_paths",
    "request_kv_bytes", "request_kv_bytes_paged", "request_page_count",
]
