"""Host-side prefix index: copy-on-write prefix sharing over the page pool.

Lexico's universal dictionary makes compressed pages *input-agnostic*: the
OMP code of cache position ``p`` is a deterministic function of the token
prefix ``[0, p]`` (and the sparsity tier), independent of anything after it —
causal masking zeroes suffix contributions exactly. Two requests that agree
on a page-aligned token prefix therefore produce bitwise-identical sparse
codes for those pages, so one physical page can serve both slots. This
module is the host-side index that finds such prefixes at admission time.

Structure: one radix trie per sparsity tier (codes depend on the tier's OMP
atom cap, so tiers never share pages). Trie edges are keyed on **hashes of
page-granularity token chunks** — the chunk of cache-space tokens a page's
compressed positions cover — with the raw chunk stored on each node so a
hash collision degrades to a miss, never to wrong sharing. A node at depth
``j`` names the physical page holding compressed positions
``[j*P, (j+1)*P)`` for every request whose tokens walk that path.

Two kinds of reuse come out of a lookup (:class:`SharePlan`):

  * **aliasing** — full pages of the shared prefix are mapped into the new
    slot's page table as-is (``PageAllocator.incref``): zero bytes moved,
    zero OMP re-run. Full pages are immutable once written (decode appends
    only ever touch positions ``>= t_c``), so aliasing is race-free.
  * **copy-on-write** — the *last, partially-filled* page of the shared
    span cannot be aliased: the recipient's decode appends will land in it.
    Instead the recipient gets a fresh page, the donor page is device-copied
    into it (``repro.serving.slots.copy_page``) before any decode write
    lands, and the copied codes are skipped from OMP like aliased ones.
    The null/trash page 0 is never registered, aliased, or copied.

The index *pins* every page it caches (one ``incref`` per registered node),
so a donor's pages stay shareable after the donor retires — "recently
retired" reuse. When the pool's free list runs dry the engine calls
:meth:`PrefixIndex.evict`, which drops pins subtree-first ranked by a
frequency/size score (``SwapPolicy.subtree_evict_key``: hit-count per
cached page, LRU tie-break) — a rarely-hit subtree spread over many pages
goes first, a hit-rich one survives (a shallower pin is useless without its
ancestors, never the reverse).

Tiered storage (``repro.serving.swap``): a cached page can be *demoted* to
the host tier instead of dropped — the engine extracts its codes, the node
is re-keyed from its device page id to a stable :class:`~repro.serving.swap
.PageHandle` (:meth:`PrefixIndex.swap_out`), and a later admission that
hits the node *promotes* the page back instead of recompressing the prefix
(:meth:`PrefixIndex.swap_in`). Demotion preserves the cache entry; dropping
destroys it — the engine prefers the former whenever the host tier has
room.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.pages import NULL_PAGE, PageAllocator
from repro.serving.swap import HostPageStore, PageHandle, PageRef, SwapPolicy


# default eviction scorer (frequency/size-aware; see SwapPolicy)
_DEFAULT_POLICY = SwapPolicy()


def _chunk_hash(tokens: Tuple[int, ...]) -> bytes:
    """Stable digest of one page-granularity token chunk (trie edge key)."""
    h = hashlib.blake2b(digest_size=16)
    for t in tokens:
        h.update(int(t).to_bytes(8, "little", signed=True))
    return h.digest()


def _tier_seed(tier: int) -> bytes:
    """Root path digest of one tier's trie (tiers never share pages, so the
    same token chunks under different tiers get disjoint path digests)."""
    return hashlib.blake2b(b"lexico-tier:%d" % int(tier),
                           digest_size=16).digest()


def _chain(parent_path: bytes, chunk_key: bytes) -> bytes:
    """Path digest of a child node: digest of the whole root-to-node chunk
    chain, computed incrementally from the parent's path."""
    return hashlib.blake2b(parent_path + chunk_key, digest_size=16).digest()


def prefix_paths(tokens: Sequence[int], tier: int, n_codes: int,
                 page_size: int) -> List[bytes]:
    """Cumulative path digests of a token key's page chunks.

    ``paths[j]`` identifies the trie node holding compressed positions
    ``[j*P, (j+1)*P)`` for this exact token prefix and tier — the same
    digest :meth:`PrefixIndex.register` stamps on the node it creates, so a
    :class:`GlobalPrefixView` keyed on these digests can answer "which
    replica already caches this prefix" without any token or page state.
    """
    if n_codes <= 0:
        return []
    chunks = PrefixIndex._chunks(tokens[:n_codes], page_size)
    path = _tier_seed(tier)
    out: List[bytes] = []
    for chunk in chunks:
        path = _chain(path, _chunk_hash(chunk))
        out.append(path)
    return out


@dataclasses.dataclass
class _Node:
    """One trie node = one cached physical page at one page position.

    ``tokens`` is the raw chunk the edge hash was computed from (collision
    guard); ``page`` is a device page id while resident or a
    :class:`~repro.serving.swap.PageHandle` while demoted to the host tier;
    ``valid`` counts the page's positions holding prefill-produced codes
    (``page_size`` for interior nodes, possibly less for a donor's boundary
    page); ``last_used`` is a monotonic LRU stamp and ``hits`` counts
    committed admissions that reused this node (the eviction scorer's
    frequency signal).
    """
    tokens: Tuple[int, ...]
    page: PageRef
    valid: int
    last_used: int = 0
    hits: int = 0
    children: Dict[bytes, "_Node"] = dataclasses.field(default_factory=dict)
    # root-to-node chain digest (see prefix_paths); roots carry the tier
    # seed so children chain off it. The digest survives swap_out/swap_in —
    # it names the *cache entry*, not the physical page backing it.
    path: bytes = b""


@dataclasses.dataclass
class SharePlan:
    """What a lookup found for one admission.

    ``aliased`` — physical pages (in page-table order, from position 0) the
    new slot maps as-is; entries may be host-tier
    :class:`~repro.serving.swap.PageHandle`\\ s when the cached page is
    currently demoted — a swap-enabled engine promotes them before aliasing
    (recompression is never needed). ``copy_src``/``copy_valid`` — donor
    page to CoW into the slot's boundary table entry ``len(aliased)``,
    holding
    ``copy_valid >= shared_codes - len(aliased)*page_size`` valid codes.
    ``shared_codes`` — compressed positions whose OMP the recipient skips;
    the restartable prefill starts at ``len(aliased) * page_size`` (page
    aligned) unless the copy covers the whole remainder, in which case it
    starts at ``shared_codes`` (== the slot's entire compressed span).

    ``lookup`` is side-effect free (admission peeks may run many times for
    a budget-blocked queue head); pass the plan to
    :meth:`PrefixIndex.commit` when the admission actually happens to
    record the hit/miss and refresh the matched nodes' LRU stamps.
    """
    aliased: List[PageRef] = dataclasses.field(default_factory=list)
    copy_src: Optional[PageRef] = None
    copy_valid: int = 0
    shared_codes: int = 0
    # trie nodes the plan matched (LRU-stamped on commit, not on lookup)
    nodes: List["_Node"] = dataclasses.field(default_factory=list, repr=False)

    @property
    def hit(self) -> bool:
        return self.shared_codes > 0


class PrefixIndex:
    """Radix trie over page-granularity token-chunk hashes, one per tier."""

    def __init__(self, page_size: int, *, max_cached_pages: Optional[int] = None):
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self.max_cached_pages = max_cached_pages
        self._roots: Dict[int, _Node] = {}   # tier -> structural root
        self._registered: Dict[int, _Node] = {}  # page id -> owning node
        # optional eviction callback, invoked as on_evict(freed, unpinned)
        # after every destructive evict() pass that dropped a pin (the
        # engine routes it into metrics + the request trace)
        self.on_evict = None
        # observers: (on_publish, on_drop) pairs called with the node's path
        # digest when a pin is created / dropped. Pure notifications — they
        # carry no page ids, so an observer can never hold a page ref.
        self._observers: List[Tuple] = []
        self._clock = 0

    def add_observer(self, on_publish, on_drop) -> None:
        """Subscribe to pin lifecycle: ``on_publish(path)`` fires when
        :meth:`register` pins a new page, ``on_drop(path)`` when
        :meth:`_unpin` releases one (evict/trim/clear). ``path`` is the
        node's chain digest (:func:`prefix_paths`) — observers see *which
        prefix chunk* is cached, never the physical page behind it."""
        self._observers.append((on_publish, on_drop))

    def live_paths(self) -> set:
        """Chain digests of every currently-pinned cache entry (both
        device- and host-tier resident)."""
        return {node.path for node in self._registered.values()}

    # ------------------------------------------------------------- internals

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _root(self, tier: int) -> _Node:
        if tier not in self._roots:
            self._roots[tier] = _Node(tokens=(), page=NULL_PAGE, valid=0,
                                      path=_tier_seed(tier))
        return self._roots[tier]

    @staticmethod
    def _chunks(tokens: Sequence[int], page_size: int):
        toks = tuple(int(t) for t in tokens)
        return [toks[i:i + page_size]
                for i in range(0, len(toks), page_size)]

    # ------------------------------------------------------------------- API

    def n_cached_pages(self) -> int:
        """Distinct pages currently pinned by the index (both tiers)."""
        return len(self._registered)

    def evictable_pages(self, allocator: PageAllocator) -> int:
        """DEVICE pages whose *only* reference is the index's pin — evicting
        them actually returns pages to the free list (pages also held by
        live slots stay resident regardless; host-tier entries free no
        device pages and are excluded)."""
        return sum(1 for p in self._registered
                   if not isinstance(p, PageHandle)
                   and allocator.refcount(p) == 1)

    # ------------------------------------------------- tiered-storage moves

    def swap_out(self, page: int, handle: PageHandle) -> bool:
        """Re-key the node caching device page ``page`` to the host-tier
        ``handle`` (the page's codes were demoted; the cache entry — and its
        shareability — survives). Returns False when ``page`` is not pinned
        here. The index's pin moves tiers with the page: the engine
        transfers the whole refcount via ``PageAllocator.demote`` /
        ``HostPageStore.put``, so no incref/decref happens."""
        node = self._registered.pop(page, None)
        if node is None:
            return False
        node.page = handle
        self._registered[handle] = node
        return True

    def swap_in(self, handle: PageHandle, page: int) -> bool:
        """Inverse of :meth:`swap_out`: the host-tier page was promoted back
        into device page ``page``; re-key the node. Returns False when
        ``handle`` is not pinned here."""
        node = self._registered.pop(handle, None)
        if node is None:
            return False
        node.page = page
        self._registered[page] = node
        return True

    def lookup(self, tokens: Sequence[int], tier: int, n_codes: int) -> SharePlan:
        """Find the longest page-aligned shared prefix for an admission.

        Args:
          tokens: cache-space token ids covering at least ``[0, n_codes)``
            (meta-token sentinels + prompt tokens, NOT generated tokens).
          tier: the request's sparsity tier (tiers never share pages).
          n_codes: the slot's compressed span at prefill time
            (``n_meta + bucket - n_b``) — sharing never extends past it.

        Pure read: LRU stamps move only when the plan is
        :meth:`commit`-ted, so repeated peeks for a budget-blocked queue
        head don't pin its subtree as MRU. Hit/miss *statistics* are the
        engine's business (``EngineMetrics.record_prefix_share``) — the
        index keeps none, so there is exactly one source of truth.
        """
        plan = SharePlan()
        node = self._roots.get(tier)
        P = self.page_size
        if node is None or n_codes <= 0:
            return plan
        chunks = self._chunks(tokens[:n_codes], P)
        # walk full pages: page j is aliasable iff wholly inside n_codes
        j = 0
        while (j + 1) * P <= n_codes:
            child = node.children.get(_chunk_hash(chunks[j]))
            if child is None or child.tokens != chunks[j] or child.valid < P:
                break
            plan.aliased.append(child.page)
            plan.nodes.append(child)
            node = child
            j += 1
        rem = n_codes - j * P
        if 0 < rem:
            # boundary: a page whose first `rem` codes match can be CoW'd.
            # Full children qualify (valid == P >= rem); a donor's partial
            # boundary page qualifies when its valid span covers rem.
            want = tuple(chunks[j][:rem]) if j < len(chunks) else ()
            best = None
            for child in node.children.values():
                if child.valid >= rem and child.tokens[:rem] == want:
                    if best is None or child.last_used > best.last_used:
                        best = child
            if best is not None:
                plan.nodes.append(best)
                plan.copy_src = best.page
                plan.copy_valid = best.valid
                plan.shared_codes = j * P + rem
        if plan.shared_codes == 0:
            plan.shared_codes = j * P
        return plan

    def commit(self, plan: SharePlan) -> None:
        """Record an admission that used ``plan``: refresh the matched
        nodes' LRU stamps and bump their hit counts — the recency and
        frequency the eviction scorer ranks on (aggregate hit/miss
        *metrics* live in ``EngineMetrics``)."""
        now = self._tick()
        for node in plan.nodes:
            node.last_used = now
            node.hits += 1

    def register(self, tokens: Sequence[int], tier: int, pages: Sequence[int],
                 n_codes: int, allocator: PageAllocator,
                 host: Optional[HostPageStore] = None) -> int:
        """Publish a freshly-prefilled slot's pages for future sharing.

        Args:
          tokens: cache-space tokens covering ``[0, n_codes)``.
          pages: the slot's page-table prefix — ``pages[j]`` holds compressed
            positions ``[j*P, (j+1)*P)``; ``ceil(n_codes / P)`` entries used.
          n_codes: prefill-produced compressed positions (``n_meta + bucket -
            n_b``). Decode-produced codes are never registered: they are
            computed through the compressed-attention path and would not be
            bitwise-reproducible by another request's prefill.
          allocator: pins each newly-registered page with one ``incref``.
          host: host tier store (swap-enabled engines) — threaded into the
            ``max_cached_pages`` trim so it can drop swapped entries too.

        Pages already cached at their position (a donor's) are left in place
        — the recipient's aliased entries are the donor's pages anyway.
        Returns the number of pages newly pinned.
        """
        P = self.page_size
        chunks = self._chunks(tokens[:n_codes], P)
        node = self._root(tier)
        now = self._tick()
        pinned = 0
        n_pages = -(-n_codes // P) if n_codes > 0 else 0
        for j in range(n_pages):
            page = int(pages[j])
            valid = min(n_codes - j * P, P)
            if page == NULL_PAGE:
                raise ValueError("cannot register the null/trash page 0")
            key = _chunk_hash(chunks[j])
            child = node.children.get(key)
            if child is not None and child.tokens == chunks[j]:
                # already cached at this position (equal tokens imply equal
                # valid span — a longer-covered page hashes to a sibling
                # key, it never replaces this node)
                child.last_used = now
                node = child
                continue
            if child is not None:      # hash collision with different tokens
                break
            if page in self._registered:   # one pin per physical page
                break
            child = _Node(tokens=chunks[j], page=page, valid=valid,
                          last_used=now, path=_chain(node.path, key))
            node.children[key] = child
            self._registered[page] = child
            allocator.incref(page)
            pinned += 1
            for on_publish, _ in self._observers:
                on_publish(child.path)
            node = child
        if self.max_cached_pages is not None:
            over = len(self._registered) - self.max_cached_pages
            if over > 0:
                self.evict(allocator, max_pages=over, only_free=False,
                           host=host)
        return pinned

    def _unpin(self, node: _Node, allocator: PageAllocator,
               host: Optional[HostPageStore]) -> bool:
        """Drop the index's pin on ``node``'s page. True iff a DEVICE page
        actually returned to the free list (no slot was holding it; dropping
        a host-tier entry frees host bytes, never device pages)."""
        page = node.page
        if isinstance(page, PageHandle):
            if host is None:
                raise ValueError(
                    f"cannot drop the pin on swapped {page} without the host "
                    "store (pass host=)")
            del self._registered[page]
            host.decref(page)
            freed = False
        else:
            del self._registered[page]
            freed = allocator.refcount(page) == 1
            allocator.decref(page)
        node.page, node.valid = NULL_PAGE, 0
        for _, on_drop in self._observers:
            on_drop(node.path)
        return freed

    def evict(self, allocator: PageAllocator, *, max_pages: int,
              only_free: bool = True, scorer=None,
              host: Optional[HostPageStore] = None) -> int:
        """Drop cached-page pins, coldest subtree first, until ``max_pages``
        device pages have returned to the free list (or nothing more can be
        evicted).

        Victims are ranked by ``scorer`` — default
        ``SwapPolicy.subtree_evict_key``, a frequency/size score: committed
        hit-count per cached page with a least-recently-used tie-break, so a
        rarely-reused subtree spread over many pages goes before a hit-rich
        compact one (pure LRU was the pre-tiering behaviour). Eviction is
        *subtree*-granular: a cached page is only reachable through its
        whole ancestor path, so a victim is removed together with everything
        under it — pins are never stranded. ``only_free=True`` (the
        free-list-ran-dry path) skips subtrees whose removal would free no
        device pages (every page in them still aliased by a live slot, or
        already demoted to the host tier); ``only_free=False`` (capacity
        trim) drops them regardless. ``host`` is required to drop swapped
        entries. Returns the number of device pages actually freed.

        Destructive by design — a swap-enabled engine prefers *demoting*
        cached pages (which preserves the entry) and only lands here when
        the host tier is full or swap is off.
        """
        if scorer is None:
            scorer = _DEFAULT_POLICY.subtree_evict_key
        freed = unpinned = 0
        while (freed if only_free else unpinned) < max_pages:
            # candidate = one directly-under-root subtree per tier trie,
            # scored over the whole subtree (newest stamp, summed hits,
            # cached-page count)
            candidates: List[Tuple[Tuple, int, _Node, bytes]] = []
            for root in self._roots.values():
                for key, child in root.children.items():
                    subtree = list(self._iter_subtree(child))
                    stats = scorer(
                        hits=sum(n.hits for n in subtree),
                        pages=len(subtree),
                        last_used=max(n.last_used for n in subtree))
                    candidates.append((stats, id(child), root, key))
            candidates.sort(key=lambda c: (c[0], c[1]))
            progressed = False
            for _, _, parent, key in candidates:
                subtree = list(self._iter_subtree(parent.children[key]))
                would_free = sum(
                    1 for n in subtree if not isinstance(n.page, PageHandle)
                    and n.page != NULL_PAGE
                    and allocator.refcount(n.page) == 1)
                if only_free and would_free == 0:
                    continue
                for n in subtree:
                    if n.page != NULL_PAGE:
                        unpinned += 1
                        if self._unpin(n, allocator, host):
                            freed += 1
                del parent.children[key]
                progressed = True
                break
            if not progressed:
                break
        if unpinned and self.on_evict is not None:
            self.on_evict(freed, unpinned)
        return freed

    @staticmethod
    def _iter_subtree(node: _Node):
        yield node
        for child in node.children.values():
            yield from PrefixIndex._iter_subtree(child)

    def clear(self, allocator: PageAllocator,
              host: Optional[HostPageStore] = None) -> int:
        """Drop every pin, both tiers (leak checks / shutdown). Returns
        device pages freed."""
        freed = 0
        for node in list(self._registered.values()):
            if self._unpin(node, allocator, host):
                freed += 1
        self._roots.clear()
        return freed


class GlobalPrefixView:
    """Cross-replica index of cached prefix chunks: path digest → replica.

    A router fronting N engine replicas :meth:`attach`\\ es each replica's
    :class:`PrefixIndex`; from then on every pin the replica publishes or
    drops updates this view synchronously through the observer hooks. The
    view stores **only** chain digests, replica ids, and hit counters —
    never tokens, page ids, or :class:`~repro.serving.swap.PageHandle`\\ s —
    so it can never pin a page or leak one: a view entry exists exactly as
    long as the replica's own index pin does.

    Routing reads it through :meth:`hit_pages`: given a request's digest
    chain (:func:`prefix_paths`), how many leading pages does each replica
    already cache? The answer is *advisory* — exactness never depends on
    it, because whichever replica admits the request runs its own
    :meth:`PrefixIndex.lookup` (which re-checks raw tokens, not digests)
    and its own prefill. A stale or collided view entry costs at most a
    missed sharing opportunity on the routed replica.

    ``journal`` (optional :class:`~repro.serving.obs.EventJournal`)
    receives ``view_publish`` / ``view_drop`` events, the router-side half
    of the cross-replica replay check
    (:func:`repro.serving.obs.replay_check_multi`).
    """

    def __init__(self, journal=None):
        self._paths: Dict[bytes, Dict[int, int]] = {}  # path -> {replica: hits}
        self._replicas: List[int] = []
        self.journal = journal

    def attach(self, replica_id: int, index: PrefixIndex) -> None:
        """Wire one replica's index into the view (call once per replica,
        before any admissions register pages)."""
        if replica_id in self._replicas:
            raise ValueError(f"replica {replica_id} already attached")
        self._replicas.append(replica_id)
        index.add_observer(
            lambda path: self.note_publish(replica_id, path),
            lambda path: self.note_drop(replica_id, path))

    # ------------------------------------------------------- observer inputs

    def note_publish(self, replica_id: int, path: bytes) -> None:
        self._paths.setdefault(path, {}).setdefault(replica_id, 0)
        if self.journal is not None:
            self.journal.emit("view_publish", replica=replica_id,
                              path=path.hex())

    def note_drop(self, replica_id: int, path: bytes) -> None:
        entry = self._paths.get(path)
        if entry is None or replica_id not in entry:
            raise KeyError(
                f"replica {replica_id} dropped unknown path {path.hex()}")
        del entry[replica_id]
        if not entry:
            del self._paths[path]
        if self.journal is not None:
            self.journal.emit("view_drop", replica=replica_id,
                              path=path.hex())

    # --------------------------------------------------------- routing reads

    @property
    def replicas(self) -> List[int]:
        return list(self._replicas)

    def __len__(self) -> int:
        return len(self._paths)

    def knows(self, replica_id: int, path: bytes) -> bool:
        return replica_id in self._paths.get(path, ())

    def hit_frequency(self, path: bytes, replica_id: int) -> int:
        return self._paths.get(path, {}).get(replica_id, 0)

    def paths_for(self, replica_id: int) -> set:
        """All digests the view believes ``replica_id`` caches (mirror of
        that replica's ``PrefixIndex.live_paths()``)."""
        return {p for p, entry in self._paths.items() if replica_id in entry}

    def hit_pages(self, paths: Sequence[bytes]) -> Dict[int, int]:
        """Expected aliasable pages per replica for a request whose digest
        chain is ``paths``: the length of the longest *leading* run of
        digests each replica caches (sharing is prefix-aligned, so a cached
        chunk behind a missing one is unreachable)."""
        hits = {r: 0 for r in self._replicas}
        live = set(hits)
        for path in paths:
            if not live:
                break
            entry = self._paths.get(path, ())
            for r in list(live):
                if r in entry:
                    hits[r] += 1
                else:
                    live.discard(r)
        return hits

    def record_hits(self, replica_id: int, paths: Sequence[bytes]) -> None:
        """Bump hit frequency on the leading run of ``paths`` cached by
        ``replica_id`` (called by the router when it routes a request
        there)."""
        for path in paths:
            entry = self._paths.get(path)
            if entry is None or replica_id not in entry:
                break
            entry[replica_id] += 1
