"""FCFS scheduler with memory-budgeted admission control.

Requests declare a sparsity tier ``s`` up front, so their worst-case KV
footprint is known exactly at submission time — the paper's ``3s + 2``
bytes/vector law (plus the full-precision recency buffer) makes the
projection sharp, unlike quantized caches whose metadata overhead varies
with runtime group boundaries. Admission packs the FCFS queue head against a
global byte budget: a request is admitted when (a) a slot is free and
(b) its projected completion-time footprint fits in the remaining budget.

FCFS is deliberately head-of-line blocking: a large request at the head
waits for bytes rather than being starved by later small ones (predictable
latency ordering; smarter packing is an open item in ROADMAP.md).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence

import numpy as np

from repro.core import sparse_cache


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tier`` is the Lexico sparsity ``s`` for this request (must be <= the
    engine's compiled ``s_max``); it controls both fidelity and the bytes
    this request is charged against the admission budget.
    """
    rid: int
    prompt: np.ndarray            # (T_prompt,) int32 token ids
    max_new_tokens: int
    tier: int
    arrival_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.max_new_tokens


def request_kv_bytes(total_tokens: int, *, tier: int, n_b: int, m: int,
                     num_layers: int, kv_heads: int, codec: str = "fp8") -> int:
    """Projected completion-time KV bytes of a request, paper accounting.

    ``sparse_cache.paper_kv_bytes`` counts one (K, V) pair of vectors per
    token per head; the model total multiplies by layers and KV heads.
    """
    t_c = max(total_tokens - n_b, 0)
    buf = min(total_tokens, n_b)
    per_head = sparse_cache.paper_kv_bytes(t_c, buf, tier, m, codec=codec)
    return num_layers * kv_heads * per_head


def request_page_count(total_tokens: int, *, n_b: int, page_size: int) -> int:
    """Completion-time page count of a request: its compressed positions
    rounded up to whole pages (the buffer lives outside the pool)."""
    from repro.serving.pages import pages_needed
    return pages_needed(max(total_tokens - n_b, 0), page_size)


def request_kv_bytes_paged(total_tokens: int, *, tier: int, n_b: int, m: int,
                           num_layers: int, kv_heads: int, page_size: int,
                           codec: str = "fp8") -> int:
    """Paged projection: like :func:`request_kv_bytes` but the compressed
    span is rounded up to whole pages — exactly what the slot will hold when
    it completes, page-granular fragmentation included."""
    pages = request_page_count(total_tokens, n_b=n_b, page_size=page_size)
    t_c = pages * page_size
    buf = min(total_tokens, n_b)
    per_head = sparse_cache.paper_kv_bytes(t_c, buf, tier, m, codec=codec)
    return num_layers * kv_heads * per_head


class FCFSScheduler:
    """First-come-first-served queue + byte-budget admission.

    ``kv_byte_budget=None`` disables the byte check (slot-count only).

    Paged mode (``page_size`` set): byte projections round the compressed
    span up to whole pages — the real page-granular footprint a slot reaches,
    not a ``t_max``-padded worst case — and ``page_budget`` additionally caps
    the *pages* admitted in flight, so lazy per-step page growth can never
    exhaust the device pool mid-decode. ``meta_tokens`` (model meta-token
    prefix) rides along in every projection.
    """

    def __init__(self, *, kv_byte_budget: Optional[int], n_b: int, m: int,
                 num_layers: int, kv_heads: int, codec: str = "fp8",
                 page_size: Optional[int] = None,
                 page_budget: Optional[int] = None, meta_tokens: int = 0):
        self.kv_byte_budget = kv_byte_budget
        self.n_b, self.m = n_b, m
        self.num_layers, self.kv_heads = num_layers, kv_heads
        self.codec = codec
        self.page_size = page_size
        self.page_budget = page_budget
        self.meta_tokens = meta_tokens
        self.queue: Deque[Request] = deque()
        self.bytes_admitted = 0          # projected bytes of in-flight requests
        self.pages_admitted = 0          # projected pages (paged mode only)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def projected_bytes(self, req: Request) -> int:
        total = req.total_tokens + self.meta_tokens
        if self.page_size is not None:
            return request_kv_bytes_paged(
                total, tier=req.tier, n_b=self.n_b, m=self.m,
                num_layers=self.num_layers, kv_heads=self.kv_heads,
                page_size=self.page_size, codec=self.codec)
        return request_kv_bytes(
            total, tier=req.tier, n_b=self.n_b, m=self.m,
            num_layers=self.num_layers, kv_heads=self.kv_heads, codec=self.codec)

    def projected_pages(self, req: Request) -> int:
        if self.page_size is None:
            return 0
        return request_page_count(req.total_tokens + self.meta_tokens,
                                  n_b=self.n_b, page_size=self.page_size)

    def _fits(self, req: Request) -> bool:
        if (self.kv_byte_budget is not None and
                self.bytes_admitted + self.projected_bytes(req)
                > self.kv_byte_budget):
            return False
        if (self.page_budget is not None and
                self.pages_admitted + self.projected_pages(req)
                > self.page_budget):
            return False
        return True

    def admit(self, free_slots: int) -> List[Request]:
        """Pop the FCFS prefix that fits (slots, bytes and pages). Head-of-
        line blocking: stop at the first request that doesn't fit."""
        admitted: List[Request] = []
        while self.queue and len(admitted) < free_slots:
            head = self.queue[0]
            if not self._fits(head):
                break
            self.queue.popleft()
            self.bytes_admitted += self.projected_bytes(head)
            self.pages_admitted += self.projected_pages(head)
            admitted.append(head)
        return admitted

    def release(self, req: Request) -> None:
        """Return a finished (or failed) request's projected bytes/pages."""
        self.bytes_admitted = max(0, self.bytes_admitted - self.projected_bytes(req))
        self.pages_admitted = max(0, self.pages_admitted - self.projected_pages(req))
