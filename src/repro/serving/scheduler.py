"""FCFS scheduler with memory-budgeted admission control.

Requests declare a sparsity tier ``s`` up front, so their worst-case KV
footprint is known exactly at submission time — the paper's ``3s + 2``
bytes/vector law (plus the full-precision recency buffer) makes the
projection sharp, unlike quantized caches whose metadata overhead varies
with runtime group boundaries. Admission packs the FCFS queue head against a
global byte budget: a request is admitted when (a) a slot is free and
(b) its projected completion-time footprint fits in the remaining budget.

FCFS is deliberately head-of-line blocking: a large request at the head
waits for bytes rather than being starved by later small ones (predictable
latency ordering; smarter packing is an open item in ROADMAP.md).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import quant, sparse_cache


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tier`` is the Lexico sparsity ``s`` for this request (must be <= the
    engine's compiled ``s_max``); it controls both fidelity and the bytes
    this request is charged against the admission budget.
    """
    rid: int
    prompt: np.ndarray            # (T_prompt,) int32 token ids
    max_new_tokens: int
    tier: int
    arrival_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.max_new_tokens


def request_kv_bytes(total_tokens: int, *, tier: int, n_b: int, m: int,
                     num_layers: int, kv_heads: int, codec: str = "fp8") -> int:
    """Projected completion-time KV bytes of a request, paper accounting.

    ``sparse_cache.paper_kv_bytes`` counts one (K, V) pair of vectors per
    token per head; the model total multiplies by layers and KV heads.
    """
    t_c = max(total_tokens - n_b, 0)
    buf = min(total_tokens, n_b)
    per_head = sparse_cache.paper_kv_bytes(t_c, buf, tier, m, codec=codec)
    return num_layers * kv_heads * per_head


def request_page_count(total_tokens: int, *, n_b: int, page_size: int) -> int:
    """Completion-time page count of a request: its compressed positions
    rounded up to whole pages (the buffer lives outside the pool)."""
    from repro.serving.pages import pages_needed
    return pages_needed(max(total_tokens - n_b, 0), page_size)


def request_kv_bytes_paged(total_tokens: int, *, tier: int, n_b: int, m: int,
                           num_layers: int, kv_heads: int, page_size: int,
                           codec: str = "fp8") -> int:
    """Paged projection: like :func:`request_kv_bytes` but the compressed
    span is rounded up to whole pages — exactly what the slot will hold when
    it completes, page-granular fragmentation included."""
    pages = request_page_count(total_tokens, n_b=n_b, page_size=page_size)
    t_c = pages * page_size
    buf = min(total_tokens, n_b)
    per_head = sparse_cache.paper_kv_bytes(t_c, buf, tier, m, codec=codec)
    return num_layers * kv_heads * per_head


class FCFSScheduler:
    """First-come-first-served queue + byte-budget admission.

    ``kv_byte_budget=None`` disables the byte check (slot-count only).

    Paged mode (``page_size`` set): byte projections round the compressed
    span up to whole pages — the real page-granular footprint a slot reaches,
    not a ``t_max``-padded worst case — and ``page_budget`` additionally caps
    the *pages* admitted in flight, so lazy per-step page growth can never
    exhaust the device pool mid-decode. ``meta_tokens`` (model meta-token
    prefix) rides along in every projection.

    Prefix sharing (``admit``'s ``shared_fn``): a request whose prompt
    prefix is already resident as shared pages is charged only for its *new*
    pages/bytes — the aliased pages are some earlier admission's (or the
    prefix cache's) to account for. The per-request charge is remembered so
    ``release`` returns exactly what was taken even though the index state
    has moved on. Because aliased pages stay resident past their charger's
    release, the plain ``pages_admitted <= page_budget`` check is no longer
    a pool-occupancy proof; a sharing engine therefore supplies
    ``pool_state_fn`` and admission switches to a reservation check against
    the allocator's live state: a request fits iff its new pages plus every
    live slot's still-unallocated reservation fit in the free list plus
    what the prefix cache could evict (minus what this admission is about
    to pin).
    """

    def __init__(self, *, kv_byte_budget: Optional[int], n_b: int, m: int,
                 num_layers: int, kv_heads: int, codec: str = "fp8",
                 page_size: Optional[int] = None,
                 page_budget: Optional[int] = None, meta_tokens: int = 0):
        self.kv_byte_budget = kv_byte_budget
        self.n_b, self.m = n_b, m
        self.num_layers, self.kv_heads = num_layers, kv_heads
        self.codec = codec
        self.page_size = page_size
        self.page_budget = page_budget
        self.meta_tokens = meta_tokens
        self.queue: Deque[Request] = deque()
        self.bytes_admitted = 0          # charged bytes of in-flight requests
        self.pages_admitted = 0          # charged pages (paged mode only)
        self.rejections = 0              # head-of-line _fits failures
        self._charged: Dict[int, Tuple[int, int]] = {}  # rid -> (bytes, pages)
        # optional rejection callback, invoked as on_reject(request) on each
        # head-of-line _fits failure (the engine routes it into metrics and
        # the request trace)
        self.on_reject: Optional[Callable[[Request], None]] = None

    def submit(self, req: Request) -> None:
        """Append ``req`` to the FCFS queue (no admission check here)."""
        self.queue.append(req)

    def __len__(self) -> int:
        return len(self.queue)

    def queued_bytes(self) -> int:
        """Projected completion-time bytes of everything still queued — the
        backlog pressure a multi-replica router weighs against other
        replicas (queue *depth* alone treats a 8-token and a 2048-token
        request as equal load)."""
        return sum(self.projected_bytes(r) for r in self.queue)

    def projected_bytes(self, req: Request) -> int:
        total = req.total_tokens + self.meta_tokens
        if self.page_size is not None:
            return request_kv_bytes_paged(
                total, tier=req.tier, n_b=self.n_b, m=self.m,
                num_layers=self.num_layers, kv_heads=self.kv_heads,
                page_size=self.page_size, codec=self.codec)
        return request_kv_bytes(
            total, tier=req.tier, n_b=self.n_b, m=self.m,
            num_layers=self.num_layers, kv_heads=self.kv_heads, codec=self.codec)

    def projected_pages(self, req: Request) -> int:
        """Completion-time page count of ``req`` (0 outside paged mode)."""
        if self.page_size is None:
            return 0
        return request_page_count(req.total_tokens + self.meta_tokens,
                                  n_b=self.n_b, page_size=self.page_size)

    def shared_byte_discount(self, req: Request, aliased_pages: int) -> int:
        """Paper-accounting bytes ``req`` does NOT newly occupy because
        ``aliased_pages`` full pages of its compressed span are physical
        pages it shares with earlier admissions (the copy-on-write boundary
        page is a private copy and gets no discount)."""
        if aliased_pages <= 0 or self.page_size is None:
            return 0
        codes = aliased_pages * self.page_size
        return (self.num_layers * self.kv_heads
                * 2 * codes * quant.payload_bytes(req.tier, self.codec))

    def _fits(self, req: Request, charge_bytes: int, charge_pages: int,
              pinned: int, promote: int, pool_state_fn) -> bool:
        if (self.kv_byte_budget is not None and
                self.bytes_admitted + charge_bytes > self.kv_byte_budget):
            return False
        if self.page_budget is not None:
            if pool_state_fn is not None:
                # reservation check against live pool state (prefix sharing
                # and/or a host swap tier): outstanding = charged-but-not-
                # yet-allocated pages of every in-flight request; evictable
                # is reduced by every page this admission is about to pin —
                # aliased pages AND the CoW source (conservative: they may
                # not have been evictable, but once pinned the only_free
                # eviction path cannot reclaim them to satisfy this
                # admission's allocation); `promote` device pages are needed
                # on top of the charge to fetch swapped aliased pages back;
                # `reclaimable` is the host tier's remaining room — device
                # pages the engine can free by demoting cold residents, so
                # the pool ceiling becomes a latency tradeoff, not a wall
                st = pool_state_fn()
                outstanding = self.pages_admitted - st["owned"]
                available = (st["free"] + max(st["evictable"] - pinned, 0)
                             + st.get("reclaimable", 0))
                if charge_pages + promote + outstanding > available:
                    return False
            elif self.pages_admitted + charge_pages > self.page_budget:
                return False
        return True

    def admit(self, free_slots: int,
              shared_fn: Optional[
                  Callable[[Request], Tuple[int, int, int, int]]] = None,
              pool_state_fn: Optional[Callable[[], Dict[str, int]]] = None,
              ) -> List[Request]:
        """Pop the FCFS prefix that fits (slots, bytes and pages).

        Args:
          free_slots: slots the engine has open right now.
          shared_fn: prefix-sharing peek — maps a request to
            ``(aliased_pages, shared_codes, pinned_pages, promote_pages)``
            it would reuse if admitted now; ``pinned_pages`` additionally
            counts the copy-on-write source page, which the admission pins
            but does not alias, and ``promote_pages`` counts aliased/CoW
            pages currently demoted to the host tier — promoting them costs
            device pages on top of the charge. The charge recorded for the
            request covers only what is new: ``projected_pages -
            aliased_pages`` pages and ``projected_bytes -
            shared_byte_discount`` bytes.
          pool_state_fn: live pool state for the reservation check (see
            class docstring): ``{"free": .., "evictable": .., "owned": ..}``
            where ``owned`` totals pages already allocated by live slots
            against their charges, plus optional ``"reclaimable"`` — device
            pages the engine can free by demoting cold residents into the
            host tier's remaining room (swap-enabled engines).

        Head-of-line blocking: stops at the first request that doesn't fit
        (each such stop is counted in ``rejections``). Returns the admitted
        requests in FCFS order.
        """
        admitted: List[Request] = []
        while self.queue and len(admitted) < free_slots:
            head = self.queue[0]
            aliased = shared = pinned = promote = 0
            if shared_fn is not None:
                aliased, shared, pinned, promote = shared_fn(head)
            charge_bytes = (self.projected_bytes(head)
                            - self.shared_byte_discount(head, aliased))
            charge_pages = max(self.projected_pages(head) - aliased, 0)
            if not self._fits(head, charge_bytes, charge_pages, pinned,
                              promote, pool_state_fn):
                self.rejections += 1
                if self.on_reject is not None:
                    self.on_reject(head)
                break
            self.queue.popleft()
            self.bytes_admitted += charge_bytes
            self.pages_admitted += charge_pages
            self._charged[head.rid] = (charge_bytes, charge_pages)
            admitted.append(head)
        return admitted

    def release(self, req: Request) -> None:
        """Return a finished (or failed) request's charged bytes/pages —
        exactly the amounts ``admit`` recorded for it."""
        charge_bytes, charge_pages = self._charged.pop(
            req.rid, (self.projected_bytes(req), self.projected_pages(req)))
        self.bytes_admitted = max(0, self.bytes_admitted - charge_bytes)
        self.pages_admitted = max(0, self.pages_admitted - charge_pages)
