"""Multi-replica serving: N engine replicas behind a prefix-affinity router.

Lexico's universal dictionary is the property that makes data-parallel
scale-out trivial to keep *exact*: the dictionary is input-agnostic, so N
replicas share one replicated :class:`~repro.core.dictionary.DictionaryBank`
(constructed once, passed to every engine by reference) while everything
stateful — slot pool, page allocator, prefix index, swap tier, scheduler —
stays strictly per-replica. A request is computed end-to-end by exactly one
replica, and every per-engine exactness gate (prefix sharing, swap, fused
kernels) already proves that one engine's tokens match the solo oracle;
routing therefore cannot change tokens, only *where* they are computed.
``tests/test_router.py`` pins that argument with a cross-replica
differential for every policy.

What routing *can* change is efficiency. Prefix sharing is per-replica: a
system prompt cached on replica 0 is invisible to replica 1, which must
re-run the prefix's OMP from scratch. The router keeps a
:class:`~repro.serving.prefix.GlobalPrefixView` — a cross-replica mirror of
every replica's prefix-index pins, keyed on chain digests
(:func:`~repro.serving.prefix.prefix_paths`), holding no page references —
and the :class:`PrefixAffinityPolicy` scores each replica by expected
aliasable pages minus load, so same-prefix traffic lands where the pages
already are. :class:`RoundRobinPolicy` and :class:`LeastLoadedPolicy` are
the baselines the benchmark compares against
(``benchmarks/serving_throughput.py --scenario router``).

Policies are deterministic pure functions of ``(request, snapshots,
hit-pages)`` — no clocks, no randomness — so routing decisions are
replayable and property-testable (monotone in hits, anti-monotone in load,
lowest-replica-id tie-breaks; ``tests/test_router.py``). See
``docs/routing.md`` for the topology, the view's staleness contract, and
the exactness argument.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

from repro.serving.engine import ContinuousBatchingEngine, EngineConfig, _bucket
from repro.serving.metrics import merge_snapshots
from repro.serving.obs import EventJournal, TraceRecorder
from repro.serving.obs.registry import MetricsRegistry, percentile
from repro.serving.prefix import GlobalPrefixView, prefix_paths
from repro.serving.scheduler import Request

__all__ = [
    "ReplicaRouter", "ReplicaSnapshot", "RoutingPolicy",
    "RoundRobinPolicy", "LeastLoadedPolicy", "PrefixAffinityPolicy",
]

# the router's trace track (requests get per-rid tracks on their replica's
# recorder; the router records only routing instants)
ROUTER_TID = 0


@dataclasses.dataclass(frozen=True)
class ReplicaSnapshot:
    """One replica's load signals at routing time (pure host-side reads —
    see ``ContinuousBatchingEngine.load_state``)."""
    replica_id: int
    queue_depth: int
    active_slots: int
    n_slots: int
    queued_bytes: int
    kv_bytes_resident: int
    host_bytes_resident: int
    free_pages: int
    total_pages: int

    @property
    def load(self) -> float:
        """Scalar load: queued requests (each >= one future slot-tenancy)
        plus two bounded [0, 1] pressure terms — slot occupancy and
        resident-page pressure — so queue depth dominates and the pressure
        terms break ties between equally-backlogged replicas. Deterministic
        in the snapshot; no clocks."""
        occupancy = self.active_slots / self.n_slots if self.n_slots else 0.0
        if self.total_pages:
            resident = (self.total_pages - self.free_pages) / self.total_pages
        else:
            resident = 0.0
        return self.queue_depth + occupancy + resident


class RoutingPolicy:
    """Pluggable routing decision: ``route(request, snapshots, hit_pages)
    -> replica_id``.

    ``snapshots`` is one :class:`ReplicaSnapshot` per replica;
    ``hit_pages`` maps replica id -> expected aliasable prefix pages for
    this request (``GlobalPrefixView.hit_pages``; all zeros when sharing is
    off). Implementations must be deterministic given their inputs — any
    state they keep (round-robin's cursor) must advance the same way for
    the same call sequence.
    """

    name = "base"

    def route(self, request: Request, snapshots: Sequence[ReplicaSnapshot],
              hit_pages: Dict[int, int]) -> int:
        raise NotImplementedError


class RoundRobinPolicy(RoutingPolicy):
    """Cycle through replicas in id order, ignoring load and prefix state."""

    name = "rr"

    def __init__(self) -> None:
        self._cursor = 0

    def route(self, request: Request, snapshots: Sequence[ReplicaSnapshot],
              hit_pages: Dict[int, int]) -> int:
        ids = sorted(s.replica_id for s in snapshots)
        choice = ids[self._cursor % len(ids)]
        self._cursor += 1
        return choice


class LeastLoadedPolicy(RoutingPolicy):
    """Lowest :attr:`ReplicaSnapshot.load`; lowest replica id on ties."""

    name = "load"

    def route(self, request: Request, snapshots: Sequence[ReplicaSnapshot],
              hit_pages: Dict[int, int]) -> int:
        return min(snapshots, key=lambda s: (s.load, s.replica_id)).replica_id


class PrefixAffinityPolicy(RoutingPolicy):
    """Score = ``affinity_weight * hit_pages - load``; highest wins.

    The score is monotone in a replica's expected prefix-hit pages and
    anti-monotone in its load, with lowest-replica-id tie-breaks — and with
    zero hits everywhere it degenerates *exactly* to
    :class:`LeastLoadedPolicy` (argmax of ``-load`` with the same
    tie-break). ``affinity_weight`` prices one aliasable page in load
    units: the default 1.0 means one cached page outweighs one queued
    request, which is the right order of magnitude because a hit page
    saves a whole page of prefill OMP on the routed replica.
    """

    name = "affinity"

    def __init__(self, affinity_weight: float = 1.0) -> None:
        if affinity_weight <= 0:
            raise ValueError("affinity_weight must be positive")
        self.affinity_weight = affinity_weight

    def score(self, hit_pages: int, load: float) -> float:
        return self.affinity_weight * hit_pages - load

    def route(self, request: Request, snapshots: Sequence[ReplicaSnapshot],
              hit_pages: Dict[int, int]) -> int:
        return min(
            snapshots,
            key=lambda s: (-self.score(hit_pages.get(s.replica_id, 0),
                                       s.load),
                           s.replica_id)).replica_id


_POLICIES = {
    "rr": RoundRobinPolicy,
    "load": LeastLoadedPolicy,
    "affinity": PrefixAffinityPolicy,
}


def make_policy(name: str) -> RoutingPolicy:
    """Fresh policy instance from its CLI name (rr | load | affinity)."""
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown routing policy {name!r}; choose from "
            f"{sorted(_POLICIES)}") from None


class ReplicaRouter:
    """N independent engine replicas behind one routing decision.

    One dictionary bank, constructed once by the caller, is shared by
    reference across every replica (it is immutable at serve time — the
    paper's universal-dictionary property); everything else is per-replica.
    ``submit`` routes each request to exactly one replica's queue;
    ``step``/``run`` drive all replicas; ``completed`` and ``to_dict``
    aggregate.

    Observability: the router keeps its own labeled
    :class:`~repro.serving.obs.registry.MetricsRegistry` (per-replica
    ``router_*`` families), an admission log (:class:`EventJournal` of
    ``route`` events interleaved with the view's ``view_publish`` /
    ``view_drop``) feeding
    :func:`~repro.serving.obs.replay_check_multi`, and — when the engine
    config enables tracing — a router-level
    :class:`~repro.serving.obs.TraceRecorder` with one instant per routing
    decision.
    """

    def __init__(self, params, cfg, lex_cfg, bank, engine_cfg: EngineConfig,
                 *, n_replicas: int = 2,
                 policy: Union[str, RoutingPolicy] = "affinity") -> None:
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        self.policy = (make_policy(policy) if isinstance(policy, str)
                       else policy)
        self.bank = bank
        self.engine_cfg = engine_cfg
        obs = engine_cfg.obs
        self.log = EventJournal()
        self.view = GlobalPrefixView(journal=self.log)
        self.tracer: Optional[TraceRecorder] = (
            TraceRecorder(process_name="lexico-router")
            if obs is not None and obs.trace else None)
        if self.tracer is not None:
            self.tracer.declare_thread(ROUTER_TID, "router")
        self.registry = MetricsRegistry()
        # every replica gets the SAME bank object — no copy, no re-init
        self.engines: List[ContinuousBatchingEngine] = [
            ContinuousBatchingEngine(params, cfg, lex_cfg, bank, engine_cfg)
            for _ in range(n_replicas)]
        for k, eng in enumerate(self.engines):
            assert eng.bank is bank
            if eng.prefix_index is not None:
                self.view.attach(k, eng.prefix_index)
        self._routed: Dict[int, int] = {}    # rid -> replica id

    # ------------------------------------------------------------- routing

    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def snapshots(self) -> List[ReplicaSnapshot]:
        """Fresh load snapshot of every replica, in replica-id order."""
        return [ReplicaSnapshot(replica_id=k, **eng.load_state())
                for k, eng in enumerate(self.engines)]

    def _request_paths(self, req: Request) -> List[bytes]:
        """The request's prefix chain digests, computed exactly the way an
        admitting engine keys its prefix index (meta sentinels + bucketed
        prompt, compressed span ``n_meta + bucket - n_b``) — so a view hit
        predicts a real index hit on that replica."""
        eng = self.engines[0]
        if eng.prefix_index is None:
            return []
        bucket = _bucket(req.prompt_len, self.engine_cfg.min_bucket)
        n_comp = eng.cfg.num_meta_tokens + bucket - eng.lex_cfg.n_b
        return prefix_paths(eng._key_tokens(req, bucket), req.tier, n_comp,
                            self.engine_cfg.page_size)

    def submit(self, req: Request) -> int:
        """Route ``req`` to one replica and enqueue it there. Returns the
        chosen replica id. Request ids must be unique fleet-wide (each rid
        is admitted on exactly one replica — the replay check's first
        invariant)."""
        if req.rid in self._routed:
            raise ValueError(f"rid {req.rid} already routed fleet-wide")
        snaps = self.snapshots()
        paths = self._request_paths(req)
        hits = self.view.hit_pages(paths) if paths else (
            {s.replica_id: 0 for s in snaps})
        choice = self.policy.route(req, snaps, hits)
        if not 0 <= choice < len(self.engines):
            raise ValueError(
                f"policy {self.policy.name!r} routed rid {req.rid} to "
                f"nonexistent replica {choice}")
        self._routed[req.rid] = choice
        self.view.record_hits(choice, paths)
        self.log.emit("route", rid=req.rid, replica=choice,
                      policy=self.policy.name,
                      hit_pages=hits.get(choice, 0))
        self.registry.counter(
            "router_requests_routed_total",
            "requests routed, by replica", replica=choice).inc()
        self.registry.counter(
            "router_prefix_hit_pages_total",
            "expected aliasable pages at routing time, by replica",
            replica=choice).inc(hits.get(choice, 0))
        if self.tracer is not None:
            self.tracer.instant("route", ROUTER_TID, rid=req.rid,
                                replica=choice, policy=self.policy.name,
                                hit_pages=hits.get(choice, 0))
        self.engines[choice].submit(req)
        return choice

    def replica_of(self, rid: int) -> int:
        """Which replica a routed request landed on."""
        return self._routed[rid]

    # ------------------------------------------------------------- driving

    def step(self) -> bool:
        """One step of every replica that has work. True while any replica
        still has queued or in-flight requests."""
        any_work = False
        for eng in self.engines:
            if eng.pool.active_slots() or len(eng.scheduler):
                any_work |= eng.step()
        return any_work

    def run(self, max_steps: int = 100_000) -> Dict[int, "object"]:
        """Drive all replicas until every queue drains; returns the merged
        ``completed`` map (rids are fleet-unique, so no key collides)."""
        for _ in range(max_steps):
            if not self.step():
                break
        return self.completed

    @property
    def completed(self) -> Dict[int, "object"]:
        out: Dict[int, object] = {}
        for eng in self.engines:
            out.update(eng.completed)
        return out

    def drain_caches(self) -> None:
        """Drop every replica's prefix-cache pins (shutdown / leak check).
        After a drained run this returns all index-pinned pages to each
        replica's free list and empties the ``GlobalPrefixView`` — the
        journals then replay with zero end-of-trace leaks
        (``replay_check_multi``)."""
        for eng in self.engines:
            if eng.prefix_index is not None:
                host = eng.swap.host if eng.swap is not None else None
                eng.prefix_index.clear(eng.allocator, host)

    # ------------------------------------------------------------- exports

    def to_dict(self) -> Dict:
        """Fleet-level metrics: ``merge_snapshots`` over the per-replica
        ``EngineMetrics.to_dict()`` snapshots (counters summed, peaks
        maxed), with the queue-latency percentiles recomputed *exactly*
        from the pooled raw samples (the snapshot-level merge can only
        weight per-replica percentiles), plus the router's own keys
        appended: ``n_replicas``, ``policy``, ``requests_routed`` (per
        replica, id order), and ``per_replica`` sub-dicts."""
        snaps = [eng.metrics.to_dict() for eng in self.engines]
        out = merge_snapshots(snaps)
        pooled = sorted(
            s for eng in self.engines for s in eng.metrics.queue_latency_s)
        if pooled:
            out["queue_latency_s_mean"] = sum(pooled) / len(pooled)
            out["queue_latency_s_max"] = max(pooled)
            out["queue_latency_s_p50"] = percentile(pooled, 0.50)
            out["queue_latency_s_p99"] = percentile(pooled, 0.99)
            if len(pooled) >= 1000:
                out["queue_latency_s_p999"] = percentile(pooled, 0.999)
        out["n_replicas"] = self.n_replicas
        out["policy"] = self.policy.name
        out["requests_routed"] = [self.requests_routed(k)
                                  for k in range(self.n_replicas)]
        out["per_replica"] = [
            {"replica": k,
             "requests_routed": self.requests_routed(k),
             "tokens_generated": s["tokens_generated"],
             "prefix_hits": s["prefix_hits"],
             "prefix_misses": s["prefix_misses"],
             "shared_page_hit_rate": s["shared_page_hit_rate"],
             "prefill_tokens_skipped": s["prefill_tokens_skipped"],
             "slot_occupancy_mean": s["slot_occupancy_mean"]}
            for k, s in enumerate(snaps)]
        return out

    def quality_summary(self) -> Dict:
        """Fleet-merged compression-quality block: exact counter/sketch
        merge over every replica's :class:`QualityRecorder` summary (see
        ``obs.merge_quality_blocks``; ``drift_score`` is the worst replica's
        score — one stale replica should surface, not be averaged away).
        Empty dict when quality telemetry is off."""
        from repro.serving.obs.quality import merge_quality_blocks
        return merge_quality_blocks(
            [eng.quality.summary() for eng in self.engines
             if eng.quality is not None])

    def requests_routed(self, replica_id: int) -> int:
        c = self.registry.get("router_requests_routed_total",
                              replica=replica_id)
        return int(c.value) if c is not None else 0

    def to_prometheus(self) -> str:
        """The router's own ``router_*`` families (per-replica labels).
        Replica engines each expose their full registry via
        ``engine.metrics.to_prometheus()`` — in a real deployment each
        replica is its own scrape target, so concatenating them here would
        collide family names."""
        return self.registry.to_prometheus()

    def save_admission_log(self, path: str) -> None:
        """Write the router's admission log (route + view events) as JSONL
        — the ``router_events`` input of ``replay_check_multi``."""
        self.log.save(path)

    def save_trace(self, path: str) -> None:
        if self.tracer is None:
            raise RuntimeError(
                "tracing is off — construct with "
                "EngineConfig(obs=ObsConfig(trace=True))")
        self.tracer.save(path)

    def replica_journals(self) -> Dict[int, List[Dict]]:
        """Per-replica journal events keyed by replica id — the
        ``replica_events`` input of ``replay_check_multi`` (requires
        journaling enabled on the engine config)."""
        out: Dict[int, List[Dict]] = {}
        for k, eng in enumerate(self.engines):
            if eng.journal is None:
                raise RuntimeError(
                    "journaling is off — construct with "
                    "EngineConfig(obs=ObsConfig(journal=True))")
            out[k] = eng.journal.events
        return out
