"""Slot lifecycle: allocate / step / retire / compact.

A slot is one row of the pooled ``ServeState``: its (B,)-indexed cache
bookkeeping advances independently of every other row, so the pool never
recompiles as requests join and leave. Host-side ``SlotPool`` tracks the
request <-> slot binding and per-slot progress; device-side ``write_slot``
splices a freshly prefilled B=1 state into row ``slot`` of the pool with one
jitted (traced-index) update — admitting a request is O(slot bytes), not
O(pool bytes), and never triggers retracing.

Paged storage adds five more traced-index device ops (each compiled once):

  * ``write_slot_paged``  — splice a B=1 contiguous prefill result into the
    shared page pool through a freshly allocated page-table row; a traced
    ``start`` masks the scatter below it so table entries aliasing another
    slot's pages (prefix sharing) are never written;
  * ``assign_page``       — grow a live slot by one page (decode crossed a
    page boundary);
  * ``copy_page``         — clone one pool page's sparse stores into another
    (copy-on-write of the last partially-filled shared page);
  * ``clear_slot_paged``  — zero a retired slot's counters + table row so its
    now-freed pages can be rebound to another slot without the idle row's
    write-backs racing the new owner;
  * ``read_slot_paged``   — gather one slot back out as a contiguous B=1
    state (debug / migration).

Which page ids a slot holds is decided host-side (``SlotInfo.pages`` +
``repro.serving.pages.PageAllocator`` + ``repro.serving.prefix``); the
device only ever sees table rows.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.core.attention import gather_pages
from repro.core.sparse_cache import LexicoLayerCache
from repro.models.model import ServeState
from repro.serving.scheduler import Request
from repro.serving.swap import PageHandle


@dataclasses.dataclass
class SlotInfo:
    """Host-side progress of the request bound to one slot.

    Fields:
      request: the :class:`~repro.serving.scheduler.Request` being served.
      fed: prompt tokens consumed so far (prefill bucket + streamed).
      generated: tokens sampled so far; ``generated_tokens`` collects them.
      pending: sampled token not yet fed back through decode.
      pages: pool pages bound in this slot's table row, in table order
        (paged layout; a host mirror of the device row). Entries are device
        page ids, or :class:`~repro.serving.swap.PageHandle` markers for
        positions whose page is currently demoted to the host tier (the
        device row holds the null page there; the engine promotes them back
        before the slot steps). The first ``pages_shared`` of them are
        *aliased* — owned jointly with other slots and/or the prefix index
        via refcounts, never written by this slot, and not counted against
        its admission reservation.
      pages_reserved: completion-time NEW-page reservation the scheduler
        charged at admission (aliased pages excluded).
      cache_len: host mirror of the device-side ``length`` row — drives
        lazy page growth without a device sync.
    """
    request: Request
    fed: int                      # prompt tokens consumed so far
    generated: int = 0
    generated_tokens: Optional[List[int]] = None
    admit_time: float = 0.0
    pending: Optional[int] = None  # sampled token not yet fed back
    pages: Optional[List[int]] = None
    pages_shared: int = 0
    pages_reserved: int = 0
    cache_len: int = 0

    def __post_init__(self):
        if self.generated_tokens is None:
            self.generated_tokens = []
        if self.pages is None:
            self.pages = []

    @property
    def pages_owned(self) -> int:
        """Pages this slot allocated for itself (counted against its
        admission reservation); aliased shared-prefix pages are excluded.
        Swapped entries still count — the codes exist, just host-side."""
        return len(self.pages) - self.pages_shared

    @property
    def device_pages(self) -> List[int]:
        """Device-resident page ids bound in this slot's table right now
        (swapped :class:`~repro.serving.swap.PageHandle` entries excluded)."""
        return [p for p in self.pages if not isinstance(p, PageHandle)]

    @property
    def swapped_pages(self) -> List["PageHandle"]:
        """Host-tier handles of this slot's demoted pages (the slot cannot
        step until the engine promotes them back)."""
        return [p for p in self.pages if isinstance(p, PageHandle)]

    @property
    def in_prompt_phase(self) -> bool:
        return self.fed < self.request.prompt_len

    @property
    def done(self) -> bool:
        return self.generated >= self.request.max_new_tokens


class SlotPool:
    """Fixed pool of ``n_slots`` request slots."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: List[Optional[SlotInfo]] = [None] * n_slots

    def free_slots(self) -> List[int]:
        """Indices of unoccupied slots (ascending)."""
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_slots(self) -> List[int]:
        """Indices of occupied slots (ascending)."""
        return [i for i, s in enumerate(self.slots) if s is not None]

    def occupancy(self) -> int:
        """Number of occupied slots."""
        return self.n_slots - len(self.free_slots())

    def allocate(self, info: SlotInfo) -> int:
        """Bind ``info`` to the lowest free slot; returns its index.
        Raises ``RuntimeError`` when the pool is full."""
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        self.slots[slot] = info
        return slot

    def retire(self, slot: int) -> SlotInfo:
        """Unbind and return slot ``slot``'s ``SlotInfo``. Raises
        ``KeyError`` if the slot is already empty (double retire)."""
        info = self.slots[slot]
        if info is None:
            raise KeyError(f"slot {slot} is empty")
        self.slots[slot] = None
        return info

    def compact(self) -> dict:
        """Host-side occupancy summary (the device pool needs no compaction —
        idle rows are masked, and admission overwrites them in place)."""
        return {
            "n_slots": self.n_slots,
            "occupied": self.occupancy(),
            "free": len(self.free_slots()),
            "prompt_phase": sum(1 for s in self.slots
                                if s is not None and s.in_prompt_phase),
        }


# ---------------------------------------------------------------------------
# Device-side slot splicing (jittable, traced slot index => no recompiles)
# ---------------------------------------------------------------------------

def write_slot(pool: ServeState, one: ServeState, slot) -> ServeState:
    """Write a B=1 ``ServeState`` into row ``slot`` of the pooled state.

    Cache leaves are (L, B, ...) — update along axis 1 at a *traced* index;
    ``length`` is (B,). Jit this once and admission never recompiles.
    """
    slot = jnp.asarray(slot, jnp.int32)
    cache = jax.tree.map(
        lambda p, o: jax.lax.dynamic_update_slice_in_dim(p, o.astype(p.dtype),
                                                         slot, axis=1),
        pool.cache, one.cache)
    length = jax.lax.dynamic_update_slice(pool.length, one.length, (slot,))
    return ServeState(cache=cache, length=length, cross=pool.cross)


def read_slot(pool: ServeState, slot) -> ServeState:
    """Extract row ``slot`` as a B=1 ``ServeState`` (debug / migration)."""
    slot = jnp.asarray(slot, jnp.int32)
    cache = jax.tree.map(
        lambda p: jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=1), pool.cache)
    length = jax.lax.dynamic_slice(pool.length, (slot,), (1,))
    return ServeState(cache=cache, length=length, cross=pool.cross)


# ---------------------------------------------------------------------------
# Paged-pool slot splicing (jittable, traced indices => no recompiles)
# ---------------------------------------------------------------------------

def write_slot_paged(pool: ServeState, one: ServeState, slot,
                     page_row, start=0) -> ServeState:
    """Splice a B=1 *contiguous* prefill result into the paged pool.

    Args:
      pool: pooled state whose ``cache`` is a stacked (L, ...)
        ``PagedLexicoLayerCache``.
      one: B=1 state the contiguous (oracle) prefill path produced — its
        cache leaves are ``(L, 1, KV, T1, s)`` stores plus ``(L, 1, ...)``
        buffers/counters.
      slot: traced int32 — destination pool row.
      page_row: ``(max_pages,)`` int32 — pages the host bound for this slot,
        padded with the null page; stripe positions past the bound pages
        land on the trash page (they are beyond ``t_c``).
      start: traced int32 — first compressed position to scatter. Positions
        below it are redirected to the trash page: under prefix sharing the
        table entries below ``start // page_size`` alias pages owned by
        other slots (or a CoW copy installed separately), and the splice
        must never write them. One compile serves every ``start``.

    The splice is O(slot bytes): the prompt stripe scatters into the slot's
    own pages, every other leaf is a row update at a traced index.
    """
    pc, oc = pool.cache, one.cache
    slot = jnp.asarray(slot, jnp.int32)
    page_row = jnp.asarray(page_row, jnp.int32)
    L = pc.page_table.shape[0]
    n_pages, _, P = pc.k_vals.shape[1:4]
    T1 = oc.k_vals.shape[3]

    t = jnp.arange(T1)
    pg = jnp.clip(page_row[jnp.clip(t // P, 0, page_row.shape[0] - 1)],
                  0, n_pages - 1)                        # (T1,)
    pg = jnp.where(t >= jnp.asarray(start, jnp.int32), pg, 0)
    off = t % P

    def scatter(pool_l, dense_l):
        # pool_l (n_pages, KV, P, s); dense_l (1, KV, T1, s)
        payload = jnp.moveaxis(dense_l[0].astype(pool_l.dtype), 0, 1)
        return pool_l.at[pg, :, off].set(payload)        # (T1, KV, s) payload

    scatter_layers = jax.vmap(scatter)

    def row_splice(p, o):
        return jax.lax.dynamic_update_slice_in_dim(p, o.astype(p.dtype),
                                                   slot, axis=1)

    table = jax.lax.dynamic_update_slice(
        pc.page_table, jnp.broadcast_to(page_row, (L, 1, page_row.shape[0])),
        (jnp.int32(0), slot, jnp.int32(0)))
    cache = pc._replace(
        k_vals=scatter_layers(pc.k_vals, oc.k_vals),
        k_idx=scatter_layers(pc.k_idx, oc.k_idx),
        v_vals=scatter_layers(pc.v_vals, oc.v_vals),
        v_idx=scatter_layers(pc.v_idx, oc.v_idx),
        page_table=table,
        k_buf=row_splice(pc.k_buf, oc.k_buf),
        v_buf=row_splice(pc.v_buf, oc.v_buf),
        t_c=row_splice(pc.t_c, oc.t_c),
        buf_len=row_splice(pc.buf_len, oc.buf_len),
        buf_start=row_splice(pc.buf_start, oc.buf_start))
    length = jax.lax.dynamic_update_slice(pool.length, one.length, (slot,))
    return ServeState(cache=cache, length=length, cross=pool.cross)


def assign_page(pool: ServeState, slot, page_pos, page_id) -> ServeState:
    """Bind pool page ``page_id`` as entry ``page_pos`` of ``slot``'s table
    (decode grew past a page boundary). All indices traced — one compile."""
    pc = pool.cache
    L = pc.page_table.shape[0]
    upd = jnp.broadcast_to(jnp.asarray(page_id, jnp.int32), (L, 1, 1))
    table = jax.lax.dynamic_update_slice(
        pc.page_table, upd,
        (jnp.int32(0), jnp.asarray(slot, jnp.int32),
         jnp.asarray(page_pos, jnp.int32)))
    return ServeState(cache=pc._replace(page_table=table),
                      length=pool.length, cross=pool.cross)


def copy_page(pool: ServeState, src, dst) -> ServeState:
    """Clone pool page ``src``'s sparse stores into page ``dst`` across all
    layers (copy-on-write of a partially-filled shared page: the recipient
    slot gets a private copy it may append into, the donor page stays
    immutable under its other holders).

    Both indices are traced int32 — one compile serves every (src, dst)
    pair. Callers must never pass the null/trash page 0 for either side;
    that is enforced host-side (``repro.serving.engine`` /
    ``repro.serving.prefix``) since traced values cannot be validated here.
    """
    pc = pool.cache
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def clone(store):
        # store: (L, n_pages, KV, P, s)
        L, _, KV, P, s = store.shape
        page = jax.lax.dynamic_slice(store, (jnp.int32(0), src, jnp.int32(0),
                                             jnp.int32(0), jnp.int32(0)),
                                     (L, 1, KV, P, s))
        return jax.lax.dynamic_update_slice(
            store, page, (jnp.int32(0), dst, jnp.int32(0), jnp.int32(0),
                          jnp.int32(0)))

    cache = pc._replace(k_vals=clone(pc.k_vals), k_idx=clone(pc.k_idx),
                        v_vals=clone(pc.v_vals), v_idx=clone(pc.v_idx))
    return ServeState(cache=cache, length=pool.length, cross=pool.cross)


def clear_slot_paged(pool: ServeState, slot) -> ServeState:
    """Zero a retired slot's counters and page-table row.

    Required before its pages are handed to another slot: an idle row still
    issues (no-op) write-backs through its table every step, and those must
    resolve to the trash page once the pages have a new owner — otherwise a
    same-cell write could race the new owner's append.
    """
    pc = pool.cache
    L, _, MP = pc.page_table.shape
    slot = jnp.asarray(slot, jnp.int32)
    table = jax.lax.dynamic_update_slice(
        pc.page_table, jnp.zeros((L, 1, MP), jnp.int32),
        (jnp.int32(0), slot, jnp.int32(0)))
    zero_row = lambda p: jax.lax.dynamic_update_slice(
        p, jnp.zeros((L, 1), p.dtype), (jnp.int32(0), slot))
    cache = pc._replace(page_table=table, t_c=zero_row(pc.t_c),
                        buf_len=zero_row(pc.buf_len),
                        buf_start=zero_row(pc.buf_start))
    length = jax.lax.dynamic_update_slice(pool.length,
                                          jnp.zeros((1,), jnp.int32), (slot,))
    return ServeState(cache=cache, length=length, cross=pool.cross)


def read_slot_paged(pool: ServeState, slot) -> ServeState:
    """Gather row ``slot`` of a paged pool as a contiguous B=1 state
    (T_max = max_pages * page_size; debug / migration / differential tests).
    """
    pc = pool.cache
    slot = jnp.asarray(slot, jnp.int32)
    table_row = jax.lax.dynamic_slice_in_dim(pc.page_table, slot, 1, axis=1)
    gather_layers = jax.vmap(gather_pages)
    row = lambda p: jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=1)
    cache = LexicoLayerCache(
        k_vals=gather_layers(pc.k_vals, table_row),
        k_idx=gather_layers(pc.k_idx, table_row),
        v_vals=gather_layers(pc.v_vals, table_row),
        v_idx=gather_layers(pc.v_idx, table_row),
        k_buf=row(pc.k_buf), v_buf=row(pc.v_buf),
        t_c=row(pc.t_c), buf_len=row(pc.buf_len), buf_start=row(pc.buf_start))
    length = jax.lax.dynamic_slice(pool.length, (slot,), (1,))
    return ServeState(cache=cache, length=length, cross=pool.cross)
