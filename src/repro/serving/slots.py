"""Slot lifecycle: allocate / step / retire / compact.

A slot is one row of the pooled ``ServeState``: its (B,)-indexed cache
bookkeeping advances independently of every other row, so the pool never
recompiles as requests join and leave. Host-side ``SlotPool`` tracks the
request <-> slot binding and per-slot progress; device-side ``write_slot``
splices a freshly prefilled B=1 state into row ``slot`` of the pool with one
jitted (traced-index) update — admitting a request is O(slot bytes), not
O(pool bytes), and never triggers retracing.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.models.model import ServeState
from repro.serving.scheduler import Request


@dataclasses.dataclass
class SlotInfo:
    """Host-side progress of the request bound to one slot."""
    request: Request
    fed: int                      # prompt tokens consumed so far
    generated: int = 0
    generated_tokens: Optional[List[int]] = None
    admit_time: float = 0.0
    pending: Optional[int] = None  # sampled token not yet fed back

    def __post_init__(self):
        if self.generated_tokens is None:
            self.generated_tokens = []

    @property
    def in_prompt_phase(self) -> bool:
        return self.fed < self.request.prompt_len

    @property
    def done(self) -> bool:
        return self.generated >= self.request.max_new_tokens


class SlotPool:
    """Fixed pool of ``n_slots`` request slots."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: List[Optional[SlotInfo]] = [None] * n_slots

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def occupancy(self) -> int:
        return self.n_slots - len(self.free_slots())

    def allocate(self, info: SlotInfo) -> int:
        free = self.free_slots()
        if not free:
            raise RuntimeError("no free slots")
        slot = free[0]
        self.slots[slot] = info
        return slot

    def retire(self, slot: int) -> SlotInfo:
        info = self.slots[slot]
        if info is None:
            raise KeyError(f"slot {slot} is empty")
        self.slots[slot] = None
        return info

    def compact(self) -> dict:
        """Host-side occupancy summary (the device pool needs no compaction —
        idle rows are masked, and admission overwrites them in place)."""
        return {
            "n_slots": self.n_slots,
            "occupied": self.occupancy(),
            "free": len(self.free_slots()),
            "prompt_phase": sum(1 for s in self.slots
                                if s is not None and s.in_prompt_phase),
        }


# ---------------------------------------------------------------------------
# Device-side slot splicing (jittable, traced slot index => no recompiles)
# ---------------------------------------------------------------------------

def write_slot(pool: ServeState, one: ServeState, slot) -> ServeState:
    """Write a B=1 ``ServeState`` into row ``slot`` of the pooled state.

    Cache leaves are (L, B, ...) — update along axis 1 at a *traced* index;
    ``length`` is (B,). Jit this once and admission never recompiles.
    """
    slot = jnp.asarray(slot, jnp.int32)
    cache = jax.tree.map(
        lambda p, o: jax.lax.dynamic_update_slice_in_dim(p, o.astype(p.dtype),
                                                         slot, axis=1),
        pool.cache, one.cache)
    length = jax.lax.dynamic_update_slice(pool.length, one.length, (slot,))
    return ServeState(cache=cache, length=length, cross=pool.cross)


def read_slot(pool: ServeState, slot) -> ServeState:
    """Extract row ``slot`` as a B=1 ``ServeState`` (debug / migration)."""
    slot = jnp.asarray(slot, jnp.int32)
    cache = jax.tree.map(
        lambda p: jax.lax.dynamic_slice_in_dim(p, slot, 1, axis=1), pool.cache)
    length = jax.lax.dynamic_slice(pool.length, (slot,), (1,))
    return ServeState(cache=cache, length=length, cross=pool.cross)
