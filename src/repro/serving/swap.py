"""Tiered KV storage: page-level swap-out to a host-memory tier.

Lexico's compressed pages are tiny — ``page_size`` vectors at ``3s + 2``
bytes each instead of ``2m`` full-precision bytes — so moving a page across
the host↔device boundary costs a fraction of what raw-KV paging would. This
module turns that into capacity: when the device page pool runs hot, cold
pages are *demoted* into a host-memory mirror instead of being lost, and
*promoted* back (bitwise, the same arrays device→host→device) the moment a
slot or a prefix-cache hit needs them. "Pool full" becomes a latency
tradeoff instead of a hard admission ceiling.

The pieces:

  * :class:`PageHandle` — a stable identity for a logical page. Device page
    ids are *positional* (an index into the pool) and are recycled the
    moment a page is demoted; the handle is what slot page-table mirrors and
    prefix-index nodes hold while the codes live host-side, so the page can
    be rebound to ANY free device slot on promotion.
  * :class:`HostPageStore` — the host tier: a pinned numpy mirror of
    demoted pages, refcounted with exactly the holder semantics of the
    device :class:`~repro.serving.pages.PageAllocator` (one ref per slot
    table entry, one per prefix-index pin). ``PageAllocator.demote``
    transfers a page's whole refcount here; ``promote`` transfers it back.
  * :class:`SwapPolicy` — cold-page scoring over last-touch recency,
    refcount fan-out and prefix-cache hit frequency. The same policy object
    scores prefix-cache eviction subtrees
    (:meth:`SwapPolicy.subtree_evict_key`), so "what do we demote" and
    "what do we drop" agree on what cold means.
  * :class:`SwapManager` — per-engine glue: the host store plus the
    per-page stats the policy scores (stats follow a page across tiers,
    keyed by device id while resident and by handle while swapped).
  * :func:`extract_page_state` / :func:`inject_page_state` — the
    ``ServeState``-level device splices (jitted once per engine, traced page
    index) wrapping ``sparse_cache.extract_page`` / ``inject_page``.

Exactness: demotion copies the page's encoded arrays off-device verbatim
and promotion writes the identical bytes back, so a demoted-then-promoted
page is indistinguishable from one that never moved — the engine
differential in ``tests/test_swap.py`` pins tokens bitwise against a
never-swapped run. See ``docs/tiered_memory.md`` for the full design.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.models.model import ServeState
from repro.core import sparse_cache


class HostTierFull(RuntimeError):
    """Raised when ``HostPageStore.put`` would exceed ``max_pages``."""


@dataclasses.dataclass(frozen=True)
class PageHandle:
    """Stable identity of a demoted page (host-tier key).

    Deliberately NOT an int: device page ids and handles live in disjoint
    namespaces, so a swapped page can never be mistaken for an allocatable
    device page (``PageAllocator.alloc`` hands out ints only — asserted in
    ``tests/test_slot_lifecycle_fuzz.py``).
    """
    hid: int


PageRef = Union[int, PageHandle]     # device page id | host-tier handle
HostStores = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def is_device_page(ref: PageRef) -> bool:
    """True for a device page id, False for a host-tier :class:`PageHandle`."""
    return not isinstance(ref, PageHandle)


@dataclasses.dataclass
class _HostPage:
    stores: HostStores            # (k_vals, k_idx, v_vals, v_idx) numpy
    refs: int                     # holders (slot table entries + index pins)
    nbytes: int
    quality: object = None        # optional PageQuality tag riding the page


class HostPageStore:
    """Host-memory tier: refcounted numpy mirror of demoted pool pages.

    Mirrors the device allocator's holder semantics exactly — a demotion
    transfers a page's whole refcount here (``put``), a promotion transfers
    it back out (``pop``), and holders that appear/disappear *while the page
    is swapped* (prefix sharing, slot retirement) move the count with
    ``incref``/``decref``. ``bytes_resident`` is the tier's real footprint
    (the arrays' nbytes across all layers), reported by the engine as
    ``host_bytes_resident``.
    """

    def __init__(self, max_pages: Optional[int] = None):
        if max_pages is not None and max_pages < 0:
            raise ValueError("max_pages must be >= 0 (or None = unbounded)")
        self.max_pages = max_pages
        self._pages: Dict[PageHandle, _HostPage] = {}
        self._next_hid = 1
        self.bytes_resident = 0
        # optional lifecycle journal (repro.serving.obs.EventJournal); None
        # keeps every operation hook-free — the host-tier twin of
        # PageAllocator.journal
        self.journal = None

    @property
    def n_pages(self) -> int:
        """Pages currently resident in the host tier."""
        return len(self._pages)

    def room(self) -> int:
        """Pages the tier can still absorb (a large sentinel if unbounded)."""
        if self.max_pages is None:
            return 1 << 30
        return max(self.max_pages - len(self._pages), 0)

    def handles(self) -> List[PageHandle]:
        """Live handles (promotion-candidate enumeration)."""
        return list(self._pages)

    def put(self, stores: HostStores, refs: int,
            quality: object = None) -> PageHandle:
        """Admit one demoted page holding ``refs`` transferred references.
        ``quality`` carries the page's encode-quality tag across the tier
        move (``None`` when telemetry is off). Raises :class:`HostTierFull`
        at ``max_pages`` — the caller falls back to destructive eviction."""
        if refs < 1:
            raise ValueError(f"a demoted page needs >= 1 holder, got {refs}")
        if self.room() <= 0:
            raise HostTierFull(
                f"host tier at capacity ({self.max_pages} pages)")
        handle = PageHandle(self._next_hid)
        self._next_hid += 1
        nbytes = int(sum(np.asarray(a).nbytes for a in stores))
        self._pages[handle] = _HostPage(stores=stores, refs=refs,
                                        nbytes=nbytes, quality=quality)
        self.bytes_resident += nbytes
        if self.journal is not None:
            self.journal.emit("host_put", hid=handle.hid, refs=refs)
        return handle

    def get(self, handle: PageHandle) -> HostStores:
        """The page's stores (read-only peek; the page stays resident)."""
        return self._pages[handle].stores

    def refcount(self, handle: PageHandle) -> int:
        """Holders of ``handle`` (0 = not resident)."""
        page = self._pages.get(handle)
        return page.refs if page is not None else 0

    def incref(self, handle: PageHandle) -> None:
        """One more holder of a swapped page (sharing while swapped)."""
        self._pages[handle].refs += 1
        if self.journal is not None:
            self.journal.emit("host_incref", hid=handle.hid,
                              refs=self._pages[handle].refs)

    def decref(self, handle: PageHandle) -> bool:
        """Drop one holder; the page leaves the tier at zero. Returns True
        iff it was dropped. Raises ``KeyError`` on an unknown handle (double
        free across tiers)."""
        page = self._pages.get(handle)
        if page is None:
            raise KeyError(f"{handle} is not host-resident (double free?)")
        page.refs -= 1
        if self.journal is not None:
            self.journal.emit("host_decref", hid=handle.hid, refs=page.refs)
        if page.refs == 0:
            del self._pages[handle]
            self.bytes_resident -= page.nbytes
            return True
        return False

    def get_quality(self, handle: PageHandle):
        """The page's encode-quality tag (``None`` when untagged)."""
        page = self._pages.get(handle)
        return page.quality if page is not None else None

    def pop_quality(self, handle: PageHandle):
        """Detach and return a resident page's tag (``None`` when untagged)
        — promotion hands it back to the device allocator *before* pop."""
        page = self._pages.get(handle)
        if page is None:
            return None
        tag, page.quality = page.quality, None
        return tag

    def pop(self, handle: PageHandle) -> Tuple[HostStores, int]:
        """Remove ``handle`` for promotion: returns ``(stores, refs)`` — the
        refcount transfers back to the device allocator verbatim."""
        page = self._pages.pop(handle)
        self.bytes_resident -= page.nbytes
        if self.journal is not None:
            self.journal.emit("host_pop", hid=handle.hid, refs=page.refs)
        return page.stores, page.refs

    def check_balanced(self) -> bool:
        """True iff the tier is empty with zeroed accounting (leak check —
        the two-tier twin of ``PageAllocator.check_balanced``)."""
        return not self._pages and self.bytes_resident == 0


@dataclasses.dataclass(frozen=True)
class SwapPolicy:
    """Cold-page scoring: who gets demoted, and what the prefix cache drops.

    ``cold_score`` ranks demotion victims: age since last touch, damped by
    refcount fan-out (a page many slots alias is expensive to stall on) and
    prefix-cache hit frequency (a page admissions keep re-using will be
    promoted right back). ``subtree_evict_key`` is the prefix-eviction
    scorer built from the same signals — hit-count per page with an LRU
    tie-break — so eviction and demotion agree on coldness.
    """
    ref_weight: float = 2.0       # damping per extra holder beyond the first
    hit_weight: float = 4.0       # damping per prefix-cache hit

    def cold_score(self, *, age: float, refs: int, hits: int) -> float:
        """Higher = colder = demoted earlier."""
        return age / (1.0 + self.ref_weight * max(refs - 1, 0)
                      + self.hit_weight * hits)

    def subtree_evict_key(self, *, hits: int, pages: int,
                          last_used: int) -> Tuple[float, int]:
        """Sort key for prefix-cache eviction victims (lowest first):
        hit-count per cached page — a rarely-hit subtree spread over many
        pages is the cheapest to lose — with least-recently-used breaking
        ties among equally (un)popular subtrees."""
        return ((1.0 + hits) / max(pages, 1), last_used)


@dataclasses.dataclass(frozen=True)
class SwapConfig:
    """Knobs of the host-memory tier (static over an engine's lifetime).

    ``watermark_pages``: proactive demotion target — after each step the
    engine demotes cold pages not bound in any live slot until at least this
    many device pages are free (0 disables proactivity; on-demand demotion
    inside allocation still runs). ``max_host_pages`` caps the host tier
    (None = unbounded); when the tier is full the engine falls back to
    destructive prefix eviction.
    """
    watermark_pages: int = 1
    max_host_pages: Optional[int] = None
    policy: SwapPolicy = dataclasses.field(default_factory=SwapPolicy)


class SwapManager:
    """Per-engine host tier + the per-page stats its policy scores.

    Stats are keyed by the page's *current* ref — device id while resident,
    :class:`PageHandle` while swapped — and follow the page across tier
    moves (:meth:`stats_move`), so a page's coldness history survives a
    round trip. The engine owns the device arrays and the holder rebinding;
    this object owns everything host-side.
    """

    def __init__(self, cfg: SwapConfig):
        self.cfg = cfg
        self.policy = cfg.policy
        self.host = HostPageStore(max_pages=cfg.max_host_pages)
        self._last_touch: Dict[PageRef, int] = {}
        self._hits: Dict[PageRef, int] = {}

    # ------------------------------------------------------------- stats

    def stats_reset(self, ref: PageRef, now: int) -> None:
        """A freshly allocated (or re-purposed) page starts warm, hitless."""
        self._last_touch[ref] = now
        self._hits[ref] = 0

    def note_touch(self, refs: Iterable[PageRef], now: int) -> None:
        """Pages read by this step's attention (they are hot *now*)."""
        for r in refs:
            self._last_touch[r] = now

    def note_hit(self, ref: PageRef) -> None:
        """One admission aliased this page (prefix-cache frequency)."""
        self._hits[ref] = self._hits.get(ref, 0) + 1

    def stats_move(self, old: PageRef, new: PageRef) -> None:
        """Re-key a page's stats across a tier move (demote or promote)."""
        self._last_touch[new] = self._last_touch.pop(old, 0)
        self._hits[new] = self._hits.pop(old, 0)

    def stats_drop(self, ref: PageRef) -> None:
        """Forget a page that left both tiers."""
        self._last_touch.pop(ref, None)
        self._hits.pop(ref, None)

    def cold_score(self, ref: PageRef, *, refs: int, now: int) -> float:
        return self.policy.cold_score(
            age=float(now - self._last_touch.get(ref, 0)), refs=refs,
            hits=self._hits.get(ref, 0))

    def coldest(self, candidates: Sequence[int], *, refcount_fn,
                now: int) -> int:
        """The single coldest demotion victim (ties broken by page id so
        the choice is deterministic for the differential tests); callers
        demote one page at a time, so no full sort is needed."""
        return min(
            candidates,
            key=lambda p: (-self.cold_score(p, refs=refcount_fn(p), now=now),
                           p))

    def prune_stats(self) -> None:
        """Drop stats for handles that left the host tier without a promote
        (destructive eviction of a swapped prefix entry, retire of a slot's
        last reference) — handles are never reused, so stale keys would
        otherwise accumulate for a server's lifetime. Device-id keys are
        bounded by the pool and reset on reallocation, so they stay."""
        live = set(self.host.handles())
        for d in (self._last_touch, self._hits):
            for k in [k for k in d
                      if isinstance(k, PageHandle) and k not in live]:
                del d[k]


# ---------------------------------------------------------------------------
# ServeState-level device splices (jitted per-engine, traced page index)
# ---------------------------------------------------------------------------

def extract_page_state(pool: ServeState, page):
    """Slice one pool page's sparse stores out of a pooled ``ServeState``
    (the device→host copy of a demotion). Pure function of the state — jit
    WITHOUT donation, the pool stays live."""
    return sparse_cache.extract_page(pool.cache, page)


def inject_page_state(pool: ServeState, page, k_vals, k_idx, v_vals,
                      v_idx) -> ServeState:
    """Write one page's sparse stores back into a pooled ``ServeState`` at
    device page ``page`` (the host→device copy of a promotion)."""
    cache = sparse_cache.inject_page(pool.cache, page, k_vals, k_idx,
                                     v_vals, v_idx)
    return ServeState(cache=cache, length=pool.length, cross=pool.cross)
