"""Host-side page allocator for the paged Lexico slot pool.

The device-side paged cache (``repro.core.sparse_cache.PagedLexicoLayerCache``)
is a shared pool of fixed-size pages plus a per-slot page table; *which* page
a slot owns is pure host bookkeeping, decided here. Pages are identified by
their index into the pool's leading ``n_pages`` axis.

Conventions:

  * page ``NULL_PAGE`` (= 0) is reserved as the null/trash page — page-table
    entries equal to ``NULL_PAGE`` mean "unallocated", and device-side writes
    by idle rows are clamped onto it so they can never race with a live
    slot's data. It is never handed out, so usable capacity is
    ``n_pages - 1``.
  * pages are refcounted. Plain admission takes one ref; prefix sharing
    (``repro.serving.prefix``) pins one physical page under several slots
    via ``incref`` — the page returns to the free list only when the last
    holder (slot or prefix-index cache entry) drops its reference.
"""
from __future__ import annotations

from typing import Dict, List

NULL_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Raised when ``alloc`` is asked for more pages than are free."""


class RefcountOverflow(RuntimeError):
    """Raised when a page's refcount would exceed ``PageAllocator.MAX_REFS``
    (a runaway incref loop — real sharing fan-out never gets close)."""


def pages_needed(n_compressed_tokens: int, page_size: int) -> int:
    """Pages required to hold ``n_compressed_tokens`` sparse-coded vectors."""
    if n_compressed_tokens <= 0:
        return 0
    return -(-n_compressed_tokens // page_size)


class PageAllocator:
    """Free-list + refcount allocator over page ids ``1..n_pages-1``.

    Purely host-side: the device only ever sees page ids through table rows.
    ``alloc`` hands out pages at refcount 1; ``incref``/``decref`` move the
    count; a page is returned to the free list exactly when its count hits
    zero. The null page 0 is never allocated, incref'd, or freed.
    """

    MAX_REFS = 1 << 16   # refcount ceiling (guards runaway incref loops)

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need n_pages >= 2 (page 0 is the null page)")
        if page_size < 1:
            raise ValueError("page_size must be positive")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages - 1, NULL_PAGE, -1))
        self._refs: Dict[int, int] = {}
        # tier-transfer counters (lifetime totals; see demote/promote)
        self.pages_demoted = 0
        self.pages_promoted = 0
        # optional lifecycle journal (repro.serving.obs.EventJournal); None
        # keeps every operation hook-free
        self.journal = None
        # optional per-page encode-quality tags (repro.serving.obs.PageQuality)
        # — populated only when the engine runs with ObsConfig(quality=True);
        # tags die with the page (freed) or travel with it (demote)
        self.quality: Dict[int, object] = {}

    @property
    def capacity(self) -> int:
        """Total usable pages (the null page is excluded)."""
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        """Pages currently on the free list."""
        return len(self._free)

    @property
    def n_used(self) -> int:
        """Pages currently allocated (refcount >= 1)."""
        return self.capacity - self.n_free

    def alloc(self, n: int = 1) -> List[int]:
        """Take ``n`` pages (refcount 1 each). All-or-nothing."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > self.n_free:
            raise PagePoolExhausted(
                f"requested {n} pages, only {self.n_free} free "
                f"of {self.capacity}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        if self.journal is not None:
            for p in pages:
                self.journal.emit("page_alloc", page=p)
        return pages

    def incref(self, page: int) -> None:
        """Pin ``page`` under one more holder (prefix sharing / cache entry).

        Raises ``ValueError`` for the null page, ``KeyError`` for a page that
        is not currently allocated (incref-after-free), and
        ``RefcountOverflow`` past ``MAX_REFS``.
        """
        if page == NULL_PAGE:
            raise ValueError("the null/trash page 0 cannot be shared")
        if page not in self._refs:
            raise KeyError(f"page {page} is not allocated (incref after free?)")
        if self._refs[page] >= self.MAX_REFS:
            raise RefcountOverflow(
                f"page {page} refcount would exceed {self.MAX_REFS}")
        self._refs[page] += 1
        if self.journal is not None:
            self.journal.emit("page_incref", page=page, refs=self._refs[page])

    def decref(self, page: int) -> None:
        """Drop one reference; the page returns to the free list at zero.

        Raises ``ValueError`` for the null page and ``KeyError`` when the
        page holds no references (double free / refcount underflow).
        """
        if page == NULL_PAGE:
            raise ValueError("the null/trash page 0 is never allocated")
        if page not in self._refs:
            raise KeyError(f"page {page} is not allocated (double free?)")
        self._refs[page] -= 1
        refs = self._refs[page]
        if refs == 0:
            del self._refs[page]
            self._free.append(page)
            self.quality.pop(page, None)
        if self.journal is not None:
            self.journal.emit("page_decref", page=page, refs=refs)

    def free(self, pages: List[int]) -> None:
        """Decref every page in ``pages`` (shared pages survive under their
        remaining holders; exclusively-held pages return to the free list)."""
        for p in pages:
            self.decref(p)

    def refcount(self, page: int) -> int:
        """Current reference count (0 = free or never allocated)."""
        return self._refs.get(page, 0)

    # ------------------------------------------------- encode-quality tags

    def set_quality(self, page: int, tag: object) -> None:
        """Attach an encode-quality tag to a *live* page (quality telemetry
        only — no-op semantics are the caller's business when disabled)."""
        if page == NULL_PAGE:
            raise ValueError("the null/trash page 0 carries no quality tag")
        if page not in self._refs:
            raise KeyError(f"page {page} is not allocated")
        self.quality[page] = tag

    def get_quality(self, page: int):
        """The page's quality tag, or ``None`` when untagged/free."""
        return self.quality.get(page)

    def pop_quality(self, page: int):
        """Detach and return the page's tag (``None`` when untagged) — used
        by demotion to hand the tag to the host tier."""
        return self.quality.pop(page, None)

    def allocated_pages(self) -> List[int]:
        """Page ids currently allocated (the demotion candidate set)."""
        return list(self._refs)

    # ------------------------------------------------- tiered-storage moves

    def demote(self, page: int) -> int:
        """Release ``page``'s *device slot* because its contents moved to the
        host tier (``repro.serving.swap.HostPageStore``).

        Distinct from :meth:`free`: no holder dropped a reference — the whole
        refcount transfers to the host tier at once (the caller must mirror
        the returned count there exactly), and the device id goes back on the
        free list so it can be rebound to a different logical page. Raises
        ``ValueError`` for the null page and ``KeyError`` for a page that is
        not allocated (demote after free).
        """
        if page == NULL_PAGE:
            raise ValueError("the null/trash page 0 is never demoted")
        if page not in self._refs:
            raise KeyError(f"page {page} is not allocated (demote after free?)")
        refs = self._refs.pop(page)
        self._free.append(page)
        self.quality.pop(page, None)  # caller pops first to carry the tag
        self.pages_demoted += 1
        if self.journal is not None:
            self.journal.emit("page_demote", page=page, refs=refs)
        return refs

    def promote(self, refs: int) -> int:
        """Take one free device page for a host-tier page rebinding into the
        pool, pre-set to ``refs`` holders — the count :meth:`demote`
        transferred out (possibly grown by sharing while swapped). Inverse of
        ``demote``; raises ``PagePoolExhausted`` when nothing is free and
        ``RefcountOverflow``/``ValueError`` on an out-of-range count.
        """
        if refs < 1:
            raise ValueError(f"promote needs >= 1 holder, got {refs}")
        if refs > self.MAX_REFS:
            raise RefcountOverflow(
                f"promoted refcount {refs} would exceed {self.MAX_REFS}")
        if not self._free:
            raise PagePoolExhausted(
                f"promote requested a page, none of {self.capacity} free")
        page = self._free.pop()
        self._refs[page] = refs
        self.pages_promoted += 1
        if self.journal is not None:
            self.journal.emit("page_promote", page=page, refs=refs)
        return page

    def check_balanced(self) -> bool:
        """True iff every allocated page has been returned (leak check)."""
        return not self._refs and self.n_free == self.capacity
