"""Continuous-batching engine over a fixed pool of Lexico cache slots.

The deployment story of the paper at serving scale: ONE universal dictionary
bank and ONE compiled decode step serve arbitrarily many heterogeneous
requests. The pool of ``n_slots`` cache rows never changes shape — requests
join by having their prompt prefilled at batch=1 and spliced into a free row
(traced slot index), and leave by simply being masked out — so XLA compiles:

  * one decode step for the whole pool (``active`` row mask, per-row
    positions/counters, per-row sparsity caps), reused for every step of
    every request mix;
  * one prefill per prompt-length *bucket* (powers of two): the prompt's
    largest bucket prefix goes through the parallel prefill path, the
    remainder is streamed through the pooled decode step (chunked-prefill
    style), so admission cost is bounded and compile count is
    ``#buckets + O(1)`` for any number of requests.

Interleaving: every engine step first admits what the FCFS + byte-budget
scheduler allows, then advances ALL active slots one token — slots still
consuming their prompt are fed prompt tokens (logits discarded), slots in
generation are fed their previously sampled token. Requests retire the
moment their ``max_new_tokens`` are sampled, freeing the slot for the queue
head on the next step.

Slot storage (``EngineConfig.layout``):

  * ``"contiguous"`` — every slot owns a full ``(t_max, s)`` stripe: a
    64-token request pays the same padded footprint as a 4k-token one.
  * ``"paged"`` — slots borrow fixed-size pages from one shared pool and a
    per-slot page table maps token positions to pages. Prompts are prefilled
    through the contiguous oracle at B=1 and scattered into freshly
    allocated pages on splice; decode appends grow a slot by one page
    exactly when its ``t_c`` crosses a page boundary (one traced-index
    table write, no recompile); retirement clears the slot row and returns
    its pages. Admission reserves each request's completion-time page count
    up front, so lazy growth can never exhaust the pool mid-decode. The
    decode step itself stays ONE compiled trace for any admit/retire mix —
    only the table contents change.

Prefix sharing (``EngineConfig.share_prefixes``, paged layout only): a
host-side radix trie (``repro.serving.prefix.PrefixIndex``) keyed on
page-granularity prompt-token-chunk hashes maps an admission's page-aligned
shared prompt prefix onto physical pages that already hold those codes —
full pages are aliased into the new slot's table (refcount++), the boundary
partially-filled page is copied-on-write, and the restartable prefill
(``M.prefill(compress_start=...)``) skips the prefix's OMP entirely. The
scheduler charges only *new* pages/bytes, and when the free list runs dry
the engine evicts cached (index-pinned) pages LRU-first. Sharing is exact:
codes are deterministic in the token prefix, so a shared run must emit
tokens bitwise-identical to an unshared run (tests/test_prefix_sharing.py).

The contiguous layout is the differential-test oracle for the paged one:
same requests through both layouts must produce identical tokens
(tests/test_paged_cache.py). See docs/serving.md for the full design.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LexicoConfig, ModelConfig
from repro.core import sparse_cache
from repro.core.dictionary import DictionaryBank
from repro.models import model as M
from repro.models.cache_policy import LexicoPolicy, PagedLexicoPolicy
from repro.serving import slots as slots_mod
from repro.serving.metrics import EngineMetrics
from repro.serving.pages import NULL_PAGE, PageAllocator, pages_needed
from repro.serving.prefix import PrefixIndex, SharePlan
from repro.serving.scheduler import FCFSScheduler, Request, request_kv_bytes
from repro.serving.slots import SlotInfo, SlotPool


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape and policy knobs (static over an engine's lifetime)."""
    n_slots: int = 8
    t_max: int = 256              # cache capacity per slot (tokens)
    kv_byte_budget: Optional[int] = None
    min_bucket: int = 16          # smallest prefill bucket (must be > n_b)
    layout: str = "contiguous"    # "contiguous" | "paged"
    page_size: int = 16           # tokens per pool page (paged layout)
    # total pool pages incl. the null page; None = full provisioning
    # (n_slots * max_pages_per_slot + 1) — size it down to oversubscribe
    n_pages: Optional[int] = None
    # copy-on-write prefix sharing over the page pool (paged layout only):
    # admissions whose prompt shares a page-aligned prefix with a live or
    # recently-retired slot alias those physical pages instead of
    # re-compressing them
    share_prefixes: bool = False
    # cap on pages the prefix index may keep pinned (None = bounded only by
    # the pool itself + LRU eviction when the free list runs dry)
    prefix_cache_pages: Optional[int] = None


def _bucket(prompt_len: int, min_bucket: int) -> int:
    """Largest power-of-two <= prompt_len, floored at min_bucket."""
    b = min_bucket
    while b * 2 <= prompt_len:
        b *= 2
    return b


class ContinuousBatchingEngine:
    """One slot pool + one compiled decode step serving many requests.

    Construct with model params, a ``ModelConfig``, a ``LexicoConfig``
    (compiled sparsity ceiling ``s``; per-request tiers cap below it), the
    dictionary bank, and an :class:`EngineConfig`. Drive with ``submit`` +
    ``step``/``run``; read ``metrics`` / ``compile_counts`` afterwards.
    """

    def __init__(self, params, cfg: ModelConfig, lex_cfg: LexicoConfig,
                 bank: Optional[DictionaryBank], engine_cfg: EngineConfig):
        if cfg.enc_dec or cfg.attn_free or cfg.parallel_ssm:
            # parallel_ssm: the Mamba recurrent state has no per-row active
            # gating yet, so idle slots would advance through garbage tokens
            raise NotImplementedError(
                "continuous batching supports decoder-only attention stacks")
        if engine_cfg.min_bucket <= lex_cfg.n_b:
            raise ValueError("min_bucket must exceed the recency buffer n_b")
        if engine_cfg.layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown layout {engine_cfg.layout!r}")
        self.paged = engine_cfg.layout == "paged"
        if engine_cfg.share_prefixes and not self.paged:
            raise ValueError(
                "share_prefixes requires layout='paged' (sharing aliases "
                "physical pool pages)")
        if self.paged and cfg.mla is not None:
            raise NotImplementedError(
                "paged slot storage covers the attention-stack Lexico cache; "
                "the MLA latent cache still uses contiguous slots")
        self.params, self.cfg, self.lex_cfg = params, cfg, lex_cfg
        self.bank = bank
        self.engine_cfg = engine_cfg
        # the contiguous policy always exists: it runs B=1 prefill in both
        # layouts (and is the paged layout's differential oracle)
        self.policy = LexicoPolicy(lex_cfg)
        self.pool = SlotPool(engine_cfg.n_slots)
        self.completed: Dict[int, SlotInfo] = {}
        self.metrics = EngineMetrics()

        B, t_max = engine_cfg.n_slots, engine_cfg.t_max
        self.allocator: Optional[PageAllocator] = None
        self.prefix_index: Optional[PrefixIndex] = None
        self._pending_plans: Dict[int, SharePlan] = {}
        decode_policy = self.policy
        if self.paged:
            P = engine_cfg.page_size
            max_pages = -(-max(t_max - lex_cfg.n_b, 1) // P)
            n_pages = (engine_cfg.n_pages if engine_cfg.n_pages is not None
                       else engine_cfg.n_slots * max_pages + 1)
            self.allocator = PageAllocator(n_pages, P)
            decode_policy = PagedLexicoPolicy(lex_cfg, n_pages=n_pages,
                                              page_size=P)
            self._max_pages = max_pages
            if engine_cfg.share_prefixes:
                self.prefix_index = PrefixIndex(
                    P, max_cached_pages=engine_cfg.prefix_cache_pages)
        self.decode_policy = decode_policy
        self.scheduler = FCFSScheduler(
            kv_byte_budget=engine_cfg.kv_byte_budget, n_b=lex_cfg.n_b,
            m=cfg.cached_vector_dim, num_layers=cfg.num_layers,
            kv_heads=cfg.cache_kv_heads, codec=lex_cfg.codec,
            page_size=engine_cfg.page_size if self.paged else None,
            page_budget=self.allocator.capacity if self.paged else None,
            meta_tokens=cfg.num_meta_tokens)

        cache = M.init_serve_cache(cfg, decode_policy, B, t_max)
        self.state = M.ServeState(cache=cache,
                                  length=jnp.zeros((B,), jnp.int32))

        # --- the compiled entry points ------------------------------------
        policy = self.policy

        def prefill_fn(params, bank, tokens, s_cap, compress_start):
            # compress_start is static: each distinct (bucket, start) pair is
            # its own trace — starts are page-aligned (or the full span), so
            # the count stays O(#buckets * max_pages) worst case, O(#buckets)
            # in practice (start=0 dominates; see docs/serving.md)
            return M.prefill(params, cfg, policy, {"tokens": tokens},
                             bank=bank, t_max=t_max, s_cap=s_cap,
                             compress_start=compress_start)

        def decode_fn(params, bank, state, token, active, s_cap):
            return M.decode_step(params, cfg, decode_policy, state, token,
                                 bank=bank, active=active, s_cap=s_cap)

        # every jitted entry point closes over a function object unique to
        # THIS engine: jax.jit keyed on a shared module-level function would
        # share one trace cache across engines, and compile_counts would
        # report other engines' (other pool shapes') traces
        def _own(fn):
            return jax.jit(lambda *a: fn(*a), donate_argnums=(0,))

        # one entry per (bucket, compress_start) pair; start is 0 unless
        # prefix sharing skipped a page-aligned prefix
        self._prefill_fn = jax.jit(prefill_fn, static_argnums=(4,))
        self._decode_fn = jax.jit(decode_fn, donate_argnums=(2,))
        if self.paged:
            self._write_fn = _own(slots_mod.write_slot_paged)
            self._assign_fn = _own(slots_mod.assign_page)
            self._clear_fn = _own(slots_mod.clear_slot_paged)
            self._copy_fn = _own(slots_mod.copy_page)
        else:
            self._write_fn = _own(slots_mod.write_slot)
            self._assign_fn = self._clear_fn = self._copy_fn = None

    # ------------------------------------------------------------------ API

    def submit(self, req: Request) -> None:
        """Queue one request, rejecting anything that could never be
        admitted (tier above the compiled ``s``, prompt below the smallest
        prefill bucket, footprint beyond ``t_max`` or the configured
        byte/page budgets). Raises ``ValueError`` with the reason."""
        if req.tier > self.lex_cfg.s:
            raise ValueError(f"tier {req.tier} exceeds compiled s={self.lex_cfg.s}")
        if req.prompt_len < self.engine_cfg.min_bucket:
            raise ValueError(
                f"prompt_len {req.prompt_len} < min_bucket "
                f"{self.engine_cfg.min_bucket}")
        need = req.total_tokens + self.cfg.num_meta_tokens
        if need > self.engine_cfg.t_max:
            raise ValueError(
                f"request needs {need} cache tokens (incl. meta) > t_max "
                f"{self.engine_cfg.t_max}")
        budget = self.engine_cfg.kv_byte_budget
        if budget is not None:
            cost = self.scheduler.projected_bytes(req)
            if cost > budget:
                raise ValueError(
                    f"request projects {cost} KV bytes > total budget {budget} "
                    "— it could never be admitted")
        if self.paged:
            pages = self.scheduler.projected_pages(req)
            if pages > self.allocator.capacity:
                # holds under prefix sharing too: aliased pages are still
                # bound in this request's own page table, so its
                # completion-time table needs `pages` distinct physical
                # pages no matter how many other holders they have
                raise ValueError(
                    f"request projects {pages} pages > pool capacity "
                    f"{self.allocator.capacity} — it could never be admitted")
        if not req.arrival_time:
            req.arrival_time = time.perf_counter()
        self.scheduler.submit(req)

    @property
    def compile_counts(self) -> Dict[str, int]:
        """Trace counts of every compiled entry point (the serving stack's
        no-recompile invariants are asserted against these in tests;
        ``prefill`` counts one trace per (bucket, compress_start) pair, the
        rest must stay at 1 regardless of the request mix)."""
        def n(fn):
            get = getattr(fn, "_cache_size", None)
            return int(get()) if callable(get) else -1
        counts = {"prefill": n(self._prefill_fn), "decode": n(self._decode_fn),
                  "write_slot": n(self._write_fn)}
        if self.paged:
            counts["assign_page"] = n(self._assign_fn)
            counts["clear_slot"] = n(self._clear_fn)
            counts["copy_page"] = n(self._copy_fn)
        return counts

    def kv_bytes_in_flight(self) -> int:
        """Paper-accounting bytes of what the active slots hold RIGHT NOW."""
        total = 0
        for i in self.pool.active_slots():
            info = self.pool.slots[i]
            # resident tokens: meta prefix + fed prompt + generated tokens
            # that were fed back (the pending one isn't in the cache yet)
            tokens_now = (self.cfg.num_meta_tokens + info.fed
                          + max(info.generated - 1, 0))
            total += request_kv_bytes(
                tokens_now, tier=info.request.tier, n_b=self.lex_cfg.n_b,
                m=self.cfg.cached_vector_dim, num_layers=self.cfg.num_layers,
                kv_heads=self.cfg.cache_kv_heads, codec=self.lex_cfg.codec)
        return total

    def kv_bytes_resident(self) -> int:
        """Bytes the active slots' sparse stores + buffers *hold*: pages
        actually bound under paging (each *physical* page counted once, no
        matter how many slots alias it via prefix sharing), full padded
        stripes under the contiguous layout. Note the device pool itself is
        preallocated (``n_pages`` pages), so this is the occupancy a
        right-sized pool must provision — the paged/contiguous gap on a
        mixed workload is the padding waste an oversubscribed pool
        (``n_pages`` sized down) reclaims as capacity, not bytes the default
        fully-provisioned pool hands back."""
        lex, cfg = self.lex_cfg, self.cfg
        val_bytes = jnp.dtype(lex.val_dtype).itemsize
        total = 0
        if self.paged:
            unique_pages = {p for i in self.pool.active_slots()
                            for p in self.pool.slots[i].pages}
            total += cfg.num_layers * len(unique_pages) * \
                sparse_cache.page_store_bytes(
                    cfg.cache_kv_heads, self.engine_cfg.page_size, lex.s,
                    val_bytes=val_bytes)
            for _ in self.pool.active_slots():   # per-slot ring buffers
                total += cfg.num_layers * sparse_cache.slot_resident_bytes(
                    0, kv_heads=cfg.cache_kv_heads,
                    page_size=self.engine_cfg.page_size, s=lex.s,
                    n_b=lex.n_b, m=cfg.cached_vector_dim, val_bytes=val_bytes)
            return total
        span = max(self.engine_cfg.t_max - lex.n_b, 1)
        for i in self.pool.active_slots():
            total += cfg.num_layers * sparse_cache.slot_resident_bytes(
                1, kv_heads=cfg.cache_kv_heads, page_size=span, s=lex.s,
                n_b=lex.n_b, m=cfg.cached_vector_dim, val_bytes=val_bytes)
        return total

    # ----------------------------------------------------------- internals

    def _consume_logits(self, slot: int, logits_row: np.ndarray) -> None:
        """Apply one step's logits to a slot: sample iff the prompt is fully
        consumed; retire when max_new_tokens have been sampled."""
        info = self.pool.slots[slot]
        if info.in_prompt_phase:
            return                      # prompt still streaming; discard
        tok = int(np.argmax(logits_row))
        info.pending = tok
        info.generated += 1
        info.generated_tokens.append(tok)
        self.metrics.tokens_generated += 1
        if info.done:
            self.pool.retire(slot)
            if self.paged:
                # zero the row's counters/table BEFORE its pages go back to
                # the free list — a re-bound page must never receive the idle
                # row's write-backs
                self.state = self._clear_fn(self.state, jnp.int32(slot))
                # decref everything the slot held: exclusively-owned pages
                # return to the free list, shared/aliased ones stay live
                # under their other holders (surviving slots / prefix cache)
                self.allocator.free(info.pages)
                info.pages = []
                info.pages_shared = 0
            self.scheduler.release(info.request)
            self.metrics.record_completion()
            self.completed[info.request.rid] = info

    def _alloc(self, n: int) -> List[int]:
        """Allocate ``n`` pool pages, evicting cached (prefix-index-pinned)
        pages LRU-first when the free list runs dry. Admission reserved
        completion-time *new*-page counts against free + evictable, so the
        eviction always recovers enough."""
        if (n > self.allocator.n_free and self.prefix_index is not None):
            self.prefix_index.evict(self.allocator,
                                    max_pages=n - self.allocator.n_free)
        return self.allocator.alloc(n)

    def _grow_pages(self, slot: int) -> None:
        """Lazy page growth: make sure ``slot``'s next compressed-token write
        position is covered by an allocated page (at most one new page per
        step — decode appends only ever touch the tail page)."""
        info = self.pool.slots[slot]
        write_pos = info.cache_len - self.lex_cfg.n_b
        need = pages_needed(write_pos + 1, self.engine_cfg.page_size)
        while len(info.pages) < need:
            (page,) = self._alloc(1)
            self.state = self._assign_fn(self.state, jnp.int32(slot),
                                         jnp.int32(len(info.pages)),
                                         jnp.int32(page))
            info.pages.append(page)

    # -------------------------------------------------- prefix sharing bits

    def _key_tokens(self, req: Request, bucket: int) -> np.ndarray:
        """Cache-space token key for the prefix trie: the (identical for
        every request) meta-token prefix as sentinels, then the prompt's
        prefill bucket. Compressed position ``p`` holds the code of cache
        token ``p``, so this sequence keys pages exactly."""
        n_meta = self.cfg.num_meta_tokens
        if n_meta:
            meta = np.full((n_meta,), -1, np.int64)
            return np.concatenate([meta, req.prompt[:bucket].astype(np.int64)])
        return req.prompt[:bucket].astype(np.int64)

    def _share_plan(self, req: Request) -> SharePlan:
        """Look up the longest page-aligned shared prefix for ``req``'s
        prefill bucket (codes past the bucket are decode-produced and never
        shared — see ``PrefixIndex.register``)."""
        bucket = _bucket(req.prompt_len, self.engine_cfg.min_bucket)
        n_comp = self.cfg.num_meta_tokens + bucket - self.lex_cfg.n_b
        return self.prefix_index.lookup(self._key_tokens(req, bucket),
                                        req.tier, n_comp)

    def _shared_peek(self, req: Request) -> Tuple[int, int, int]:
        """Scheduler peek: (aliased pages, shared codes, pages the
        admission will pin) for the head request. The pin count includes
        the CoW source page — pinned pages can't be evicted to satisfy
        this same admission's allocation, so the reservation check must
        not count them as evictable. The plan is cached and consumed by
        the subsequent ``_admit_one`` so lookup and commit can't
        disagree."""
        plan = self._share_plan(req)
        self._pending_plans[req.rid] = plan
        pinned = len(plan.aliased) + (1 if plan.copy_src is not None else 0)
        return len(plan.aliased), plan.shared_codes, pinned

    def _pool_state(self) -> Dict[str, int]:
        """Live pool state for the scheduler's reservation check."""
        owned = sum(self.pool.slots[i].pages_owned
                    for i in self.pool.active_slots())
        return {"free": self.allocator.n_free,
                "evictable": self.prefix_index.evictable_pages(self.allocator),
                "owned": owned}

    # ------------------------------------------------------------ admission

    def _admit(self) -> None:
        if self.prefix_index is None:
            now = time.perf_counter()
            for req in self.scheduler.admit(len(self.pool.free_slots())):
                self._admit_one(req, now)
            return
        # sharing: admit one at a time so each reservation check and prefix
        # lookup sees the pool state left by the previous splice
        while self.pool.free_slots():
            self._pending_plans.clear()
            admitted = self.scheduler.admit(1, shared_fn=self._shared_peek,
                                            pool_state_fn=self._pool_state)
            if not admitted:
                break
            self._admit_one(admitted[0], time.perf_counter())

    def _admit_one(self, req: Request, now: float) -> None:
        """Prefill (possibly restarted past a shared prefix) + splice one
        admitted request into a free slot."""
        bucket = _bucket(req.prompt_len, self.engine_cfg.min_bucket)
        cache_len = self.cfg.num_meta_tokens + bucket
        n_comp = cache_len - self.lex_cfg.n_b
        plan = self._pending_plans.pop(req.rid, None)
        start = plan.shared_codes if plan is not None else 0

        tokens = jnp.asarray(req.prompt[:bucket][None], jnp.int32)
        cap = jnp.full((1,), req.tier, jnp.int32)
        logits, one = self._prefill_fn(self.params, self.bank, tokens, cap,
                                       int(start))
        info = SlotInfo(request=req, fed=bucket, admit_time=now,
                        cache_len=cache_len,
                        pages_reserved=max(
                            self.scheduler.projected_pages(req)
                            - (len(plan.aliased) if plan else 0), 0))
        slot = self.pool.allocate(info)
        if self.paged:
            # pages covering the prefilled prompt's compressed span; the
            # scheduler reserved the completion-time count of NEW pages, so
            # this (and every later growth step) cannot exhaust the pool
            n_prompt = pages_needed(n_comp, self.engine_cfg.page_size)
            aliased = list(plan.aliased) if plan is not None else []
            copy_src = plan.copy_src if plan is not None else None
            for p in aliased:
                self.allocator.incref(p)
            if copy_src is not None:
                # pin the CoW source across the allocation: _alloc may evict
                # index-only pages, and the source must not be freed and
                # recycled as the very page we are about to copy into
                self.allocator.incref(copy_src)
            new_pages = self._alloc(n_prompt - len(aliased))
            info.pages = aliased + new_pages
            info.pages_shared = len(aliased)
            if copy_src is not None:
                # copy-on-write of the boundary page: the recipient appends
                # into a private copy; the donor page stays immutable. The
                # trash page can never be copied — it is never registered.
                assert copy_src != NULL_PAGE and new_pages, \
                    "CoW of the null/trash page is impossible"
                self.state = self._copy_fn(self.state, jnp.int32(copy_src),
                                           jnp.int32(new_pages[0]))
                self.allocator.decref(copy_src)
            row = np.zeros((self._max_pages,), np.int32)
            row[:n_prompt] = info.pages
            self.state = self._write_fn(self.state, one, jnp.int32(slot),
                                        jnp.asarray(row),
                                        jnp.int32(start))
            if self.prefix_index is not None:
                self.prefix_index.commit(plan if plan is not None
                                         else SharePlan())
                self.prefix_index.register(
                    self._key_tokens(req, bucket), req.tier, info.pages,
                    n_comp, self.allocator)
                self.metrics.record_prefix_share(
                    aliased=len(aliased),
                    copied=1 if (plan and plan.copy_src is not None) else 0,
                    skipped_codes=start,
                    bytes_deduped=self.scheduler.shared_byte_discount(
                        req, len(aliased)))
        else:
            self.state = self._write_fn(self.state, one, jnp.int32(slot))
        self.metrics.record_admission(now - req.arrival_time)
        self.metrics.prompt_tokens_processed += bucket
        self.metrics.prefill_tokens_compressed += n_comp - start
        self._consume_logits(slot, np.asarray(logits[0]))

    def step(self) -> bool:
        """Admit + advance every active slot one token. Returns True if any
        work remains (queued or in flight)."""
        self._admit()
        active_ids = self.pool.active_slots()
        if not active_ids:
            return len(self.scheduler) > 0

        B = self.engine_cfg.n_slots
        token = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        s_cap = np.full((B,), self.lex_cfg.s, np.int32)
        for i in active_ids:
            info = self.pool.slots[i]
            if info.in_prompt_phase:
                token[i] = int(info.request.prompt[info.fed])
            else:
                token[i] = info.pending
            active[i] = True
            s_cap[i] = info.request.tier
            if self.paged:
                self._grow_pages(i)

        logits, self.state = self._decode_fn(
            self.params, self.bank, self.state,
            jnp.asarray(token), jnp.asarray(active), jnp.asarray(s_cap))
        logits_np = np.asarray(logits)

        for i in active_ids:
            info = self.pool.slots[i]
            info.cache_len += 1          # host mirror of the device length row
            if info.in_prompt_phase:
                info.fed += 1
                self.metrics.prompt_tokens_processed += 1
            self._consume_logits(i, logits_np[i])

        shared_now = 0
        if self.paged:
            held = Counter(p for i in self.pool.active_slots()
                           for p in self.pool.slots[i].pages)
            shared_now = sum(1 for c in held.values() if c >= 2)
        self.metrics.sample_step(
            occupancy=self.pool.occupancy(),
            kv_bytes_in_flight=self.kv_bytes_in_flight(),
            kv_bytes_resident=self.kv_bytes_resident(),
            pages_in_use=self.allocator.n_used if self.paged else 0,
            shared_pages=shared_now)
        return bool(self.pool.active_slots()) or len(self.scheduler) > 0

    def run(self, max_steps: int = 100_000) -> Dict[int, SlotInfo]:
        """Drive until the queue drains and all slots retire."""
        for _ in range(max_steps):
            if not self.step():
                break
        return self.completed
