"""Continuous-batching engine over a fixed pool of Lexico cache slots.

The deployment story of the paper at serving scale: ONE universal dictionary
bank and ONE compiled decode step serve arbitrarily many heterogeneous
requests. The pool of ``n_slots`` cache rows never changes shape — requests
join by having their prompt prefilled at batch=1 and spliced into a free row
(traced slot index), and leave by simply being masked out — so XLA compiles:

  * one decode step for the whole pool (``active`` row mask, per-row
    positions/counters, per-row sparsity caps), reused for every step of
    every request mix;
  * one prefill per prompt-length *bucket* (powers of two): the prompt's
    largest bucket prefix goes through the parallel prefill path, the
    remainder is streamed through the pooled decode step (chunked-prefill
    style), so admission cost is bounded and compile count is
    ``#buckets + O(1)`` for any number of requests.

Interleaving: every engine step first admits what the FCFS + byte-budget
scheduler allows, then advances ALL active slots one token — slots still
consuming their prompt are fed prompt tokens (logits discarded), slots in
generation are fed their previously sampled token. Requests retire the
moment their ``max_new_tokens`` are sampled, freeing the slot for the queue
head on the next step.

Slot storage (``EngineConfig.layout``):

  * ``"contiguous"`` — every slot owns a full ``(t_max, s)`` stripe: a
    64-token request pays the same padded footprint as a 4k-token one.
  * ``"paged"`` — slots borrow fixed-size pages from one shared pool and a
    per-slot page table maps token positions to pages. Prompts are prefilled
    through the contiguous oracle at B=1 and scattered into freshly
    allocated pages on splice; decode appends grow a slot by one page
    exactly when its ``t_c`` crosses a page boundary (one traced-index
    table write, no recompile); retirement clears the slot row and returns
    its pages. Admission reserves each request's completion-time page count
    up front, so lazy growth can never exhaust the pool mid-decode. The
    decode step itself stays ONE compiled trace for any admit/retire mix —
    only the table contents change.

Prefix sharing (``EngineConfig.share_prefixes``, paged layout only): a
host-side radix trie (``repro.serving.prefix.PrefixIndex``) keyed on
page-granularity prompt-token-chunk hashes maps an admission's page-aligned
shared prompt prefix onto physical pages that already hold those codes —
full pages are aliased into the new slot's table (refcount++), the boundary
partially-filled page is copied-on-write, and the restartable prefill
(``M.prefill(compress_start=...)``) skips the prefix's OMP entirely. The
scheduler charges only *new* pages/bytes, and when the free list runs dry
the engine evicts cached (index-pinned) pages LRU-first. Sharing is exact:
codes are deterministic in the token prefix, so a shared run must emit
tokens bitwise-identical to an unshared run (tests/test_prefix_sharing.py).

Tiered storage (``EngineConfig(swap=SwapConfig(...))``, paged layout only):
a host-memory tier under the page pool (``repro.serving.swap``). When
free-list pressure crosses the watermark — or an allocation cannot be
served — the engine *demotes* cold pages (scored by ``SwapPolicy`` over
last-touch recency, refcount fan-out and prefix-hit frequency) into a
pinned numpy mirror, freeing their device ids for rebinding; any access to
a swapped page *promotes* it back with a blocking fetch-and-rebind before
the step. A slot whose pages cannot all be made device-resident for a step
**stalls** — it is masked out of that decode step (bit-identical idle row)
and retried next step — so concurrent admissions may oversubscribe the
device pool: the scheduler counts the host tier's remaining room as
reclaimable capacity, turning "pool full" from a hard admission ceiling
into a latency tradeoff (``promote_stall_steps``). Demote→promote round
trips move the encoded arrays device→host→device verbatim, so tokens are
bitwise identical to a never-swapped run (tests/test_swap.py). Cached
prefix pages are demoted in preference to being dropped, and an
admission-time prefix hit on a swapped page promotes it instead of
recompressing the prefix.

The contiguous layout is the differential-test oracle for the paged one:
same requests through both layouts must produce identical tokens
(tests/test_paged_cache.py). See docs/serving.md and docs/tiered_memory.md
for the full design.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LexicoConfig, ModelConfig
from repro.core import sparse_cache
from repro.core.dictionary import DictionaryBank
from repro.models import model as M
from repro.models.cache_policy import LexicoPolicy, PagedLexicoPolicy
from repro.serving import slots as slots_mod
from repro.serving import swap as swap_mod
from repro.serving.metrics import EngineMetrics
from repro.serving.obs import (
    ENGINE_TID, EventJournal, ObsConfig, PageQuality, QualityRecorder,
    TraceRecorder,
)
from repro.serving.pages import (
    NULL_PAGE, PageAllocator, PagePoolExhausted, pages_needed,
)
from repro.serving.prefix import PrefixIndex, SharePlan
from repro.serving.scheduler import FCFSScheduler, Request, request_kv_bytes
from repro.serving.slots import SlotInfo, SlotPool
from repro.serving.swap import PageHandle, SwapConfig, SwapManager


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine shape and policy knobs (static over an engine's lifetime)."""
    n_slots: int = 8
    t_max: int = 256              # cache capacity per slot (tokens)
    kv_byte_budget: Optional[int] = None
    min_bucket: int = 16          # smallest prefill bucket (must be > n_b)
    layout: str = "contiguous"    # "contiguous" | "paged"
    page_size: int = 16           # tokens per pool page (paged layout)
    # total pool pages incl. the null page; None = full provisioning
    # (n_slots * max_pages_per_slot + 1) — size it down to oversubscribe
    n_pages: Optional[int] = None
    # copy-on-write prefix sharing over the page pool (paged layout only):
    # admissions whose prompt shares a page-aligned prefix with a live or
    # recently-retired slot alias those physical pages instead of
    # re-compressing them
    share_prefixes: bool = False
    # cap on pages the prefix index may keep pinned (None = bounded only by
    # the pool itself + eviction when the free list runs dry)
    prefix_cache_pages: Optional[int] = None
    # host-memory swap tier over the page pool (paged layout only): cold
    # pages demote to a pinned numpy mirror under free-list pressure and
    # promote back — bitwise — on access; None disables tiering
    swap: Optional[SwapConfig] = None
    # observability switches (repro.serving.obs): request-lifecycle tracing,
    # page-lifecycle journaling and/or compression-quality telemetry; None
    # records nothing and pays nothing (phase timers and the metrics
    # registry are always on)
    obs: Optional[ObsConfig] = None
    # fused paged sparse-attention (paged layout only): decode attention
    # computes directly from the packed pool codes through the page tables
    # (kernels/paged_sparse_attn.py) instead of gather-then-mask; same
    # tokens, one compiled decode step either way
    fused_attention: bool = False
    # force the Pallas kernel itself (interpret mode off-TPU) rather than
    # its jnp oracle — parity testing / TPU-shaped runs; implies nothing
    # unless fused_attention is set
    fused_force_kernel: bool = False
    # fused OMP prefill encoder (either layout): prompt compression runs the
    # tile-batched early-exit encoder (kernels/omp_encode.py) with Pallas
    # correlation/select kernels instead of the vmapped per-vector oracle;
    # same codes (idx exact), same one-trace-per-(bucket, start) prefill
    fused_omp: bool = False
    # force the OMP selection kernels (interpret mode off-TPU) rather than
    # their jnp oracles; implies nothing unless fused_omp is set
    fused_omp_force_kernel: bool = False


def _bucket(prompt_len: int, min_bucket: int) -> int:
    """Largest power-of-two <= prompt_len, floored at min_bucket."""
    b = min_bucket
    while b * 2 <= prompt_len:
        b *= 2
    return b


class ContinuousBatchingEngine:
    """One slot pool + one compiled decode step serving many requests.

    Construct with model params, a ``ModelConfig``, a ``LexicoConfig``
    (compiled sparsity ceiling ``s``; per-request tiers cap below it), the
    dictionary bank, and an :class:`EngineConfig`. Drive with ``submit`` +
    ``step``/``run``; read ``metrics`` / ``compile_counts`` afterwards.
    """

    def __init__(self, params, cfg: ModelConfig, lex_cfg: LexicoConfig,
                 bank: Optional[DictionaryBank], engine_cfg: EngineConfig):
        if cfg.enc_dec or cfg.attn_free or cfg.parallel_ssm:
            # parallel_ssm: the Mamba recurrent state has no per-row active
            # gating yet, so idle slots would advance through garbage tokens
            raise NotImplementedError(
                "continuous batching supports decoder-only attention stacks")
        if engine_cfg.min_bucket <= lex_cfg.n_b:
            raise ValueError("min_bucket must exceed the recency buffer n_b")
        if engine_cfg.layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown layout {engine_cfg.layout!r}")
        self.paged = engine_cfg.layout == "paged"
        if engine_cfg.share_prefixes and not self.paged:
            raise ValueError(
                "share_prefixes requires layout='paged' (sharing aliases "
                "physical pool pages)")
        if engine_cfg.swap is not None and not self.paged:
            raise ValueError(
                "swap requires layout='paged' (the host tier mirrors pool "
                "pages)")
        if engine_cfg.fused_attention and not self.paged:
            raise ValueError(
                "fused_attention requires layout='paged' (the kernel walks "
                "pool page tables)")
        if self.paged and cfg.mla is not None:
            raise NotImplementedError(
                "paged slot storage covers the attention-stack Lexico cache; "
                "the MLA latent cache still uses contiguous slots")
        self.params, self.cfg, self.lex_cfg = params, cfg, lex_cfg
        self.bank = bank
        self.engine_cfg = engine_cfg
        # the contiguous policy always exists: it runs B=1 prefill in both
        # layouts (and is the paged layout's differential oracle)
        omp_backend = ("fused_kernel" if engine_cfg.fused_omp_force_kernel
                       else "fused") if engine_cfg.fused_omp else "ref"
        self.policy = LexicoPolicy(lex_cfg, omp_backend=omp_backend)
        self.pool = SlotPool(engine_cfg.n_slots)
        self.completed: Dict[int, SlotInfo] = {}
        self.metrics = EngineMetrics()

        B, t_max = engine_cfg.n_slots, engine_cfg.t_max
        self.allocator: Optional[PageAllocator] = None
        self.prefix_index: Optional[PrefixIndex] = None
        self.swap: Optional[SwapManager] = None
        self._pending_plans: Dict[int, SharePlan] = {}
        decode_policy = self.policy
        if self.paged:
            P = engine_cfg.page_size
            max_pages = -(-max(t_max - lex_cfg.n_b, 1) // P)
            n_pages = (engine_cfg.n_pages if engine_cfg.n_pages is not None
                       else engine_cfg.n_slots * max_pages + 1)
            self.allocator = PageAllocator(n_pages, P)
            decode_policy = PagedLexicoPolicy(
                lex_cfg, n_pages=n_pages, page_size=P,
                fused=engine_cfg.fused_attention,
                fused_force_kernel=engine_cfg.fused_force_kernel,
                omp_backend=omp_backend)
            self._max_pages = max_pages
            if engine_cfg.share_prefixes:
                self.prefix_index = PrefixIndex(
                    P, max_cached_pages=engine_cfg.prefix_cache_pages)
            if engine_cfg.swap is not None:
                self.swap = SwapManager(engine_cfg.swap)
        self.decode_policy = decode_policy
        self.scheduler = FCFSScheduler(
            kv_byte_budget=engine_cfg.kv_byte_budget, n_b=lex_cfg.n_b,
            m=cfg.cached_vector_dim, num_layers=cfg.num_layers,
            kv_heads=cfg.cache_kv_heads, codec=lex_cfg.codec,
            page_size=engine_cfg.page_size if self.paged else None,
            page_budget=self.allocator.capacity if self.paged else None,
            meta_tokens=cfg.num_meta_tokens)

        # --- observability (repro.serving.obs) ----------------------------
        obs = engine_cfg.obs
        self.tracer: Optional[TraceRecorder] = (
            TraceRecorder() if obs is not None and obs.trace else None)
        self.journal: Optional[EventJournal] = (
            EventJournal() if obs is not None and obs.journal else None)
        # compression-quality telemetry (ObsConfig(quality=True)): the
        # recorder is the ONLY quality state — when None the compiled
        # functions don't even return the quality aux
        self.quality: Optional[QualityRecorder] = None
        if obs is not None and obs.quality:
            self.quality = QualityRecorder(
                n_layers=cfg.num_layers, s_max=lex_cfg.s,
                registry=self.metrics.registry)
            self.metrics.quality = self.quality
        if self.journal is not None:
            if self.allocator is not None:
                self.allocator.journal = self.journal
            if self.swap is not None:
                self.swap.host.journal = self.journal
        self.scheduler.on_reject = self._on_reject
        if self.prefix_index is not None:
            self.prefix_index.on_evict = self._on_prefix_evict
            if self.journal is not None:
                # mirror prefix-pin lifecycle into the journal: the
                # cross-replica replay check (replay_check_multi) compares
                # these against the router's GlobalPrefixView events
                j = self.journal
                self.prefix_index.add_observer(
                    lambda path: j.emit("prefix_publish", path=path.hex()),
                    lambda path: j.emit("prefix_drop", path=path.hex()))
        # first-trace compile detection: the decode step compiles exactly
        # once, prefill once per (bucket, compress_start) pair — when a
        # timed call grew the jit cache, the elapsed time is compile time,
        # not steady-state work, and lands in metrics.compile_s
        self._decode_compiled = False

        cache = M.init_serve_cache(cfg, decode_policy, B, t_max)
        self.state = M.ServeState(cache=cache,
                                  length=jnp.zeros((B,), jnp.int32))

        # --- the compiled entry points ------------------------------------
        policy = self.policy
        # static Python bool fixed at construction: quality-on and
        # quality-off engines trace DIFFERENT functions (one returns the
        # aux, one doesn't), but each engine still traces its decode step
        # exactly once and its prefill once per (bucket, start) pair
        collect_quality = self.quality is not None

        def prefill_fn(params, bank, tokens, s_cap, compress_start):
            # compress_start is static: each distinct (bucket, start) pair is
            # its own trace — starts are page-aligned (or the full span), so
            # the count stays O(#buckets * max_pages) worst case, O(#buckets)
            # in practice (start=0 dominates; see docs/serving.md)
            return M.prefill(params, cfg, policy, {"tokens": tokens},
                             bank=bank, t_max=t_max, s_cap=s_cap,
                             compress_start=compress_start,
                             collect_quality=collect_quality)

        def decode_fn(params, bank, state, token, active, s_cap):
            return M.decode_step(params, cfg, decode_policy, state, token,
                                 bank=bank, active=active, s_cap=s_cap,
                                 collect_quality=collect_quality)

        # every jitted entry point closes over a function object unique to
        # THIS engine: jax.jit keyed on a shared module-level function would
        # share one trace cache across engines, and compile_counts would
        # report other engines' (other pool shapes') traces
        def _own(fn):
            return jax.jit(lambda *a: fn(*a), donate_argnums=(0,))

        # one entry per (bucket, compress_start) pair; start is 0 unless
        # prefix sharing skipped a page-aligned prefix
        self._prefill_fn = jax.jit(prefill_fn, static_argnums=(4,))
        self._decode_fn = jax.jit(decode_fn, donate_argnums=(2,))
        if self.paged:
            self._write_fn = _own(slots_mod.write_slot_paged)
            self._assign_fn = _own(slots_mod.assign_page)
            self._clear_fn = _own(slots_mod.clear_slot_paged)
            self._copy_fn = _own(slots_mod.copy_page)
        else:
            self._write_fn = _own(slots_mod.write_slot)
            self._assign_fn = self._clear_fn = self._copy_fn = None
        self._extract_fn = self._inject_fn = None
        if self.swap is not None:
            # extract reads the pool (jit WITHOUT donation — the state stays
            # live); inject replaces it (donate, like the other splices)
            self._extract_fn = jax.jit(
                lambda *a: swap_mod.extract_page_state(*a))
            self._inject_fn = _own(swap_mod.inject_page_state)

    # ------------------------------------------------------------------ API

    def submit(self, req: Request) -> None:
        """Queue one request, rejecting anything that could never be
        admitted (tier above the compiled ``s``, prompt below the smallest
        prefill bucket, footprint beyond ``t_max`` or the configured
        byte/page budgets). Raises ``ValueError`` with the reason."""
        if req.tier > self.lex_cfg.s:
            raise ValueError(f"tier {req.tier} exceeds compiled s={self.lex_cfg.s}")
        if req.prompt_len < self.engine_cfg.min_bucket:
            raise ValueError(
                f"prompt_len {req.prompt_len} < min_bucket "
                f"{self.engine_cfg.min_bucket}")
        need = req.total_tokens + self.cfg.num_meta_tokens
        if need > self.engine_cfg.t_max:
            raise ValueError(
                f"request needs {need} cache tokens (incl. meta) > t_max "
                f"{self.engine_cfg.t_max}")
        budget = self.engine_cfg.kv_byte_budget
        if budget is not None:
            cost = self.scheduler.projected_bytes(req)
            if cost > budget:
                raise ValueError(
                    f"request projects {cost} KV bytes > total budget {budget} "
                    "— it could never be admitted")
        if self.paged:
            pages = self.scheduler.projected_pages(req)
            if pages > self.allocator.capacity:
                # holds under prefix sharing too: aliased pages are still
                # bound in this request's own page table, so its
                # completion-time table needs `pages` distinct physical
                # pages no matter how many other holders they have
                raise ValueError(
                    f"request projects {pages} pages > pool capacity "
                    f"{self.allocator.capacity} — it could never be admitted")
        if not req.arrival_time:
            req.arrival_time = time.perf_counter()
        if self.tracer is not None:
            tid = self._tid(req.rid)
            self.tracer.declare_thread(tid, f"req {req.rid}")
            self.tracer.begin("request", tid, rid=req.rid, tier=req.tier,
                              prompt_len=req.prompt_len,
                              max_new_tokens=req.max_new_tokens)
            self.tracer.begin("queued", tid)
        if self.journal is not None:
            self.journal.emit("submit", rid=req.rid)
        self.scheduler.submit(req)

    @property
    def compile_counts(self) -> Dict[str, int]:
        """Trace counts of every compiled entry point (the serving stack's
        no-recompile invariants are asserted against these in tests;
        ``prefill`` counts one trace per (bucket, compress_start) pair, the
        rest must stay at 1 regardless of the request mix)."""
        def n(fn):
            get = getattr(fn, "_cache_size", None)
            return int(get()) if callable(get) else -1
        counts = {"prefill": n(self._prefill_fn), "decode": n(self._decode_fn),
                  "write_slot": n(self._write_fn)}
        if self.paged:
            counts["assign_page"] = n(self._assign_fn)
            counts["clear_slot"] = n(self._clear_fn)
            counts["copy_page"] = n(self._copy_fn)
        if self.swap is not None:
            counts["extract_page"] = n(self._extract_fn)
            counts["inject_page"] = n(self._inject_fn)
        return counts

    def kv_bytes_in_flight(self) -> int:
        """Paper-accounting bytes of what the active slots hold RIGHT NOW."""
        total = 0
        for i in self.pool.active_slots():
            info = self.pool.slots[i]
            # resident tokens: meta prefix + fed prompt + generated tokens
            # that were fed back (the pending one isn't in the cache yet)
            tokens_now = (self.cfg.num_meta_tokens + info.fed
                          + max(info.generated - 1, 0))
            total += request_kv_bytes(
                tokens_now, tier=info.request.tier, n_b=self.lex_cfg.n_b,
                m=self.cfg.cached_vector_dim, num_layers=self.cfg.num_layers,
                kv_heads=self.cfg.cache_kv_heads, codec=self.lex_cfg.codec)
        return total

    def kv_bytes_resident(self) -> int:
        """Bytes the active slots' sparse stores + buffers *hold*: pages
        actually bound under paging (each *physical* page counted once, no
        matter how many slots alias it via prefix sharing), full padded
        stripes under the contiguous layout. Note the device pool itself is
        preallocated (``n_pages`` pages), so this is the occupancy a
        right-sized pool must provision — the paged/contiguous gap on a
        mixed workload is the padding waste an oversubscribed pool
        (``n_pages`` sized down) reclaims as capacity, not bytes the default
        fully-provisioned pool hands back."""
        lex, cfg = self.lex_cfg, self.cfg
        val_bytes = jnp.dtype(lex.val_dtype).itemsize
        total = 0
        if self.paged:
            # device-resident pages only: a swapped page's bytes live in the
            # host tier and are reported by host_bytes_resident — the two
            # views never double-count a page
            unique_pages = {p for i in self.pool.active_slots()
                            for p in self.pool.slots[i].device_pages}
            total += cfg.num_layers * len(unique_pages) * \
                sparse_cache.page_store_bytes(
                    cfg.cache_kv_heads, self.engine_cfg.page_size, lex.s,
                    val_bytes=val_bytes)
            for _ in self.pool.active_slots():   # per-slot ring buffers
                total += cfg.num_layers * sparse_cache.slot_resident_bytes(
                    0, kv_heads=cfg.cache_kv_heads,
                    page_size=self.engine_cfg.page_size, s=lex.s,
                    n_b=lex.n_b, m=cfg.cached_vector_dim, val_bytes=val_bytes)
            return total
        span = max(self.engine_cfg.t_max - lex.n_b, 1)
        for i in self.pool.active_slots():
            total += cfg.num_layers * sparse_cache.slot_resident_bytes(
                1, kv_heads=cfg.cache_kv_heads, page_size=span, s=lex.s,
                n_b=lex.n_b, m=cfg.cached_vector_dim, val_bytes=val_bytes)
        return total

    def host_bytes_resident(self) -> int:
        """Bytes the host swap tier holds right now (0 without swap) — the
        two-tier complement of :meth:`kv_bytes_resident`: a demoted page's
        bytes move here, a promoted page's bytes move back, and no page is
        ever counted in both (tests/test_memory_accounting.py)."""
        return self.swap.host.bytes_resident if self.swap is not None else 0

    def load_state(self) -> Dict[str, int]:
        """Instantaneous load signals a multi-replica router snapshots
        before each routing decision (pure host-side reads, no device
        sync): queue depth + projected backlog bytes, slot occupancy, and
        the two residency pressures (device bytes, pool free pages)."""
        return {
            "queue_depth": len(self.scheduler),
            "queued_bytes": self.scheduler.queued_bytes(),
            "active_slots": len(self.pool.active_slots()),
            "n_slots": self.engine_cfg.n_slots,
            "kv_bytes_resident": self.kv_bytes_resident(),
            "host_bytes_resident": self.host_bytes_resident(),
            "free_pages": self.allocator.n_free if self.paged else 0,
            "total_pages": self.allocator.capacity if self.paged else 0,
        }

    # -------------------------------------------------- observability bits

    @staticmethod
    def _tid(rid: int) -> int:
        """Trace track of request ``rid`` (track 0 is the engine's)."""
        return rid + 1

    def _on_reject(self, req: Request) -> None:
        """Head-of-line admission failure: the request stays queued."""
        self.metrics.record_rejection()
        if self.tracer is not None:
            self.tracer.instant("reject", ENGINE_TID, rid=req.rid)
        if self.journal is not None:
            self.journal.emit("reject", rid=req.rid)

    def _on_prefix_evict(self, freed: int, unpinned: int) -> None:
        """Destructive prefix-cache eviction pass dropped ``unpinned`` pins
        (``freed`` device pages actually returned to the free list)."""
        self.metrics.record_prefix_evict(freed, unpinned)
        if self.tracer is not None:
            self.tracer.instant("prefix_evict", ENGINE_TID, freed=freed,
                                unpinned=unpinned)

    def _phase(self, name: str, t0: float, t1: float) -> None:
        """One engine.step() phase's wall time -> metrics (+ engine track)."""
        self.metrics.record_phase(name, t1 - t0)
        if self.tracer is not None:
            self.tracer.complete(name, ENGINE_TID, t0, t1)

    def _jit_traces(self, fn) -> int:
        get = getattr(fn, "_cache_size", None)
        return int(get()) if callable(get) else -1

    def save_trace(self, path: str) -> None:
        """Write the Chrome/Perfetto trace JSON (tracing must be enabled)."""
        if self.tracer is None:
            raise RuntimeError(
                "tracing is off — construct with "
                "EngineConfig(obs=ObsConfig(trace=True))")
        self.tracer.save(path)

    def save_journal(self, path: str) -> None:
        """Write the lifecycle event journal as JSONL (must be enabled)."""
        if self.journal is None:
            raise RuntimeError(
                "journaling is off — construct with "
                "EngineConfig(obs=ObsConfig(journal=True))")
        self.journal.save(path)

    # ----------------------------------------------------------- internals

    def _consume_logits(self, slot: int, logits_row: np.ndarray) -> None:
        """Apply one step's logits to a slot: sample iff the prompt is fully
        consumed; retire when max_new_tokens have been sampled."""
        info = self.pool.slots[slot]
        if info.in_prompt_phase:
            return                      # prompt still streaming; discard
        tok = int(np.argmax(logits_row))
        info.pending = tok
        info.generated += 1
        info.generated_tokens.append(tok)
        self.metrics.record_token(info.request.tier)
        if info.done:
            self.pool.retire(slot)
            if self.paged:
                # zero the row's counters/table BEFORE its pages go back to
                # the free list — a re-bound page must never receive the idle
                # row's write-backs
                self.state = self._clear_fn(self.state, jnp.int32(slot))
                # decref everything the slot held, in BOTH tiers:
                # exclusively-owned device pages return to the free list,
                # swapped entries drop their host-tier reference; shared/
                # aliased pages stay live under their other holders
                # (surviving slots / prefix cache)
                device_pages = info.device_pages
                if self.swap is not None:
                    for p in device_pages:
                        if self.allocator.refcount(p) == 1:
                            self.swap.stats_drop(p)
                    for h in info.swapped_pages:
                        if self.swap.host.decref(h):
                            self.swap.stats_drop(h)
                self.allocator.free(device_pages)
                info.pages = []
                info.pages_shared = 0
            self.scheduler.release(info.request)
            self.metrics.record_completion(info.request.tier)
            rid = info.request.rid
            if self.tracer is not None:
                tid = self._tid(rid)
                self.tracer.instant("retire", tid, generated=info.generated)
                self.tracer.end("request", tid)
            if self.journal is not None:
                self.journal.emit("retire", rid=rid, slot=slot)
            self.completed[rid] = info

    def _alloc(self, n: int, hot=frozenset()) -> List[int]:
        """Allocate ``n`` pool pages. When the free list runs dry: a
        swap-enabled engine first *demotes* cold pages (outside the ``hot``
        set — pages this very operation must keep device-resident) into the
        host tier, falling back to destructive prefix eviction only when the
        host tier is full; without swap, cached (prefix-index-pinned) pages
        are evicted directly. Admission reserved completion-time *new*-page
        counts against free + evictable + reclaimable, so this normally
        recovers enough (`PagePoolExhausted` otherwise — swap-mode callers
        on the growth path catch it and stall the slot)."""
        if n > self.allocator.n_free:
            if self.swap is not None:
                self._make_free(n, hot)     # best effort; alloc raises below
            elif self.prefix_index is not None:
                self.prefix_index.evict(self.allocator,
                                        max_pages=n - self.allocator.n_free)
        pages = self.allocator.alloc(n)
        if self.swap is not None:
            for p in pages:                 # a rebound id starts warm, hitless
                self.swap.stats_reset(p, self.metrics.steps)
        return pages

    def _grow_pages(self, slot: int, hot=frozenset()) -> bool:
        """Lazy page growth: make sure ``slot``'s next compressed-token write
        position is covered by an allocated page (at most one new page per
        step — decode appends only ever touch the tail page). Returns False
        when (swap mode only) the pool cannot supply the page even after
        demotions — the slot stalls this step and retries."""
        info = self.pool.slots[slot]
        write_pos = info.cache_len - self.lex_cfg.n_b
        need = pages_needed(write_pos + 1, self.engine_cfg.page_size)
        while len(info.pages) < need:
            try:
                (page,) = self._alloc(1, hot)
            except PagePoolExhausted:
                if self.swap is None:
                    raise
                return False
            self.state = self._assign_fn(self.state, jnp.int32(slot),
                                         jnp.int32(len(info.pages)),
                                         jnp.int32(page))
            info.pages.append(page)
        return True

    # --------------------------------------------------- tiered storage bits

    def _demote_page(self, page: int) -> PageHandle:
        """Move one device page's codes into the host tier and free its
        device id for rebinding: extract the arrays (blocking device→host
        copy), null every holding slot's table entry (the holders keep a
        :class:`PageHandle` marker), re-key the prefix-index pin if any, and
        transfer the whole refcount via ``PageAllocator.demote``."""
        stores = self._extract_fn(self.state, jnp.int32(page))
        stores_np = tuple(np.asarray(x) for x in stores)
        refs = self.allocator.refcount(page)
        # the quality tag rides the page across the tier move (None when
        # quality telemetry is off — the allocator dict is simply empty)
        handle = self.swap.host.put(stores_np, refs=refs,
                                    quality=self.allocator.pop_quality(page))
        holders = 0
        for i in self.pool.active_slots():
            info = self.pool.slots[i]
            for j, entry in enumerate(info.pages):
                if entry == page:
                    self.state = self._assign_fn(
                        self.state, jnp.int32(i), jnp.int32(j),
                        jnp.int32(NULL_PAGE))
                    info.pages[j] = handle
                    holders += 1
        if (self.prefix_index is not None
                and self.prefix_index.swap_out(page, handle)):
            holders += 1
        if holders != refs:
            raise RuntimeError(
                f"refcount mismatch demoting page {page}: allocator holds "
                f"{refs} refs but {holders} holders were rebound")
        self.allocator.demote(page)
        self.swap.stats_move(page, handle)
        self.metrics.record_swap(demoted=1)
        if self.tracer is not None:
            self.tracer.instant("demote", ENGINE_TID, page=page,
                                hid=handle.hid, refs=refs)
        return handle

    def _promote_handle(self, handle: PageHandle,
                        hot=frozenset()) -> Optional[int]:
        """Fetch one host-tier page back into the device pool (blocking
        host→device copy) and rebind every holder — slot table entries and
        the prefix-index pin — onto the freshly allocated device id. The
        refcount transfers back verbatim. Returns the device page id, or
        None when no device page can be freed (the caller stalls)."""
        if self.allocator.n_free == 0 and not self._make_free(1, hot):
            return None
        tag = self.swap.host.pop_quality(handle)
        stores, refs = self.swap.host.pop(handle)
        page = self.allocator.promote(refs)
        if tag is not None:
            # the tag returns with the codes; re-stamp the journal so replay
            # sees the tag re-attach to the (freshly allocated) device id
            self.allocator.set_quality(page, tag)
            if self.journal is not None:
                self.journal.emit("page_quality", page=page, **tag.to_event())
        self.state = self._inject_fn(self.state, jnp.int32(page),
                                     *(jnp.asarray(x) for x in stores))
        holders = 0
        for i in self.pool.active_slots():
            info = self.pool.slots[i]
            for j, entry in enumerate(info.pages):
                if entry == handle:
                    self.state = self._assign_fn(
                        self.state, jnp.int32(i), jnp.int32(j),
                        jnp.int32(page))
                    info.pages[j] = page
                    holders += 1
        if (self.prefix_index is not None
                and self.prefix_index.swap_in(handle, page)):
            holders += 1
        if holders != refs:
            raise RuntimeError(
                f"refcount mismatch promoting {handle}: host held {refs} "
                f"refs but {holders} holders were rebound")
        self.swap.stats_move(handle, page)
        self.metrics.record_swap(promoted=1)
        if self.tracer is not None:
            self.tracer.instant("promote", ENGINE_TID, hid=handle.hid,
                                page=page, refs=refs)
        return page

    def _make_free(self, n: int, hot=frozenset(), *,
                   evict_fallback: bool = True) -> bool:
        """Free device pages until at least ``n`` are free: demote the
        coldest non-``hot`` resident pages (policy-scored; the cache/slot
        bindings survive the move), then — unless ``evict_fallback`` is
        off — fall back to destructive prefix eviction when the host tier
        is full. True iff ``n`` are now free."""
        while self.allocator.n_free < n:
            victim = None
            if self.swap.host.room() > 0:
                cands = [p for p in self.allocator.allocated_pages()
                         if p not in hot]
                if cands:
                    victim = self.swap.coldest(
                        cands, refcount_fn=self.allocator.refcount,
                        now=self.metrics.steps)
            if victim is not None:
                self._demote_page(victim)
                continue
            if evict_fallback and self.prefix_index is not None:
                freed = self.prefix_index.evict(
                    self.allocator, max_pages=n - self.allocator.n_free,
                    scorer=self.swap.policy.subtree_evict_key,
                    host=self.swap.host)
                if freed:
                    continue
            return False
        return True

    def _prepare_slots(self, active_ids: List[int]) -> set:
        """Swap-aware pre-step pass: make every active slot's pages device-
        resident — promote its swapped pages, then grow its tail page — slot
        by slot in ascending order. A slot whose residency cannot be
        satisfied *stalls*: it is masked out of this decode step (an idle
        row is bit-identical, so its output stream is only delayed, never
        changed) and retried next step, while its resident pages become
        demotion candidates for the slots that do run. The first slot
        processed can always be satisfied (one request's pages never exceed
        the pool — enforced at submit), so every step makes progress."""
        stalled = set()
        hot: set = set()
        for i in active_ids:
            info = self.pool.slots[i]
            own = set(info.device_pages)
            ok = True
            for j in range(len(info.pages)):
                entry = info.pages[j]
                if not isinstance(entry, PageHandle):
                    # already resident (possibly promoted moments ago
                    # through a co-holding slot's rebind)
                    continue
                page = self._promote_handle(entry, hot | own)
                if page is None:
                    ok = False
                    break
                own.add(page)
            if ok:
                ok = self._grow_pages(i, hot | own)
            if ok:
                hot |= set(info.device_pages)
            else:
                stalled.add(i)
                self.metrics.record_swap(stalls=1)
                rid = info.request.rid
                if self.tracer is not None:
                    self.tracer.instant("promote_stall", self._tid(rid),
                                        slot=i)
                if self.journal is not None:
                    self.journal.emit("stall", rid=rid, slot=i)
        return stalled

    def _proactive_trim(self) -> None:
        """Watermark demotion (the proactive half of the tiering policy):
        keep at least ``SwapConfig.watermark_pages`` device pages free by
        demoting cold pages no live slot binds — in practice the prefix
        cache's index-only pages, which keep their trie entries and stay
        promotable. Never destructive (proactivity is not pressure), and
        demoting a slot-bound page here would only force a promote next
        step; on-demand demotion inside ``_alloc`` handles real pressure."""
        slot_pages = {p for i in self.pool.active_slots()
                      for p in self.pool.slots[i].device_pages}
        self._make_free(self.swap.cfg.watermark_pages, slot_pages,
                        evict_fallback=False)

    # -------------------------------------------------- prefix sharing bits

    def _key_tokens(self, req: Request, bucket: int) -> np.ndarray:
        """Cache-space token key for the prefix trie: the (identical for
        every request) meta-token prefix as sentinels, then the prompt's
        prefill bucket. Compressed position ``p`` holds the code of cache
        token ``p``, so this sequence keys pages exactly."""
        n_meta = self.cfg.num_meta_tokens
        if n_meta:
            meta = np.full((n_meta,), -1, np.int64)
            return np.concatenate([meta, req.prompt[:bucket].astype(np.int64)])
        return req.prompt[:bucket].astype(np.int64)

    def _share_plan(self, req: Request) -> SharePlan:
        """Look up the longest page-aligned shared prefix for ``req``'s
        prefill bucket (codes past the bucket are decode-produced and never
        shared — see ``PrefixIndex.register``)."""
        bucket = _bucket(req.prompt_len, self.engine_cfg.min_bucket)
        n_comp = self.cfg.num_meta_tokens + bucket - self.lex_cfg.n_b
        return self.prefix_index.lookup(self._key_tokens(req, bucket),
                                        req.tier, n_comp)

    def _shared_peek(self, req: Request) -> Tuple[int, int, int, int]:
        """Scheduler peek: (aliased pages, shared codes, pages the
        admission will pin, swapped pages it must promote) for the head
        request. The pin count includes the CoW source page — pinned pages
        can't be evicted to satisfy this same admission's allocation, so
        the reservation check must not count them as evictable; the promote
        count prices fetching host-tier entries back into device pages. The
        plan is cached and consumed by the subsequent ``_admit_one`` so
        lookup and commit can't disagree."""
        plan = self._share_plan(req)
        self._pending_plans[req.rid] = plan
        pinned = len(plan.aliased) + (1 if plan.copy_src is not None else 0)
        promote = sum(1 for p in plan.aliased if isinstance(p, PageHandle))
        if isinstance(plan.copy_src, PageHandle):
            promote += 1
        return len(plan.aliased), plan.shared_codes, pinned, promote

    def _pool_state(self) -> Dict[str, int]:
        """Live pool state for the scheduler's reservation check."""
        owned = sum(self.pool.slots[i].pages_owned
                    for i in self.pool.active_slots())
        st = {"free": self.allocator.n_free,
              "evictable": (self.prefix_index.evictable_pages(self.allocator)
                            if self.prefix_index is not None else 0),
              "owned": owned}
        if self.swap is not None:
            # device pages the engine can free by demoting cold residents
            # into the host tier's remaining room; evictable pages are
            # already counted once, so they are excluded here
            st["reclaimable"] = min(
                self.swap.host.room(),
                max(self.allocator.n_used - st["evictable"], 0))
        return st

    # ------------------------------------------------------------ admission

    def _admit(self) -> None:
        if self.prefix_index is None and self.swap is None:
            now = time.perf_counter()
            for req in self.scheduler.admit(len(self.pool.free_slots())):
                self._admit_one(req, now)
            return
        # sharing and/or tiering: admit one at a time so each reservation
        # check and prefix lookup sees the pool state left by the previous
        # splice (including pages it demoted or promoted)
        while self.pool.free_slots():
            self._pending_plans.clear()
            admitted = self.scheduler.admit(
                1,
                shared_fn=(self._shared_peek if self.prefix_index is not None
                           else None),
                pool_state_fn=self._pool_state)
            if not admitted:
                break
            self._admit_one(admitted[0], time.perf_counter())

    def _admit_one(self, req: Request, now: float) -> None:
        """Prefill (possibly restarted past a shared prefix) + splice one
        admitted request into a free slot."""
        bucket = _bucket(req.prompt_len, self.engine_cfg.min_bucket)
        cache_len = self.cfg.num_meta_tokens + bucket
        n_comp = cache_len - self.lex_cfg.n_b
        plan = self._pending_plans.pop(req.rid, None)
        start = plan.shared_codes if plan is not None else 0

        if self.tracer is not None:
            self.tracer.end("queued", self._tid(req.rid))
        tokens = jnp.asarray(req.prompt[:bucket][None], jnp.int32)
        cap = jnp.full((1,), req.tier, jnp.int32)
        n_traces = self._jit_traces(self._prefill_fn)
        t0 = time.perf_counter()
        qaux = None
        if self.quality is not None:
            logits, one, qaux = self._prefill_fn(self.params, self.bank,
                                                 tokens, cap, int(start))
        else:
            logits, one = self._prefill_fn(self.params, self.bank, tokens,
                                           cap, int(start))
        t1 = time.perf_counter()
        if self._jit_traces(self._prefill_fn) > n_traces:
            # a new (bucket, compress_start) trace: the elapsed time is
            # dominated by compilation, not prefill work
            self.metrics.record_compile(t1 - t0)
        else:
            # steady-state prompt compression: the phase timer feeds the
            # prefill p50/p99 the fused-OMP before/after comparison reads
            self.metrics.record_phase("prefill", t1 - t0)
        if self.tracer is not None:
            self.tracer.complete("prefill", self._tid(req.rid), t0, t1,
                                 bucket=bucket, compress_start=int(start))
        info = SlotInfo(request=req, fed=bucket, admit_time=now,
                        cache_len=cache_len,
                        pages_reserved=max(
                            self.scheduler.projected_pages(req)
                            - (len(plan.aliased) if plan else 0), 0))
        slot = self.pool.allocate(info)
        if self.paged:
            # pages covering the prefilled prompt's compressed span; the
            # scheduler reserved the completion-time count of NEW pages, so
            # this (and every later growth step) cannot exhaust the pool
            n_prompt = pages_needed(n_comp, self.engine_cfg.page_size)
            aliased = list(plan.aliased) if plan is not None else []
            copy_src = plan.copy_src if plan is not None else None
            if self.swap is not None and plan is not None:
                # materialize host-tier plan entries: a prefix hit on a
                # swapped page PROMOTES it back (bitwise) instead of
                # recompressing the prefix — the reservation check counted
                # these promote pages, so residency cannot fail here. Every
                # device-resident plan page (the CoW source included) is
                # hot: the promotions' demotions must not recycle a page
                # this admission is about to pin
                hot = {p for p in aliased if not isinstance(p, PageHandle)}
                if (copy_src is not None
                        and not isinstance(copy_src, PageHandle)):
                    hot.add(copy_src)
                for j, entry in enumerate(aliased):
                    if isinstance(entry, PageHandle):
                        page = self._promote_handle(entry, hot)
                        if page is None:
                            raise PagePoolExhausted(
                                "admission could not promote a shared page "
                                "the reservation check accounted for")
                        aliased[j] = page
                        hot.add(page)
                if isinstance(copy_src, PageHandle):
                    page = self._promote_handle(copy_src, hot)
                    if page is None:
                        raise PagePoolExhausted(
                            "admission could not promote the CoW source "
                            "page the reservation check accounted for")
                    copy_src = page
            for p in aliased:
                self.allocator.incref(p)
                if self.swap is not None:
                    self.swap.note_hit(p)
            if copy_src is not None:
                # pin the CoW source across the allocation: _alloc may evict
                # index-only pages, and the source must not be freed and
                # recycled as the very page we are about to copy into
                self.allocator.incref(copy_src)
                if self.swap is not None:
                    self.swap.note_hit(copy_src)
            keep = set(aliased) | ({copy_src} if copy_src is not None
                                   else set())
            new_pages = self._alloc(n_prompt - len(aliased), hot=keep)
            info.pages = aliased + new_pages
            info.pages_shared = len(aliased)
            if self.tracer is not None and aliased:
                self.tracer.instant("page_alias", self._tid(req.rid),
                                    pages=len(aliased))
            if copy_src is not None:
                # copy-on-write of the boundary page: the recipient appends
                # into a private copy; the donor page stays immutable. The
                # trash page can never be copied — it is never registered.
                assert copy_src != NULL_PAGE and new_pages, \
                    "CoW of the null/trash page is impossible"
                self.state = self._copy_fn(self.state, jnp.int32(copy_src),
                                           jnp.int32(new_pages[0]))
                if self.tracer is not None:
                    self.tracer.instant("cow_copy", self._tid(req.rid),
                                        src=copy_src, dst=new_pages[0])
                if self.quality is not None:
                    # the private copy inherits the donor page's tag (the
                    # copied codes ARE the donor's); the recipient's own
                    # encode span is folded in by _record_prefill_quality
                    src_tag = self.allocator.get_quality(copy_src)
                    if src_tag is not None:
                        self.allocator.set_quality(new_pages[0],
                                                   src_tag.copy())
                self.allocator.decref(copy_src)
            row = np.zeros((self._max_pages,), np.int32)
            row[:n_prompt] = info.pages
            self.state = self._write_fn(self.state, one, jnp.int32(slot),
                                        jnp.asarray(row),
                                        jnp.int32(start))
            if self.prefix_index is not None:
                self.prefix_index.commit(plan if plan is not None
                                         else SharePlan())
                self.prefix_index.register(
                    self._key_tokens(req, bucket), req.tier, info.pages,
                    n_comp, self.allocator,
                    host=self.swap.host if self.swap is not None else None)
                self.metrics.record_prefix_share(
                    aliased=len(aliased),
                    copied=1 if (plan and plan.copy_src is not None) else 0,
                    skipped_codes=start,
                    bytes_deduped=self.scheduler.shared_byte_discount(
                        req, len(aliased)))
        else:
            self.state = self._write_fn(self.state, one, jnp.int32(slot))
        self.metrics.record_admission(now - req.arrival_time)
        self.metrics.record_prompt_tokens(bucket)
        self.metrics.record_prefill_compressed(n_comp - start)
        if self.journal is not None:
            self.journal.emit("admit", rid=req.rid, slot=slot,
                              pages=[p for p in info.pages
                                     if not isinstance(p, PageHandle)],
                              aliased=info.pages_shared)
        if self.quality is not None:
            self._record_prefill_quality(qaux, req, info, int(start), n_comp)
        self._consume_logits(slot, np.asarray(logits[0]))

    def _record_prefill_quality(self, qaux, req: Request, info: SlotInfo,
                                start: int, n_comp: int) -> None:
        """Feed one admission's prefill encode-quality aux (layer-stacked
        numpy-able dict from ``M.prefill(collect_quality=True)``) into the
        recorder, stamp the slot's freshly-encoded pages with quality tags,
        and emit ``page_quality`` journal events + a trace counter sample."""
        q = {k: np.asarray(v) for k, v in qaux.items()}
        self.quality.record_prefill(q, tier=req.tier)
        if q["k_rel"].size == 0:
            return          # fully shared-prefix-skipped: nothing encoded
        if self.tracer is not None:
            self.tracer.counter("prefill_rel_residual", ENGINE_TID,
                                k=float(q["k_rel"].mean()),
                                v=float(q["v_rel"].mean()))
        if not self.paged:
            return
        P = self.engine_cfg.page_size
        # page pi holds compressed positions [pi*P, (pi+1)*P); this encode
        # produced [start, n_comp) — aliased prefix pages keep the donor's
        # tag (the codes are physically shared, so the quality is too)
        for pi, page in enumerate(info.pages):
            lo, hi = max(pi * P, start), min((pi + 1) * P, n_comp)
            if hi <= lo or isinstance(page, PageHandle):
                continue
            sl = slice(lo - start, hi - start)
            tag = self.allocator.get_quality(page)
            if tag is None:
                tag = PageQuality()
            tag.add(np.concatenate([q["k_rel"][..., sl].ravel(),
                                    q["v_rel"][..., sl].ravel()]),
                    np.concatenate([q["k_nnz"][..., sl].ravel(),
                                    q["v_nnz"][..., sl].ravel()]))
            self.allocator.set_quality(page, tag)
            if self.journal is not None:
                self.journal.emit("page_quality", page=page, **tag.to_event())

    def _record_decode_quality(self, qnp: Dict[str, np.ndarray],
                               step_ids: List[int], pre_pos: Dict[int, int],
                               s_cap: np.ndarray) -> None:
        """Feed one decode step's single-evictee encode quality into the
        recorder and roll the written positions into their pages' tags.
        ``pre_pos`` maps slot -> the compressed position the evictee landed
        at (captured before the per-slot ``cache_len`` increments)."""
        self.quality.record_decode(qnp, tiers=s_cap)
        wrote = np.asarray(qnp["wrote"])
        w = np.asarray(wrote[0] if wrote.ndim == 2 else wrote, bool)
        rows = [i for i in step_ids if w[i]]
        if not rows:
            return          # every row's recency buffer still filling
        if self.tracer is not None:
            self.tracer.counter("encode_rel_residual", ENGINE_TID,
                                k=float(qnp["k_rel"][:, rows].mean()),
                                v=float(qnp["v_rel"][:, rows].mean()))
            self.tracer.counter("encode_nnz", ENGINE_TID,
                                k=float(qnp["k_nnz"][:, rows].mean()),
                                v=float(qnp["v_nnz"][:, rows].mean()))
        if not self.paged:
            return
        P = self.engine_cfg.page_size
        for i in rows:
            info = self.pool.slots[i]
            if info is None or not info.pages:
                continue    # retired this very step — its pages are gone
            pos = pre_pos[i]
            pi = pos // P
            if pi >= len(info.pages):
                continue
            page = info.pages[pi]
            if isinstance(page, PageHandle) or page == NULL_PAGE:
                continue
            tag = self.allocator.get_quality(page)
            if tag is None:
                tag = PageQuality()
            tag.add(np.concatenate([qnp["k_rel"][:, i].ravel(),
                                    qnp["v_rel"][:, i].ravel()]),
                    np.concatenate([qnp["k_nnz"][:, i].ravel(),
                                    qnp["v_nnz"][:, i].ravel()]))
            self.allocator.set_quality(page, tag)
            if self.journal is not None and pos % P == P - 1:
                # the page just sealed (last position written): one journal
                # stamp per page, not one per decoded token
                self.journal.emit("page_quality", page=page, **tag.to_event())

    def step(self) -> bool:
        """Admit + advance every active slot one token (swap mode: every
        active slot whose pages could be made device-resident — the rest
        stall, bit-identical, until promotion succeeds). Returns True if any
        work remains (queued or in flight)."""
        self.metrics.start_clock()
        t0 = time.perf_counter()
        self._admit()
        t1 = time.perf_counter()
        self._phase("admit", t0, t1)
        active_ids = self.pool.active_slots()
        if not active_ids:
            return len(self.scheduler) > 0

        stalled: set = set()
        if self.swap is not None:
            stalled = self._prepare_slots(active_ids)
            t2 = time.perf_counter()
            self._phase("prepare_slots", t1, t2)
            if len(stalled) == len(active_ids):
                raise RuntimeError(
                    "tiered pool livelock: every active slot is stalled on "
                    "promotion — raise n_pages or SwapConfig.max_host_pages")
        step_ids = [i for i in active_ids if i not in stalled]

        B = self.engine_cfg.n_slots
        token = np.zeros((B,), np.int32)
        active = np.zeros((B,), bool)
        s_cap = np.full((B,), self.lex_cfg.s, np.int32)
        for i in step_ids:
            info = self.pool.slots[i]
            if info.in_prompt_phase:
                token[i] = int(info.request.prompt[info.fed])
            else:
                token[i] = info.pending
            active[i] = True
            s_cap[i] = info.request.tier
            if self.paged and self.swap is None:
                self._grow_pages(i)    # swap mode grew in _prepare_slots

        touched = [p for i in step_ids
                   for p in self.pool.slots[i].device_pages]

        pre_pos: Dict[int, int] = {}
        if self.quality is not None:
            # evictee write position per slot (the pre-step compressed
            # count) — captured BEFORE cache_len increments below
            pre_pos = {i: self.pool.slots[i].cache_len - self.lex_cfg.n_b
                       for i in step_ids}

        t_disp0 = time.perf_counter()
        qaux = None
        if self.quality is not None:
            logits, self.state, qaux = self._decode_fn(
                self.params, self.bank, self.state,
                jnp.asarray(token), jnp.asarray(active), jnp.asarray(s_cap))
        else:
            logits, self.state = self._decode_fn(
                self.params, self.bank, self.state,
                jnp.asarray(token), jnp.asarray(active), jnp.asarray(s_cap))
        t_disp1 = time.perf_counter()
        self._phase("decode_dispatch", t_disp0, t_disp1)
        if not self._decode_compiled:
            self._decode_compiled = True
            if self._jit_traces(self._decode_fn) >= 1:
                self.metrics.record_compile(t_disp1 - t_disp0)
        logits_np = np.asarray(logits)
        qnp = (None if qaux is None
               else {k: np.asarray(v) for k, v in qaux.items()})
        t_sync = time.perf_counter()
        self._phase("host_sync", t_disp1, t_sync)

        for i in step_ids:
            info = self.pool.slots[i]
            info.cache_len += 1          # host mirror of the device length row
            if self.tracer is not None:
                self.tracer.complete("decode", self._tid(info.request.rid),
                                     t_disp0, t_sync, slot=i)
            if info.in_prompt_phase:
                info.fed += 1
                self.metrics.record_prompt_tokens(1)
            self._consume_logits(i, logits_np[i])
        if qnp is not None:
            self._record_decode_quality(qnp, step_ids, pre_pos, s_cap)
        t_consume = time.perf_counter()
        self._phase("consume_logits", t_sync, t_consume)

        shared_now = 0
        if self.paged:
            held = Counter(p for i in self.pool.active_slots()
                           for p in self.pool.slots[i].pages)
            shared_now = sum(1 for c in held.values() if c >= 2)
        if self.swap is not None:
            self.swap.note_touch(touched, self.metrics.steps)
            self._proactive_trim()
            # handles dropped without a promote (destructive eviction of a
            # swapped prefix entry, retire of a last reference) would leak
            # their stats forever — handles are never reused
            self.swap.prune_stats()
            self._phase("trim", t_consume, time.perf_counter())
        self.metrics.sample_step(
            occupancy=self.pool.occupancy(),
            kv_bytes_in_flight=self.kv_bytes_in_flight(),
            kv_bytes_resident=self.kv_bytes_resident(),
            pages_in_use=self.allocator.n_used if self.paged else 0,
            shared_pages=shared_now,
            host_bytes_resident=self.host_bytes_resident())
        return bool(self.pool.active_slots()) or len(self.scheduler) > 0

    def run(self, max_steps: int = 100_000) -> Dict[int, SlotInfo]:
        """Drive until the queue drains and all slots retire."""
        for _ in range(max_steps):
            if not self.step():
                break
        return self.completed
