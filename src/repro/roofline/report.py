"""Render EXPERIMENTS.md tables from results/dryrun.json.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(results: dict, *, mesh: str = "singlepod",
                   variant: str = "baseline") -> str:
    rows = []
    for key, r in sorted(results.items()):
        if "error" in r:
            continue
        arch, shape, m, v = key.split("|")
        if m != mesh or v != variant:
            continue
        dom = r["bottleneck"]
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = (min(r["compute_s"] / total, 1.0) if total else 0.0)
        rows.append(
            f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{dom}** | {r['useful_ratio']:.2f} | {frac:.2f} |")
    hdr = ("| arch | shape | compute | memory | collective | bottleneck | "
           "MODEL/HLO flops | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def dryrun_table(results: dict, *, variant: str = "baseline") -> str:
    rows = []
    for key, r in sorted(results.items()):
        if "error" in r:
            rows.append(f"| {key} | FAILED | | | |")
            continue
        arch, shape, m, v = key.split("|")
        if v != variant:
            continue
        mem = r.get("mem", {})
        per_dev = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
                   + mem.get("output_bytes", 0) - mem.get("alias_bytes", 0))
        coll = r.get("collective_bytes", {})
        coll_str = ", ".join(f"{k.split('-')[-1][:3]} {fmt_b(val)}"
                             for k, val in coll.items()
                             if k != "count" and val) or "none"
        rows.append(f"| {arch} | {shape} | {m} | {fmt_b(per_dev)} | "
                    f"{coll_str} | {r['compile_s']:.0f}s |")
    hdr = ("| arch | shape | mesh | bytes/device (args+temp+out) | "
           "collective schedule (bytes/step) | compile |\n"
           "|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    results = json.load(open(path))
    ok = [k for k, v in results.items() if "error" not in v]
    bad = [k for k, v in results.items() if "error" in v]
    print(f"## {len(ok)} cells compiled, {len(bad)} failed\n")
    if bad:
        for k in bad:
            print(f"FAILED: {k}")
    print("\n### Roofline (single-pod 16x16, baseline)\n")
    print(roofline_table(results))
    print("\n### Dry-run memory/collectives\n")
    print(dryrun_table(results))


if __name__ == "__main__":
    main()
