"""Analytic HBM-byte / FLOP model of the paged compressed-attention read.

``analyze_compiled`` prices whatever XLA compiled — but off-TPU the fused
kernel lowers through Pallas interpret mode, whose HLO is a simulation
artifact, not the TPU memory traffic. This module prices the *algorithm*
instead, from first principles, for the two ways the engine can read the
compressed half of the cache each decode step:

  gather path (``paged_attend`` default)
      ``gather_pages`` streams the four sparse stores out of the pool,
      writes a per-row contiguous copy, and attention re-reads that copy —
      the resident codes cross HBM three times — then materialises the
      (B, KV, G, T) logits and probabilities in f32 (written + re-read by
      the softmax/value stages).

  fused path (``kernels/paged_sparse_attn.py``)
      the kernel walks the page tables in-place: the codes cross HBM once,
      and the only other traffic is the broadcast ``qd`` read plus the
      (m, l, c) carry written once per (row, head). No gathered copy, no
      logits array.

Both paths do the same arithmetic (scores + scatter + the two N·m
dictionary matmuls), so FLOPs are shared and the fused win is purely a
bytes win — ``compare_paged_attention`` reports it per decode step along
with V5E roofline times. The strict inequality ``fused.total_bytes <
gather.total_bytes`` for any non-empty cache is pinned by
``tests/test_paged_sparse_attn.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.roofline.analysis import HW, V5E


@dataclasses.dataclass(frozen=True)
class PagedAttnShape:
    """Static shape of one layer's paged compressed-attention read."""
    batch: int              # B decode rows (slots)
    kv_heads: int           # KV
    q_per_kv: int           # G (GQA group size)
    head_dim: int           # m
    n_dict: int             # N dictionary atoms
    s: int                  # sparsity (nonzeros per cached vector)
    pages_per_row: int      # page-table width (max_pages)
    page_size: int          # tokens per page
    val_bytes: int = 1      # coefficient storage (fp8 codec)
    idx_bytes: int = 2      # index storage (int16)
    acc_bytes: int = 4      # f32 accumulation / activations

    @property
    def tokens(self) -> int:
        """Compressed positions swept per row (table width x page size)."""
        return self.pages_per_row * self.page_size

    @property
    def code_bytes(self) -> int:
        """Resident sparse-code bytes swept per decode step: four stores
        (k/v values + indices), s entries per token per KV head."""
        per_tok = 2 * self.s * (self.val_bytes + self.idx_bytes)
        return self.batch * self.kv_heads * self.tokens * per_tok

    @property
    def qd_bytes(self) -> int:
        """Dictionary-projected queries (B, KV, G, N) f32, read once."""
        return (self.batch * self.kv_heads * self.q_per_kv
                * self.n_dict * self.acc_bytes)

    @property
    def coeff_bytes(self) -> int:
        """The f32 coefficient accumulator (B, KV, G, N) — BOTH paths
        materialise it (``compressed_values``'s scatter output / the
        kernel's ``c`` carry) and the D_v decode re-reads it."""
        return (self.batch * self.kv_heads * self.q_per_kv
                * self.n_dict * self.acc_bytes)

    @property
    def flops(self) -> int:
        """Shared arithmetic of both paths: s-sparse score dots + the
        probability scatter (2·s MAC each per token per query head) plus
        the q·D_k projection and c·D_vᵀ decode (N·m matmuls per query)."""
        bq = self.batch * self.kv_heads * self.q_per_kv
        sparse = 2 * bq * self.tokens * (2 * self.s)
        dense = 2 * bq * self.n_dict * self.head_dim * 2
        return sparse + dense


def gather_path_bytes(shape: PagedAttnShape) -> Dict[str, int]:
    """Per-decode-step HBM bytes of gather-then-mask (one layer)."""
    codes = shape.code_bytes
    bqt = (shape.batch * shape.kv_heads * shape.q_per_kv
           * shape.tokens * shape.acc_bytes)
    out = {
        "pool_read": codes,          # gather_pages streams the pool
        "gather_write": codes,       # ...into the per-row contiguous copy
        "gather_reread": codes,      # ...which attention then reads
        "qd_read": shape.qd_bytes,
        "logits": 2 * 2 * bqt,       # s_c and p, each written + re-read f32
        "coeff": 2 * shape.coeff_bytes,   # scatter write + D_v decode read
    }
    out["total_bytes"] = sum(out.values())
    return out


def fused_path_bytes(shape: PagedAttnShape) -> Dict[str, int]:
    """Per-decode-step HBM bytes of the fused page-table-walking kernel."""
    ml = (shape.batch * shape.kv_heads * shape.q_per_kv
          * 2 * shape.acc_bytes)
    out = {
        "pool_read": shape.code_bytes,       # codes cross HBM exactly once
        "qd_read": shape.qd_bytes,
        # (m, l, c) written once per row/head; c re-read by the D_v decode
        "carry": 2 * shape.coeff_bytes + ml,
    }
    out["total_bytes"] = sum(out.values())
    return out


def compare_paged_attention(shape: PagedAttnShape,
                            hw: HW = V5E) -> Dict[str, object]:
    """Gather vs fused decode-step cost, with roofline times on ``hw``.

    ``bytes_ratio`` < 1 is the fused win; FLOPs are identical by
    construction so the time ratio is bounded by the bytes ratio.
    """
    g, f = gather_path_bytes(shape), fused_path_bytes(shape)
    flops = shape.flops

    def terms(b):
        return {"t_mem_s": b["total_bytes"] / hw.hbm_bw,
                "t_compute_s": flops / hw.peak_flops,
                "t_roofline_s": max(b["total_bytes"] / hw.hbm_bw,
                                    flops / hw.peak_flops)}

    return {
        "shape": dataclasses.asdict(shape),
        "flops": flops,
        "hw": hw.name,
        "gather": {**g, **terms(g)},
        "fused": {**f, **terms(f)},
        "bytes_ratio": f["total_bytes"] / g["total_bytes"],
        "bytes_saved": g["total_bytes"] - f["total_bytes"],
    }
