"""Analytic HBM-byte / FLOP model of the paged compressed-attention read.

``analyze_compiled`` prices whatever XLA compiled — but off-TPU the fused
kernel lowers through Pallas interpret mode, whose HLO is a simulation
artifact, not the TPU memory traffic. This module prices the *algorithm*
instead, from first principles, for the two ways the engine can read the
compressed half of the cache each decode step:

  gather path (``paged_attend`` default)
      ``gather_pages`` streams the four sparse stores out of the pool,
      writes a per-row contiguous copy, and attention re-reads that copy —
      the resident codes cross HBM three times — then materialises the
      (B, KV, G, T) logits and probabilities in f32 (written + re-read by
      the softmax/value stages).

  fused path (``kernels/paged_sparse_attn.py``)
      the kernel walks the page tables in-place: the codes cross HBM once,
      and the only other traffic is the broadcast ``qd`` read plus the
      (m, l, c) carry written once per (row, head). No gathered copy, no
      logits array.

Both paths do the same arithmetic (scores + scatter + the two N·m
dictionary matmuls), so FLOPs are shared and the fused win is purely a
bytes win — ``compare_paged_attention`` reports it per decode step along
with V5E roofline times. The strict inequality ``fused.total_bytes <
gather.total_bytes`` for any non-empty cache is pinned by
``tests/test_paged_sparse_attn.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.roofline.analysis import HW, V5E


@dataclasses.dataclass(frozen=True)
class PagedAttnShape:
    """Static shape of one layer's paged compressed-attention read."""
    batch: int              # B decode rows (slots)
    kv_heads: int           # KV
    q_per_kv: int           # G (GQA group size)
    head_dim: int           # m
    n_dict: int             # N dictionary atoms
    s: int                  # sparsity (nonzeros per cached vector)
    pages_per_row: int      # page-table width (max_pages)
    page_size: int          # tokens per page
    val_bytes: int = 1      # coefficient storage (fp8 codec)
    idx_bytes: int = 2      # index storage (int16)
    acc_bytes: int = 4      # f32 accumulation / activations

    @property
    def tokens(self) -> int:
        """Compressed positions swept per row (table width x page size)."""
        return self.pages_per_row * self.page_size

    @property
    def code_bytes(self) -> int:
        """Resident sparse-code bytes swept per decode step: four stores
        (k/v values + indices), s entries per token per KV head."""
        per_tok = 2 * self.s * (self.val_bytes + self.idx_bytes)
        return self.batch * self.kv_heads * self.tokens * per_tok

    @property
    def qd_bytes(self) -> int:
        """Dictionary-projected queries (B, KV, G, N) f32, read once."""
        return (self.batch * self.kv_heads * self.q_per_kv
                * self.n_dict * self.acc_bytes)

    @property
    def coeff_bytes(self) -> int:
        """The f32 coefficient accumulator (B, KV, G, N) — BOTH paths
        materialise it (``compressed_values``'s scatter output / the
        kernel's ``c`` carry) and the D_v decode re-reads it."""
        return (self.batch * self.kv_heads * self.q_per_kv
                * self.n_dict * self.acc_bytes)

    @property
    def flops(self) -> int:
        """Shared arithmetic of both paths: s-sparse score dots + the
        probability scatter (2·s MAC each per token per query head) plus
        the q·D_k projection and c·D_vᵀ decode (N·m matmuls per query)."""
        bq = self.batch * self.kv_heads * self.q_per_kv
        sparse = 2 * bq * self.tokens * (2 * self.s)
        dense = 2 * bq * self.n_dict * self.head_dim * 2
        return sparse + dense


def gather_path_bytes(shape: PagedAttnShape) -> Dict[str, int]:
    """Per-decode-step HBM bytes of gather-then-mask (one layer)."""
    codes = shape.code_bytes
    bqt = (shape.batch * shape.kv_heads * shape.q_per_kv
           * shape.tokens * shape.acc_bytes)
    out = {
        "pool_read": codes,          # gather_pages streams the pool
        "gather_write": codes,       # ...into the per-row contiguous copy
        "gather_reread": codes,      # ...which attention then reads
        "qd_read": shape.qd_bytes,
        "logits": 2 * 2 * bqt,       # s_c and p, each written + re-read f32
        "coeff": 2 * shape.coeff_bytes,   # scatter write + D_v decode read
    }
    out["total_bytes"] = sum(out.values())
    return out


def fused_path_bytes(shape: PagedAttnShape) -> Dict[str, int]:
    """Per-decode-step HBM bytes of the fused page-table-walking kernel."""
    ml = (shape.batch * shape.kv_heads * shape.q_per_kv
          * 2 * shape.acc_bytes)
    out = {
        "pool_read": shape.code_bytes,       # codes cross HBM exactly once
        "qd_read": shape.qd_bytes,
        # (m, l, c) written once per row/head; c re-read by the D_v decode
        "carry": 2 * shape.coeff_bytes + ml,
    }
    out["total_bytes"] = sum(out.values())
    return out


def compare_paged_attention(shape: PagedAttnShape,
                            hw: HW = V5E) -> Dict[str, object]:
    """Gather vs fused decode-step cost, with roofline times on ``hw``.

    ``bytes_ratio`` < 1 is the fused win; FLOPs are identical by
    construction so the time ratio is bounded by the bytes ratio.
    """
    g, f = gather_path_bytes(shape), fused_path_bytes(shape)
    flops = shape.flops

    def terms(b):
        return {"t_mem_s": b["total_bytes"] / hw.hbm_bw,
                "t_compute_s": flops / hw.peak_flops,
                "t_roofline_s": max(b["total_bytes"] / hw.hbm_bw,
                                    flops / hw.peak_flops)}

    return {
        "shape": dataclasses.asdict(shape),
        "flops": flops,
        "hw": hw.name,
        "gather": {**g, **terms(g)},
        "fused": {**f, **terms(f)},
        "bytes_ratio": f["total_bytes"] / g["total_bytes"],
        "bytes_saved": g["total_bytes"] - f["total_bytes"],
    }


# ---------------------------------------------------------------------------
# OMP prefill encoder (the compress write path — PR 8's twin of the above)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OMPEncodeShape:
    """Static shape of one Gram-path OMP selection iteration.

    One prefill encodes ``batch = B·KV·T_head`` vectors against ``n_dict``
    atoms; each iteration subtracts ``s`` (padded) selected-atom Gram rows
    from ``alpha0`` and argmaxes over atoms. Both paths below stream all
    ``s`` padded slots every iteration (trailing y's are zero), so
    per-iteration bytes are iteration-independent and the early-exit win
    multiplies on top.
    """
    batch: int              # vectors encoded together (B·KV·T_head)
    head_dim: int           # m
    n_dict: int             # N dictionary atoms
    s: int                  # s_max padded selection slots
    acc_bytes: int = 4      # f32 accumulation
    sel_bytes: int = 1      # bool selected mask

    @property
    def flops(self) -> int:
        """Shared per-iteration arithmetic: the Gram-row MACs of the
        correlation (2·B·N·s) plus the pair of batched triangular solves
        (O(B·s²) — noise next to the correlation at N >> s)."""
        return (2 * self.batch * self.n_dict * self.s
                + 2 * self.batch * self.s * self.s)


def omp_gathered_bytes(shape: OMPEncodeShape) -> Dict[str, int]:
    """Per-iteration HBM bytes of the gathered-Gram oracle correlation.

    The reference path (``ref.omp_gram_corr_ref`` / the vmapped
    ``core.omp`` encoder) gathers the selected rows ``G[idx]`` into a
    (B, s, N) f32 copy (pool read + copy write + matvec re-read), then
    materialises the (B, N) correlation matrix, which the masked argmax
    re-reads."""
    bn = shape.batch * shape.n_dict * shape.acc_bytes
    out = {
        "gram_rows_read": shape.s * bn,     # G[idx] streamed out of G
        "gather_write": shape.s * bn,       # ...into the (B, s, N) copy
        "gather_reread": shape.s * bn,      # ...re-read by the y·rows matvec
        "alpha0_read": bn,
        "corr_matrix": 2 * bn,              # c written f32 + argmax re-read
        "sel_read": shape.batch * shape.n_dict * shape.sel_bytes,
    }
    out["total_bytes"] = sum(out.values())
    return out


def omp_streamed_bytes(shape: OMPEncodeShape) -> Dict[str, int]:
    """Per-iteration HBM bytes of the streamed-tile kernel
    (``kernels/omp_corr.omp_gram_argmax``): Gram rows cross HBM once,
    the running correlation lives in VMEM scratch, and only the (B,)
    max/argmax carry leaves the kernel."""
    bn = shape.batch * shape.n_dict * shape.acc_bytes
    small = shape.batch * (2 * shape.s + 2) * shape.acc_bytes  # idx,y + out
    out = {
        "gram_rows_read": shape.s * bn,     # read once, never copied
        "alpha0_read": bn,
        "sel_read": shape.batch * shape.n_dict * shape.sel_bytes,
        "carry": small,
    }
    out["total_bytes"] = sum(out.values())
    return out


def compare_omp_encode(shape: OMPEncodeShape, hw: HW = V5E,
                       iters: int | None = None) -> Dict[str, object]:
    """Gathered-Gram vs streamed-tile selection cost per OMP iteration.

    ``bytes_ratio`` < 1 is the fused win (≈ (s+1)/(3s+3) at f32 — the
    three Gram-row crossings collapse to one and the (B, N) correlation
    matrix disappears); the strict inequality is pinned in
    tests/test_omp_encode.py. ``iters`` (default ``shape.s``) scales the
    per-iteration terms to a whole encode — early exit lowers it to the
    mean ``nnz``, multiplying on top of the per-iteration win.
    """
    g, f = omp_gathered_bytes(shape), omp_streamed_bytes(shape)
    flops = shape.flops
    n_it = shape.s if iters is None else iters

    def terms(b):
        return {"t_mem_s": b["total_bytes"] / hw.hbm_bw,
                "t_compute_s": flops / hw.peak_flops,
                "t_roofline_s": max(b["total_bytes"] / hw.hbm_bw,
                                    flops / hw.peak_flops),
                "encode_total_bytes": n_it * b["total_bytes"]}

    return {
        "shape": dataclasses.asdict(shape),
        "flops_per_iter": flops,
        "iters": n_it,
        "hw": hw.name,
        "gathered": {**g, **terms(g)},
        "streamed": {**f, **terms(f)},
        "bytes_ratio": f["total_bytes"] / g["total_bytes"],
        "bytes_saved_per_iter": g["total_bytes"] - f["total_bytes"],
    }
