from repro.roofline.analysis import (
    V5E, RooflineReport, analyze_compiled, collective_bytes_from_hlo,
)
