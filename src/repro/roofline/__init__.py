from repro.roofline.analysis import (
    V5E, RooflineReport, analyze_compiled, collective_bytes_from_hlo,
)
from repro.roofline.kernel_model import (
    PagedAttnShape, compare_paged_attention, fused_path_bytes,
    gather_path_bytes,
)
