"""Roofline terms from a compiled (AOT) module — no hardware required.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed of the
*partitioned per-device* module (XLA SPMD reports the per-participant
program), so the per-chip terms divide by peak per chip directly.
Collective bytes are not in cost_analysis — we parse the post-partitioning
HLO text and sum the result-shape bytes of every collective op, per class.

Hardware model (TPU v5e, per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per direction; we charge each collective's full payload
against one link, a conservative single-link model).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float      # per chip, /s
    hbm_bw: float          # per chip, B/s
    link_bw: float         # per link, B/s


V5E = HW(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=50e9)


def _shape_bytes(sig: str) -> int:
    """Bytes of 'bf16[8,128]' / tuple '(f32[2], s32[4])' signatures."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective class from compiled HLO text."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        # result-typed op lines look like: '%name = bf16[..] all-reduce(...)'
        m = re.search(r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+([a-z\-]+)", ls)
        if not m:
            continue
        op = m.group(2)
        # normalise fusion/start variants: all-reduce-start, all-gather-start...
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                out[c] += _shape_bytes(m.group(1))
                out["count"] += 1
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: Dict[str, int]
    peak_memory_per_device: Optional[int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float            # MODEL_FLOPS / (HLO_FLOPs * chips)
    bottleneck: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                     chips: int, model_flops: float, hw: HW = V5E,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    # NOTE: compiled.cost_analysis() counts while-loop bodies ONCE — useless
    # for scan-over-layers models. We use our own HLO cost model with loop
    # multipliers (repro.roofline.hlo_cost, validated in tests).
    from repro.roofline import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    hc = hlo_cost.analyze(text)
    flops = float(hc["flops"])
    byts = float(hc["bytes"])
    coll = {k: int(v) for k, v in hc["collectives"].items()}
    coll["count"] = -1
    coll_total = float(hc["collective_bytes"])

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = int(getattr(ma, "temp_size_in_bytes", 0)
                  + getattr(ma, "argument_size_in_bytes", 0)
                  + getattr(ma, "output_size_in_bytes", 0)
                  - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass

    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    coll_s = coll_total / hw.link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes=coll, peak_memory_per_device=mem,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        model_flops=model_flops, useful_ratio=useful, bottleneck=bottleneck)


def achieved_vs_predicted(report: RooflineReport,
                          achieved_s: float) -> Dict[str, float]:
    """Compare a *measured* wall time for one invocation of the analyzed
    module against its roofline prediction.

    ``achieved_s`` is the observed seconds per call (e.g. the serving
    engine's ``decode_dispatch`` + ``host_sync`` phase p50);
    ``predicted_s`` is the roofline bound — the max of the compute, memory
    and collective terms, i.e. the fastest the module could run on the
    report's hardware model. ``roofline_fraction`` = predicted/achieved is
    the fraction of the roofline actually reached (1.0 = at the roof; tiny
    on hardware slower than the model, e.g. CPU CI runs scored against the
    TPU model).
    """
    achieved_s = max(achieved_s, 1e-12)
    predicted_s = max(report.compute_s, report.memory_s,
                      report.collective_s, 1e-12)
    return {
        "achieved_s": achieved_s,
        "predicted_s": predicted_s,
        "roofline_fraction": predicted_s / achieved_s,
        "predicted_flops": report.flops_per_device,
        "predicted_bytes": report.bytes_per_device,
        "achieved_flops_per_s": report.flops_per_device / achieved_s,
        "achieved_bytes_per_s": report.bytes_per_device / achieved_s,
        "bottleneck": report.bottleneck,
    }


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int,
                    steps: int = 1) -> float:
    """MODEL_FLOPS: 6·N·D training, 2·N_active·D inference (per step)."""
    n_active = cfg.active_param_count()
    tokens = seq_len * global_batch
    if shape_kind == "train":
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * global_batch * steps   # decode: one token/seq
