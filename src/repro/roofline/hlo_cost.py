"""HLO-text cost model with correct loop accounting.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
under-reports scan-over-layers models by ~L× (verified in
tests/test_roofline.py). This module re-derives the three roofline inputs
from the compiled HLO text with call-graph multipliers:

  * flops            — every ``dot`` op: 2 x prod(result dims) x contracted
                       dims (operand shapes resolved through a per-computation
                       symbol table), x loop multiplier.
  * bytes accessed   — per top-level op of each *non-fusion* computation
                       (fusion internals don't touch HBM): operand + result
                       bytes, x loop multiplier — XLA's own per-op byte model
                       with loop trips applied.
  * collective bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       x loop multiplier.

Loop trip counts come from the ``backend_config known_trip_count`` that XLA
attaches to rolled loops (fallback: the integer constant in the loop cond).
Multipliers propagate topologically over the call graph; bytes use a second
multiplier that is zeroed through fusion edges (fusion internals are
register/VMEM traffic, not HBM).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_NAME_EQ = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OP_KW = re.compile(r"([a-z][a-z0-9\-]*)\(")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*"?n"?[^0-9]*(\d+)')

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that move no HBM bytes themselves (bodies/consumers account for them)
_NO_BYTES = ("parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota", "while", "conditional", "call", "custom-call",
             "optimization-barrier", "partition-id", "replica-id")
# ops whose traffic is result-sized (they read only a slice of the operand)
_SLICE_OPS = ("dynamic-slice", "gather", "slice", "reshape", "broadcast",
              "transpose", "concatenate", "pad", "reverse", "copy")
_DUS_OPS = ("dynamic-update-slice", "scatter", "select-and-scatter")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(sig: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(sig)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",") if d) if dims else ()


def _split_op(line: str) -> Optional[Tuple[str, str, str, str]]:
    """'%name = SIG opkw(rest...' -> (name, sig, op, rest)."""
    m = _NAME_EQ.match(line)
    if not m:
        return None
    rest0 = line[m.end():]
    m2 = _OP_KW.search(rest0)
    if not m2:
        return None
    return m.group(1), rest0[: m2.start()].strip(), m2.group(1), rest0[m2.end():]


class Computation:
    __slots__ = ("name", "flops", "bytes", "collective", "edges", "const_ints",
                 "root_op")

    def __init__(self, name: str):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.collective: Dict[str, float] = defaultdict(float)
        # (callee, flop_weight, byte_weight) — trip counts already folded in
        self.edges: List[Tuple[str, float, float]] = []
        self.const_ints: List[int] = []
        self.root_op: str = ""


def _operands_sig(rest: str, table: Dict[str, str]) -> str:
    depth = 1
    end = len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    names = _OPERAND.findall(rest[:end])
    return " ".join(table.get(n, "") for n in names)


def parse_hlo(text: str):
    comps: Dict[str, Computation] = {}
    tables: Dict[str, Dict[str, str]] = {}
    pending: List[Tuple[Computation, str, str, str, str]] = []
    cur: Optional[Computation] = None
    table: Dict[str, str] = {}
    entry: Optional[str] = None

    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and not _NAME_EQ.match(stripped):
            name_part = stripped.split("(")[0].strip()
            is_entry = name_part.startswith("ENTRY")
            name = name_part.replace("ENTRY", "").strip().lstrip("%")
            if name:
                cur = Computation(name)
                comps[name] = cur
                table = {}
                tables[name] = table
                if is_entry:
                    entry = name
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        parts = _split_op(line)
        if parts is None:
            continue
        name, sig, op, rest = parts
        table[name] = sig
        if stripped.startswith("ROOT"):
            cur.root_op = op
        for c in _CONST_INT.findall(line):
            cur.const_ints.append(int(c))
        pending.append((cur, line, sig, op, rest))

    # second pass: costs + edges (symbol tables complete)
    for comp, line, sig, op, rest in pending:
        table = tables[comp.name]
        if op == "dot":
            res = _first_dims(sig)
            shapes = _SHAPE_RE.findall(_operands_sig(rest, table))
            contracted = 1
            if shapes:
                lhs_dims = ([int(d) for d in shapes[0][1].split(",") if d]
                            if shapes[0][1] else [])
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                if mm and mm.group(1):
                    for i in mm.group(1).split(","):
                        idx = int(i)
                        if idx < len(lhs_dims):
                            contracted *= lhs_dims[idx]
            comp.flops += 2.0 * math.prod(res or (0,)) * contracted
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                comp.collective[c] += _shape_bytes(sig)

        # --- byte accounting (op-aware) ---
        eff_op = op
        if op == "fusion":
            mcall = re.search(r"calls=%?([\w\.\-]+)", line)
            callee = comps.get(mcall.group(1)) if mcall else None
            if callee is not None and callee.root_op:
                eff_op = callee.root_op
        if op in _NO_BYTES:
            pass
        elif eff_op in _DUS_OPS:
            # in-place update: read+write of the update payload only (the big
            # aliased buffer is untouched except the slice)
            op_sig = _operands_sig(rest, table)
            sizes = sorted((_shape_bytes(s[0] + "[" + s[1] + "]")
                            for s in _SHAPE_RE.findall(op_sig)), reverse=True)
            comp.bytes += 2.0 * sum(sizes[1:]) if len(sizes) > 1 else _shape_bytes(sig)
        elif eff_op in _SLICE_OPS:
            comp.bytes += 2.0 * _shape_bytes(sig)
        else:
            comp.bytes += _shape_bytes(sig) + _shape_bytes(_operands_sig(rest, table))

        if op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            mt = _TRIP.search(line)
            if mb and mc:
                body, cond = mb.group(1), mc.group(1)
                if mt:
                    trip = int(mt.group(1))
                else:
                    cints = comps[cond].const_ints if cond in comps else []
                    trip = max([c for c in cints if c > 0], default=1)
                comp.edges.append((body, float(trip), float(trip)))
                comp.edges.append((cond, float(trip + 1), 0.0))
        elif op == "fusion":
            mcall = re.search(r"calls=%?([\w\.\-]+)", line)
            if mcall:
                comp.edges.append((mcall.group(1), 1.0, 0.0))
        else:
            for attr in ("to_apply", "calls", "computation"):
                mm = re.search(attr + r"=\{?%?([\w\.\-]+)", line)
                if mm:
                    comp.edges.append((mm.group(1), 1.0, 1.0))
            mm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if mm:
                for br in _OPERAND.findall(mm.group(1)):
                    comp.edges.append((br, 1.0, 1.0))
    return comps, entry


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_hlo(text)
    if entry is None or entry not in comps:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "collective_bytes": 0.0}

    # topological propagation (Kahn) over the call DAG
    indeg: Dict[str, int] = defaultdict(int)
    for c in comps.values():
        for callee, _, _ in c.edges:
            indeg[callee] += 1
    m_flops: Dict[str, float] = defaultdict(float)
    m_bytes: Dict[str, float] = defaultdict(float)
    m_flops[entry] = 1.0
    m_bytes[entry] = 1.0
    q = deque([n for n in comps if indeg[n] == 0])
    processed = set()
    while q:
        n = q.popleft()
        processed.add(n)
        c = comps.get(n)
        if c is None:
            continue
        for callee, wf, wb in c.edges:
            m_flops[callee] += m_flops[n] * wf
            m_bytes[callee] += m_bytes[n] * wb
            indeg[callee] -= 1
            if indeg[callee] == 0:
                q.append(callee)

    flops = 0.0
    byts = 0.0
    coll: Dict[str, float] = defaultdict(float)
    for name, c in comps.items():
        flops += m_flops.get(name, 0.0) * c.flops
        byts += m_bytes.get(name, 0.0) * c.bytes
        for k, v in c.collective.items():
            coll[k] += m_flops.get(name, 0.0) * v
    return {"flops": flops, "bytes": byts, "collectives": dict(coll),
            "collective_bytes": float(sum(coll.values()))}
