"""Continuous-batching serving demo: heterogeneous requests, one engine.

    PYTHONPATH=src python examples/serve_continuous.py [--n-requests 10]

Eight-plus requests with different prompt lengths, generation lengths and
Lexico sparsity tiers stream through one fixed pool of cache slots. The
engine interleaves prefill and decode — prompts longer than their prefill
bucket finish streaming through the pooled decode step while other requests
are already generating — and the FCFS scheduler packs admissions against a
global KV-byte budget using the paper's 3s+2 bytes/vector accounting.

Everything runs through three compiled functions (one prefill per
power-of-two bucket, one pooled decode, one slot splice): watch the compile
counts stay flat as requests join and leave.

With ``--share-prefixes`` (paged layout) half the requests start from one
shared system prompt: their page-aligned prefix pages are deduplicated in
the pool via copy-on-write prefix sharing, and the dedup metrics (hit rate,
pages aliased, prefill OMP skipped, bytes saved) are printed at the end.

With ``--swap`` (implies paged) the device page pool is deliberately sized
below the workload's concurrent working set and a host-memory tier absorbs
the overflow: cold pages demote to a pinned numpy mirror, promote back
(bitwise) on access, slots briefly stall instead of being refused, and the
tier metrics (pages demoted/promoted, host bytes peak, promote stalls) are
printed at the end.

With ``--replicas N`` (implies paged + prefix sharing) the same workload
streams through a ``ReplicaRouter`` fronting N engine replicas that share
ONE dictionary bank; ``--route {rr,load,affinity}`` picks the routing
policy. Prefix-affinity routing scores each request's expected
prefix-page hits (from the cross-replica ``GlobalPrefixView``) against
load skew, so requests sharing the system prompt herd onto the replica
whose cache is already warm — the per-replica occupancy and hit-rate
table at the end makes the difference visible against ``--route rr``.

With ``--trace out.json`` the run records a request-lifecycle span tree
(queued/prefill/per-step decode per request, demote/promote/stall instants)
and writes Chrome/Perfetto trace JSON — open it at https://ui.perfetto.dev.
``--metrics-snapshot out.prom`` writes the labeled metrics registry as
Prometheus text, and ``--journal out.jsonl`` the page-lifecycle event
journal (replayable with ``repro.serving.obs.replay_check``).

With ``--quality`` the engine records live compression-quality telemetry
(per-encode relative residual and nnz, streamed into exact mergeable
sketches) and, after the drain, prints the per-layer residual/nnz table
plus a dictionary-drift score: the decode-phase residual distribution is
scored against the run's own prefill residuals as a calibration baseline
(total-variation distance — near 0 when the universal dictionary covers
decode-time keys/values as well as it covered the prompts). Works with
``--replicas`` too, where the table is the exact fleet merge.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..'))

import jax
import numpy as np

from benchmarks.common import BENCH_CFG, trained_params
from benchmarks.memory_fidelity import trained_bank
from repro.configs.base import LexicoConfig
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, ObsConfig, ReplicaRouter,
    Request, SwapConfig,
)
from repro.serving.obs import DriftMonitor, layer_table_from_block, replay_check


def print_quality(recorders, rows, block):
    """Per-layer residual/nnz table plus an in-run drift score.

    The drift baseline is the run's own prefill residual distribution —
    decode-time encodes drifting away from it is exactly the signal a
    stale calibration set would show in production.
    """
    print("\ncompression quality (live telemetry):")
    print("  layer   k_rel mean/p99    v_rel mean/p99    k_nnz   v_nnz")
    for row in rows:
        print(f"  {row['layer']:5d}   "
              f"{row['k_rel_mean']:.4f}/{row['k_rel_p99']:.4f}    "
              f"{row['v_rel_mean']:.4f}/{row['v_rel_p99']:.4f}    "
              f"{row['k_nnz_mean']:5.2f}   {row['v_nnz_mean']:5.2f}")
    print(f"  {block['encodes']} encodes, delta attained on "
          f"{block['delta_attained_rate']:.0%} "
          f"(tiers: {', '.join('s' + t for t in sorted(block['tiers'], key=int))})")
    base = recorders[0].rel_hist(phase="prefill")
    live = recorders[0].rel_hist(phase="decode")
    for rec in recorders[1:]:
        base = base.merge(rec.rel_hist(phase="prefill"))
        live = live.merge(rec.rel_hist(phase="decode"))
    if base.count and live.count:
        score = DriftMonitor(base).score(live)
        print(f"  drift score (decode residuals vs prefill calibration "
              f"baseline, TV distance): {score:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=10)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--t-max", type=int, default=96)
    ap.add_argument("--budget-kb", type=int, default=None,
                    help="global KV byte budget (KiB); default: unlimited")
    ap.add_argument("--layout", choices=["contiguous", "paged"],
                    default="contiguous",
                    help="slot storage: padded per-slot stripes or a shared "
                         "page pool with per-slot page tables")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per pool page (paged layout)")
    ap.add_argument("--share-prefixes", action="store_true",
                    help="copy-on-write prefix sharing over the page pool "
                         "(implies --layout paged); half the demo requests "
                         "share a system-prompt prefix so pages dedup")
    ap.add_argument("--swap", action="store_true",
                    help="tiered storage (implies --layout paged): size the "
                         "device pool below the concurrent working set and "
                         "spill cold pages to a host-memory tier, promoting "
                         "them back on access — same tokens, smaller pool")
    ap.add_argument("--replicas", type=int, default=1,
                    help="front N engine replicas with a ReplicaRouter "
                         "(implies --share-prefixes); ONE dictionary bank "
                         "is shared by reference, everything stateful is "
                         "per-replica")
    ap.add_argument("--route", choices=["rr", "load", "affinity"],
                    default="affinity",
                    help="routing policy for --replicas: round-robin, "
                         "least-loaded, or prefix-affinity (expected "
                         "prefix-page hits vs load skew)")
    ap.add_argument("--fused-omp", action="store_true",
                    help="prefill through the fused batched-OMP encoder "
                         "(tile-batched early-exit iteration, Pallas "
                         "selection on TPU); a baseline engine runs the "
                         "identical requests first and the prefill-phase "
                         "p50/p99 is printed before/after — same tokens")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record a request-lifecycle trace and write it as "
                         "Chrome/Perfetto trace-event JSON (load at "
                         "ui.perfetto.dev)")
    ap.add_argument("--metrics-snapshot", metavar="PATH", default=None,
                    help="write the metrics registry as Prometheus text at "
                         "the end of the run")
    ap.add_argument("--journal", metavar="PATH", default=None,
                    help="record the page-lifecycle event journal and write "
                         "it as JSONL (post-hoc invariant replay)")
    ap.add_argument("--quality", action="store_true",
                    help="record live compression-quality telemetry and "
                         "print the per-layer residual/nnz table plus a "
                         "dictionary-drift score (decode residuals vs the "
                         "run's prefill calibration baseline) after drain")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.replicas > 1:
        args.share_prefixes = True
    if args.share_prefixes or args.swap:
        args.layout = "paged"

    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")

    # --swap: an oversubscribed pool — one long request's working set plus
    # one page per slot; the host tier absorbs the rest of the concurrency
    n_pages = None
    max_pages = -(-max(args.t_max - lex.n_b, 1) // args.page_size)
    if args.swap:
        n_pages = max_pages + args.n_slots + 1
    engine_cfg = EngineConfig(
        n_slots=args.n_slots, t_max=args.t_max, min_bucket=8,
        layout=args.layout, page_size=args.page_size,
        share_prefixes=args.share_prefixes,
        n_pages=n_pages,
        swap=SwapConfig() if args.swap else None,
        fused_omp=args.fused_omp,
        obs=(ObsConfig(trace=args.trace is not None,
                       journal=args.journal is not None,
                       quality=args.quality)
             if (args.trace or args.journal or args.quality) else None),
        kv_byte_budget=(args.budget_kb * 1024
                        if args.budget_kb else None))
    eng = None
    if args.replicas == 1:
        eng = ContinuousBatchingEngine(params, cfg, lex, bank, engine_cfg)
    if args.swap and eng is not None:
        print(f"swap tier on: device pool {eng.allocator.capacity} usable "
              f"pages vs {args.n_slots * max_pages} fully provisioned — "
              "oversubscribed on purpose")

    rng = np.random.default_rng(args.seed)
    tiers = [2, 4, 8, 16]
    # a common "system prompt": with --share-prefixes, every even request
    # starts with it, so their page-aligned prefixes dedup in the pool
    system_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    print(f"{args.n_requests} requests -> {args.n_slots} slots "
          f"(s_max={s_max}, tiers {tiers})")
    workload = []
    for rid in range(args.n_requests):
        if args.share_prefixes and rid % 2 == 0:
            tail = rng.integers(0, cfg.vocab_size,
                                int(rng.integers(1, 16))).astype(np.int32)
            prompt = np.concatenate([system_prompt, tail])
            tier = 16          # sharing requires equal tiers
        else:
            prompt = rng.integers(0, cfg.vocab_size,
                                  int(rng.integers(9, 64))).astype(np.int32)
            tier = int(rng.choice(tiers))
        workload.append((prompt, int(rng.integers(4, 16)), tier))
        print(f"  req {rid}: prompt={len(prompt):3d} "
              f"new={workload[-1][1]:2d} tier=s{tier}"
              + ("  [shared system prompt]"
                 if args.share_prefixes and rid % 2 == 0 else ""))

    def submit_all(engine):
        for rid, (prompt, max_new, tier) in enumerate(workload):
            engine.submit(Request(rid=rid, prompt=prompt,
                                  max_new_tokens=max_new, tier=tier))

    if args.replicas > 1:
        router = ReplicaRouter(params, cfg, lex, bank, engine_cfg,
                               n_replicas=args.replicas, policy=args.route)
        submit_all(router)
        done = router.run()
        stats = router.to_dict()
        print(f"\ncompleted {len(done)}/{args.n_requests} requests across "
              f"{args.replicas} replicas (policy={stats['policy']}) "
              f"in {stats['steps']} fleet decode steps")
        for rid in sorted(done):
            print(f"  req {rid} -> replica {router.replica_of(rid)} "
                  f"(tier s{done[rid].request.tier}): "
                  f"{done[rid].generated_tokens}")
        print(f"\nfleet throughput: "
              f"{stats['tokens_per_s_ex_compile']:.1f} tok/s ex-compile, "
              f"{stats['tokens_generated']} tokens")
        print("per-replica occupancy + prefix-cache hit rates:")
        for sub in stats["per_replica"]:
            admits = sub["prefix_hits"] + sub["prefix_misses"]
            print(f"  replica {sub['replica']}: "
                  f"routed {sub['requests_routed']:2d}  "
                  f"occupancy mean {sub['slot_occupancy_mean']:.2f}  "
                  f"hit rate {sub['shared_page_hit_rate']:.0%} "
                  f"({sub['prefix_hits']}/{admits})  "
                  f"prefill OMP skipped {sub['prefill_tokens_skipped']}")
        print(f"fleet: hit rate {stats['shared_page_hit_rate']:.0%}, "
              f"{stats['pages_aliased']} pages aliased, "
              f"{stats['prefill_tokens_skipped']} prefill tokens skipped, "
              f"{stats['bytes_deduped']} B deduplicated")
        router.drain_caches()
        balanced = all(e.allocator.check_balanced() for e in router.engines)
        print(f"after dropping every replica's prefix pins: "
              f"balanced={balanced}, global view empty={len(router.view) == 0}")
        if args.quality:
            block = router.quality_summary()   # exact fleet merge
            print_quality([e.quality for e in router.engines if e.quality],
                          layer_table_from_block(block), block)
        return

    base_done = base_prefill = None
    if args.fused_omp:
        # baseline first: the identical workload through the ref encoder,
        # so the prefill-phase before/after below is apples to apples
        base_eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            dataclasses.replace(engine_cfg, fused_omp=False, obs=None))
        submit_all(base_eng)
        base_done = base_eng.run()
        base_prefill = base_eng.metrics.to_dict()["phase_times"].get("prefill")
        if base_eng.prefix_index is not None:
            base_eng.prefix_index.clear(
                base_eng.allocator,
                host=base_eng.swap.host if base_eng.swap else None)

    submit_all(eng)
    done = eng.run()
    stats = eng.metrics.to_dict()

    print(f"\ncompleted {len(done)}/{args.n_requests} requests "
          f"in {stats['steps']} pooled decode steps")
    for rid in sorted(done):
        toks = done[rid].generated_tokens
        print(f"  req {rid} (tier s{done[rid].request.tier}): {toks}")
    print(f"\ncompile counts (flat in #requests): {eng.compile_counts}")
    print(f"decode throughput: {stats['tokens_per_s']:.1f} tok/s, "
          f"{stats['decode_tokens_per_step']:.2f} tok/step")
    print(f"slot occupancy: mean {stats['slot_occupancy_mean']:.2f} / "
          f"peak {stats['slot_occupancy_peak']}")
    print(f"KV bytes in flight: mean {stats['kv_bytes_in_flight_mean']:.0f} / "
          f"peak {stats['kv_bytes_in_flight_peak']} "
          f"(paper 3s+2 accounting)")
    print(f"KV bytes resident ({args.layout}): "
          f"peak {stats['kv_bytes_resident_peak']}")
    if args.layout == "paged":
        print(f"pool pages: peak {stats['pages_in_use_peak']} in use, "
              f"balanced={eng.allocator.check_balanced()}")
    if args.share_prefixes:
        print(f"prefix sharing: hit rate "
              f"{stats['shared_page_hit_rate']:.0%} "
              f"({stats['prefix_hits']}/{stats['prefix_hits'] + stats['prefix_misses']} admissions)")
        print(f"  pages aliased {stats['pages_aliased']}, CoW copies "
              f"{stats['pages_copied']}, peak {stats['shared_pages_peak']} "
              f"pages held by >=2 slots")
        print(f"  prefill OMP skipped for {stats['prefill_tokens_skipped']} "
              f"of "
              f"{stats['prefill_tokens_skipped'] + stats['prefill_tokens_compressed']} "
              f"compressed positions, {stats['bytes_deduped']} B deduplicated")
        eng.prefix_index.clear(eng.allocator,
                               host=eng.swap.host if eng.swap else None)
        print(f"  after dropping prefix-cache pins: "
              f"balanced={eng.allocator.check_balanced()}")
    if args.swap:
        print(f"tiered storage: {stats['pages_demoted']} pages demoted, "
              f"{stats['pages_promoted']} promoted "
              f"(host bytes peak {stats['host_bytes_resident_peak']})")
        print(f"  promote stalls: {stats['promote_stall_steps']} slot-steps; "
              f"admission rejections: {eng.scheduler.rejections}")
        print(f"  host tier balanced at drain: "
              f"{eng.swap.host.check_balanced()}")
    print(f"queue latency: mean {stats['queue_latency_s_mean'] * 1e3:.0f} ms, "
          f"p50 {stats['queue_latency_s_p50'] * 1e3:.0f} ms, "
          f"p99 {stats['queue_latency_s_p99'] * 1e3:.0f} ms")
    phases = stats["phase_times"]
    if phases:
        print("step phases (p50 / p99 ms):")
        for name, summary in phases.items():
            print(f"  {name:16s} {summary['p50'] * 1e3:7.2f} / "
                  f"{summary['p99'] * 1e3:7.2f}  (n={summary['count']})")
    print(f"setup {stats['setup_s']:.2f}s, compile {stats['compile_s']:.2f}s "
          f"-> {stats['tokens_per_s_ex_compile']:.1f} tok/s ex-compile")
    if args.fused_omp:
        fused_prefill = stats["phase_times"].get("prefill")
        same = ({r: base_done[r].generated_tokens for r in base_done}
                == {r: done[r].generated_tokens for r in done})
        print("\nfused batched-OMP prefill (before = ref encoder, "
              "after = fused):")
        for label, summary in (("before", base_prefill),
                               ("after", fused_prefill)):
            if summary:
                print(f"  {label:6s} p50 {summary['p50'] * 1e3:7.2f} ms / "
                      f"p99 {summary['p99'] * 1e3:7.2f} ms "
                      f"(n={summary['count']})")
            else:
                print(f"  {label:6s} no steady-state prefill samples "
                      "(every bucket compiled fresh)")
        print(f"  identical tokens vs baseline: {same}")

    if args.quality:
        print_quality([eng.quality], eng.quality.layer_table(),
                      stats["quality"])

    if args.trace:
        eng.save_trace(args.trace)
        print(f"\ntrace: {len(eng.tracer)} events -> {args.trace} "
              "(open at https://ui.perfetto.dev)")
    if args.metrics_snapshot:
        with open(args.metrics_snapshot, "w") as f:
            f.write(eng.metrics.to_prometheus())
        print(f"metrics snapshot -> {args.metrics_snapshot}")
    if args.journal:
        eng.save_journal(args.journal)
        violations = replay_check(eng.journal.events)
        print(f"journal: {len(eng.journal)} events -> {args.journal}; "
              f"replay check: "
              f"{'CLEAN' if not violations else [str(v) for v in violations]}")


if __name__ == "__main__":
    main()
