"""End-to-end serving driver: batched requests through prefill + decode with
the Lexico cache policy, reporting KV memory vs the full cache and fidelity
against the uncompressed model.

    PYTHONPATH=src python examples/serve_lexico.py [--s 8] [--new-tokens 24]

This is the paper's deployment story in one file: one universal dictionary
bank serves every request in the batch; the cache stores 3s+2 bytes/vector
instead of 2*head_dim.
"""
import argparse
import os
import sys

# examples use the benchmark substrate (trained toy model);
# make the repo root importable regardless of invocation dir
sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..'))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, trained_params
from benchmarks.memory_fidelity import trained_bank
from repro.configs.base import LexicoConfig
from repro.core import sparse_cache
from repro.data.synthetic import SyntheticCorpus
from repro.models import model as M
from repro.models.cache_policy import DensePolicy, LexicoPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = BENCH_CFG
    params, _ = trained_params()
    N = 192
    bank = trained_bank(params, cfg, N, min(args.s, 16))
    lex = LexicoConfig(N=N, s=args.s, n_b=8, chunk=None, codec="fp8")
    policy = LexicoPolicy(lex)

    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    prompts = jnp.asarray(corpus.sample(args.batch, args.prompt_len, seed=42),
                          jnp.int32)
    t_max = args.prompt_len + args.new_tokens + 8

    print(f"prefill: batch={args.batch} prompt={args.prompt_len} s={args.s}")
    lg, state = M.prefill(params, cfg, policy, {"tokens": prompts},
                          bank=bank, t_max=t_max)
    # greedy decode, Lexico vs full cache side by side
    lg_d, state_d = M.prefill(params, cfg, DensePolicy(), {"tokens": prompts},
                              bank=None, t_max=t_max)
    tok, tok_d = jnp.argmax(lg, -1), jnp.argmax(lg_d, -1)
    agree = [float(jnp.mean(tok == tok_d))]
    outs = [tok]
    for i in range(args.new_tokens - 1):
        lg, state = M.decode_step(params, cfg, policy, state, tok, bank=bank)
        lg_d, state_d = M.decode_step(params, cfg, DensePolicy(), state_d, tok_d,
                                      bank=None)
        tok, tok_d = jnp.argmax(lg, -1), jnp.argmax(lg_d, -1)
        agree.append(float(jnp.mean(tok == tok_d)))
        outs.append(tok)

    total = args.prompt_len + args.new_tokens
    pct = sparse_cache.kv_size_percent(t_c=total - lex.n_b, n_b=lex.n_b,
                                       s=args.s, m=cfg.hd)
    print(f"generated {args.new_tokens} tokens/request")
    print(f"greedy-token agreement with full cache: {np.mean(agree):.2%}")
    print(f"KV size: {pct:.1f}% of FP16 full cache "
          f"(paper law: 1.17*s% + buffer)")
    print("sample continuation (request 0):",
          np.asarray(jnp.stack(outs))[:, 0].tolist())


if __name__ == "__main__":
    main()
