"""End-to-end training driver: train a ~100M-parameter llama-family model for
a few hundred steps with the full production loop — sharded data pipeline,
AdamW + warmup-cosine, global-norm clipping, async checkpointing, preemption
guard, straggler monitor, resume-from-checkpoint.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--resume]

On this CPU container it runs a reduced width by default (--full for the real
100M); the loop/code path is identical to the multi-pod launch (launch/train
lowers the same step function with shardings).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataPipeline
from repro.launch.train import TrainState, init_train_state, make_train_step
from repro.runtime.fault_tolerance import HeartbeatMonitor, PreemptionGuard

CFG_100M = ModelConfig(
    name="repro-100m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
    d_ff=2048, vocab_size=32768, tie_embeddings=True, param_dtype="float32",
)
CFG_SMALL = ModelConfig(
    name="repro-small", family="dense",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
    d_ff=704, vocab_size=2048, tie_embeddings=True, param_dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="train the 100M config")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt", default="checkpoints/train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = CFG_100M if args.full else CFG_SMALL
    print(f"config {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params")

    step_fn = jax.jit(make_train_step(cfg, base_lr=3e-3, warmup=20,
                                      total_steps=args.steps, remat=False))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(args.ckpt, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        start = mgr.latest_step()
        state = mgr.restore_latest(state)
        print(f"resumed from step {start}")

    pipe = DataPipeline(cfg.vocab_size, global_batch=args.batch,
                        seq_len=args.seq, seed=0).start(from_step=start)
    guard = PreemptionGuard().install()
    monitor = HeartbeatMonitor()

    t_last = time.time()
    for i in range(start, args.steps):
        batch = next(pipe)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        dt = time.time() - t_last
        t_last = time.time()
        monitor.record("host0", dt)
        if i % 10 == 0:
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"lr={float(metrics['lr']):.2e}  {dt*1000:.0f} ms")
        if i % args.ckpt_every == args.ckpt_every - 1:
            mgr.save(state, step=i + 1, blocking=False)   # async
        if guard.should_stop():
            print("preemption requested -> emergency checkpoint")
            mgr.wait()
            mgr.save(state, step=i + 1)
            break
    pipe.stop()
    mgr.wait()
    mgr.save(state, step=int(state.step))
    print(f"done at step {int(state.step)}; stragglers: {monitor.stragglers()}")


if __name__ == "__main__":
    main()
