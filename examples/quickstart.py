"""Quickstart: compress a KV cache with Lexico in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a dictionary, OMP-encodes a batch of synthetic key vectors at several
sparsity levels, and prints the memory/error trade-off (the paper's core
mechanism end to end).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    init_dictionary, omp_batch, reconstruct, dict_train_init, dict_train_step,
)
from repro.core.quant import kv_size_fraction

m, N = 64, 512
rng = np.random.default_rng(0)

# structured "keys": mixture of low-rank subspaces (paper Fig. 3 structure)
bases = rng.normal(size=(6, m, 4))
which = rng.integers(0, 6, 2048)
K = jnp.asarray(np.einsum("bmr,br->bm", bases[which], rng.normal(size=(2048, 4)))
                + 0.02 * rng.normal(size=(2048, m)), jnp.float32)

# 1) train a universal dictionary with OMP in the loop (paper §3.3)
state = dict_train_init(init_dictionary(jax.random.PRNGKey(0), m, N))
for step in range(60):
    state, metrics = dict_train_step(state, K[:1024], s=8, base_lr=3e-3,
                                     lr_schedule_len=60)
    if step % 20 == 0:
        print(f"dict step {step:3d}  rel_err={float(metrics['rel_err_mean']):.3f}")

# 2) compress held-out keys at several sparsity levels (paper §3.2)
held = K[1024:]
print(f"\n{'s':>4} {'KV size %':>10} {'rel err':>9}")
for s in (2, 4, 8, 16, 32):
    res = omp_batch(held, state.D, s)
    rec = reconstruct(res, state.D)
    rel = float(jnp.mean(jnp.linalg.norm(rec - held, axis=-1)
                         / jnp.linalg.norm(held, axis=-1)))
    print(f"{s:>4} {100 * kv_size_fraction(s, m):>10.1f} {rel:>9.3f}")

print("\n(The dictionary is input-agnostic: reuse it for every request.)")
