"""Dictionary pretraining driver (paper §3.3, Figure 4) — the 'training'
stage of Lexico: harvest KV vectors from a model over a corpus, train
per-(layer, role) dictionaries with OMP in the loop, checkpoint the bank.

    PYTHONPATH=src python examples/train_dictionary.py [--steps 60]

Production notes: the loop is data-parallel (KV batches shard over 'data');
this driver runs it single-host with the same code path, and saves the bank
with the sharded checkpointer (restorable onto any mesh).
"""
import argparse
import os
import sys

# examples use the benchmark substrate (trained toy model);
# make the repo root importable regardless of invocation dir
sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..'))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, harvest_kv, trained_params
from repro.checkpoint import CheckpointManager
from repro.core.dict_learning import dict_train_init, dict_train_step
from repro.core.dictionary import DictionaryBank, init_dictionary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--N", type=int, default=192)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--out", default="checkpoints/dictionary_bank")
    args = ap.parse_args()

    cfg = BENCH_CFG
    print("training the backbone LM on the synthetic corpus (~1 min)...")
    params, losses = trained_params()
    print(f"  lm loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    print("harvesting KV vectors...")
    kv = harvest_kv(params, cfg, corpus_seed=0, batches=3)      # (L, 2, n, hd)
    K_train = jnp.asarray(kv[:, :, :512])

    keys = jax.random.split(jax.random.PRNGKey(0), cfg.num_layers * 2)
    D0 = jax.vmap(lambda k: init_dictionary(k, cfg.hd, args.N))(keys)
    state = dict_train_init(D0.reshape(cfg.num_layers, 2, cfg.hd, args.N))

    mgr = CheckpointManager(args.out, keep=2)
    for step in range(args.steps):
        state, metrics = dict_train_step(state, K_train, s=args.s,
                                         base_lr=3e-3, lr_schedule_len=args.steps)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
                  f"rel_err={float(metrics['rel_err_mean']):.3f}"
                  f"±{float(metrics['rel_err_std']):.3f}")
        if step % 20 == 19:
            mgr.save({"D": state.D, "step": jnp.int32(step)}, step=step,
                     blocking=False)   # async checkpoint
    mgr.wait()
    G = jnp.einsum("lrmn,lrmp->lrnp", state.D, state.D)
    mgr.save({"D": state.D, "G": G, "step": jnp.int32(args.steps)},
             step=args.steps)
    print(f"dictionary bank saved under {args.out} "
          f"({state.D.size * 4 / 1e6:.1f} MB, constant wrt batch/users)")


if __name__ == "__main__":
    main()
