"""§4.2.4 adaptive dictionaries: growing input-specific atoms under an error
threshold improves reconstruction at the cost of KV-budget bytes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, harvest_kv, trained_params
from repro.core.adaptive import adaptive_encode, adaptive_extra_bytes, init_adaptive
from repro.core.dict_learning import dict_train_init, dict_train_step
from repro.core.dictionary import init_dictionary


def run(emit):
    cfg = BENCH_CFG
    params, _ = trained_params()
    kv = harvest_kv(params, cfg, corpus_seed=21)   # off-domain-ish stream
    X = jnp.asarray(kv[1, 0][:160])
    N, s = 96, 4   # tight budget so some vectors genuinely miss the threshold
    state = dict_train_init(init_dictionary(jax.random.PRNGKey(0), cfg.hd, N))
    Xtr = jnp.asarray(harvest_kv(params, cfg, corpus_seed=0)[1, 0][:256])
    for i in range(40):
        state, _ = dict_train_step(state, Xtr, s=s, base_lr=3e-3, lr_schedule_len=40)

    for delta in (0.15, 0.25):
        # static baseline in the SAME threshold mode (paper Table 6 protocol:
        # both encoders target delta; the static one fails on hard vectors,
        # the adaptive one grows an atom and hits it exactly)
        from repro.core.omp import omp_batch
        res0 = omp_batch(X, state.D, s, delta=delta)
        base_err = float(jnp.mean(jnp.sqrt(res0.resid2) / jnp.linalg.norm(X, axis=-1)))
        base_miss = float(jnp.mean((jnp.sqrt(res0.resid2)
                                    / jnp.linalg.norm(X, axis=-1)) > delta))
        ad = init_adaptive(state.D, capacity=N + 64)
        ad2, res = adaptive_encode(ad, X, s=s, delta=delta)
        err = float(jnp.mean(jnp.sqrt(res.resid2) / jnp.linalg.norm(X, axis=-1)))
        miss = float(jnp.mean((jnp.sqrt(res.resid2)
                               / jnp.linalg.norm(X, axis=-1)) > delta + 1e-4))
        grown = int(ad2.n_used - ad2.n_base)
        emit(f"adaptive/delta{delta}/static_rel_err", base_err)
        emit(f"adaptive/delta{delta}/adaptive_rel_err", err)
        emit(f"adaptive/delta{delta}/static_threshold_miss_rate", base_miss)
        emit(f"adaptive/delta{delta}/adaptive_threshold_miss_rate", miss)
        emit(f"adaptive/delta{delta}/atoms_grown", grown)
        emit(f"adaptive/delta{delta}/extra_bytes", int(adaptive_extra_bytes(ad2)))
        emit(f"adaptive/delta{delta}/improves", float(err <= base_err + 1e-6
                                                      and miss <= base_miss))
