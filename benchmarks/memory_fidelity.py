"""Tables 2-3 / Figure 1 analogue: KV-size %% vs generation fidelity for
Lexico against KIVI-4/KIVI-2/per-token-quant/eviction/full-cache.

Without pretrained checkpoints + GSM8K, the end metric is the per-token
fidelity of compressed-cache decoding against the full-cache model: top-1
next-token agreement and mean |Δlogit| over a decode rollout of a trained
small model. The paper's falsifiable claim reproduced here: below ~25%% KV
size Lexico dominates the quantization baselines, and eviction trails
everywhere (§4.1, Figure 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, harvest_kv, timer, trained_params
from repro.configs.base import LexicoConfig
from repro.baselines import EvictionPolicy, KIVIPolicy, PerTokenQuantPolicy
from repro.core.dict_learning import dict_train_init, dict_train_step
from repro.core.dictionary import DictionaryBank, init_dictionary
from repro.core.quant import kv_size_fraction
from repro.models import model as M
from repro.models.cache_policy import DensePolicy, LexicoPolicy


def trained_bank(params, cfg, N, s, steps=40):
    kv = harvest_kv(params, cfg, corpus_seed=0)   # (L, 2, n, hd)
    K_train = jnp.asarray(kv[:, :, :256])          # (L, 2, 256, hd)
    D0 = jax.vmap(jax.vmap(lambda k: init_dictionary(k, cfg.hd, N)))(
        jax.random.split(jax.random.PRNGKey(0), cfg.num_layers * 2
                         ).reshape(cfg.num_layers, 2, 2))
    state = dict_train_init(D0)
    for i in range(steps):
        state, _ = dict_train_step(state, K_train, s=s, base_lr=3e-3,
                                   lr_schedule_len=steps)
    D = state.D
    G = jnp.einsum("lrmn,lrmp->lrnp", D, D)
    return DictionaryBank(D=D, G=G)


def rollout_fidelity(cfg, params, policy, bank, tokens, Tp):
    jax.clear_caches()   # decode_step recompiles per (policy, shape) combo
    B, T = tokens.shape
    full = M.forward_train(params, cfg, {"tokens": tokens, "labels": tokens})
    pb = {"tokens": tokens[:, :Tp]}
    lg, state = M.prefill(params, cfg, policy, pb, bank=bank, t_max=T + 8)
    agree, dl = [], []
    for t in range(Tp, T):
        lg, state = M.decode_step(params, cfg, policy, state, tokens[:, t], bank=bank)
        agree.append(np.mean(np.asarray(jnp.argmax(lg, -1) == jnp.argmax(full[:, t], -1))))
        dl.append(float(jnp.mean(jnp.abs(lg - full[:, t]))))
    return float(np.mean(agree)), float(np.mean(dl))


def run(emit):
    cfg = BENCH_CFG
    params, losses = trained_params()
    emit("train/first_loss", losses[0])
    emit("train/last_loss", losses[-1])
    rng = np.random.default_rng(0)
    from repro.data.synthetic import SyntheticCorpus
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    tokens = jnp.asarray(corpus.sample(4, 48, seed=777), jnp.int32)
    Tp = 32
    m = cfg.hd

    N = 192
    bank_cache = {}
    rows = []
    # Lexico at several sparsity levels (paper sweeps s to trace the curve)
    for s in (2, 4, 8, 16):
        if s not in bank_cache:
            bank_cache[s] = trained_bank(params, cfg, N, min(s, 16))
        lex = LexicoConfig(N=N, s=s, n_b=8, chunk=None, codec="fp8")
        pol = LexicoPolicy(lex)
        a, d = rollout_fidelity(cfg, params, pol, bank_cache[s], tokens, Tp)
        size = 100 * kv_size_fraction(s, m)
        rows.append(("lexico", s, size, a, d))
        emit(f"fidelity/lexico_s{s}/kv_pct", size)
        emit(f"fidelity/lexico_s{s}/top1_agree", a)
        emit(f"fidelity/lexico_s{s}/mean_dlogit", d)

    baselines = [
        ("full", DensePolicy(), 100.0),
        ("kivi4", KIVIPolicy(bits=4, group=8, n_b=8), 100 * KIVIPolicy(bits=4, group=8).kv_size_fraction(m)),
        ("kivi2", KIVIPolicy(bits=2, group=8, n_b=8), 100 * KIVIPolicy(bits=2, group=8).kv_size_fraction(m)),
        ("ptq4", PerTokenQuantPolicy(bits=4, n_b=8), 100 * PerTokenQuantPolicy(bits=4).kv_size_fraction(m)),
        ("evict25", EvictionPolicy(budget=12, recent=4), 100 * 12 / 48),
    ]
    for name, pol, size in baselines:
        a, d = rollout_fidelity(cfg, params, pol, None, tokens, Tp)
        rows.append((name, None, size, a, d))
        emit(f"fidelity/{name}/kv_pct", size)
        emit(f"fidelity/{name}/top1_agree", a)
        emit(f"fidelity/{name}/mean_dlogit", d)

    # paper claim: in the low-memory regime lexico beats the 2-bit baseline
    lex_low = [r for r in rows if r[0] == "lexico" and r[2] < 30]
    kivi2 = [r for r in rows if r[0] == "kivi2"][0]
    best_low = max(lex_low, key=lambda r: r[3])
    emit("fidelity/claim_lexico_beats_kivi2_low_mem",
         float(best_low[3] >= kivi2[3] - 0.02))
