"""Table 7: latency decomposition — standard forward (qK^T) vs Lexico's
compressed-score path vs OMP compression, per decode token.

CPU wall-times are not TPU numbers; the deliverable is (a) the decomposition
and (b) the *derived* v5e-time from the roofline byte counts — the dry-run
§Roofline carries the production-scale version. N=192 vs N=768 reproduces the
paper's observation that dictionary size mostly moves OMP time, barely the
forward pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timer
from repro.core.attention import compressed_scores, decode_attention
from repro.core.omp import omp_batch
from repro.core.dictionary import init_dictionary


def run(emit):
    rng = np.random.default_rng(0)
    B, KV, G, m, T, s, n_b = 2, 4, 2, 64, 1024, 16, 32
    q = jnp.asarray(rng.normal(size=(B, KV, G, m)), jnp.float32)
    K_cache = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.bfloat16)

    @jax.jit
    def std_scores(q, K):
        return jnp.einsum("bkgm,bktm->bkgt", q.astype(jnp.float32),
                          K.astype(jnp.float32))

    t_std = timer(std_scores, q, K_cache)
    emit("latency/std_qKT_us", t_std)

    for N in (192, 768):
        D = init_dictionary(jax.random.PRNGKey(0), m, N)
        vals = jnp.asarray(rng.normal(size=(B, KV, T, s)), jnp.float8_e4m3fn)
        idx = jnp.asarray(rng.integers(0, N, (B, KV, T, s)), jnp.int16)

        @jax.jit
        def lex_scores(q, vals, idx):
            qd = jnp.einsum("bkgm,mn->bkgn", q.astype(jnp.float32), D)
            return compressed_scores(qd, vals, idx, scale=1.0)

        t_lex = timer(lex_scores, q, vals, idx)
        emit(f"latency/lexico_scores_N{N}_us", t_lex)

        X = jnp.asarray(rng.normal(size=(B * KV, m)), jnp.float32)
        G_ = D.T @ D

        @jax.jit
        def omp_step(X):
            return omp_batch(X, D, s, use_gram=True, G=G_).vals

        t_omp = timer(omp_step, X)
        emit(f"latency/omp_na{B*KV}_N{N}_us", t_omp)

    # derived v5e decode-time bound from bytes: compressed read (3s+2)/token
    # vs dense 2*m bytes/token at 819 GB/s
    from repro.core.quant import payload_bytes
    dense_bytes = 2 * m * 2 * T * B * KV
    lex_bytes = 2 * payload_bytes(s) * T * B * KV
    emit("latency/v5e_dense_cache_read_us", 1e6 * dense_bytes / 819e9)
    emit("latency/v5e_lexico_cache_read_us", 1e6 * lex_bytes / 819e9)
    emit("latency/v5e_read_speedup", dense_bytes / lex_bytes)
