"""Serving-throughput benchmark: the continuous-batching engine under load.

Emits a JSON document (stdout, plus ``name,value`` CSV rows when driven by
``benchmarks.run``) with decode tokens/s, per-step batch efficiency, slot
occupancy, KV-bytes-in-flight (paper 3s+2 accounting), KV-bytes-resident
(bytes the slots hold in their layout — the pool capacity a right-sized
deployment must provision), and queue latency — the numbers
that track whether the serving stack is getting faster and denser over the
bench trajectory.

The same short/long mixed workload runs through BOTH slot-storage layouts
(contiguous stripes vs paged pool) and the JSON carries the comparison:
paged slots must hold fewer KV bytes than the padded stripes do (the
headroom an oversubscribed ``n_pages`` turns into extra admitted requests).

A second scenario (``--scenario prefix``) is many clients sharing one
system prompt — the workload copy-on-write prefix sharing exists for — and
reports shared vs unshared resident KV bytes, dedup'd bytes, hit rate, and
the prefill OMP positions skipped.

A third scenario (``--scenario swap``) oversubscribes the device page pool:
the same workload runs with and without the host-memory swap tier
(``EngineConfig(swap=SwapConfig())``). The no-swap scheduler *rejects* the
concurrency (head-of-line blocking, occupancy pinned low); the tiered run
fills every slot by demoting cold pages to host memory and promoting them
back on access — same tokens, and the JSON reports device-peak pages,
host-peak bytes, promote stalls and tier traffic.

A fourth scenario (``--scenario obs``) runs the swap workload with the
observability layer on vs off: measured tracing overhead on steady-state
tokens/s (``--overhead-budget 0.02`` turns it into a CI gate), per-phase
p50/p99, the AOT roofline of the compiled decode step (achieved vs
predicted bytes/FLOPs), journal replay, and ``--trace``/``--journal``/
``--metrics-snapshot`` artifact outputs.

A fifth scenario (``--scenario fused-kernel``) runs the paged engine with
the fused sparse-attention kernel path off vs on: token identity, per-mode
throughput/compile counts/decode rooflines, and the analytic kernel-model
comparison (gather vs fused HBM bytes per decode step — fused must predict
strictly fewer).

A sixth scenario (``--scenario omp-kernel``) runs the paged engine with the
fused batched-OMP prefill encoder off vs on vs forced-kernel: token
identity, prefill tokens/s per mode from the steady-state phase timer, the
streamed-vs-gathered selection bytes model, and the early-exit vs
always-``s_max`` CPU wall clock with the ``nnz`` histogram.

A seventh scenario (``--scenario router``) runs a staged two-wave family
workload through a 3-replica ``ReplicaRouter`` under each routing policy
(rr, load, affinity) plus a solo single-engine oracle: every policy's
tokens must be bitwise identical to the solo run, and prefix-affinity
routing must beat round-robin on BOTH aggregate tokens/s (ex-compile) and
shared-page hit rate (exit non-zero otherwise — the CI gate).

An eighth scenario (``--scenario quality``) is the compression-quality
gate: the swap workload with quality telemetry off vs on (identical
tokens, decode still one compile, measured overhead against
``--overhead-budget``), dictionary-drift score of a calibration-like
rerun against a frozen baseline (must stay below ``--drift-budget``),
clean ``page_quality`` journal replay, and the bounded-error tolerance
harness — a lossless rerun must pass a tight ``ToleranceGate`` while an
injected int8 value requantization must be flagged.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--scenario all]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..'))

import numpy as np

from benchmarks.common import BENCH_CFG, trained_params
from benchmarks.memory_fidelity import trained_bank
from repro.configs.base import LexicoConfig
from repro.roofline.analysis import achieved_vs_predicted
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, ObsConfig, Request, SwapConfig,
)
from repro.serving.obs import engine_decode_roofline, replay_check


def _submit_workload(eng, cfg, *, n_requests: int, seed: int) -> None:
    """Short/long mixed workload: half the requests are short chats, half
    long documents — the mix where per-slot padding wastes the most."""
    rng = np.random.default_rng(seed)
    for rid in range(n_requests):
        if rid % 2 == 0:
            prompt_len = int(rng.integers(9, 20))      # short
        else:
            prompt_len = int(rng.integers(48, 80))     # long
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 16)),
            tier=int(rng.choice([2, 4, 8, 16]))))


def run_serving_bench(*, n_requests: int = 12, n_slots: int = 4,
                      t_max: int = 96, seed: int = 0,
                      layout: str = "contiguous", page_size: int = 8) -> dict:
    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    eng = ContinuousBatchingEngine(
        params, cfg, lex, bank,
        EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                     layout=layout, page_size=page_size))
    _submit_workload(eng, cfg, n_requests=n_requests, seed=seed)
    done = eng.run()
    stats = eng.metrics.to_dict()
    stats.update(
        n_requests=n_requests,
        n_slots=n_slots,
        layout=layout,
        completed=len(done),
        compile_counts=eng.compile_counts,
    )
    if eng.paged:
        stats["page_size"] = page_size
        stats["pool_pages"] = eng.allocator.capacity
        stats["pages_balanced"] = eng.allocator.check_balanced()
    return stats


def _submit_same_system_prompt(eng, cfg, *, n_requests: int, seed: int) -> None:
    """Many clients, one system prompt: every request starts with the same
    32-token prefix (page-aligned at page_size 8) and appends its own short
    question. One tier — sharing requires equal OMP atom caps."""
    rng = np.random.default_rng(seed)
    system_prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    for rid in range(n_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 14))).astype(np.int32)
        eng.submit(Request(
            rid=rid, prompt=np.concatenate([system_prompt, tail]),
            max_new_tokens=int(rng.integers(4, 12)), tier=16))


def run_prefix_sharing_bench(*, n_requests: int = 12, n_slots: int = 4,
                             t_max: int = 96, seed: int = 0,
                             page_size: int = 8) -> dict:
    """The many-clients-same-system-prompt scenario through the paged
    engine with sharing off vs on; tokens must match exactly."""
    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    sides = {}
    tokens = {}
    for share in (False, True):
        eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                         layout="paged", page_size=page_size,
                         share_prefixes=share))
        _submit_same_system_prompt(eng, cfg, n_requests=n_requests, seed=seed)
        done = eng.run()
        stats = eng.metrics.to_dict()
        stats.update(n_requests=n_requests, completed=len(done),
                     compile_counts=eng.compile_counts)
        if share:
            stats["prefix_cache_pages"] = eng.prefix_index.n_cached_pages()
            eng.prefix_index.clear(eng.allocator)
        stats["pages_balanced"] = eng.allocator.check_balanced()
        sides["shared" if share else "unshared"] = stats
        tokens[share] = {rid: done[rid].generated_tokens for rid in done}
    sh, un = sides["shared"], sides["unshared"]
    return {
        "unshared": un,
        "shared": sh,
        "sharing": {
            # the headline: resident KV bytes with vs without dedup
            "kv_bytes_resident_peak_unshared": un["kv_bytes_resident_peak"],
            "kv_bytes_resident_peak_shared": sh["kv_bytes_resident_peak"],
            "kv_bytes_resident_peak_ratio": (
                sh["kv_bytes_resident_peak"]
                / max(un["kv_bytes_resident_peak"], 1)),
            "bytes_deduped": sh["bytes_deduped"],
            "shared_page_hit_rate": sh["shared_page_hit_rate"],
            "pages_aliased": sh["pages_aliased"],
            "pages_copied": sh["pages_copied"],
            "prefill_tokens_skipped": sh["prefill_tokens_skipped"],
            "same_tokens": tokens[False] == tokens[True],
        },
    }


def run_swap_bench(*, n_requests: int = 10, n_slots: int = 4,
                   t_max: int = 96, seed: int = 0,
                   page_size: int = 8) -> dict:
    """Oversubscribed-pool scenario: the pool holds one long request's
    working set plus change, the workload wants several at once. Runs the
    identical workload three ways — unconstrained oracle, constrained
    no-swap, constrained + host tier — and reports what the tier buys."""
    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    # tight pool: the longest request (~80 tokens -> 10 pages) fits alone,
    # the concurrent mix does not
    n_pages = 15
    sides, tokens = {}, {}
    for name, kw in (("oracle", {}),
                     ("no_swap", {"n_pages": n_pages}),
                     ("swap", {"n_pages": n_pages, "swap": SwapConfig()})):
        eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                         layout="paged", page_size=page_size, **kw))
        _submit_workload(eng, cfg, n_requests=n_requests, seed=seed)
        done = eng.run()
        stats = eng.metrics.to_dict()
        stats.update(n_requests=n_requests, completed=len(done),
                     rejections=eng.scheduler.rejections,
                     pages_balanced=eng.allocator.check_balanced())
        if eng.swap is not None:
            stats["host_balanced"] = eng.swap.host.check_balanced()
        sides[name] = stats
        tokens[name] = {rid: done[rid].generated_tokens for rid in done}
    sw, ns = sides["swap"], sides["no_swap"]
    return {
        "oracle": sides["oracle"],
        "no_swap": ns,
        "swap": sw,
        "tiering": {
            # the headline: concurrency the no-swap scheduler rejected is
            # served by the tier, for the same (bitwise) tokens
            "no_swap_rejections": ns["rejections"],
            "occupancy_peak_no_swap": ns["slot_occupancy_peak"],
            "occupancy_peak_swap": sw["slot_occupancy_peak"],
            "device_pages_peak": sw["pages_in_use_peak"],
            "host_bytes_resident_peak": sw["host_bytes_resident_peak"],
            "pages_demoted": sw["pages_demoted"],
            "pages_promoted": sw["pages_promoted"],
            "promote_stall_steps": sw["promote_stall_steps"],
            "same_tokens_vs_oracle": tokens["swap"] == tokens["oracle"],
        },
    }


def run_obs_bench(*, n_requests: int = 10, n_slots: int = 4,
                  t_max: int = 96, seed: int = 0, page_size: int = 8,
                  repeats: int = 2, trace_path: str = None,
                  journal_path: str = None, metrics_path: str = None) -> dict:
    """Observability scenario: the oversubscribed swap workload with
    tracing + journaling ON vs OFF.

    Reports (a) measured tracing overhead on steady-state tokens/s
    (best-of-``repeats`` per mode, compile time excluded — the 2%% budget
    the CI job gates on), (b) per-phase p50/p99 of the instrumented run,
    (c) the AOT roofline of the compiled decode step with achieved (phase
    p50) vs predicted (HLO cost model) bytes/FLOPs, and (d) the journal
    replay verdict. Optionally writes the Perfetto trace, the JSONL
    journal, and a Prometheus metrics snapshot as artifacts."""
    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    n_pages = 15    # tight pool, same as run_swap_bench: forces tier traffic

    def one_run(obs):
        eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                         layout="paged", page_size=page_size,
                         n_pages=n_pages, swap=SwapConfig(), obs=obs))
        _submit_workload(eng, cfg, n_requests=n_requests, seed=seed)
        done = eng.run()
        return eng, done

    best, tokens, last_eng = {}, {}, {}
    for mode, obs in (("off", None),
                      ("on", ObsConfig(trace=True, journal=True))):
        rates = []
        for _ in range(repeats):
            eng, done = one_run(obs)
            rates.append(eng.metrics.to_dict()["tokens_per_s_ex_compile"])
            last_eng[mode] = eng
            tokens[mode] = {rid: done[rid].generated_tokens for rid in done}
        best[mode] = max(rates)
    eng_on = last_eng["on"]
    md_on = eng_on.metrics.to_dict()
    overhead = 1.0 - best["on"] / max(best["off"], 1e-9)

    # roofline: AOT-predicted bytes/FLOPs of the decode module the hot loop
    # dispatches, vs the achieved per-step decode time (dispatch + sync p50)
    report = engine_decode_roofline(eng_on)
    achieved_s = (md_on["phase_times"]["decode_dispatch"]["p50"]
                  + md_on["phase_times"]["host_sync"]["p50"])
    roofline = {
        "decode": report.to_json(),
        "decode_achieved_vs_predicted": achieved_vs_predicted(report,
                                                              achieved_s),
    }

    if trace_path:
        eng_on.save_trace(trace_path)
    if journal_path:
        eng_on.save_journal(journal_path)
    if metrics_path:
        with open(metrics_path, "w") as f:
            f.write(eng_on.metrics.to_prometheus())
    violations = replay_check(eng_on.journal.events)
    return {
        "tokens_per_s_ex_compile_off": best["off"],
        "tokens_per_s_ex_compile_on": best["on"],
        "tracing_overhead": overhead,
        "same_tokens": tokens["off"] == tokens["on"],
        "trace_events": len(eng_on.tracer),
        "journal_events": len(eng_on.journal),
        "journal_violations": [str(v) for v in violations],
        "phase_times": md_on["phase_times"],
        "queue_latency_s_p50": md_on["queue_latency_s_p50"],
        "queue_latency_s_p99": md_on["queue_latency_s_p99"],
        "compile_s": md_on["compile_s"],
        "setup_s": md_on["setup_s"],
        "roofline": roofline,
        "on": md_on,
    }


def run_quality_bench(*, n_requests: int = 10, n_slots: int = 4,
                      t_max: int = 96, seed: int = 0, page_size: int = 8,
                      repeats: int = 2, journal_path: str = None) -> dict:
    """Compression-quality scenario: the swap workload with quality
    telemetry OFF vs ON, plus drift and the tolerance harness.

    Reports (a) measured telemetry overhead on steady-state tokens/s
    (best-of-``repeats`` per mode) with token identity and the one-compile
    decode invariant, (b) the live quality summary (residual/nnz stats,
    per-tier delta attainment) and the ``page_quality`` journal replay
    verdict, (c) the drift score of a fresh calibration-like run scored
    against the first run's frozen baseline (snapshot round trip included —
    ≈ 0 means the dictionary still fits the traffic), and (d) the
    bounded-error tolerance harness: a lossless decode rerun must produce
    an all-zero DiffReport that passes a tight gate, while an injected int8
    value requantization of the cache must be flagged."""
    import jax.numpy as jnp

    from repro.models import model as M
    from repro.models.cache_policy import LexicoPolicy
    from repro.serving.obs import (
        ToleranceGate, diff_runs, int8_requantize_cache,
    )

    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    n_pages = 15    # tight pool, same as run_swap_bench: tags cross tiers

    def one_run(obs, run_seed):
        eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                         layout="paged", page_size=page_size,
                         n_pages=n_pages, swap=SwapConfig(), obs=obs))
        _submit_workload(eng, cfg, n_requests=n_requests, seed=run_seed)
        done = eng.run()
        return eng, {rid: done[rid].generated_tokens for rid in done}

    # (a) telemetry off vs on: same tokens, one decode compile, overhead.
    # Quality only on the "on" side — the journal's own overhead is the obs
    # scenario's budget, not this one's
    best, tokens, last_eng = {}, {}, {}
    for mode, obs in (("off", None), ("on", ObsConfig(quality=True))):
        rates = []
        for _ in range(repeats):
            eng, toks = one_run(obs, seed)
            rates.append(eng.metrics.to_dict()["tokens_per_s_ex_compile"])
            last_eng[mode], tokens[mode] = eng, toks
        best[mode] = max(rates)
    overhead = 1.0 - best["on"] / max(best["off"], 1e-9)

    # artifacts run: quality + journal, so page tags are journaled and the
    # replay checker sees the page_quality events
    eng_on, toks_j = one_run(ObsConfig(quality=True, journal=True), seed)
    same_tokens = tokens["off"] == tokens["on"] == toks_j
    violations = replay_check(eng_on.journal.events)
    if journal_path:
        eng_on.save_journal(journal_path)

    # (b) drift: freeze this run's residual distribution as the calibration
    # baseline, round-trip it through the snapshot dict, score a fresh run
    # of the same traffic mix (different seed) against it
    eng_on.quality.set_baseline()
    baseline = eng_on.quality.baseline_dict()
    eng_b, _ = one_run(ObsConfig(quality=True), seed + 1)
    eng_b.quality.load_baseline(baseline)
    drift = eng_b.quality.drift_score()

    # (c) tolerance harness at the model level. codec fp16: the fp8 grid is
    # coarser than per-vector-scaled int8, so the injection would be a
    # no-op under the serving codec above
    lex16 = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp16")
    pol = LexicoPolicy(lex16)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 24)), jnp.int32)
    lg, state = M.prefill(params, cfg, pol, {"tokens": toks}, bank=bank,
                          t_max=48)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg_ref, _ = M.decode_step(params, cfg, pol, state, tok, bank=bank)
    lg_rerun, _ = M.decode_step(params, cfg, pol, state, tok, bank=bank)
    state_q = state._replace(cache=int8_requantize_cache(state.cache))
    lg_lossy, _ = M.decode_step(params, cfg, pol, state_q, tok, bank=bank)
    gate = ToleranceGate(max_abs=1e-6, require_token_match=True)
    lossless = diff_runs(lg_ref, lg_rerun,
                         jnp.argmax(lg_ref, -1), jnp.argmax(lg_rerun, -1))
    lossy = diff_runs(lg_ref, lg_lossy,
                      jnp.argmax(lg_ref, -1), jnp.argmax(lg_lossy, -1))

    return {
        "tokens_per_s_ex_compile_off": best["off"],
        "tokens_per_s_ex_compile_on": best["on"],
        "quality_overhead": overhead,
        "same_tokens": same_tokens,
        "decode_one_compile": (
            last_eng["off"].compile_counts["decode"] == 1
            and eng_on.compile_counts["decode"] == 1),
        "journal_violations": [str(v) for v in violations],
        "page_quality_events": sum(e["ev"] == "page_quality"
                                   for e in eng_on.journal.events),
        "drift_score": drift,
        "tolerance": {
            "gate": gate.to_dict(),
            "lossless": lossless.to_dict(),
            "lossy": lossy.to_dict(),
            "lossless_ok": gate.ok(lossless),
            "lossy_flagged": not gate.ok(lossy),
            "lossy_violations": gate.check(lossy),
        },
        # NOT under the key "quality": when this scenario runs alone the
        # outer {"quality": ...} wrapper is unwrapped, and the gate lookup
        # `stats.get("quality", stats)` must not land on this block
        "summary": eng_on.metrics.to_dict()["quality"],
    }


def run_fused_kernel_bench(*, n_requests: int = 12, n_slots: int = 4,
                           t_max: int = 96, seed: int = 0,
                           page_size: int = 8) -> dict:
    """Fused paged sparse-attention scenario: the mixed workload through the
    paged engine with ``fused_attention`` off vs on.

    Reports (a) token identity between the two engines (the fused path is a
    reread of the same cache, not an approximation), (b) throughput and
    compile counts per mode (decode must stay one compile either way),
    (c) each mode's AOT decode roofline with achieved (phase p50) vs
    predicted bytes/FLOPs, and (d) the *analytic* kernel-model comparison
    (``repro.roofline.kernel_model``) — the HLO cost model prices whatever
    the backend lowered (interpret-mode Pallas on CPU), so the first-
    principles model is the number that transfers to TPU: the fused path
    must predict strictly fewer HBM bytes per decode step."""
    from repro.roofline.kernel_model import (
        PagedAttnShape, compare_paged_attention,
    )
    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")

    def one_run(fused):
        eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                         layout="paged", page_size=page_size,
                         fused_attention=fused))
        _submit_workload(eng, cfg, n_requests=n_requests, seed=seed)
        done = eng.run()
        return eng, {rid: done[rid].generated_tokens for rid in done}

    out = {}
    tokens = {}
    for mode, fused in (("gather", False), ("fused", True)):
        eng, tokens[mode] = one_run(fused)
        md = eng.metrics.to_dict()
        report = engine_decode_roofline(eng)
        achieved_s = (md["phase_times"]["decode_dispatch"]["p50"]
                      + md["phase_times"]["host_sync"]["p50"])
        out[mode] = {
            "tokens_per_s": md["tokens_per_s"],
            "tokens_per_s_ex_compile": md["tokens_per_s_ex_compile"],
            "compile_counts": eng.compile_counts,
            "roofline": report.to_json(),
            "achieved_vs_predicted": achieved_vs_predicted(report,
                                                           achieved_s),
        }

    # analytic per-decode-step model at the live engine shapes (per layer)
    shape = PagedAttnShape(
        batch=n_slots, kv_heads=cfg.cache_kv_heads,
        q_per_kv=cfg.num_heads // cfg.cache_kv_heads,
        head_dim=cfg.cached_vector_dim, n_dict=N, s=s_max,
        pages_per_row=eng._max_pages, page_size=page_size)
    model = compare_paged_attention(shape)
    return {
        "same_tokens": tokens["gather"] == tokens["fused"],
        "gather": out["gather"],
        "fused": out["fused"],
        "kernel_model": model,
        "fused_predicts_fewer_bytes": (
            model["fused"]["total_bytes"] < model["gather"]["total_bytes"]),
    }


def run_omp_kernel_bench(*, n_requests: int = 12, n_slots: int = 4,
                         t_max: int = 96, seed: int = 0,
                         page_size: int = 8) -> dict:
    """Fused batched-OMP prefill-encoder scenario: the mixed workload through
    the paged engine with ``fused_omp`` off vs on vs forced-kernel.

    Reports (a) token identity across the three engines (the fused encoder
    selects the same atoms, not an approximation), (b) prefill tokens/s per
    mode from the steady-state prefill phase timer + the compressed-token
    counter (compile-dominated first-trace calls are excluded by the timer
    itself), (c) the analytic kernel-model comparison at the live encode
    shape (streamed selection must predict strictly fewer HBM bytes per OMP
    iteration than the gathered-Gram oracle), and (d) a direct CPU
    wall-clock measurement of the early-exit ``while_loop`` vs the
    always-``s_max`` ``fori_loop`` on the same tile body at ``delta > 0``,
    with the iteration-count (``nnz``) histogram that explains the win."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.omp import clear_gram_cache
    from repro.kernels.omp_encode import omp_encode_batch
    from repro.roofline.kernel_model import OMPEncodeShape, compare_omp_encode

    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")

    out, tokens = {}, {}
    for mode, over in (("off", {}),
                       ("fused", dict(fused_omp=True)),
                       ("fused_kernel", dict(fused_omp=True,
                                             fused_omp_force_kernel=True))):
        eng = ContinuousBatchingEngine(
            params, cfg, lex, bank,
            EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                         layout="paged", page_size=page_size, **over))
        _submit_workload(eng, cfg, n_requests=n_requests, seed=seed)
        done = eng.run()
        md = eng.metrics.to_dict()
        tokens[mode] = {rid: done[rid].generated_tokens for rid in done}
        prefill = md["phase_times"].get("prefill",
                                        {"count": 0, "mean": 0.0,
                                         "p50": 0.0, "p99": 0.0})
        steady_s = prefill["count"] * prefill["mean"]
        out[mode] = {
            "prefill_tokens_compressed": md["prefill_tokens_compressed"],
            "prefill_steady_calls": prefill["count"],
            "prefill_s_p50": prefill["p50"],
            "prefill_s_p99": prefill["p99"],
            # compressed positions per steady-state prefill second; the
            # first trace per bucket lands in compile_s, not here
            "prefill_tokens_per_s": (md["prefill_tokens_compressed"]
                                     / steady_s if steady_s > 0 else 0.0),
            "tokens_per_s_ex_compile": md["tokens_per_s_ex_compile"],
            "compile_counts": eng.compile_counts,
        }

    # analytic per-iteration model at the live encode shape: one layer's
    # prefill flattens (B=1, KV, T_comp) vectors per K/V dictionary
    shape = OMPEncodeShape(
        batch=cfg.cache_kv_heads * (t_max - lex.n_b),
        head_dim=cfg.cached_vector_dim, n_dict=N, s=s_max)
    model = compare_omp_encode(shape)

    # early exit vs always-s_max: same compiled body, identical outputs
    # (pinned bitwise in tests) — the win is pure wall clock, scaling with
    # how far below s_max the delta stop lands (the nnz histogram)
    rng = np.random.default_rng(seed)
    m, B, delta = cfg.cached_vector_dim, 4096, 0.55
    D = rng.normal(size=(m, N)).astype(np.float32)
    D /= np.linalg.norm(D, axis=0, keepdims=True)
    D = jnp.asarray(D)
    G = D.T @ D
    K = jnp.asarray(rng.normal(size=(B, m)), jnp.float32)
    clear_gram_cache()

    def timed(early_exit):
        run = lambda: jax.block_until_ready(omp_encode_batch(
            K, D, s_max, G=G, delta=delta, early_exit=early_exit))
        res = run()                       # compile + warm caches
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            run()
            ts.append(time.perf_counter() - t0)
        return min(ts), res

    t_early, res = timed(True)
    t_full, res_full = timed(False)
    nnz = np.asarray(res.nnz)
    same = (np.array_equal(nnz, np.asarray(res_full.nnz))
            and np.array_equal(np.asarray(res.idx), np.asarray(res_full.idx)))
    early = {
        "delta": delta,
        "batch": B,
        "s_max": s_max,
        "t_early_exit_s": t_early,
        "t_always_smax_s": t_full,
        "speedup": t_full / max(t_early, 1e-9),
        "mean_nnz": float(nnz.mean()),
        "nnz_hist": np.bincount(nnz, minlength=s_max + 1).tolist(),
        "same_result": bool(same),
    }
    return {
        "same_tokens": (tokens["fused"] == tokens["off"]
                        and tokens["fused_kernel"] == tokens["off"]),
        "same_prefill_compiles": (
            out["fused"]["compile_counts"]["prefill"]
            == out["off"]["compile_counts"]["prefill"]
            == out["fused_kernel"]["compile_counts"]["prefill"]),
        "off": out["off"],
        "fused": out["fused"],
        "fused_kernel": out["fused_kernel"],
        "kernel_model": model,
        "streamed_predicts_fewer_bytes": (
            model["streamed"]["total_bytes"]
            < model["gathered"]["total_bytes"]),
        "early_exit": early,
    }


def _router_waves(cfg, seed: int, *, n_families: int = 3,
                  n_followers: int = 12):
    """Two-wave fleet workload: wave 1 is one seeder request per system-
    prompt family (cold view — any policy spreads them), wave 2 is
    ``n_followers`` requests over the same families in *random* family
    order (so round-robin's cursor can't accidentally align with the
    family that seeded each replica). The 64-token system prompts make the
    family prefix's OMP the dominant per-request cost — exactly the regime
    where routing a follower away from its family's cache re-buys the
    whole prefix compression. Fresh Request objects every call."""
    rng = np.random.default_rng(seed)
    families = [rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
                for _ in range(n_families)]
    wave1, wave2, rid = [], [], 0
    for fam in families:
        tail = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        wave1.append(Request(rid=rid, prompt=np.concatenate([fam, tail]),
                             max_new_tokens=3, tier=16))
        rid += 1
    for _ in range(n_followers):
        fam = families[int(rng.integers(0, n_families))]
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 7))).astype(np.int32)
        wave2.append(Request(rid=rid, prompt=np.concatenate([fam, tail]),
                             max_new_tokens=int(rng.integers(3, 6)), tier=16))
        rid += 1
    return wave1, wave2


def run_router_bench(*, n_replicas: int = 3, n_slots: int = 2,
                     t_max: int = 96, seed: int = 0,
                     page_size: int = 8, warm_steps: int = 16) -> dict:
    """Multi-replica routing scenario: the same staged two-wave family
    workload through a 3-replica ``ReplicaRouter`` under each routing
    policy (rr, load, affinity), plus a solo single-engine oracle.

    Wave 1 seeds each replica's prefix cache; after ``warm_steps`` fleet
    steps the ``GlobalPrefixView`` is warm and wave 2 arrives. The headline
    claims: (a) every policy's tokens are bitwise identical to the solo
    run — routing decides *where* a request computes, never *what* (the
    dictionary is universal, each request runs on exactly one engine);
    (b) prefix-affinity routing beats round-robin on BOTH aggregate
    tokens/s (ex-compile — in-process replicas compile sequentially) and
    shared-page hit rate, because it concentrates each family on the
    replica that already caches it while rr re-runs the family prefix's
    OMP on every replica it sprays."""
    import dataclasses

    from repro.serving import ReplicaRouter

    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    engine_cfg = EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8,
                              layout="paged", page_size=page_size,
                              share_prefixes=True)

    def staged(submit, step):
        wave1, wave2 = _router_waves(cfg, seed)
        for req in wave1:
            submit(req)
        for _ in range(warm_steps):
            step()
        for req in wave2:
            submit(req)

    # solo oracle: one engine with the fleet's total slots serves everything
    solo = ContinuousBatchingEngine(
        params, cfg, lex, bank,
        dataclasses.replace(engine_cfg, n_slots=n_replicas * n_slots))
    staged(solo.submit, solo.step)
    done = solo.run()
    solo_tokens = {rid: done[rid].generated_tokens for rid in done}
    solo_stats = solo.metrics.to_dict()
    solo.prefix_index.clear(solo.allocator)

    sides, tokens = {}, {}
    for policy in ("rr", "load", "affinity"):
        router = ReplicaRouter(params, cfg, lex, bank, engine_cfg,
                               n_replicas=n_replicas, policy=policy)
        staged(router.submit, router.step)
        done = router.run()
        tokens[policy] = {rid: done[rid].generated_tokens for rid in done}
        md = router.to_dict()
        router.drain_caches()
        md["pages_balanced"] = all(eng.allocator.check_balanced()
                                   for eng in router.engines)
        sides[policy] = md

    rr, aff = sides["rr"], sides["affinity"]
    return {
        "solo": {k: solo_stats[k]
                 for k in ("tokens_per_s", "tokens_per_s_ex_compile",
                           "shared_page_hit_rate", "prefill_tokens_skipped",
                           "requests_completed")},
        "rr": rr,
        "load": sides["load"],
        "affinity": aff,
        "routing": {
            # the headline: same tokens everywhere, affinity wins both axes
            "same_tokens_vs_solo": all(tokens[p] == solo_tokens
                                       for p in sides),
            "tokens_per_s_ex_compile_rr": rr["tokens_per_s_ex_compile"],
            "tokens_per_s_ex_compile_load": (
                sides["load"]["tokens_per_s_ex_compile"]),
            "tokens_per_s_ex_compile_affinity": aff["tokens_per_s_ex_compile"],
            "affinity_speedup_vs_rr": (
                aff["tokens_per_s_ex_compile"]
                / max(rr["tokens_per_s_ex_compile"], 1e-9)),
            "shared_page_hit_rate_rr": rr["shared_page_hit_rate"],
            "shared_page_hit_rate_affinity": aff["shared_page_hit_rate"],
            "prefill_tokens_skipped_rr": rr["prefill_tokens_skipped"],
            "prefill_tokens_skipped_affinity": aff["prefill_tokens_skipped"],
            "requests_routed_affinity": aff["requests_routed"],
            "affinity_wins_throughput": bool(
                aff["tokens_per_s_ex_compile"]
                > rr["tokens_per_s_ex_compile"]),
            "affinity_wins_hit_rate": bool(
                aff["shared_page_hit_rate"] > rr["shared_page_hit_rate"]),
        },
    }


def run_layout_comparison(**kw) -> dict:
    """Same workload through both layouts + the memory/throughput deltas."""
    cont = run_serving_bench(layout="contiguous", **kw)
    paged = run_serving_bench(layout="paged", **kw)
    resident_ratio = (paged["kv_bytes_resident_peak"]
                      / max(cont["kv_bytes_resident_peak"], 1))
    return {
        "contiguous": cont,
        "paged": paged,
        "paged_vs_contiguous": {
            "kv_bytes_resident_peak_ratio": resident_ratio,
            "kv_bytes_resident_peak_saved": (cont["kv_bytes_resident_peak"]
                                             - paged["kv_bytes_resident_peak"]),
            "tokens_per_s_ratio": (paged["tokens_per_s"]
                                   / max(cont["tokens_per_s"], 1e-9)),
            "same_token_counts": (cont["tokens_generated"]
                                  == paged["tokens_generated"]),
        },
    }


def run(emit):
    """Entry point for benchmarks.run: flat name,value rows."""
    stats = run_layout_comparison()
    for layout in ("contiguous", "paged"):
        side = stats[layout]
        for key in ("tokens_per_s", "tokens_per_s_ex_compile",
                    "decode_tokens_per_step",
                    "slot_occupancy_mean", "kv_bytes_in_flight_peak",
                    "kv_bytes_resident_peak", "queue_latency_s_mean",
                    "queue_latency_s_p50", "queue_latency_s_p99",
                    "requests_completed"):
            emit(f"serving/{layout}/{key}", side[key])
        for phase in ("decode_dispatch", "host_sync"):
            summary = side["phase_times"].get(phase)
            if summary:
                emit(f"serving/{layout}/{phase}_p50", summary["p50"])
                emit(f"serving/{layout}/{phase}_p99", summary["p99"])
        emit(f"serving/{layout}/compiles_decode",
             side["compile_counts"]["decode"])
        emit(f"serving/{layout}/compiles_prefill",
             side["compile_counts"]["prefill"])
    emit("serving/paged_resident_peak_ratio",
         stats["paged_vs_contiguous"]["kv_bytes_resident_peak_ratio"])
    prefix = run_prefix_sharing_bench()
    for key, val in prefix["sharing"].items():
        emit(f"serving/prefix/{key}", float(val))
    tiering = run_swap_bench()["tiering"]
    for key, val in tiering.items():
        emit(f"serving/swap/{key}", float(val))
    routing = run_router_bench()["routing"]
    for key, val in routing.items():
        if key == "requests_routed_affinity":
            continue                      # per-replica list, not a scalar row
        emit(f"serving/router/{key}", float(val))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--t-max", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--layout", choices=["contiguous", "paged", "both"],
                    default="both")
    ap.add_argument("--scenario",
                    choices=["mix", "prefix", "swap", "obs", "fused-kernel",
                             "omp-kernel", "router", "quality", "both",
                             "all"],
                    default="mix",
                    help="mix: short/long layout comparison; prefix: many "
                         "clients sharing one system prompt (shared vs "
                         "unshared resident KV bytes); swap: oversubscribed "
                         "pool with the host-memory tier (device/host peaks, "
                         "promote stalls); obs: tracing on-vs-off overhead, "
                         "phase p50/p99, decode roofline, journal replay; "
                         "fused-kernel: paged engine with fused sparse-"
                         "attention off vs on (token identity, rooflines, "
                         "analytic bytes model); omp-kernel: fused OMP "
                         "prefill encoder off vs on vs forced-kernel "
                         "(token identity, prefill tokens/s, streamed-vs-"
                         "gathered bytes model, early-exit wall clock); "
                         "router: 3-replica fleet, rr vs load vs affinity "
                         "routing (token identity vs a solo engine; affinity "
                         "must win tokens/s AND hit rate — exit non-zero "
                         "otherwise, the CI gate); "
                         "quality: compression-quality telemetry off vs on "
                         "(token identity, overhead, drift vs a frozen "
                         "baseline, page_quality journal replay, tolerance "
                         "harness — the quality-gate CI job); "
                         "both: mix+prefix; all: everything")
    ap.add_argument("--repeats", type=int, default=2,
                    help="obs scenario: runs per mode (overhead = best-of)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="obs scenario: write the Perfetto trace JSON here")
    ap.add_argument("--journal", metavar="PATH", default=None,
                    help="obs scenario: write the lifecycle journal (JSONL)")
    ap.add_argument("--metrics-snapshot", metavar="PATH", default=None,
                    help="obs scenario: write a Prometheus text snapshot")
    ap.add_argument("--overhead-budget", type=float, default=None,
                    help="obs/quality scenarios: exit non-zero if measured "
                         "recording overhead exceeds this fraction "
                         "(CI gate: 0.02)")
    ap.add_argument("--drift-budget", type=float, default=0.25,
                    help="quality scenario: exit non-zero if the drift "
                         "score of the calibration-like rerun exceeds this "
                         "(the workload hasn't changed, so the score must "
                         "be ~0 up to sampling noise)")
    ap.add_argument("--json-only", action="store_true")
    args = ap.parse_args()
    kw = dict(n_requests=args.n_requests, n_slots=args.n_slots,
              t_max=args.t_max, seed=args.seed, page_size=args.page_size)
    stats = {}
    if args.scenario in ("mix", "both", "all"):
        if args.layout == "both":
            stats["mix"] = run_layout_comparison(**kw)
        else:
            stats["mix"] = run_serving_bench(layout=args.layout, **kw)
    if args.scenario in ("prefix", "both", "all"):
        stats["prefix"] = run_prefix_sharing_bench(**kw)
    if args.scenario in ("swap", "all"):
        stats["swap"] = run_swap_bench(
            n_slots=args.n_slots, t_max=args.t_max, seed=args.seed,
            page_size=args.page_size)
    if args.scenario in ("fused-kernel", "all"):
        stats["fused_kernel"] = run_fused_kernel_bench(**kw)
    if args.scenario in ("omp-kernel", "all"):
        stats["omp_kernel"] = run_omp_kernel_bench(**kw)
    if args.scenario in ("obs", "all"):
        stats["obs"] = run_obs_bench(
            n_requests=args.n_requests, n_slots=args.n_slots,
            t_max=args.t_max, seed=args.seed, page_size=args.page_size,
            repeats=args.repeats, trace_path=args.trace,
            journal_path=args.journal, metrics_path=args.metrics_snapshot)
    if args.scenario in ("router", "all"):
        stats["router"] = run_router_bench(
            t_max=args.t_max, seed=args.seed, page_size=args.page_size)
    if args.scenario in ("quality", "all"):
        stats["quality"] = run_quality_bench(
            n_requests=args.n_requests, n_slots=args.n_slots,
            t_max=args.t_max, seed=args.seed, page_size=args.page_size,
            repeats=args.repeats, journal_path=args.journal)
    if len(stats) == 1:
        stats = next(iter(stats.values()))
    print(json.dumps(stats, indent=2, default=float))
    router_stats = stats.get("router", stats)
    if "routing" in router_stats:
        routing = router_stats["routing"]
        failures = [claim for claim in ("same_tokens_vs_solo",
                                        "affinity_wins_throughput",
                                        "affinity_wins_hit_rate")
                    if not routing[claim]]
        if failures:
            print(f"router scenario FAILED: {failures}", file=sys.stderr)
            sys.exit(1)
    obs_stats = stats.get("obs", stats)
    if (args.overhead_budget is not None
            and "tracing_overhead" in obs_stats):
        if obs_stats["journal_violations"]:
            print(f"journal replay FAILED: {obs_stats['journal_violations']}",
                  file=sys.stderr)
            sys.exit(1)
        if obs_stats["tracing_overhead"] > args.overhead_budget:
            print(f"tracing overhead {obs_stats['tracing_overhead']:.4f} "
                  f"exceeds budget {args.overhead_budget:.4f}",
                  file=sys.stderr)
            sys.exit(1)
    quality_stats = stats.get("quality", stats)
    if "quality_overhead" in quality_stats:
        failures = []
        if not quality_stats["same_tokens"]:
            failures.append("same_tokens")
        if not quality_stats["decode_one_compile"]:
            failures.append("decode_one_compile")
        if quality_stats["journal_violations"]:
            failures.append(
                f"journal replay: {quality_stats['journal_violations']}")
        if not quality_stats["tolerance"]["lossless_ok"]:
            failures.append("tolerance gate rejected the lossless rerun")
        if not quality_stats["tolerance"]["lossy_flagged"]:
            failures.append("tolerance gate missed the int8 requantization")
        if quality_stats["drift_score"] > args.drift_budget:
            failures.append(
                f"drift {quality_stats['drift_score']:.4f} exceeds "
                f"budget {args.drift_budget:.4f}")
        if (args.overhead_budget is not None
                and quality_stats["quality_overhead"] > args.overhead_budget):
            failures.append(
                f"quality overhead {quality_stats['quality_overhead']:.4f} "
                f"exceeds budget {args.overhead_budget:.4f}")
        if failures:
            print(f"quality scenario FAILED: {failures}", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
