"""Serving-throughput benchmark: the continuous-batching engine under load.

Emits a JSON document (stdout, plus ``name,value`` CSV rows when driven by
``benchmarks.run``) with decode tokens/s, per-step batch efficiency, slot
occupancy, KV-bytes-in-flight (paper 3s+2 accounting), and queue latency —
the numbers that track whether the serving stack is getting faster and
denser over the bench trajectory.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--json-only]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), '..'))

import numpy as np

from benchmarks.common import BENCH_CFG, trained_params
from benchmarks.memory_fidelity import trained_bank
from repro.configs.base import LexicoConfig
from repro.serving import ContinuousBatchingEngine, EngineConfig, Request


def run_serving_bench(*, n_requests: int = 12, n_slots: int = 4,
                      t_max: int = 96, seed: int = 0) -> dict:
    cfg = BENCH_CFG
    params, _ = trained_params()
    N, s_max = 192, 16
    bank = trained_bank(params, cfg, N, s_max)
    lex = LexicoConfig(N=N, s=s_max, n_b=4, chunk=None, codec="fp8")
    eng = ContinuousBatchingEngine(
        params, cfg, lex, bank,
        EngineConfig(n_slots=n_slots, t_max=t_max, min_bucket=8))

    rng = np.random.default_rng(seed)
    for rid in range(n_requests):
        prompt_len = int(rng.integers(9, 64))
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32),
            max_new_tokens=int(rng.integers(4, 16)),
            tier=int(rng.choice([2, 4, 8, 16]))))

    done = eng.run()
    stats = eng.metrics.to_dict()
    stats.update(
        n_requests=n_requests,
        n_slots=n_slots,
        completed=len(done),
        compile_counts=eng.compile_counts,
    )
    return stats


def run(emit):
    """Entry point for benchmarks.run: flat name,value rows."""
    stats = run_serving_bench()
    for key in ("tokens_per_s", "decode_tokens_per_step",
                "slot_occupancy_mean", "kv_bytes_in_flight_peak",
                "queue_latency_s_mean", "requests_completed"):
        emit(f"serving/{key}", stats[key])
    emit("serving/compiles_decode", stats["compile_counts"]["decode"])
    emit("serving/compiles_prefill", stats["compile_counts"]["prefill"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--t-max", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-only", action="store_true")
    args = ap.parse_args()
    stats = run_serving_bench(n_requests=args.n_requests, n_slots=args.n_slots,
                              t_max=args.t_max, seed=args.seed)
    print(json.dumps(stats, indent=2, default=float))


if __name__ == "__main__":
    main()
