"""Table 5 + §4.2.3: balancing memory between the recency buffer and sparse
codes at a fixed total KV budget — and the no-buffer degradation (Figure 7).
The paper's claim: neither extreme wins; intermediate (s, n_b) splits are
best, and removing the buffer entirely hurts sharply at low KV sizes."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, trained_params
from benchmarks.memory_fidelity import rollout_fidelity, trained_bank
from repro.configs.base import LexicoConfig
from repro.models.cache_policy import LexicoPolicy
from repro.data.synthetic import SyntheticCorpus


def run(emit):
    cfg = BENCH_CFG
    params, _ = trained_params()
    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    tokens = jnp.asarray(corpus.sample(4, 48, seed=555), jnp.int32)
    Tp, m, N = 32, cfg.hd, 192
    bank = trained_bank(params, cfg, N, 16)

    # fixed budget ~= 25% of full: trade buffer slots for sparsity
    combos = [(1, 16), (4, 12), (8, 8), (14, 2)]
    scores = {}
    for s, n_b in combos:
        lex = LexicoConfig(N=N, s=s, n_b=max(n_b, 1), chunk=None, codec="fp8")
        a, d = rollout_fidelity(cfg, params, LexicoPolicy(lex), bank, tokens, Tp)
        scores[(s, n_b)] = a
        emit(f"buffer_balance/s{s}_nb{n_b}/top1_agree", a)
        emit(f"buffer_balance/s{s}_nb{n_b}/mean_dlogit", d)
    best = max(scores, key=scores.get)
    emit("buffer_balance/best_is_intermediate",
         float(best not in [combos[0], combos[-1]]))

    # no-buffer ablation (Figure 7): same s, n_b -> 1 (minimum ring slot)
    for s in (4, 8):
        with_buf = scores.get((s, 12 if s == 4 else 8))
        lex = LexicoConfig(N=N, s=s, n_b=1, chunk=None, codec="fp8")
        a_nb, _ = rollout_fidelity(cfg, params, LexicoPolicy(lex), bank, tokens, Tp)
        emit(f"buffer_balance/no_buffer_s{s}/top1_agree", a_nb)
        if with_buf is not None:
            emit(f"buffer_balance/no_buffer_s{s}/buffer_helps",
                 float(with_buf >= a_nb - 0.02))
