"""Shared benchmark utilities: a small trained-ish model + KV harvesting.

Fidelity benchmarks need KV vectors with real structure. We train a ~1-2M
param llama-style model for a few hundred steps on the synthetic corpus
(fast on CPU), then harvest its KV cache on held-out batches — playing the
role the paper's Llama-3.1-8B + WikiText-103 play. Different corpus seeds
(different topic structure) stand in for the out-of-domain datasets of
Table 1 (CNN/DailyMail, IMDB, TweetEval).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.configs.base import ModelConfig
from repro.data.synthetic import SyntheticCorpus
from repro.models import model as M
from repro.optim import adamw_tree_init, adamw_tree_update, clip_by_global_norm

BENCH_CFG = ModelConfig(
    name="bench-llama", family="dense",
    num_layers=4, d_model=128, num_heads=8, num_kv_heads=4,
    d_ff=384, vocab_size=512, tie_embeddings=True, param_dtype="float32",
)

_CACHE = {}


def trained_params(cfg: ModelConfig = BENCH_CFG, *, steps: int = 120,
                   seed: int = 0, lr: float = 3e-3):
    key = (cfg.name, steps, seed)
    if key in _CACHE:
        return _CACHE[key]
    corpus = SyntheticCorpus(cfg.vocab_size, seed=seed)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    opt = adamw_tree_init(params)

    @jax.jit
    def step(params, opt, tokens):
        def loss_fn(p):
            return M.lm_loss(p, cfg, {"tokens": tokens, "labels": tokens})
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_tree_update(params, grads, opt, lr=lr)
        return params, opt, loss

    losses = []
    for i in range(steps):
        tokens = jnp.asarray(corpus.sample(8, 64, seed=i), jnp.int32)
        params, opt, loss = step(params, opt, tokens)
        losses.append(float(loss))
    _CACHE[key] = (params, losses)
    return params, losses


def harvest_kv(params, cfg: ModelConfig, *, corpus_seed: int = 0, batches: int = 2,
               B: int = 4, T: int = 64):
    """Run the model and collect post-RoPE K/V vectors per layer.

    Returns (L, 2, n_vectors, hd) float32 — axis 1 is (K, V).
    """
    corpus = SyntheticCorpus(cfg.vocab_size, seed=corpus_seed)
    from repro.models.model import _embed_tokens, _window_arr, layer_seq

    collected = None
    for b in range(batches):
        tokens = jnp.asarray(corpus.sample(B, T, seed=1000 + b), jnp.int32)
        x = _embed_tokens(params, cfg, tokens)
        positions = jnp.arange(T)

        def body(h, lp):
            h, kv, _, _ = layer_seq(lp, cfg, h, positions, None)
            k, v = kv   # (B, KV, T, hd)
            flat = jnp.stack([k.reshape(-1, k.shape[-1]),
                              v.reshape(-1, v.shape[-1])])
            return h, flat

        _, kvs = jax.lax.scan(body, x, params["layers"])   # (L, 2, n, hd)
        kvs = np.asarray(kvs, np.float32)
        collected = kvs if collected is None else np.concatenate(
            [collected, kvs], axis=2)
    return collected


def timer(fn, *args, repeats: int = 5, warmup: int = 2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)  # us
