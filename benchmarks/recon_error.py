"""Table 1: relative reconstruction error — Lexico-trained dictionary vs
sparse autoencoder vs random dictionary, on in-domain and out-of-domain
corpora (synthetic stand-ins; see benchmarks/common.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, harvest_kv, trained_params
from repro.core.dict_learning import (
    dict_train_init, dict_train_step, relative_error,
)
from repro.core.dictionary import init_dictionary, normalize_atoms


def train_sae(K_train, N, s, steps=200, lr=1e-2, seed=0):
    """Two-layer perceptron with hard top-k activation (the paper's SAE
    baseline): encoder W_e, decoder D; top-k on the code. Encoder is
    initialised as the decoder transpose (standard SAE practice)."""
    m = K_train.shape[-1]
    key = jax.random.PRNGKey(seed)
    D = init_dictionary(jax.random.fold_in(key, 1), m, N)
    params = {"W_e": D * 3.0, "D": D}

    def loss_fn(p, X):
        code = X @ p["W_e"]                                   # (B, N)
        kth = jax.lax.top_k(jax.lax.stop_gradient(jnp.abs(code)), s)[0][:, -1:]
        code = jnp.where(jnp.abs(code) >= kth, code, 0.0)
        rec = code @ p["D"].T
        return jnp.mean(jnp.sum((X - rec) ** 2, axis=-1))

    from repro.optim import adamw_tree_init, adamw_tree_update
    opt = adamw_tree_init(params)
    step = jax.jit(lambda p, o, X: _sae_step(p, o, X, loss_fn, lr))
    for i in range(steps):
        params, opt, _ = step(params, opt, K_train)
    return params


def _sae_step(p, o, X, loss_fn, lr):
    from repro.optim import adamw_tree_update
    loss, grads = jax.value_and_grad(loss_fn)(p, X)
    p, o = adamw_tree_update(p, grads, o, lr=lr)
    return p, o, loss


def sae_error(p, X, s):
    code = X @ p["W_e"]
    thresh = jnp.sort(jnp.abs(code), axis=-1)[:, -s][:, None]
    code = jnp.where(jnp.abs(code) >= thresh, code, 0.0)
    rec = code @ p["D"].T
    return jnp.linalg.norm(X - rec, axis=-1) / (jnp.linalg.norm(X, axis=-1) + 1e-12)


def run(emit):
    N, s = 192, 8
    params, _ = trained_params()
    kv_in = harvest_kv(params, BENCH_CFG, corpus_seed=0)       # in-domain
    layer = 1
    K_train = jnp.asarray(kv_in[layer, 0][:384])
    held = {
        "in-domain": jnp.asarray(kv_in[layer, 0][384:512]),
        "ood-A": jnp.asarray(harvest_kv(params, BENCH_CFG, corpus_seed=7)[layer, 0][:128]),
        "ood-B": jnp.asarray(harvest_kv(params, BENCH_CFG, corpus_seed=13)[layer, 0][:128]),
    }

    # Lexico dictionary (OMP-in-the-loop training)
    state = dict_train_init(init_dictionary(jax.random.PRNGKey(0), K_train.shape[-1], N))
    for i in range(50):
        state, m = dict_train_step(state, K_train, s=s, base_lr=3e-3, lr_schedule_len=50)

    sae = train_sae(K_train, N, s)
    D_rand = init_dictionary(jax.random.PRNGKey(99), K_train.shape[-1], N)

    for name, X in held.items():
        e_lex = float(jnp.mean(relative_error(state.D, X, s)))
        e_sae = float(jnp.mean(sae_error(sae, X, s)))
        e_rand = float(jnp.mean(relative_error(D_rand, X, s)))
        emit(f"recon_error/{name}/lexico", e_lex)
        emit(f"recon_error/{name}/sae", e_sae)
        emit(f"recon_error/{name}/random", e_rand)
        # the paper's ordering: lexico < sae < random (Table 1)
        emit(f"recon_error/{name}/lexico_beats_random", float(e_lex < e_rand))
