"""Table 4: δ-threshold early termination — KV size shrinks monotonically as
δ grows, and the achieved relative error respects the threshold."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CFG, harvest_kv, trained_params
from repro.core.dict_learning import dict_train_init, dict_train_step
from repro.core.dictionary import init_dictionary
from repro.core.omp import omp_batch
from repro.core.quant import payload_bytes


def run(emit):
    cfg = BENCH_CFG
    params, _ = trained_params()
    kv = harvest_kv(params, cfg, corpus_seed=0)
    X = jnp.asarray(kv[1, 0][:256])
    N, s_max = 192, 16
    state = dict_train_init(init_dictionary(jax.random.PRNGKey(0), cfg.hd, N))
    for i in range(40):
        state, _ = dict_train_step(state, X, s=8, base_lr=3e-3, lr_schedule_len=40)
    X_test = jnp.asarray(kv[1, 0][256:384])

    prev_size = None
    for delta in (0.2, 0.3, 0.4, 0.5):
        res = omp_batch(X_test, state.D, s_max, delta=delta)
        nnz = np.asarray(res.nnz, np.float64)
        rel = np.sqrt(np.asarray(res.resid2)) / np.linalg.norm(np.asarray(X_test), axis=-1)
        mean_s = float(nnz.mean())
        # effective KV size using the paper's 3s+2 law with the *mean* nnz
        size = 100 * (1 * mean_s + 2 * mean_s + 2) / (2 * cfg.hd)
        emit(f"threshold/delta{delta}/mean_nnz", mean_s)
        emit(f"threshold/delta{delta}/kv_pct", size)
        emit(f"threshold/delta{delta}/mean_rel_err", float(rel.mean()))
        met = (rel <= delta + 1e-4) | (nnz == s_max)
        emit(f"threshold/delta{delta}/threshold_respected", float(met.mean()))
        if prev_size is not None:
            emit(f"threshold/delta{delta}/size_monotone", float(size <= prev_size + 1e-6))
        prev_size = size
