"""Benchmark harness — one module per paper table/figure.

Prints ``name,value`` CSV rows; ``python -m benchmarks.run [--only X]``.
Roofline numbers (§Roofline) come from the dry-run
(``python -m repro.launch.dryrun --sweep``), not from here: this file covers
the paper's *algorithmic* tables on CPU-sized models.
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = [
    ("recon_error", "Table 1: dictionary reconstruction error"),
    ("memory_fidelity", "Tables 2-3 / Fig 1: KV size vs fidelity vs baselines"),
    ("threshold_ablation", "Table 4: delta-threshold early termination"),
    ("buffer_balance", "Table 5 + Fig 7: buffer/sparsity balance, no-buffer"),
    ("adaptive_dict", "Table 6 / 4.2.4: adaptive dictionary growth"),
    ("latency", "Table 7: forward vs OMP latency decomposition"),
    ("serving_throughput", "Beyond-paper: continuous-batching engine load"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def emit(name, value):
        rows.append((name, value))
        print(f"{name},{value}", flush=True)

    import jax
    for mod_name, desc in MODULES:
        if only and mod_name not in only:
            continue
        print(f"# === {mod_name}: {desc} ===", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        mod.run(emit)
        jax.clear_caches()   # each module compiles many shapes; cap host RSS
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)

    claims = [(n, v) for n, v in rows if "claim" in n or "beats" in n
              or "monotone" in n or "respected" in n or "helps" in n
              or "improves" in n or "best_is" in n]
    bad = [(n, v) for n, v in claims if float(v) != 1.0]
    print(f"# claims checked: {len(claims)}, violated: {len(bad)}")
    for n, v in bad:
        print(f"# VIOLATED: {n} = {v}")


if __name__ == "__main__":
    main()
