"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.
All kernels run in interpret=True on CPU (the TPU path shares the body).
hypothesis is optional — property tests skip when it isn't installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import given, settings, st

from repro.kernels import ref
from repro.kernels.omp_corr import omp_corr_argmax
from repro.kernels.sparse_scores import sparse_scores
from repro.kernels.sparse_values import sparse_values
from tests.conftest import make_unit_dict


@pytest.mark.parametrize("T,s,N,blk", [(64, 8, 256, 16), (128, 4, 512, 32),
                                       (32, 16, 128, 32), (96, 8, 256, 32)])
@pytest.mark.parametrize("vdtype", [jnp.float32, jnp.bfloat16, jnp.float8_e4m3fn])
@pytest.mark.parametrize("idtype", [jnp.int32, jnp.int16])
def test_sparse_scores_sweep(rng, T, s, N, blk, vdtype, idtype):
    qd = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(T, s)), jnp.float32).astype(vdtype)
    idx = jnp.asarray(rng.integers(0, N, (T, s)), idtype)
    out = sparse_scores(qd, vals, idx, block_t=blk, interpret=True)
    exp = ref.sparse_scores_ref(qd, vals, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


@pytest.mark.parametrize("T,s,N,blk", [(64, 8, 256, 16), (32, 16, 128, 32)])
@pytest.mark.parametrize("vdtype", [jnp.float32, jnp.float8_e4m3fn])
def test_sparse_values_sweep(rng, T, s, N, blk, vdtype):
    probs = jnp.asarray(rng.random(T), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(T, s)), jnp.float32).astype(vdtype)
    idx = jnp.asarray(rng.integers(0, N, (T, s)), jnp.int16)
    out = sparse_values(probs, vals, idx, N=N, block_t=blk, interpret=True)
    exp = ref.sparse_values_ref(probs, vals, idx, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


@pytest.mark.parametrize("B,m,N,bb,bn", [(16, 32, 256, 8, 64), (8, 16, 128, 8, 128),
                                         (32, 64, 512, 16, 256)])
def test_omp_corr_sweep(rng, B, m, N, bb, bn):
    D = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    r = jnp.asarray(rng.normal(size=(B, m)), jnp.float32)
    sel = jnp.zeros((B, N), bool)
    sel = sel.at[:, rng.integers(0, N, 3)].set(True)
    arg, mx = omp_corr_argmax(r, D, sel, block_b=bb, block_n=bn, interpret=True)
    rarg, rmx = ref.omp_corr_ref(D, r, sel)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(rarg))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx), rtol=1e-6)


@pytest.mark.parametrize("B,N,bb,bn", [(13, 72, 8, 32), (21, 100, 16, 64),
                                       (5, 33, 8, 16), (1, 17, 4, 16)])
def test_omp_corr_ragged(rng, B, N, bb, bn):
    """B and N that don't divide the block sizes: pad rows are sliced off,
    pad atoms stream through as selected and can never win."""
    m = 12
    D = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    r = jnp.asarray(rng.normal(size=(B, m)), jnp.float32)
    sel = jnp.zeros((B, N), bool)
    sel = sel.at[:, rng.integers(0, N, 3)].set(True)
    arg, mx = omp_corr_argmax(r, D, sel, block_b=bb, block_n=bn, interpret=True)
    assert arg.shape == mx.shape == (B,)
    rarg, rmx = ref.omp_corr_ref(D, r, sel)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(rarg))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), T=st.sampled_from([16, 48, 64]),
       s=st.sampled_from([2, 8]))
def test_scores_property(seed, T, s):
    """Kernel == oracle for random shapes; scores are linear in vals."""
    rng = np.random.default_rng(seed)
    N = 128
    qd = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(T, s)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, N, (T, s)), jnp.int32)
    out = sparse_scores(qd, vals, idx, block_t=16, interpret=True)
    exp = ref.sparse_scores_ref(qd, vals, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)
    out2 = sparse_scores(qd, 2.0 * vals, idx, block_t=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out2), 2 * np.asarray(out), atol=1e-4)


def test_values_mass_conservation(rng):
    """sum_n c[n] == sum_t probs[t] * sum_j vals[t,j]."""
    T, s, N = 64, 8, 256
    probs = jnp.asarray(rng.random(T), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(T, s)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, N, (T, s)), jnp.int32)
    c = sparse_values(probs, vals, idx, N=N, block_t=16, interpret=True)
    lhs = float(jnp.sum(c))
    rhs = float(jnp.sum(probs[:, None] * vals))
    assert abs(lhs - rhs) < 1e-3
