"""Pin the memory-accounting numbers and codec paths that gate admission.

The serving engine's byte-budget admission controller trusts
``paper_kv_bytes`` / ``kv_size_percent`` / ``request_kv_bytes`` exactly, and
the stores it packs go through ``_encode_store`` — so these are contract
tests, not smoke tests: the numbers are pinned to the paper's 3s+2 law.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant, sparse_cache
from repro.core.sparse_cache import (
    _encode_store, array_bytes, init_layer_cache, init_paged_layer_cache,
    kv_size_percent, page_store_bytes, paper_kv_bytes, slot_resident_bytes,
)
from repro.serving.scheduler import (
    request_kv_bytes, request_kv_bytes_paged, request_page_count,
)


def test_paper_kv_bytes_law():
    # per (head, K+V pair): 2 * (t_c * (3s+2) + n_b * m * fp_bytes)
    assert paper_kv_bytes(t_c=1000, n_b=128, s=16, m=128) == \
        2 * (1000 * 50 + 128 * 128 * 2)
    # fp16 codec: 4s+2 per vector
    assert paper_kv_bytes(t_c=10, n_b=0, s=8, m=128, codec="fp16") == \
        2 * 10 * (4 * 8 + 2)
    # int8 codec matches fp8 payload (1 byte/value)
    assert paper_kv_bytes(t_c=10, n_b=0, s=8, m=128, codec="int8") == \
        paper_kv_bytes(t_c=10, n_b=0, s=8, m=128, codec="fp8")
    # buffer-only cache is exactly the dense footprint
    assert paper_kv_bytes(t_c=0, n_b=64, s=16, m=128) == 2 * 64 * 128 * 2


def test_kv_size_percent_asymptote():
    # long-context limit -> payload/(2m) = (3s+2)/(2*128) = 19.53% at s=16
    pct = kv_size_percent(t_c=10**7, n_b=128, s=16, m=128)
    assert abs(pct - 100 * 50 / 256) < 0.01
    # all-buffer cache is 100% of dense
    assert kv_size_percent(t_c=0, n_b=128, s=16, m=128) == pytest.approx(100.0)


def test_kv_size_percent_empty_cache():
    """t_c + n_b == 0 (a freshly cleared serving slot) must report 0%, not
    raise ZeroDivisionError."""
    assert kv_size_percent(t_c=0, n_b=0, s=16, m=128) == 0.0
    # every codec path hits the same guard
    for codec in ("fp8", "int8", "fp16"):
        assert kv_size_percent(t_c=0, n_b=0, s=8, m=64, codec=codec) == 0.0


def test_request_kv_bytes_composition():
    # model total = L * KV * per-head-pair bytes, buffer clamped to total
    per_head = paper_kv_bytes(26, 4, 8, 16)
    assert request_kv_bytes(30, tier=8, n_b=4, m=16,
                            num_layers=3, kv_heads=2) == 3 * 2 * per_head
    # shorter than the buffer: nothing compressed
    assert request_kv_bytes(3, tier=8, n_b=4, m=16,
                            num_layers=1, kv_heads=1) == \
        paper_kv_bytes(0, 3, 8, 16)


def test_array_bytes_padded_layout():
    cache = init_layer_cache(2, 3, 16, t_max=32, n_b=4, s=8)
    # fp8 vals (1B) + int16 idx (2B) for K and V + two bf16 buffers
    expect = (2 * 3 * 32 * 8) * (1 + 2) * 2 + (2 * 3 * 4 * 16) * 2 * 2
    assert array_bytes(cache) == expect
    # paper accounting is strictly smaller than the padded layout at low fill
    assert paper_kv_bytes(4, 4, 8, 16) * 2 * 3 < array_bytes(cache)


def test_paged_request_accounting():
    """Paged admission charges whole pages: the compressed span rounds up to
    page multiples, the buffer stays page-free, and the page count matches
    what the engine's lazy growth will actually allocate."""
    # 26 compressed positions at page_size 8 -> 4 pages (ceil)
    assert request_page_count(30, n_b=4, page_size=8) == 4
    assert request_page_count(4, n_b=4, page_size=8) == 0   # buffer-only
    assert request_kv_bytes_paged(30, tier=8, n_b=4, m=16, num_layers=3,
                                  kv_heads=2, page_size=8) == \
        3 * 2 * paper_kv_bytes(32, 4, 8, 16)
    # page-aligned span: paged == exact paper accounting
    assert request_kv_bytes_paged(36, tier=8, n_b=4, m=16, num_layers=3,
                                  kv_heads=2, page_size=8) == \
        request_kv_bytes(36, tier=8, n_b=4, m=16, num_layers=3, kv_heads=2)
    # fragmentation overhead is bounded by one page per request
    frag = (request_kv_bytes_paged(30, tier=8, n_b=4, m=16, num_layers=1,
                                   kv_heads=1, page_size=8)
            - request_kv_bytes(30, tier=8, n_b=4, m=16, num_layers=1,
                               kv_heads=1))
    assert 0 < frag <= paper_kv_bytes(8, 0, 8, 16)


def test_paged_pool_array_bytes():
    """The shared pool's device footprint is n_pages * page bytes + tables +
    buffers — independent of how many slots exist or how full they are."""
    cache = init_paged_layer_cache(2, 3, 16, n_pages=10, page_size=4,
                                   max_pages=8, n_b=4, s=8)
    pool_bytes = 10 * page_store_bytes(3, 4, 8)          # fp8 vals + int16 idx
    buf_bytes = 2 * (2 * 3 * 4 * 16) * 2                 # two bf16 ring buffers
    table_bytes = 2 * 8 * 4
    assert array_bytes(cache) == pool_bytes + buf_bytes + table_bytes
    # per-page store bytes: K+V, vals (1B) + idx (2B) per coefficient
    assert page_store_bytes(3, 4, 8) == 2 * 3 * 4 * 8 * 3


def test_slot_resident_bytes_tracks_pages():
    one_page = slot_resident_bytes(1, kv_heads=2, page_size=4, s=8, n_b=4, m=16)
    two_pages = slot_resident_bytes(2, kv_heads=2, page_size=4, s=8, n_b=4, m=16)
    assert two_pages - one_page == page_store_bytes(2, 4, 8)
    # zero pages = just the ring buffers
    assert slot_resident_bytes(0, kv_heads=2, page_size=4, s=8, n_b=4, m=16) \
        == 2 * 2 * 4 * 16 * 2


def test_payload_bytes_codecs():
    assert quant.payload_bytes(16, "fp8") == 3 * 16 + 2
    assert quant.payload_bytes(16, "int8") == 3 * 16 + 2
    assert quant.payload_bytes(16, "fp16") == 4 * 16 + 2
    with pytest.raises(KeyError):
        quant.payload_bytes(16, "fp4")


def test_encode_store_fp8_and_fp16(rng):
    vals = jnp.asarray(rng.normal(size=(2, 3, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, (2, 3, 8)), jnp.int32)
    v8, i8 = _encode_store(vals, idx, jnp.float8_e4m3fn)
    assert v8.dtype == jnp.float8_e4m3fn and i8.dtype == jnp.int16
    # fp8 e4m3 keeps ~2 decimal digits around 1.0
    np.testing.assert_allclose(np.asarray(v8, np.float32), np.asarray(vals),
                               atol=0.25, rtol=0.07)
    v16, i16 = _encode_store(vals, idx, jnp.bfloat16)
    assert v16.dtype == jnp.bfloat16 and i16.dtype == jnp.int16


def test_encode_store_int8_branch(rng):
    """The int8 branch quantizes through quant.encode_int8: int8 codes on the
    [-127, 127] grid with the per-vector scale folded out of the store (the
    benchmark path carries the scale via quant.encode directly)."""
    vals = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, (4, 8)), jnp.int32)
    v, i = _encode_store(vals, idx, jnp.int8)
    assert v.dtype == jnp.int8 and i.dtype == jnp.int16
    arr = np.asarray(v, np.int32)
    assert arr.min() >= -127 and arr.max() <= 127
    # codes match the reference codec exactly
    code = quant.encode_int8(vals, idx)
    np.testing.assert_array_equal(arr, np.asarray(code.vals, np.int32))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(code.idx))
    # each row's max-magnitude value hits the edge of the grid (scale = amax/127)
    assert np.all(np.abs(arr).max(axis=-1) == 127)
    # decode with the codec's scale round-trips to ~1% of the row max
    deq = np.asarray(quant.decode_vals(code))
    err = np.abs(deq - np.asarray(vals)).max(axis=-1)
    assert np.all(err <= np.abs(np.asarray(vals)).max(axis=-1) / 127 + 1e-6)


def test_int8_cache_end_to_end(rng):
    """init_layer_cache with the int8 codec stores int8 through prefill."""
    from tests.conftest import make_unit_dict
    D = jnp.asarray(make_unit_dict(rng, 16, 64), jnp.float32)
    cache = init_layer_cache(1, 1, 16, t_max=16, n_b=2, s=4,
                             val_dtype=jnp.int8)
    K = jnp.asarray(rng.normal(size=(1, 1, 6, 16)), jnp.float32)
    cache = sparse_cache.prefill_compress(cache, K, K, D, D, s=4)
    assert cache.k_vals.dtype == jnp.int8
    assert int(cache.t_c[0]) == 4


# ---------------------------------------------------------------------------
# tiered storage: two-tier byte accounting
# ---------------------------------------------------------------------------

def _page_arrays(num_layers, kv_heads, page_size, s):
    """Numpy arrays shaped/typed like one extracted pool page (fp8 values
    stand in as int8 here — same 1-byte width the accounting assumes)."""
    shape = (num_layers, 1, kv_heads, page_size, s)
    return (np.zeros(shape, np.int8), np.zeros(shape, np.int16),
            np.zeros(shape, np.int8), np.zeros(shape, np.int16))


def test_host_store_bytes_match_page_store_bytes():
    """One demoted page's host bytes == num_layers * page_store_bytes: the
    exact amount kv_bytes_resident stops counting device-side, so a
    demotion moves bytes between the tiers without creating or losing
    any."""
    from repro.serving import HostPageStore
    L, KV, P, S = 3, 2, 4, 8
    h = HostPageStore()
    handle = h.put(_page_arrays(L, KV, P, S), refs=1)
    assert h.bytes_resident == L * page_store_bytes(KV, P, S)
    # a second page doubles it; dropping each returns exactly its share
    other = h.put(_page_arrays(L, KV, P, S), refs=2)
    assert h.bytes_resident == 2 * L * page_store_bytes(KV, P, S)
    h.pop(handle)
    assert h.bytes_resident == L * page_store_bytes(KV, P, S)
    assert not h.decref(other) and h.bytes_resident > 0   # still one holder
    assert h.decref(other)
    assert h.bytes_resident == 0 and h.check_balanced()


def test_two_tier_accounting_conserves_bytes():
    """A demote→promote round trip through the allocator + host store moves
    one page's bytes host-ward and back; the two-tier total is constant and
    nothing is double-counted at any point."""
    from repro.serving import HostPageStore, PageAllocator
    L, KV, P, S = 2, 2, 4, 8
    page_b = L * page_store_bytes(KV, P, S)
    alloc = PageAllocator(6, P)
    host = HostPageStore()
    pages = alloc.alloc(3)

    def device_bytes():
        return alloc.n_used * page_b

    total = device_bytes() + host.bytes_resident
    assert total == 3 * page_b

    refs = alloc.demote(pages[0])
    handle = host.put(_page_arrays(L, KV, P, S), refs=refs)
    assert device_bytes() == 2 * page_b           # device view dropped one
    assert host.bytes_resident == page_b          # host view gained the same
    assert device_bytes() + host.bytes_resident == total

    _, refs = host.pop(handle)
    alloc.promote(refs)
    assert host.bytes_resident == 0
    assert device_bytes() + host.bytes_resident == total


def test_engine_kv_bytes_resident_is_device_only():
    """The engine-facing contract (pinned here at the formula level; the
    live-engine version is tests/test_swap.py): a slot holding one device
    page and one swapped page contributes one page to kv_bytes_resident and
    one page to host_bytes_resident."""
    from repro.serving import HostPageStore, SlotInfo
    from repro.serving.scheduler import Request as Req
    h = HostPageStore()
    handle = h.put(_page_arrays(2, 2, 4, 8), refs=1)
    info = SlotInfo(request=Req(rid=0, prompt=np.zeros(4, np.int32),
                                max_new_tokens=1, tier=4),
                    fed=4, pages=[3, handle])
    assert info.device_pages == [3]
    assert info.swapped_pages == [handle]
    assert info.pages_owned == 2        # both tiers count against the charge
    h.pop(handle)
