"""LexicoCache: prefill/decode vs dense-reconstruction oracle; ring buffer;
flash-decode == naive softmax; window masking; memory accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core.omp import OMPResult, reconstruct
from tests.conftest import make_unit_dict


def _mk(rng, B=2, KV=2, m=16, N=64, s=6, n_b=4, T_max=32):
    D_k = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    D_v = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    cache = core.init_layer_cache(B, KV, m, t_max=T_max, n_b=n_b, s=s,
                                  val_dtype=jnp.float32)
    return D_k, D_v, cache


def _oracle_attend(cache, q, D_k, D_v, m):
    # lockstep batches: all rows share one (t_c, buf_len)
    t_c, buf_len = int(cache.t_c[0]), int(cache.buf_len[0])
    rk = OMPResult(cache.k_vals.astype(jnp.float32), cache.k_idx.astype(jnp.int32), None, None)
    rv = OMPResult(cache.v_vals.astype(jnp.float32), cache.v_idx.astype(jnp.int32), None, None)
    K_hat = reconstruct(rk, D_k)[:, :, :t_c]
    V_hat = reconstruct(rv, D_v)[:, :, :t_c]
    # ring order is irrelevant to softmax; restrict to valid entries
    kb = cache.k_buf.astype(jnp.float32)[:, :, :buf_len]
    vb = cache.v_buf.astype(jnp.float32)[:, :, :buf_len]
    K_all = jnp.concatenate([K_hat, kb], axis=2)
    V_all = jnp.concatenate([V_hat, vb], axis=2)
    s_ = jnp.einsum("bkgm,bktm->bkgt", q, K_all) / np.sqrt(m)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bkgt,bktm->bkgm", p, V_all)


def test_prefill_attend_matches_oracle(rng):
    B, KV, G, m, N, s, n_b = 2, 2, 2, 16, 64, 6, 4
    D_k, D_v, cache = _mk(rng, B=B, KV=KV, m=m, N=N, s=s, n_b=n_b)
    T = 12
    K = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    cache = core.prefill_compress(cache, K, V, D_k, D_v, s=s)
    assert cache.t_c.shape == (B,) and cache.buf_len.shape == (B,)
    assert np.all(np.asarray(cache.t_c) == T - n_b)
    assert np.all(np.asarray(cache.buf_len) == n_b)
    q = jnp.asarray(rng.normal(size=(B, KV, G, m)), jnp.float32)
    out = core.attend(cache, q, D_k, D_v, N=N)
    ref = _oracle_attend(cache, q, D_k, D_v, m)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_decode_ring_and_flash(rng):
    B, KV, G, m, N, s, n_b = 2, 2, 2, 16, 64, 6, 4
    D_k, D_v, cache = _mk(rng, B=B, KV=KV, m=m, N=N, s=s, n_b=n_b)
    T = 8
    K = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    cache = core.prefill_compress(cache, K, V, D_k, D_v, s=s)
    for i in range(7):
        kt = jnp.asarray(rng.normal(size=(B, KV, m)), jnp.float32)
        cache = core.decode_update(cache, kt, kt, D_k, D_v, s=s)
    assert np.all(np.asarray(cache.t_c) == (T - n_b) + 7)
    assert np.all(np.asarray(cache.buf_len) == n_b)
    assert np.all(np.asarray(cache.buf_start) == 7 % n_b)
    q = jnp.asarray(rng.normal(size=(B, KV, G, m)), jnp.float32)
    naive = core.attend(cache, q, D_k, D_v, N=N, chunk=None)
    flash = core.attend(cache, q, D_k, D_v, N=N, chunk=5)   # non-dividing chunk
    np.testing.assert_allclose(np.asarray(naive), np.asarray(flash), atol=1e-5)
    ref = _oracle_attend(cache, q, D_k, D_v, m)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(ref), atol=1e-5)


def test_window_masking(rng):
    B, KV, G, m, N, s, n_b = 1, 1, 1, 16, 64, 8, 2
    D_k, D_v, cache = _mk(rng, B=B, KV=KV, m=m, N=N, s=s, n_b=n_b, T_max=32)
    T = 10
    K = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    cache = core.prefill_compress(cache, K, K, D_k, D_v, s=s)
    q = jnp.asarray(rng.normal(size=(B, KV, G, m)), jnp.float32)
    win = 4  # only last 4 tokens (2 compressed + 2 buffer)
    out = core.attend(cache, q, D_k, D_v, N=N, window=jnp.int32(win))
    # oracle: mask compressed positions < length-win
    rk = OMPResult(cache.k_vals.astype(jnp.float32), cache.k_idx.astype(jnp.int32), None, None)
    rv = OMPResult(cache.v_vals.astype(jnp.float32), cache.v_idx.astype(jnp.int32), None, None)
    K_hat = reconstruct(rk, D_k)[:, :, :int(cache.t_c[0])]
    V_hat = reconstruct(rv, D_v)[:, :, :int(cache.t_c[0])]
    lo = T - win
    K_all = jnp.concatenate([K_hat[:, :, lo:], cache.k_buf.astype(jnp.float32)], axis=2)
    V_all = jnp.concatenate([V_hat[:, :, lo:], cache.v_buf.astype(jnp.float32)], axis=2)
    s_ = jnp.einsum("bkgm,bktm->bkgt", q, K_all) / np.sqrt(m)
    p = jax.nn.softmax(s_, axis=-1)
    ref = jnp.einsum("bkgt,bktm->bkgm", p, V_all)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_memory_accounting():
    # paper's law: payload = 3s+2 bytes per vector -> 1.17s% of fp16 at m=128
    from repro.core.quant import kv_size_fraction, payload_bytes
    assert payload_bytes(16, "fp8") == 50
    assert abs(kv_size_fraction(16, 128) - 0.1953) < 1e-3
    assert abs(100 * kv_size_fraction(32, 128) - 38.28) < 0.1
    pct = core.kv_size_percent(t_c=1000, n_b=128, s=16, m=128)
    assert 19.0 < pct < 29.0


def test_fp8_storage_roundtrip(rng):
    B, KV, m, N, s, n_b = 1, 1, 16, 64, 6, 2
    D_k = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    cache = core.init_layer_cache(B, KV, m, t_max=16, n_b=n_b, s=s)  # fp8 default
    K = jnp.asarray(rng.normal(size=(B, KV, 6, m)), jnp.float32)
    cache = core.prefill_compress(cache, K, K, D_k, D_k, s=s)
    assert cache.k_vals.dtype == jnp.float8_e4m3fn
    assert cache.k_idx.dtype == jnp.int16
    rk = OMPResult(cache.k_vals.astype(jnp.float32), cache.k_idx.astype(jnp.int32), None, None)
    K_hat = reconstruct(rk, D_k)[:, :, :4]
    rel = jnp.linalg.norm(K_hat - K[:, :, :4], axis=-1) / jnp.linalg.norm(K[:, :, :4], axis=-1)
    assert float(jnp.max(rel)) < 0.6   # fp8 coefficients still approximate
