"""Sharding rules: param specs by path, cache specs, batch fallback."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import data_sharding, spec_for_param


def test_param_specs():
    # stacked layer weights: (L, d_in, d_out) -> FSDP on d_in, TP on d_out
    assert spec_for_param("layers/attn/wq", 3, moe=False) == P(None, "data", "model")
    assert spec_for_param("layers/attn/wo", 3, moe=False) == P(None, "model", "data")
    assert spec_for_param("layers/mlp/w_up", 3, moe=False) == P(None, "data", "model")
    assert spec_for_param("layers/mlp/w_down", 3, moe=False) == P(None, "model", "data")
    # MoE experts: EP on E
    assert spec_for_param("layers/mlp/w_up", 4, moe=True) == P(None, "model", "data", None)
    assert spec_for_param("layers/mlp/w_down", 4, moe=True) == P(None, "model", "data", None)
    assert spec_for_param("layers/mlp/router", 3, moe=True) == P(None, None, None)
    # embeddings
    assert spec_for_param("embed", 2, moe=False) == P("model", "data")
    assert spec_for_param("lm_head", 2, moe=False) == P("data", "model")
    # norms replicate
    assert spec_for_param("layers/ln1/w", 2, moe=False) == P()
    # no-FSDP mode drops the data axis
    assert spec_for_param("layers/attn/wq", 3, moe=False, fsdp=False) == P(None, None, "model")
    # MLA
    assert spec_for_param("layers/attn/w_uk", 3, moe=False) == P(None, "data", "model")
    # rwkv
    assert spec_for_param("layers/rwkv/w_r", 3, moe=False) == P(None, "data", "model")
    assert spec_for_param("layers/rwkv/w0", 2, moe=False) == P()


def test_data_sharding_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    s = data_sharding(mesh, batch_size=1)
    assert s.spec == P("data") or s.spec == P()  # 1 % 1 == 0 -> keeps axis
    s2 = data_sharding(mesh, batch_size=7)
    assert s2.spec in (P("data"), P())


def test_cache_shardings_single_device():
    from repro.core.sparse_cache import init_layer_cache
    from repro.runtime.sharding import cache_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    cache = init_layer_cache(2, 2, 16, t_max=32, n_b=4, s=4)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * 3), cache)
    sh = cache_shardings(mesh, stacked, seq_axis="model")
    # vals get a token-axis entry; scalars replicate
    assert sh.k_vals.spec[3] == "model"
    assert sh.t_c.spec == P()
