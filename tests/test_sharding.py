"""Sharding rules: param specs by path, cache specs, batch fallback."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import data_sharding, spec_for_param


def test_param_specs():
    # stacked layer weights: (L, d_in, d_out) -> FSDP on d_in, TP on d_out
    assert spec_for_param("layers/attn/wq", 3, moe=False) == P(None, "data", "model")
    assert spec_for_param("layers/attn/wo", 3, moe=False) == P(None, "model", "data")
    assert spec_for_param("layers/mlp/w_up", 3, moe=False) == P(None, "data", "model")
    assert spec_for_param("layers/mlp/w_down", 3, moe=False) == P(None, "model", "data")
    # MoE experts: EP on E
    assert spec_for_param("layers/mlp/w_up", 4, moe=True) == P(None, "model", "data", None)
    assert spec_for_param("layers/mlp/w_down", 4, moe=True) == P(None, "model", "data", None)
    assert spec_for_param("layers/mlp/router", 3, moe=True) == P(None, None, None)
    # embeddings
    assert spec_for_param("embed", 2, moe=False) == P("model", "data")
    assert spec_for_param("lm_head", 2, moe=False) == P("data", "model")
    # norms replicate
    assert spec_for_param("layers/ln1/w", 2, moe=False) == P()
    # no-FSDP mode drops the data axis
    assert spec_for_param("layers/attn/wq", 3, moe=False, fsdp=False) == P(None, None, "model")
    # MLA
    assert spec_for_param("layers/attn/w_uk", 3, moe=False) == P(None, "data", "model")
    # rwkv
    assert spec_for_param("layers/rwkv/w_r", 3, moe=False) == P(None, "data", "model")
    assert spec_for_param("layers/rwkv/w0", 2, moe=False) == P()


def test_data_sharding_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    s = data_sharding(mesh, batch_size=1)
    assert s.spec == P("data") or s.spec == P()  # 1 % 1 == 0 -> keeps axis
    s2 = data_sharding(mesh, batch_size=7)
    assert s2.spec in (P("data"), P())


def test_seq_shard_body_matches_unsharded(rng):
    """The shard_map decode body with per-row (B,) counters, active masks and
    tier caps matches plain decode_update + attend on a 1-shard mesh."""
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from repro.configs.base import LexicoConfig
    from repro.core import sparse_cache as sc
    from repro.core.sharded_decode import SeqShardLexicoPolicy, _decode_attend_local

    lex = LexicoConfig(N=64, s=4, n_b=4, chunk=None, use_gram=False)
    pol = SeqShardLexicoPolicy(lex)
    B, KV, m = 2, 2, 16
    D = rng.normal(size=(m, 64))
    D = jnp.asarray(D / np.linalg.norm(D, axis=0), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, KV, 8, m)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, KV, 2, m)), jnp.float32)
    kt = jnp.asarray(rng.normal(size=(B, KV, m)), jnp.float32)
    cache = pol.prefill(pol.init(B, KV, m, t_max=20), K, K, (D, D))
    act = jnp.asarray([True, False])
    cap = jnp.asarray([2, 4], jnp.int32)

    mesh = jax.make_mesh((1,), ("model",), devices=jax.devices()[:1])
    specs = type(cache)(
        k_vals=P(None, None, "model", None), k_idx=P(None, None, "model", None),
        v_vals=P(None, None, "model", None), v_idx=P(None, None, "model", None),
        k_buf=P(), v_buf=P(), t_c=P(), buf_len=P(), buf_start=P())
    body = lambda c, qq, kk, vv, aa, cc: _decode_attend_local(
        c, qq, kk, vv, D, D, s=4, N=64, delta=0.0, window=None,
        active=aa, s_cap=cc)
    out, nc = shard_map(body, mesh=mesh,
                        in_specs=(specs, P(), P(), P(), P(), P()),
                        out_specs=(P(), specs), check_rep=False)(
        cache, q, kt, kt, act, cap)
    ref_cache = sc.decode_update(cache, kt, kt, D, D, s=4, use_gram=False,
                                 active=act, s_cap=cap)
    ref = sc.attend(ref_cache, q, D, D, N=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(nc.t_c), np.asarray(ref_cache.t_c))
    np.testing.assert_array_equal(np.asarray(nc.buf_len),
                                  np.asarray(ref_cache.buf_len))


def test_cache_shardings_single_device():
    from repro.core.sparse_cache import init_layer_cache
    from repro.runtime.sharding import cache_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"), devices=jax.devices()[:1])
    cache = init_layer_cache(2, 2, 16, t_max=32, n_b=4, s=4)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * 3), cache)
    sh = cache_shardings(mesh, stacked, seq_axis="model")
    # vals get a token-axis entry; (L, B) bookkeeping follows the batch axis
    assert sh.k_vals.spec[3] == "model"
    assert sh.t_c.spec in (P(None, "data"), P(None, ("pod", "data")))
