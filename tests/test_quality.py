"""Compression-quality observability: sketch correctness, the live↔offline
agreement anchor, engine differential, page tags, drift, and the
bounded-error tolerance harness.

The proof obligations (ISSUE PR 10):

  * ``StreamingHist`` — exact associative/commutative merge, bounded
    quantiles (at most one bin width above the empirical quantile for
    in-range data), NaN/±inf/empty handling, dict round trip;
  * agreement — the live telemetry residual (``omp.relative_residual`` over
    the resid2 threaded out of ``prefill_compress``) matches the offline
    Table-1 number (``dict_learning.relative_error``) on the same
    dictionary/inputs to 1e-6;
  * engine differential — tokens are bitwise identical with quality
    telemetry on vs off, decode still compiles exactly once, and with
    quality *off* the engine holds zero recording state;
  * page tags — stamped at encode, carried across demote/promote, and every
    emitted ``page_quality`` journal event replays clean;
  * tolerance gate — a lossless rerun produces an all-zero DiffReport that
    passes a tight gate; an injected int8 value-requantization is flagged.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.core import dict_learning as dl
from repro.core import omp
from repro.core import sparse_cache as sc
from repro.models import model as M
from repro.models.cache_policy import LexicoPolicy
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, ObsConfig, Request, SwapConfig,
)
from repro.serving.obs import (
    DiffReport, DriftMonitor, MetricsRegistry, PageQuality, QualityRecorder,
    StreamingHist, ToleranceGate, compare_logits, diff_runs,
    int8_requantize_cache, layer_table_from_block, merge_quality_blocks,
    replay_check, token_divergence,
)
from tests.conftest import given, settings, st

# ---------------------------------------------------------------------------
# StreamingHist
# ---------------------------------------------------------------------------


def test_hist_counts_flows_and_moments():
    h = StreamingHist(0.0, 1.0, 4)
    h.add([0.1, 0.3, 0.3, 0.9])
    h.add(np.array([-0.5, 2.0]))            # one under, one over
    assert h.count == 6
    assert h.underflow == 1 and h.overflow == 1
    assert list(h.counts) == [1, 2, 0, 1]
    assert h.vmin == -0.5 and h.vmax == 2.0
    assert h.mean == pytest.approx((0.1 + 0.3 + 0.3 + 0.9 - 0.5 + 2.0) / 6)


def test_hist_nan_and_inf():
    h = StreamingHist(0.0, 1.0, 4)
    h.add([math.nan, 0.5, math.inf, -math.inf, math.nan])
    assert h.nan_count == 2
    assert h.count == 3                      # NaNs excluded from count
    assert h.overflow == 1 and h.underflow == 1
    assert h.vmax == math.inf and h.vmin == -math.inf


def test_hist_empty():
    h = StreamingHist(0.0, 1.0, 8)
    assert h.count == 0
    assert math.isnan(h.mean)
    assert math.isnan(h.quantile(0.5))
    assert math.isnan(h.distance(StreamingHist(0.0, 1.0, 8)))
    h.add([])                                # no-op, not an error
    assert h.count == 0


def test_hist_quantile_upper_bound(rng):
    """quantile(q) is an upper bound on the empirical q-quantile, tight to
    one bin width for in-range values."""
    vals = np.sort(rng.uniform(0.0, 1.0, 500))
    h = StreamingHist(0.0, 1.0, 64)
    h.add(vals)
    width = 1.0 / 64
    n = vals.size
    for q in (0.0, 0.1, 0.5, 0.9, 0.99, 1.0):
        rank = min(n - 1, max(0, math.ceil(q * n) - 1))
        emp = vals[rank]
        got = h.quantile(q)
        assert emp - 1e-12 <= got <= emp + width + 1e-12, q
    assert h.quantile(1.0) == pytest.approx(h.vmax)
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)


def test_hist_merge_matches_bulk_add(rng):
    a_vals = rng.normal(0.5, 0.3, 200)
    b_vals = np.concatenate([rng.normal(0.2, 0.1, 150), [math.nan, 9.0, -9.0]])
    a = StreamingHist(0.0, 1.0, 32)
    b = StreamingHist(0.0, 1.0, 32)
    both = StreamingHist(0.0, 1.0, 32)
    a.add(a_vals)
    b.add(b_vals)
    both.add(a_vals)
    both.add(b_vals)
    m = a.merge(b)
    assert list(m.counts) == list(both.counts)
    assert (m.underflow, m.overflow, m.nan_count) == \
        (both.underflow, both.overflow, both.nan_count)
    assert m.vmin == both.vmin and m.vmax == both.vmax
    assert m.total_sum == pytest.approx(both.total_sum)
    # inputs not mutated
    assert a.count == np.isfinite(a_vals).sum()


def test_hist_layout_mismatch_raises():
    with pytest.raises(ValueError, match="bin layout"):
        StreamingHist(0.0, 1.0, 8).merge(StreamingHist(0.0, 2.0, 8))
    with pytest.raises(ValueError, match="hi > lo"):
        StreamingHist(1.0, 1.0, 8)
    with pytest.raises(ValueError, match="n_bins"):
        StreamingHist(0.0, 1.0, 0)


def test_hist_dict_roundtrip():
    h = StreamingHist(0.0, 1.5, 16)
    h.add([0.1, 0.7, 5.0, -1.0, math.nan])
    back = StreamingHist.from_dict(h.to_dict())
    assert back.to_dict() == h.to_dict()
    bad = h.to_dict()
    bad["counts"] = bad["counts"][:-1]
    with pytest.raises(ValueError, match="counts shape"):
        StreamingHist.from_dict(bad)


def test_hist_distance_total_variation():
    a = StreamingHist(0.0, 1.0, 2)
    b = StreamingHist(0.0, 1.0, 2)
    a.add([0.1, 0.2])                        # all mass in bin 0
    b.add([0.8, 0.9])                        # all mass in bin 1
    assert a.distance(b) == pytest.approx(1.0)
    assert a.distance(a) == 0.0
    assert b.distance(a) == a.distance(b)    # symmetric


@given(st.lists(st.floats(-2.0, 3.0), max_size=40),
       st.lists(st.floats(-2.0, 3.0), max_size=40),
       st.lists(st.floats(-2.0, 3.0), max_size=40))
@settings(max_examples=50, deadline=None)
def test_hist_merge_associative_commutative(xs, ys, zs):
    def mk(vals):
        h = StreamingHist(0.0, 1.0, 8)
        h.add(vals)
        return h
    a, b, c = mk(xs), mk(ys), mk(zs)
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.to_dict() == right.to_dict()
    assert a.merge(b).to_dict() == b.merge(a).to_dict()


# ---------------------------------------------------------------------------
# PageQuality / DriftMonitor
# ---------------------------------------------------------------------------


def test_page_quality_tag():
    t = PageQuality()
    t.add([0.1, 0.3], [2, 4])
    t.add(np.array([0.5]), np.array([8]))
    assert t.count == 3
    assert t.rel_mean == pytest.approx(0.3)
    assert t.rel_max == pytest.approx(0.5)
    assert t.nnz_mean == pytest.approx(14 / 3)
    t.add([], [])                            # no-op
    assert t.count == 3

    other = PageQuality()
    other.add([0.9], [1])
    m = t.merge(other)
    assert (m.count, m.rel_max) == (4, pytest.approx(0.9))
    assert t.count == 3                      # merge does not mutate

    c = t.copy()
    c.add([1.0], [1])
    assert t.count == 3 and c.count == 4     # copy is independent

    ev = t.to_event()
    assert set(ev) == {"count", "rel_mean", "rel_max", "nnz_mean"}
    assert ev["count"] == 3


def test_drift_monitor(rng):
    base = StreamingHist(0.0, 1.5, 64)
    base.add(rng.uniform(0.1, 0.3, 400))
    mon = DriftMonitor(base)
    like = StreamingHist(0.0, 1.5, 64)
    like.add(rng.uniform(0.1, 0.3, 400))
    shifted = StreamingHist(0.0, 1.5, 64)
    shifted.add(rng.uniform(0.9, 1.1, 400))
    assert mon.score(like) < 0.15            # calibration-like traffic
    assert mon.score(shifted) > 0.9          # residual mass moved
    with pytest.raises(ValueError, match="empty"):
        DriftMonitor(StreamingHist(0.0, 1.5, 64))
    back = DriftMonitor.from_dict(mon.to_dict())
    assert back.baseline.to_dict() == base.to_dict()


# ---------------------------------------------------------------------------
# QualityRecorder (unit: fake aux)
# ---------------------------------------------------------------------------

L, B, KV = 2, 3, 2


def _prefill_aux(rng, n=4):
    return {
        "k_rel": rng.uniform(0.0, 0.5, (L, 1, KV, n)).astype(np.float32),
        "k_nnz": rng.integers(1, 9, (L, 1, KV, n)).astype(np.int32),
        "v_rel": rng.uniform(0.0, 0.5, (L, 1, KV, n)).astype(np.float32),
        "v_nnz": rng.integers(1, 9, (L, 1, KV, n)).astype(np.int32),
    }


def _decode_aux(rng, wrote):
    return {
        "k_rel": rng.uniform(0.0, 0.5, (L, B, KV)).astype(np.float32),
        "k_nnz": rng.integers(1, 9, (L, B, KV)).astype(np.int32),
        "v_rel": rng.uniform(0.0, 0.5, (L, B, KV)).astype(np.float32),
        "v_nnz": rng.integers(1, 9, (L, B, KV)).astype(np.int32),
        "wrote": np.broadcast_to(np.asarray(wrote, bool), (L, B)),
    }


def test_recorder_prefill_and_decode_accounting(rng):
    rec = QualityRecorder(n_layers=L, s_max=8)
    aux = _prefill_aux(rng)
    rec.record_prefill(aux, tier=8)
    assert rec.encodes == L * 2 * KV * 4     # both roles, every position
    # delta attainment bookkeeping matches a direct count against the cap
    expect = int((aux["k_nnz"] < 8).sum()) + int((aux["v_nnz"] < 8).sum())
    assert rec.delta_attained == expect

    # fully-shared admission (zero compressed positions) records nothing
    rec.record_prefill(_prefill_aux(rng, n=0), tier=8)
    assert rec.encodes == L * 2 * KV * 4

    # decode: only `wrote` rows count, grouped by per-slot tier
    daux = _decode_aux(rng, [True, False, True])
    rec.record_decode(daux, tiers=np.array([2, 4, 8]))
    assert rec.encodes == L * 2 * KV * 4 + L * 2 * KV * 2
    s = rec.summary()
    assert set(s["tiers"]) == {"2", "8"}     # tier 4's row never wrote
    assert s["tiers"]["2"]["encodes"] == L * 2 * KV

    # a step where nothing wrote records nothing
    before = rec.encodes
    rec.record_decode(_decode_aux(rng, [False] * B), tiers=np.array([2, 4, 8]))
    assert rec.encodes == before


def test_recorder_filters_and_layer_table(rng):
    rec = QualityRecorder(n_layers=L, s_max=8)
    rec.record_prefill(_prefill_aux(rng), tier=4)
    whole = rec.rel_hist()
    by_layer = sum(rec.rel_hist(layer=i).count for i in range(L))
    by_role = sum(rec.rel_hist(role=r).count for r in ("k", "v"))
    assert whole.count == by_layer == by_role
    assert rec.rel_hist(phase="decode").count == 0
    assert rec.nnz_hist(tier=4).count == whole.count
    # nnz sketch uses unit bins => exact integral counts
    assert rec.nnz_hist().quantile(1.0) == rec.nnz_hist().vmax

    table = rec.layer_table()
    assert [r["layer"] for r in table] == list(range(L))
    assert all(0.0 <= r["k_rel_mean"] <= 1.5 for r in table)


def test_recorder_drift_baseline_roundtrip(rng):
    rec = QualityRecorder(n_layers=L, s_max=8)
    assert rec.drift_score() is None         # no baseline yet
    rec.record_prefill(_prefill_aux(rng, n=400), tier=8)
    rec.set_baseline()
    assert rec.drift_score() == 0.0          # live == baseline right now

    # snapshot -> fresh recorder -> load: same-distribution traffic scores ~0
    saved = rec.baseline_dict()
    rec2 = QualityRecorder(n_layers=L, s_max=8)
    rec2.load_baseline(saved)
    assert rec2.drift_score() is None        # baseline but no live data
    rec2.record_prefill(_prefill_aux(rng, n=400), tier=8)
    assert rec2.drift_score() < 0.25


def test_recorder_registry_families(rng):
    reg = MetricsRegistry()
    rec = QualityRecorder(n_layers=L, s_max=8, registry=reg)
    rec.record_prefill(_prefill_aux(rng), tier=8)
    text = reg.to_prometheus()
    assert "lexico_quality_encodes_total" in text
    assert "lexico_quality_delta_attained_total" in text
    assert "lexico_quality_rel_residual_mean" in text
    assert 'phase="prefill"' in text and 'role="k"' in text


def test_merge_quality_blocks_exact(rng):
    r1 = QualityRecorder(n_layers=L, s_max=8)
    r2 = QualityRecorder(n_layers=L, s_max=8)
    both = QualityRecorder(n_layers=L, s_max=8)
    a1, a2 = _prefill_aux(rng), _prefill_aux(rng, n=6)
    r1.record_prefill(a1, tier=4)
    r2.record_prefill(a2, tier=8)
    both.record_prefill(a1, tier=4)
    both.record_prefill(a2, tier=8)

    merged = merge_quality_blocks([r1.summary(), r2.summary()])
    ref = both.summary()
    assert merged["encodes"] == ref["encodes"]
    assert merged["tiers"] == ref["tiers"]
    assert merged["per_layer"] == ref["per_layer"]      # sketch-exact
    assert merged["rel_residual"] == ref["rel_residual"]
    assert layer_table_from_block(merged) == layer_table_from_block(ref)

    assert merge_quality_blocks([]) == {}
    assert merge_quality_blocks([{}, r1.summary()])["encodes"] == r1.encodes

    # drift merges as the worst replica, not the average
    r1.set_baseline()
    r2.record_prefill(_prefill_aux(rng), tier=8)
    s1, s2 = r1.summary(), r2.summary()
    s2["drift_score"] = 0.7
    assert merge_quality_blocks([s1, s2])["drift_score"] == 0.7


# ---------------------------------------------------------------------------
# agreement: live telemetry == offline Table-1 numbers (same dict, same keys)
# ---------------------------------------------------------------------------

AGREE_TOL = 1e-6


def test_relative_residual_matches_offline_relative_error(rng):
    """The live path (resid2 threaded out of OMP -> omp.relative_residual)
    and the offline Table-1 path (dict_learning.relative_error) are the same
    number on the same dictionary/inputs — the shared-helper contract."""
    d, N, s = 16, 64, 4
    D = jnp.asarray(rng.normal(size=(d, N)), jnp.float32)
    D = D / jnp.linalg.norm(D, axis=0, keepdims=True)
    K = jnp.asarray(rng.normal(size=(24, d)), jnp.float32)
    res = omp.omp_batch(K, D, s)
    live = np.asarray(omp.relative_residual(res.resid2, K))
    offline = np.asarray(dl.relative_error(D, K, s))
    np.testing.assert_allclose(live, offline, atol=AGREE_TOL)


def test_prefill_quality_aux_matches_offline(rng):
    """The per-position k_rel/v_rel the engine records equals the offline
    relative error of the exact same rows."""
    d, N, s, kv, T = 16, 64, 4, 2, 12
    D = jnp.asarray(rng.normal(size=(d, N)), jnp.float32)
    D = D / jnp.linalg.norm(D, axis=0, keepdims=True)
    K = jnp.asarray(rng.normal(size=(1, kv, T, d)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(1, kv, T, d)), jnp.float32)
    cache = sc.init_layer_cache(1, kv, d, t_max=16, n_b=4, s=s,
                                val_dtype=jnp.float32)
    _, qaux = sc.prefill_compress(cache, K, V, D, D, s=s, return_quality=True)
    k_rel = np.asarray(qaux["k_rel"])[0]            # (kv, n_comp)
    n_comp = k_rel.shape[-1]
    assert n_comp == T - 4                           # n_b stays uncompressed
    for role, X in (("k_rel", K), ("v_rel", V)):
        got = np.asarray(qaux[role])[0]
        ref = np.asarray(dl.relative_error(
            D, X[0, :, :n_comp].reshape(-1, d), s)).reshape(kv, n_comp)
        np.testing.assert_allclose(got, ref, atol=AGREE_TOL, err_msg=role)
    assert np.all(np.asarray(qaux["k_nnz"]) <= s)
    assert np.all(np.asarray(qaux["k_nnz"]) >= 1)


# ---------------------------------------------------------------------------
# engine differential (the acceptance gate)
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _requests(rng):
    spec = [(9, 3, 2), (30, 4, 8), (12, 2, 4), (26, 3, 6), (8, 2, 2)]
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, pl).astype(np.int32),
                    max_new_tokens=mn, tier=tier)
            for i, (pl, mn, tier) in enumerate(spec)]


def _run(params, bank, reqs, **cfg_kw):
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, **cfg_kw))
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    done = eng.run()
    return {rid: done[rid].generated_tokens for rid in done}, eng


def test_engine_quality_differential(served):
    """Tokens bitwise identical with quality telemetry on vs off; zero
    recording state when disabled; decode still compiles exactly once; every
    page_quality journal event replays clean."""
    params, bank = served
    reqs = _requests(np.random.default_rng(7))

    plain, off_eng = _run(params, bank, reqs)
    on, eng = _run(params, bank, reqs,
                   obs=ObsConfig(quality=True, journal=True))

    assert sorted(on) == sorted(plain)
    for rid in plain:
        assert on[rid] == plain[rid], rid

    # zero recording state when disabled
    assert off_eng.quality is None
    assert "quality" not in off_eng.metrics.to_dict()

    # decode is still a single compile on both engines
    assert off_eng.compile_counts["decode"] == 1
    assert eng.compile_counts["decode"] == 1

    q = eng.metrics.to_dict()["quality"]
    assert q["encodes"] > 0
    assert set(q["tiers"]) <= {"2", "4", "6", "8"}   # the request tiers
    assert sum(d["encodes"] for d in q["tiers"].values()) == q["encodes"]
    # delta=0.0 => OMP never early-exits => attainment is exactly zero
    assert q["delta_attained_rate"] == 0.0
    assert q["rel_residual"]["count"] == q["encodes"]
    assert 0.0 < q["rel_residual"]["mean"] < 1.5
    assert len(q["per_layer"]) == CFG.num_layers
    # both phases observed: admissions and decode evictee writes
    assert eng.quality.rel_hist(phase="prefill").count > 0
    assert eng.quality.rel_hist(phase="decode").count > 0

    # page tags were stamped and journaled, and the journal replays clean
    evs = eng.journal.events
    assert sum(e["ev"] == "page_quality" for e in evs) > 0
    assert replay_check(evs) == []

    # the human-facing table is well-formed
    table = eng.quality.layer_table()
    assert len(table) == CFG.num_layers
    assert all(np.isfinite(r["k_rel_mean"]) for r in table)


def test_engine_quality_tags_survive_swap(served):
    """Quality tags ride demote/promote: a swap-constrained quality run still
    matches the unconstrained oracle bitwise, pages genuinely round-trip
    device→host→device, and the journal (including the re-stamped
    page_quality events after promote) replays clean."""
    params, bank = served
    reqs = _requests(np.random.default_rng(7))

    oracle, _ = _run(params, bank, reqs)
    swapped, eng = _run(params, bank, reqs, n_pages=6, swap=SwapConfig(),
                        obs=ObsConfig(quality=True, journal=True))

    assert sorted(swapped) == sorted(oracle)
    for rid in oracle:
        assert swapped[rid] == oracle[rid], rid

    md = eng.metrics.to_dict()
    assert md["pages_demoted"] > 0 and md["pages_promoted"] > 0
    assert md["quality"]["encodes"] > 0
    evs = eng.journal.events
    assert sum(e["ev"] == "page_quality" for e in evs) > 0
    assert replay_check(evs) == []


# ---------------------------------------------------------------------------
# bounded-error differential harness
# ---------------------------------------------------------------------------


def test_compare_logits_and_diff_report(rng):
    ref = rng.normal(size=(6, 32))
    max_abs, kl, overlap = compare_logits(ref, ref)
    assert np.all(max_abs == 0) and np.all(kl == 0) and np.all(overlap == 1)

    test = ref.copy()
    test[3] += 0.5 * rng.normal(size=32)
    r = diff_runs(ref, test, [1, 2, 3, 9, 5, 6], [1, 2, 3, 4, 5, 6])
    assert r.n_positions == 6
    assert r.max_abs > 0 and r.mean_kl > 0
    assert r.first_divergent_token == 3
    assert isinstance(r, DiffReport) and r.to_dict()["max_abs"] == r.max_abs

    with pytest.raises(ValueError, match="shape mismatch"):
        compare_logits(ref, ref[:3])


def test_token_divergence():
    assert token_divergence([1, 2, 3], [1, 2, 3]) == -1
    assert token_divergence([1, 2, 3], [1, 9, 3]) == 1
    assert token_divergence([1, 2, 3], [1, 2]) == 2      # length mismatch
    assert token_divergence([], []) == -1


def test_tolerance_gate_violations():
    rep = DiffReport(n_positions=4, max_abs=0.1, mean_kl=0.01, max_kl=0.02,
                     topk_overlap=0.6, first_divergent_token=2)
    loose = ToleranceGate()
    assert loose.ok(rep)                     # fully permissive defaults
    tight = ToleranceGate(max_abs=1e-6, max_mean_kl=1e-6,
                          min_topk_overlap=0.9, require_token_match=True)
    v = tight.check(rep)
    assert len(v) == 4
    assert any("max_abs" in s for s in v)
    assert any("diverge at position 2" in s for s in v)
    zero = DiffReport(n_positions=1, max_abs=0.0, mean_kl=0.0, max_kl=0.0,
                      topk_overlap=1.0, first_divergent_token=-1)
    assert tight.ok(zero)


def test_tolerance_harness_flags_int8_requant(rng):
    """The acceptance gate: a lossless rerun passes a tight gate; an injected
    int8 value requantization of the cache produces a nonzero diff the same
    gate flags. (codec="fp16": the fp8 grid is coarser than per-vector int8,
    so the default codec would make the injection a no-op.)"""
    lex = LexicoConfig(N=64, s=8, n_b=4, chunk=None, codec="fp16")
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, lex)
    pol = LexicoPolicy(lex)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, 12)), jnp.int32)
    lg, state = M.prefill(params, CFG, pol, {"tokens": toks}, bank=bank,
                          t_max=32)
    tok = jnp.argmax(lg, -1).astype(jnp.int32)

    lg_ref, _ = M.decode_step(params, CFG, pol, state, tok, bank=bank)
    lg_rerun, _ = M.decode_step(params, CFG, pol, state, tok, bank=bank)
    state_q = state._replace(cache=int8_requantize_cache(state.cache))
    lg_lossy, _ = M.decode_step(params, CFG, pol, state_q, tok, bank=bank)

    gate = ToleranceGate(max_abs=1e-6, require_token_match=True)
    lossless = diff_runs(lg_ref, lg_rerun,
                         jnp.argmax(lg_ref, -1), jnp.argmax(lg_rerun, -1))
    assert lossless.max_abs == 0.0 and lossless.mean_kl == 0.0
    assert gate.ok(lossless)

    # the requantization genuinely moved stored values...
    delta = np.abs(np.asarray(state.cache.k_vals, np.float32)
                   - np.asarray(state_q.cache.k_vals, np.float32))
    assert delta.max() > 0
    # ...and the gate flags the resulting bounded logit error
    lossy = diff_runs(lg_ref, lg_lossy)
    assert lossy.max_abs > 1e-6
    assert not gate.ok(lossy)
    assert any("max_abs" in s for s in gate.check(lossy))
