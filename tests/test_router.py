"""Multi-replica routing: policy properties, merged metrics, differential.

Three layers of proof, cheapest first:

  * **Policy properties** (hypothesis, no engines): routing is
    deterministic given (request, snapshots, hits); the affinity score is
    monotone in prefix-hit pages and anti-monotone in load; ties break to
    the lowest replica id; with zero hits the affinity policy degenerates
    *exactly* to least-loaded.
  * **Merged metrics**: ``merge_snapshots`` keeps the single-engine
    ``to_dict`` key schema (golden-key pin), sums counters, maxes peaks
    (never sums a gauge), and recomputes rates from merged totals.
  * **Cross-replica differential** (the acceptance gate): for each of the
    three policies, every request served through a 2-replica router —
    under swap pressure and prefix aliasing — emits tokens bitwise equal
    to a solo single-engine run of that request; the global prefix view
    mirrors each replica's index exactly; and the per-replica journals +
    router admission log replay clean through ``replay_check_multi``.
"""
import dataclasses

import jax
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, EngineMetrics, ObsConfig,
    ReplicaRouter, ReplicaSnapshot, Request, SwapConfig, make_policy,
    merge_snapshots,
)
from repro.serving.obs import replay_check_multi
from repro.serving.router import LeastLoadedPolicy, PrefixAffinityPolicy
from tests.conftest import given, settings, st

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)

# a request object for policy calls (policies may not depend on anything
# but what the router hands them, so any request works)
REQ = Request(rid=0, prompt=np.arange(16, dtype=np.int32),
              max_new_tokens=1, tier=4)


# ---------------------------------------------------------------------------
# routing-policy properties (pure host code, no engines)
# ---------------------------------------------------------------------------

def _mk_snapshots(rng, n):
    snaps = []
    for k in range(n):
        total = int(rng.integers(4, 17))
        snaps.append(ReplicaSnapshot(
            replica_id=k,
            queue_depth=int(rng.integers(0, 6)),
            active_slots=int(rng.integers(0, 5)),
            n_slots=4,
            queued_bytes=int(rng.integers(0, 1 << 16)),
            kv_bytes_resident=int(rng.integers(0, 1 << 20)),
            host_bytes_resident=int(rng.integers(0, 1 << 18)),
            free_pages=int(rng.integers(0, total + 1)),
            total_pages=total))
    return snaps


def _mk_hits(rng, n):
    return {k: int(rng.integers(0, 6)) for k in range(n)}


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 5),
       name=st.sampled_from(["rr", "load", "affinity"]))
def test_routing_deterministic(seed, n, name):
    """Same (request, snapshots, hits) call sequence -> same decisions:
    two fresh policy instances agree call-for-call (round-robin's cursor
    is state, but it advances identically for identical sequences)."""
    rng = np.random.default_rng(seed)
    traces = [(_mk_snapshots(rng, n), _mk_hits(rng, n)) for _ in range(4)]
    a, b = make_policy(name), make_policy(name)
    for snaps, hits in traces:
        assert a.route(REQ, snaps, hits) == b.route(REQ, snaps, hits)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 5))
def test_stateless_policies_snapshot_order_invariant(seed, n):
    """load/affinity decisions depend on snapshot *contents*, not the
    order the router happened to list replicas in."""
    rng = np.random.default_rng(seed)
    snaps, hits = _mk_snapshots(rng, n), _mk_hits(rng, n)
    for name in ("load", "affinity"):
        pol = make_policy(name)
        assert (pol.route(REQ, snaps, hits)
                == pol.route(REQ, list(reversed(snaps)), dict(hits)))


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 5),
       delta=st.integers(1, 8))
def test_affinity_monotone_in_hit_pages(seed, n, delta):
    """Raising the chosen replica's expected hit pages never un-chooses
    it (the affinity score is monotone increasing in hits)."""
    rng = np.random.default_rng(seed)
    snaps, hits = _mk_snapshots(rng, n), _mk_hits(rng, n)
    pol = PrefixAffinityPolicy()
    choice = pol.route(REQ, snaps, hits)
    boosted = dict(hits)
    boosted[choice] = boosted.get(choice, 0) + delta
    assert pol.route(REQ, snaps, boosted) == choice


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 5),
       delta=st.integers(1, 8))
def test_affinity_anti_monotone_in_load(seed, n, delta):
    """Loading up a *different* replica never steals the choice (the
    affinity score is monotone decreasing in load)."""
    rng = np.random.default_rng(seed)
    snaps, hits = _mk_snapshots(rng, n), _mk_hits(rng, n)
    pol = PrefixAffinityPolicy()
    choice = pol.route(REQ, snaps, hits)
    loser = int(rng.choice([s.replica_id for s in snaps
                            if s.replica_id != choice]))
    bumped = [dataclasses.replace(s, queue_depth=s.queue_depth + delta)
              if s.replica_id == loser else s for s in snaps]
    assert pol.route(REQ, bumped, hits) == choice


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 5))
def test_tie_break_is_lowest_replica_id(seed, n):
    """Indistinguishable replicas -> deterministic lowest-id choice, for
    both score-based policies."""
    rng = np.random.default_rng(seed)
    proto = _mk_snapshots(rng, 1)[0]
    snaps = [dataclasses.replace(proto, replica_id=k) for k in range(n)]
    hits = {k: 3 for k in range(n)}
    assert LeastLoadedPolicy().route(REQ, snaps, hits) == 0
    assert PrefixAffinityPolicy().route(REQ, snaps, hits) == 0
    # ids shifted: the tie-break tracks the *lowest id present*, not 0
    shifted = [dataclasses.replace(proto, replica_id=k + 5)
               for k in range(n)]
    assert LeastLoadedPolicy().route(REQ, shifted, {}) == 5


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 5))
def test_affinity_degenerates_to_least_loaded_on_zero_hits(seed, n):
    """With no prefix hits anywhere the affinity score is exactly -load,
    so the two policies agree — including the tie-break."""
    rng = np.random.default_rng(seed)
    snaps = _mk_snapshots(rng, n)
    zero = {k: 0 for k in range(n)}
    assert (PrefixAffinityPolicy().route(REQ, snaps, zero)
            == LeastLoadedPolicy().route(REQ, snaps, zero))


def test_make_policy_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown routing policy"):
        make_policy("random")


# ---------------------------------------------------------------------------
# merged metrics: golden key schema, counter/gauge semantics
# ---------------------------------------------------------------------------

def _busy_metrics(occupancies, tokens, latency):
    m = EngineMetrics()
    for occ in occupancies:
        m.sample_step(occupancy=occ, kv_bytes_in_flight=occ * 100,
                      kv_bytes_resident=occ * 80, pages_in_use=occ,
                      shared_pages=1, host_bytes_resident=occ * 10)
    for _ in range(tokens):
        m.record_token(tier=4)
    m.record_admission(latency)
    m.record_prompt_tokens(9)
    m.record_prefill_compressed(7)
    m.record_prefix_share(aliased=2, copied=1, skipped_codes=16,
                          bytes_deduped=512)
    m.record_swap(demoted=1, promoted=1, stalls=2)
    m.record_phase("admit", 0.01)
    m.record_phase("decode_dispatch", 0.02)
    m.record_compile(0.5)
    m.record_rejection()
    m.record_completion(tier=4)
    return m


def test_merge_snapshots_pins_single_engine_schema():
    """Golden-key gate: the merged dict has exactly the single-engine
    to_dict key sequence — a new engine metric must teach the merge how it
    pools, or this fails."""
    s1 = _busy_metrics([1, 2, 3], tokens=5, latency=0.1).to_dict()
    s2 = _busy_metrics([4, 1], tokens=3, latency=0.3).to_dict()
    merged = merge_snapshots([s1, s2])
    assert list(merged.keys()) == list(s1.keys())
    assert set(merged["phase_times"]) == set(s1["phase_times"])
    for phase in merged["phase_times"]:
        assert (list(merged["phase_times"][phase].keys())
                == list(s1["phase_times"][phase].keys()))


def test_merge_snapshots_counters_sum_gauges_max():
    s1 = _busy_metrics([1, 2, 3], tokens=5, latency=0.1).to_dict()
    s2 = _busy_metrics([4, 1], tokens=3, latency=0.3).to_dict()
    merged = merge_snapshots([s1, s2])
    # counters sum
    assert merged["steps"] == 5
    assert merged["tokens_generated"] == 8
    assert merged["prefills"] == 2
    assert merged["pages_aliased"] == 4
    assert merged["pages_demoted"] == 2
    assert merged["admission_rejections"] == 2
    assert merged["compile_s"] == pytest.approx(1.0)
    # gauges/peaks take the max — NEVER the sum
    assert merged["slot_occupancy_peak"] == 4
    assert merged["kv_bytes_in_flight_peak"] == 400
    assert merged["pages_in_use_peak"] == 4
    assert merged["queue_latency_s_max"] == pytest.approx(0.3)
    assert merged["elapsed_s"] == pytest.approx(
        max(s1["elapsed_s"], s2["elapsed_s"]))
    # means pool step-weighted: 5 steps of [1,2,3,4,1]
    assert merged["slot_occupancy_mean"] == pytest.approx(11 / 5)
    # rates recomputed from merged totals, not averaged
    assert merged["tokens_per_s"] == pytest.approx(
        merged["tokens_generated"] / merged["elapsed_s"])
    assert merged["decode_tokens_per_step"] == pytest.approx(8 / 5)
    assert merged["shared_page_hit_rate"] == pytest.approx(1.0)


def test_merge_snapshots_single_is_identity_on_counters():
    s1 = _busy_metrics([2, 2], tokens=4, latency=0.2).to_dict()
    merged = merge_snapshots([s1])
    for key, val in s1.items():
        if key in ("tokens_per_s", "tokens_per_s_ex_compile", "elapsed_s"):
            continue  # recomputed against max-elapsed; equal up to clock read
        if isinstance(val, (int, float)):
            assert merged[key] == pytest.approx(val), key


def test_merge_snapshots_rejects_empty():
    with pytest.raises(ValueError, match="at least one"):
        merge_snapshots([])


# ---------------------------------------------------------------------------
# cross-replica engine differential (the acceptance gate)
# ---------------------------------------------------------------------------

# tight pool (5 usable pages/replica) + swap: concurrent slots force
# demotions; two prompt families force aliasing; journal feeds the replay
ENGINE_CFG = EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                          page_size=8, n_pages=6, share_prefixes=True,
                          swap=SwapConfig(), obs=ObsConfig(journal=True))


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _workload():
    """Two shared-prefix families + long singletons, working set sized to
    oversubscribe each replica's pool. Returns (wave1, wave2): the second
    wave arrives after the first is in flight, so the prefix view is warm
    for affinity routing."""
    rng = np.random.default_rng(42)
    sys_a = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
    sys_b = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)

    def fam(rid, sys_prompt, tier):
        tail = rng.integers(0, CFG.vocab_size, 6).astype(np.int32)
        return Request(rid=rid, prompt=np.concatenate([sys_prompt, tail]),
                       max_new_tokens=3, tier=tier)

    def single(rid, plen, tier):
        return Request(rid=rid,
                       prompt=rng.integers(0, CFG.vocab_size,
                                           plen).astype(np.int32),
                       max_new_tokens=3, tier=tier)

    wave1 = [fam(0, sys_a, 8), fam(1, sys_b, 4), single(2, 30, 8)]
    wave2 = [fam(3, sys_a, 8), fam(4, sys_a, 8), fam(5, sys_b, 4),
             single(6, 26, 6), fam(7, sys_a, 8)]
    return wave1, wave2


@pytest.fixture(scope="module")
def solo_tokens(served):
    """Each request served alone in a single-slot engine — the oracle every
    policy's routed run must match bitwise."""
    params, bank = served
    solo_cfg = dataclasses.replace(ENGINE_CFG, n_slots=1, obs=None)
    out = {}
    for req in [*_workload()[0], *_workload()[1]]:
        eng = ContinuousBatchingEngine(params, CFG, LEX, bank, solo_cfg)
        eng.submit(dataclasses.replace(req))
        done = eng.run()
        out[req.rid] = done[req.rid].generated_tokens
    return out


def _route_workload(params, bank, policy):
    router = ReplicaRouter(params, CFG, LEX, bank, ENGINE_CFG,
                           n_replicas=2, policy=policy)
    wave1, wave2 = _workload()
    for req in wave1:
        router.submit(dataclasses.replace(req))
    for _ in range(16):          # wave 1 in flight; prefixes registering
        router.step()
    for req in wave2:
        router.submit(dataclasses.replace(req))
    router.run()
    return router


@pytest.mark.parametrize("policy", ["rr", "load", "affinity"])
def test_cross_replica_differential(served, solo_tokens, policy):
    """Tokens through the routed fleet == solo single-engine run, bitwise,
    per request — under swap pressure and prefix aliasing — and the run
    leaves a clean cross-replica journal."""
    params, bank = served
    router = _route_workload(params, bank, policy)
    done = router.completed
    assert sorted(done) == sorted(solo_tokens)
    for rid, tokens in solo_tokens.items():
        assert done[rid].generated_tokens == tokens, (policy, rid)
    # the workload genuinely exercised both features somewhere in the fleet
    d = router.to_dict()
    assert d["pages_aliased"] > 0, "no prefix aliasing happened"
    assert d["pages_demoted"] > 0, "no swap pressure happened"
    assert d["policy"] == policy
    assert sum(d["requests_routed"]) == len(solo_tokens)
    # both replicas actually served traffic (it's a router, not a bypass)
    assert all(n > 0 for n in d["requests_routed"]), d["requests_routed"]
    # global view == each replica's live index, both directions
    for k, eng in enumerate(router.engines):
        assert eng.prefix_index.live_paths() == router.view.paths_for(k)
    # journals replay clean once the shutdown drop empties the caches
    router.drain_caches()
    assert len(router.view) == 0
    assert replay_check_multi(router.replica_journals(),
                              router.log.events) == []
    for eng in router.engines:
        eng.allocator.check_balanced()


def test_replicas_share_one_bank_object(served):
    """The dictionary bank is constructed once and shared by reference —
    the universal-dictionary property the scale-out design leans on."""
    params, bank = served
    router = ReplicaRouter(params, CFG, LEX, bank, ENGINE_CFG,
                           n_replicas=2, policy="rr")
    assert all(eng.bank is bank for eng in router.engines)
    assert router.bank is bank


def test_router_to_dict_golden_keys(served):
    """Router-level to_dict = the merged single-engine schema plus exactly
    the router's own appended keys."""
    params, bank = served
    router = _route_workload(params, bank, "affinity")
    d = router.to_dict()
    single = router.engines[0].metrics.to_dict()
    assert list(d.keys()) == (list(single.keys())
                              + ["n_replicas", "policy", "requests_routed",
                                 "per_replica"])
    assert d["n_replicas"] == 2
    assert len(d["per_replica"]) == 2
    # per-replica counters sum to the fleet totals (no double counting)
    assert sum(r["tokens_generated"] for r in d["per_replica"]) \
        == d["tokens_generated"]


def test_router_rejects_duplicate_rid(served):
    params, bank = served
    router = ReplicaRouter(params, CFG, LEX, bank, ENGINE_CFG,
                           n_replicas=2, policy="rr")
    req = _workload()[0][0]
    router.submit(dataclasses.replace(req))
    with pytest.raises(ValueError, match="already routed"):
        router.submit(dataclasses.replace(req))
