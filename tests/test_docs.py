"""CI gate for the docs/ subsystem.

Keeps the documentation from rotting out from under the code:

  * the three core pages exist and are non-trivial;
  * every relative markdown link inside docs/ and README.md resolves to a
    real file (anchors are stripped — heading drift is a lesser evil than a
    dead page);
  * every public symbol exported from ``repro.serving`` appears in
    docs/serving.md, so a new export forces a documentation entry.
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
DOCS = REPO / "docs"

REQUIRED_PAGES = ["architecture.md", "serving.md", "memory_accounting.md",
                  "tiered_memory.md", "observability.md", "kernels.md",
                  "routing.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")


def test_docs_pages_exist():
    assert DOCS.is_dir(), "docs/ directory missing"
    for page in REQUIRED_PAGES:
        path = DOCS / page
        assert path.is_file(), f"docs/{page} missing"
        assert len(path.read_text()) > 500, f"docs/{page} is a stub"


def _md_files():
    return [REPO / "README.md"] + sorted(DOCS.glob("*.md"))


@pytest.mark.parametrize("md", _md_files(), ids=lambda p: p.name)
def test_internal_links_resolve(md):
    if not md.exists():
        pytest.skip(f"{md} absent")
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (md.parent / rel).resolve()
        assert resolved.exists(), f"{md.name}: dead link -> {target}"


def test_readme_links_all_doc_pages():
    readme = (REPO / "README.md").read_text()
    for page in REQUIRED_PAGES:
        assert f"docs/{page}" in readme, f"README does not link docs/{page}"


def test_every_serving_export_documented():
    import repro.serving as serving

    text = (DOCS / "serving.md").read_text()
    missing = [sym for sym in serving.__all__ if sym not in text]
    assert not missing, (
        f"docs/serving.md does not mention public serving symbols: {missing}")


def test_every_obs_export_documented():
    import repro.serving.obs as obs

    text = (DOCS / "observability.md").read_text()
    missing = [sym for sym in obs.__all__ if sym not in text]
    assert not missing, (
        f"docs/observability.md does not mention public obs symbols: "
        f"{missing}")
