"""Randomized slot-lifecycle fuzz: admit / step / retire under paged storage.

Drives the exact host-side bookkeeping loop the engine runs (FCFS admission
with page-granular budgets, prompt-page allocation at splice, lazy one-page
growth per decode step, free-on-retire) over hundreds of randomized traces,
without the model — the device arrays are irrelevant to the allocation
contract. Invariants checked at every step:

  * the allocator never exhausts (admission reserved completion-time pages);
  * a slot never holds more pages than its reservation;
  * bytes/pages admitted never exceed the configured budgets;
  * no page is double-freed (the allocator raises), and every trace ends
    with the allocator exactly balanced — zero leaked pages.

The engine-integrated version of the same contract (real device pool) is
``tests/test_paged_cache.py::test_engine_paged_matches_contiguous_oracle``.
"""
import numpy as np
import pytest

from repro.serving import (
    FCFSScheduler, PageAllocator, Request, SlotInfo, SlotPool, pages_needed,
)
from repro.serving.engine import _bucket   # the engine's own bucketing

M_DIM, N_LAYERS, KV_HEADS = 16, 2, 2


def _run_trace(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    n_b = int(rng.integers(2, 6))
    min_bucket = n_b + int(rng.integers(1, 5))
    page_size = int(rng.choice([2, 4, 8]))
    n_slots = int(rng.integers(1, 5))
    n_pages = int(rng.integers(6, 40))
    allocator = PageAllocator(n_pages, page_size)
    byte_budget = (None if rng.random() < 0.3
                   else int(rng.integers(20_000, 200_000)))
    sched = FCFSScheduler(
        kv_byte_budget=byte_budget, n_b=n_b, m=M_DIM, num_layers=N_LAYERS,
        kv_heads=KV_HEADS, page_size=page_size,
        page_budget=allocator.capacity)
    pool = SlotPool(n_slots)

    n_requests = int(rng.integers(3, 14))
    submitted = 0
    for rid in range(n_requests):
        prompt_len = int(rng.integers(min_bucket, 6 * page_size + min_bucket))
        req = Request(rid=rid, prompt=np.zeros(prompt_len, np.int32),
                      max_new_tokens=int(rng.integers(1, 12)),
                      tier=int(rng.choice([2, 4, 8])))
        # engine.submit contract: drop never-admissible requests up front
        if sched.projected_pages(req) > allocator.capacity:
            continue
        if byte_budget is not None and sched.projected_bytes(req) > byte_budget:
            continue
        sched.submit(req)
        submitted += 1

    completed, steps, peak_pages = 0, 0, 0
    while (len(sched) or pool.active_slots()) and steps < 10_000:
        steps += 1
        # --- admit (mirrors ContinuousBatchingEngine._admit) ---
        for req in sched.admit(len(pool.free_slots())):
            bucket = _bucket(req.prompt_len, min_bucket)
            info = SlotInfo(request=req, fed=bucket, cache_len=bucket,
                            pages_reserved=sched.projected_pages(req))
            slot = pool.allocate(info)
            n_prompt = pages_needed(info.cache_len - n_b, page_size)
            info.pages = allocator.alloc(n_prompt)   # must never exhaust
            assert len(info.pages) <= info.pages_reserved

        # --- advance every active slot one token (lazy page growth) ---
        for slot in pool.active_slots():
            info = pool.slots[slot]
            need = pages_needed(info.cache_len - n_b + 1, page_size)
            while len(info.pages) < need:
                info.pages += allocator.alloc(1)
            assert len(info.pages) <= info.pages_reserved, \
                "slot outgrew its admission reservation"
            info.cache_len += 1
            if info.in_prompt_phase:
                info.fed += 1
            else:
                info.generated += 1
            if info.done:
                pool.retire(slot)
                allocator.free(info.pages)
                info.pages = []
                sched.release(info.request)
                completed += 1

        # --- per-step global invariants ---
        peak_pages = max(peak_pages, allocator.n_used)
        assert allocator.n_used <= allocator.capacity
        assert sched.pages_admitted <= allocator.capacity
        if byte_budget is not None:
            assert sched.bytes_admitted <= byte_budget
        held = sum(len(pool.slots[i].pages) for i in pool.active_slots())
        assert held == allocator.n_used, "pages leaked outside live slots"

    assert completed == submitted, (completed, submitted, seed)
    assert allocator.check_balanced(), f"page leak (seed {seed})"
    assert sched.bytes_admitted == 0 and sched.pages_admitted == 0
    return {"steps": steps, "completed": completed, "peak_pages": peak_pages}


def test_lifecycle_fuzz_many_traces():
    stats = [_run_trace(seed) for seed in range(150)]
    # the fuzz actually exercised contention: some trace had to queue on
    # pages/bytes while others sailed through
    assert max(x["peak_pages"] for x in stats) > 4
    assert sum(x["completed"] for x in stats) > 300


def test_double_free_is_detected():
    allocator = PageAllocator(6, 4)
    pages = allocator.alloc(2)
    allocator.free(pages)
    with pytest.raises(KeyError, match="double free"):
        allocator.free(pages)


def test_refcounted_page_survives_one_owner_retiring():
    """Prefix-sharing contract: a page pinned by two owners only returns to
    the free list when the second ref drops."""
    allocator = PageAllocator(6, 4)
    (page,) = allocator.alloc(1)
    allocator.incref(page)          # second owner
    allocator.decref(page)
    assert allocator.refcount(page) == 1 and allocator.n_used == 1
    allocator.decref(page)
    assert allocator.check_balanced()
