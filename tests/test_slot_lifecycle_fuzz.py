"""Randomized slot-lifecycle fuzz: admit / step / retire under paged storage.

Drives the exact host-side bookkeeping loop the engine runs (FCFS admission
with page-granular budgets, prompt-page allocation at splice, lazy one-page
growth per decode step, free-on-retire) over hundreds of randomized traces,
without the model — the device arrays are irrelevant to the allocation
contract. Invariants checked at every step:

  * the allocator never exhausts (admission reserved completion-time pages);
  * a slot never holds more pages than its reservation;
  * bytes/pages admitted never exceed the configured budgets;
  * no page is double-freed (the allocator raises), and every trace ends
    with the allocator exactly balanced — zero leaked pages.

A second trace family (``_run_shared_trace``) layers prefix sharing on top:
requests drawn from a few prompt families alias each other's pages through
a ``PrefixIndex``, the boundary page is copied-on-write, admission charges
only new pages, and the free list is topped up by LRU eviction of cached
pages. Extra invariants: every page's refcount equals the number of slots
binding it plus its index pin, pool occupancy equals the union of
slot-bound and index-pinned pages, and after the index drops its pins the
allocator balances exactly.

A third trace family (``_run_swap_trace``) adds the tiered-storage moves:
random pages demote to a ``HostPageStore`` (their device ids immediately
reusable by ``alloc``) and promote back before their slot steps. Extra
invariants: refcounts — including extra pins — survive a demote→promote
round trip exactly, a page's payload is bitwise intact after its old device
id was re-handed out, a swapped page (a ``PageHandle``) is never what
``alloc`` returns, and at drain BOTH tiers balance (``check_balanced`` on
the allocator and the host store).

A fourth trace family (``_run_router_trace``) goes multi-replica: 2-3
independent replica states (each its own allocator / index / scheduler /
pool / host tier / journal) behind a real routing policy from
``repro.serving.router`` and a ``GlobalPrefixView`` wired through the
index observer hooks. Randomized route/admit/step/retire/demote traces;
per-step invariants per replica (refcounts, reservation) PLUS the
cross-replica ones: the view's entries for a replica always equal that
replica's live index paths (neither side outlives the other — including
across swap_out/swap_in, which re-keys the page but not the path), every
request is admitted on exactly the replica it was routed to, and at drain
every replica's tiers balance and the per-replica journals + router log
replay clean through ``replay_check_multi``.

The engine-integrated version of the same contract (real device pool) is
``tests/test_paged_cache.py::test_engine_paged_matches_contiguous_oracle``
plus ``tests/test_prefix_sharing.py`` and ``tests/test_swap.py`` (and
``tests/test_router.py`` for the multi-replica differential).
"""
from collections import Counter

import numpy as np
import pytest

from repro.serving import (
    FCFSScheduler, GlobalPrefixView, HostPageStore, PageAllocator,
    PageHandle, PrefixIndex, Request, SlotInfo, SlotPool, make_policy,
    pages_needed, prefix_paths,
)
from repro.serving.engine import _bucket   # the engine's own bucketing
from repro.serving.obs import EventJournal, replay_check_multi
from repro.serving.router import ReplicaSnapshot

M_DIM, N_LAYERS, KV_HEADS = 16, 2, 2


def _run_trace(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    n_b = int(rng.integers(2, 6))
    min_bucket = n_b + int(rng.integers(1, 5))
    page_size = int(rng.choice([2, 4, 8]))
    n_slots = int(rng.integers(1, 5))
    n_pages = int(rng.integers(6, 40))
    allocator = PageAllocator(n_pages, page_size)
    byte_budget = (None if rng.random() < 0.3
                   else int(rng.integers(20_000, 200_000)))
    sched = FCFSScheduler(
        kv_byte_budget=byte_budget, n_b=n_b, m=M_DIM, num_layers=N_LAYERS,
        kv_heads=KV_HEADS, page_size=page_size,
        page_budget=allocator.capacity)
    pool = SlotPool(n_slots)

    n_requests = int(rng.integers(3, 14))
    submitted = 0
    for rid in range(n_requests):
        prompt_len = int(rng.integers(min_bucket, 6 * page_size + min_bucket))
        req = Request(rid=rid, prompt=np.zeros(prompt_len, np.int32),
                      max_new_tokens=int(rng.integers(1, 12)),
                      tier=int(rng.choice([2, 4, 8])))
        # engine.submit contract: drop never-admissible requests up front
        if sched.projected_pages(req) > allocator.capacity:
            continue
        if byte_budget is not None and sched.projected_bytes(req) > byte_budget:
            continue
        sched.submit(req)
        submitted += 1

    completed, steps, peak_pages = 0, 0, 0
    while (len(sched) or pool.active_slots()) and steps < 10_000:
        steps += 1
        # --- admit (mirrors ContinuousBatchingEngine._admit) ---
        for req in sched.admit(len(pool.free_slots())):
            bucket = _bucket(req.prompt_len, min_bucket)
            info = SlotInfo(request=req, fed=bucket, cache_len=bucket,
                            pages_reserved=sched.projected_pages(req))
            slot = pool.allocate(info)
            n_prompt = pages_needed(info.cache_len - n_b, page_size)
            info.pages = allocator.alloc(n_prompt)   # must never exhaust
            assert len(info.pages) <= info.pages_reserved

        # --- advance every active slot one token (lazy page growth) ---
        for slot in pool.active_slots():
            info = pool.slots[slot]
            need = pages_needed(info.cache_len - n_b + 1, page_size)
            while len(info.pages) < need:
                info.pages += allocator.alloc(1)
            assert len(info.pages) <= info.pages_reserved, \
                "slot outgrew its admission reservation"
            info.cache_len += 1
            if info.in_prompt_phase:
                info.fed += 1
            else:
                info.generated += 1
            if info.done:
                pool.retire(slot)
                allocator.free(info.pages)
                info.pages = []
                sched.release(info.request)
                completed += 1

        # --- per-step global invariants ---
        peak_pages = max(peak_pages, allocator.n_used)
        assert allocator.n_used <= allocator.capacity
        assert sched.pages_admitted <= allocator.capacity
        if byte_budget is not None:
            assert sched.bytes_admitted <= byte_budget
        held = sum(len(pool.slots[i].pages) for i in pool.active_slots())
        assert held == allocator.n_used, "pages leaked outside live slots"

    assert completed == submitted, (completed, submitted, seed)
    assert allocator.check_balanced(), f"page leak (seed {seed})"
    assert sched.bytes_admitted == 0 and sched.pages_admitted == 0
    return {"steps": steps, "completed": completed, "peak_pages": peak_pages}


def test_lifecycle_fuzz_many_traces():
    stats = [_run_trace(seed) for seed in range(150)]
    # the fuzz actually exercised contention: some trace had to queue on
    # pages/bytes while others sailed through
    assert max(x["peak_pages"] for x in stats) > 4
    assert sum(x["completed"] for x in stats) > 300


# ---------------------------------------------------------------------------
# shared admissions: the prefix-sharing variant of the same loop
# ---------------------------------------------------------------------------

def _run_shared_trace(seed: int) -> dict:
    """Mirror of ``ContinuousBatchingEngine._admit_one``/``_grow_pages``/
    retire under ``share_prefixes=True``, host bookkeeping only."""
    rng = np.random.default_rng(seed)
    n_b = int(rng.integers(2, 6))
    min_bucket = n_b + int(rng.integers(1, 5))
    page_size = int(rng.choice([2, 4, 8]))
    n_slots = int(rng.integers(1, 5))
    n_pages = int(rng.integers(8, 40))
    allocator = PageAllocator(n_pages, page_size)
    index = PrefixIndex(page_size)
    sched = FCFSScheduler(
        kv_byte_budget=None, n_b=n_b, m=M_DIM, num_layers=N_LAYERS,
        kv_heads=KV_HEADS, page_size=page_size,
        page_budget=allocator.capacity)
    pool = SlotPool(n_slots)

    # prompt families: shared prefixes happen by construction
    families = [rng.integers(0, 1000, 64).astype(np.int64) for _ in range(3)]

    n_requests = int(rng.integers(4, 16))
    submitted = 0
    for rid in range(n_requests):
        prompt_len = int(rng.integers(min_bucket, 6 * page_size + min_bucket))
        fam = families[int(rng.integers(0, len(families)))]
        prompt = fam[:prompt_len].copy()
        if rng.random() < 0.3:      # diverge somewhere inside the prompt
            cut = int(rng.integers(0, prompt_len))
            prompt[cut:] = rng.integers(0, 1000, prompt_len - cut)
        req = Request(rid=rid, prompt=prompt.astype(np.int32),
                      max_new_tokens=int(rng.integers(1, 12)),
                      tier=int(rng.choice([4, 8])))
        if sched.projected_pages(req) > allocator.capacity:
            continue
        sched.submit(req)
        submitted += 1

    plans = {}

    def shared_fn(req):
        bucket = _bucket(req.prompt_len, min_bucket)
        plan = index.lookup(req.prompt[:bucket], req.tier, bucket - n_b)
        plans[req.rid] = plan
        pinned = len(plan.aliased) + (1 if plan.copy_src is not None else 0)
        return len(plan.aliased), plan.shared_codes, pinned, 0

    def pool_state_fn():
        owned = sum(pool.slots[i].pages_owned for i in pool.active_slots())
        return {"free": allocator.n_free,
                "evictable": index.evictable_pages(allocator),
                "owned": owned}

    def alloc(n):
        if n > allocator.n_free:
            index.evict(allocator, max_pages=n - allocator.n_free)
        return allocator.alloc(n)      # must never exhaust

    def check_invariants():
        held = Counter(p for i in pool.active_slots()
                       for p in pool.slots[i].pages)
        resident = set(held) | set(index._registered)
        assert allocator.n_used == len(resident), "stray allocated pages"
        for p in resident:
            expect = held.get(p, 0) + (1 if p in index._registered else 0)
            assert allocator.refcount(p) == expect, (p, seed)
        owned = sum(pool.slots[i].pages_owned for i in pool.active_slots())
        # reservation invariant: outstanding future growth always fits in
        # free + evictable (this is what admission checked)
        assert (sched.pages_admitted - owned
                <= allocator.n_free + index.evictable_pages(allocator)), seed
        assert sched.pages_admitted <= allocator.capacity

    completed, steps, peak_shared, hits = 0, 0, 0, 0
    while (len(sched) or pool.active_slots()) and steps < 10_000:
        steps += 1
        while pool.free_slots():
            admitted = sched.admit(1, shared_fn=shared_fn,
                                   pool_state_fn=pool_state_fn)
            if not admitted:
                break
            req = admitted[0]
            bucket = _bucket(req.prompt_len, min_bucket)
            plan = plans.pop(req.rid)
            n_comp = bucket - n_b
            n_prompt = pages_needed(n_comp, page_size)
            info = SlotInfo(request=req, fed=bucket, cache_len=bucket,
                            pages_reserved=max(
                                sched.projected_pages(req) - len(plan.aliased),
                                0))
            for p in plan.aliased:
                allocator.incref(p)
            if plan.copy_src is not None:
                # mirror the engine: pin the CoW source across the alloc so
                # only_free eviction can't free-and-recycle it
                allocator.incref(plan.copy_src)
            new_pages = alloc(n_prompt - len(plan.aliased))
            info.pages = list(plan.aliased) + new_pages
            info.pages_shared = len(plan.aliased)
            if plan.copy_src is not None:
                assert new_pages, "CoW needs a destination page"
                allocator.decref(plan.copy_src)
            pool.allocate(info)
            index.commit(plan)
            hits += 1 if plan.hit else 0
            index.register(req.prompt[:bucket], req.tier, info.pages,
                           n_comp, allocator)

        for slot in pool.active_slots():
            info = pool.slots[slot]
            need = pages_needed(info.cache_len - n_b + 1, page_size)
            while len(info.pages) < need:
                info.pages += alloc(1)
            assert info.pages_owned <= info.pages_reserved, \
                "slot outgrew its admission reservation"
            info.cache_len += 1
            if info.in_prompt_phase:
                info.fed += 1
            else:
                info.generated += 1
            if info.done:
                pool.retire(slot)
                allocator.free(info.pages)
                info.pages, info.pages_shared = [], 0
                sched.release(info.request)
                completed += 1

        held = Counter(p for i in pool.active_slots()
                       for p in pool.slots[i].pages)
        peak_shared = max(peak_shared,
                          sum(1 for c in held.values() if c >= 2))
        check_invariants()

    assert completed == submitted, (completed, submitted, seed)
    index.clear(allocator)
    assert allocator.check_balanced(), f"page leak (seed {seed})"
    assert sched.bytes_admitted == 0 and sched.pages_admitted == 0
    return {"steps": steps, "completed": completed,
            "peak_shared": peak_shared, "hits": hits}


def test_shared_lifecycle_fuzz_many_traces():
    stats = [_run_shared_trace(seed) for seed in range(120)]
    # sharing genuinely happened: pages held by >= 2 slots at once, and the
    # trie served real hits
    assert max(x["peak_shared"] for x in stats) >= 1
    assert sum(x["hits"] for x in stats) > 40
    assert sum(x["completed"] for x in stats) > 300


# ---------------------------------------------------------------------------
# tiered storage: demote/promote actions in the randomized traces
# ---------------------------------------------------------------------------

def _run_swap_trace(seed: int) -> dict:
    """``_run_trace`` plus host-tier moves: random demotions of live slots'
    pages into a ``HostPageStore`` (sometimes carrying an extra pin, the way
    a prefix-index entry would), mandatory promotion before the owning slot
    steps, payload-integrity and refcount-conservation checks on every round
    trip, and two-tier balance at drain."""
    rng = np.random.default_rng(seed)
    n_b = int(rng.integers(2, 6))
    min_bucket = n_b + int(rng.integers(1, 5))
    page_size = int(rng.choice([2, 4, 8]))
    n_slots = int(rng.integers(1, 5))
    n_pages = int(rng.integers(6, 40))
    allocator = PageAllocator(n_pages, page_size)
    host = HostPageStore()
    sched = FCFSScheduler(
        kv_byte_budget=None, n_b=n_b, m=M_DIM, num_layers=N_LAYERS,
        kv_heads=KV_HEADS, page_size=page_size,
        page_budget=allocator.capacity)
    pool = SlotPool(n_slots)

    n_requests = int(rng.integers(3, 14))
    submitted = 0
    for rid in range(n_requests):
        prompt_len = int(rng.integers(min_bucket, 6 * page_size + min_bucket))
        req = Request(rid=rid, prompt=np.zeros(prompt_len, np.int32),
                      max_new_tokens=int(rng.integers(1, 12)),
                      tier=int(rng.choice([2, 4, 8])))
        if sched.projected_pages(req) > allocator.capacity:
            continue
        sched.submit(req)
        submitted += 1

    # handle -> (payload marker, transferred refs, carries an extra pin)
    expected = {}
    marker_clock = [0]

    def demote(info, j):
        page = info.pages[j]
        pinned = bool(rng.random() < 0.5)
        if pinned:                       # an index-pin-style second holder
            allocator.incref(page)
        refs = allocator.refcount(page)
        marker_clock[0] += 1
        marker = np.float32(seed * 10_000 + marker_clock[0])
        stores = tuple(np.full((3,), marker) for _ in range(4))
        handle = host.put(stores, refs=refs)
        moved = allocator.demote(page)
        assert moved == refs
        info.pages[j] = handle

        expected[handle] = (marker, refs, pinned)

    def promote(info, j):
        handle = info.pages[j]
        marker, want_refs, pinned = expected.pop(handle)
        assert host.refcount(handle) == want_refs
        stores, refs = host.pop(handle)
        # refcounts survive the round trip; payload survived its old device
        # id being re-handed out by alloc in the meantime
        assert refs == want_refs
        assert all(np.all(s == marker) for s in stores)
        page = allocator.promote(refs)
        assert not isinstance(page, PageHandle)   # device ids only
        assert allocator.refcount(page) == refs
        info.pages[j] = page
        if pinned:                        # the extra holder lets go
            allocator.decref(page)
            assert allocator.refcount(page) == 1

    def alloc(n):
        pages = allocator.alloc(n)
        for p in pages:
            # a swapped page is never handed out: alloc returns device ids,
            # handles live in a disjoint namespace
            assert not isinstance(p, PageHandle)
        return pages

    completed, steps, demotions, promotions = 0, 0, 0, 0
    while (len(sched) or pool.active_slots()) and steps < 10_000:
        steps += 1
        for req in sched.admit(len(pool.free_slots())):
            bucket = _bucket(req.prompt_len, min_bucket)
            info = SlotInfo(request=req, fed=bucket, cache_len=bucket,
                            pages_reserved=sched.projected_pages(req))
            pool.allocate(info)
            info.pages = alloc(pages_needed(info.cache_len - n_b, page_size))

        # random demotions of resident pages (their slots are idle "now")
        for slot in pool.active_slots():
            info = pool.slots[slot]
            for j, entry in enumerate(info.pages):
                if not isinstance(entry, PageHandle) and rng.random() < 0.15:
                    demote(info, j)
                    demotions += 1

        for slot in pool.active_slots():
            info = pool.slots[slot]
            # a slot steps only fully device-resident: promote its handles
            # (admission reserved every in-flight page, so the device pool
            # can always take a promoted page back)
            for j, entry in enumerate(info.pages):
                if isinstance(entry, PageHandle):
                    promote(info, j)
                    promotions += 1
            need = pages_needed(info.cache_len - n_b + 1, page_size)
            while len(info.pages) < need:
                info.pages += alloc(1)
            assert len(info.pages) <= info.pages_reserved
            info.cache_len += 1
            if info.in_prompt_phase:
                info.fed += 1
            else:
                info.generated += 1
            if info.done:
                pool.retire(slot)
                # two-tier release: device pages decref, swapped drop host
                for entry in info.pages:
                    if isinstance(entry, PageHandle):
                        if host.decref(entry):
                            expected.pop(entry)
                    else:
                        allocator.decref(entry)
                info.pages = []
                sched.release(info.request)
                completed += 1

        # per-step two-tier invariants: no page counted (or lost) anywhere
        device_held = [p for i in pool.active_slots()
                       for p in pool.slots[i].pages
                       if not isinstance(p, PageHandle)]
        swapped_held = [p for i in pool.active_slots()
                        for p in pool.slots[i].pages
                        if isinstance(p, PageHandle)]
        assert allocator.n_used == len(device_held), "device-tier leak"
        assert host.n_pages == len(swapped_held) == len(expected), \
            "host-tier leak"
        assert sched.pages_admitted <= allocator.capacity

    assert completed == submitted, (completed, submitted, seed)
    # both tiers balance at drain (the satellite contract)
    assert allocator.check_balanced(), f"device page leak (seed {seed})"
    assert host.check_balanced(), f"host page leak (seed {seed})"
    assert sched.bytes_admitted == 0 and sched.pages_admitted == 0
    return {"steps": steps, "completed": completed,
            "demotions": demotions, "promotions": promotions}


def test_swap_lifecycle_fuzz_many_traces():
    stats = [_run_swap_trace(seed) for seed in range(120)]
    # the traces genuinely moved pages across tiers, both directions
    assert sum(x["demotions"] for x in stats) > 200
    assert sum(x["promotions"] for x in stats) > 100
    assert sum(x["completed"] for x in stats) > 250


# ---------------------------------------------------------------------------
# multi-replica: routed traces against independent replica states
# ---------------------------------------------------------------------------

class _Replica:
    """One replica's full host-side serving state for the router fuzz:
    allocator + prefix index + scheduler + slot pool + host swap tier, all
    journaled, running the ``_run_shared_trace`` admission/advance loop with
    the swap-aware extras (promote-at-admission for plan entries demoted to
    the host tier, random demotions of index-pin-only pages)."""

    def __init__(self, rid_: int, rng, *, n_b, min_bucket, page_size):
        self.k = rid_
        self.n_b, self.min_bucket, self.page_size = n_b, min_bucket, page_size
        self.n_slots = int(rng.integers(1, 4))
        self.journal = EventJournal()
        self.allocator = PageAllocator(int(rng.integers(16, 40)), page_size)
        self.allocator.journal = self.journal
        self.host = HostPageStore()
        self.host.journal = self.journal
        self.index = PrefixIndex(page_size)
        self.index.add_observer(
            lambda p: self.journal.emit("prefix_publish", path=p.hex()),
            lambda p: self.journal.emit("prefix_drop", path=p.hex()))
        self.sched = FCFSScheduler(
            kv_byte_budget=None, n_b=n_b, m=M_DIM, num_layers=N_LAYERS,
            kv_heads=KV_HEADS, page_size=page_size,
            page_budget=self.allocator.capacity)
        self.pool = SlotPool(self.n_slots)
        self.plans = {}
        self.completed = 0
        self.admitted = 0
        self.hits = self.demotions = self.promotions = 0

    @property
    def busy(self) -> bool:
        return bool(len(self.sched) or self.pool.active_slots())

    def snapshot(self) -> ReplicaSnapshot:
        return ReplicaSnapshot(
            replica_id=self.k, queue_depth=len(self.sched),
            active_slots=len(self.pool.active_slots()), n_slots=self.n_slots,
            queued_bytes=self.sched.queued_bytes(),
            kv_bytes_resident=0, host_bytes_resident=0,
            free_pages=self.allocator.n_free,
            total_pages=self.allocator.capacity)

    # ------------------------------------------------------- admission loop

    def _shared_fn(self, req):
        bucket = _bucket(req.prompt_len, self.min_bucket)
        plan = self.index.lookup(req.prompt[:bucket], req.tier,
                                 bucket - self.n_b)
        self.plans[req.rid] = plan
        refs = list(plan.aliased)
        if plan.copy_src is not None:
            refs.append(plan.copy_src)
        promote = sum(1 for p in refs if isinstance(p, PageHandle))
        return len(plan.aliased), plan.shared_codes, len(refs) - promote, \
            promote

    def _pool_state_fn(self):
        owned = sum(self.pool.slots[i].pages_owned
                    for i in self.pool.active_slots())
        return {"free": self.allocator.n_free,
                "evictable": self.index.evictable_pages(self.allocator),
                "owned": owned}

    def _alloc(self, n):
        if n > self.allocator.n_free:
            self.index.evict(self.allocator,
                             max_pages=n - self.allocator.n_free,
                             host=self.host)
        return self.allocator.alloc(n)      # must never exhaust

    def _promote(self, handle):
        """Promote one host-tier plan entry back to a device page. The
        caller already holds a temp host ref on ``handle``, so a concurrent
        eviction dropping its index pin cannot free it; the transferred
        temp ref becomes the caller's hold on the device page."""
        if self.allocator.n_free == 0:
            self.index.evict(self.allocator, max_pages=1, host=self.host)
        stores, refs = self.host.pop(handle)
        page = self.allocator.promote(refs)
        self.index.swap_in(handle, page)    # no-op if the pin was evicted
        self.promotions += 1
        return page

    def admit_all(self):
        while self.pool.free_slots():
            got = self.sched.admit(1, shared_fn=self._shared_fn,
                                   pool_state_fn=self._pool_state_fn)
            if not got:
                break
            req = got[0]
            bucket = _bucket(req.prompt_len, self.min_bucket)
            plan = self.plans.pop(req.rid)
            n_comp = bucket - self.n_b
            n_prompt = pages_needed(n_comp, self.page_size)
            info = SlotInfo(request=req, fed=bucket, cache_len=bucket,
                            pages_reserved=max(
                                self.sched.projected_pages(req)
                                - len(plan.aliased), 0))
            aliased = list(plan.aliased)
            copy_src = plan.copy_src
            # pin every device plan page, temp-ref every host-tier one:
            # eviction triggered by the promotes/allocs below can then
            # neither recycle nor drop a page this admission is using
            for p in aliased:
                if isinstance(p, PageHandle):
                    self.host.incref(p)
                else:
                    self.allocator.incref(p)
            if copy_src is not None:
                if isinstance(copy_src, PageHandle):
                    self.host.incref(copy_src)
                else:
                    self.allocator.incref(copy_src)
            # prefix hit on a swapped page: promote it back instead of
            # recompressing (the scheduler's reservation check priced it)
            for j, p in enumerate(aliased):
                if isinstance(p, PageHandle):
                    aliased[j] = self._promote(p)
            if isinstance(copy_src, PageHandle):
                copy_src = self._promote(copy_src)
            new_pages = self._alloc(n_prompt - len(aliased))
            info.pages = aliased + new_pages
            info.pages_shared = len(aliased)
            if copy_src is not None:
                assert new_pages, "CoW needs a destination page"
                self.allocator.decref(copy_src)
            slot = self.pool.allocate(info)
            self.index.commit(plan)
            self.hits += 1 if plan.hit else 0
            self.index.register(req.prompt[:bucket], req.tier, info.pages,
                                n_comp, self.allocator, host=self.host)
            self.admitted += 1
            self.journal.emit("admit", rid=req.rid, slot=slot,
                              pages=len(info.pages),
                              aliased=info.pages_shared)

    # --------------------------------------------------------- decode + swap

    def advance(self, rng):
        # random demotions of pages only the index pins (cold cache entries
        # moving to the host tier; the cache entry — and its view path —
        # survives the move)
        for page in [p for p, nd in list(self.index._registered.items())
                     if not isinstance(p, PageHandle)
                     and self.allocator.refcount(p) == 1]:
            if rng.random() < 0.2:
                refs = self.allocator.refcount(page)
                handle = self.host.put((np.zeros(1, np.float32),), refs=refs)
                self.allocator.demote(page)
                self.index.swap_out(page, handle)
                self.demotions += 1

        for slot in self.pool.active_slots():
            info = self.pool.slots[slot]
            need = pages_needed(info.cache_len - self.n_b + 1, self.page_size)
            while len(info.pages) < need:
                info.pages += self._alloc(1)
            assert info.pages_owned <= info.pages_reserved, \
                "slot outgrew its admission reservation"
            info.cache_len += 1
            if info.in_prompt_phase:
                info.fed += 1
            else:
                info.generated += 1
            if info.done:
                self.pool.retire(slot)
                self.allocator.free(info.pages)
                info.pages, info.pages_shared = [], 0
                self.sched.release(info.request)
                self.completed += 1

    # ------------------------------------------------------------ invariants

    def check_invariants(self, seed):
        held = Counter(p for i in self.pool.active_slots()
                       for p in self.pool.slots[i].pages)
        assert not any(isinstance(p, PageHandle) for p in held), \
            "slots hold device pages only in this trace family"
        dev_pins = {p for p in self.index._registered
                    if not isinstance(p, PageHandle)}
        swapped = {p for p in self.index._registered
                   if isinstance(p, PageHandle)}
        resident = set(held) | dev_pins
        assert self.allocator.n_used == len(resident), \
            f"stray allocated pages (replica {self.k}, seed {seed})"
        for p in resident:
            expect = held.get(p, 0) + (1 if p in dev_pins else 0)
            assert self.allocator.refcount(p) == expect, (self.k, p, seed)
        # every host-tier page is exactly one index pin (slots never hold
        # handles here, and temp refs never outlive an admission)
        assert self.host.n_pages == len(swapped), \
            f"host-tier leak (replica {self.k}, seed {seed})"
        for h in swapped:
            assert self.host.refcount(h) == 1, (self.k, h, seed)
        owned = sum(self.pool.slots[i].pages_owned
                    for i in self.pool.active_slots())
        assert (self.sched.pages_admitted - owned
                <= self.allocator.n_free
                + self.index.evictable_pages(self.allocator)), \
            f"reservation invariant (replica {self.k}, seed {seed})"
        assert self.sched.pages_admitted <= self.allocator.capacity


def _run_router_trace(seed: int) -> dict:
    """Multi-replica routed traces: N independent replica states behind a
    real routing policy and a ``GlobalPrefixView`` wired through the index
    observers, requests drawn from fleet-shared prompt families."""
    rng = np.random.default_rng(seed)
    n_b = int(rng.integers(2, 6))
    min_bucket = n_b + int(rng.integers(1, 5))
    page_size = int(rng.choice([2, 4]))
    n_replicas = int(rng.integers(2, 4))
    replicas = [_Replica(k, rng, n_b=n_b, min_bucket=min_bucket,
                         page_size=page_size) for k in range(n_replicas)]

    router_log = EventJournal()
    view = GlobalPrefixView(journal=router_log)
    for rep in replicas:
        view.attach(rep.k, rep.index)
    policy = make_policy(str(rng.choice(["rr", "load", "affinity"])))

    min_cap = min(rep.allocator.capacity for rep in replicas)
    families = [rng.integers(0, 1000, 64).astype(np.int64) for _ in range(3)]
    pending = []
    for rid in range(int(rng.integers(6, 20))):
        prompt_len = int(rng.integers(min_bucket, 4 * page_size + min_bucket))
        prompt = families[int(rng.integers(0, 3))][:prompt_len].copy()
        if rng.random() < 0.3:
            cut = int(rng.integers(0, prompt_len))
            prompt[cut:] = rng.integers(0, 1000, prompt_len - cut)
        req = Request(rid=rid, prompt=prompt.astype(np.int32),
                      max_new_tokens=int(rng.integers(1, 9)),
                      tier=int(rng.choice([4, 8])))
        # must be admissible on ANY replica: the policy is free to pick one
        if replicas[0].sched.projected_pages(req) > min_cap:
            continue
        pending.append(req)
    submitted = len(pending)

    steps = 0
    while (pending or any(rep.busy for rep in replicas)) and steps < 10_000:
        steps += 1
        # --- route a few arrivals through the real policy ---
        for _ in range(int(rng.integers(0, 3))):
            if not pending:
                break
            req = pending.pop(0)
            bucket = _bucket(req.prompt_len, min_bucket)
            paths = prefix_paths(req.prompt[:bucket], req.tier,
                                 bucket - n_b, page_size)
            hits = view.hit_pages(paths)
            choice = policy.route(req, [rep.snapshot() for rep in replicas],
                                  hits)
            view.record_hits(choice, paths)
            router_log.emit("route", rid=req.rid, replica=choice,
                            policy=policy.name,
                            hit_pages=hits.get(choice, 0))
            replicas[choice].sched.submit(req)

        # --- each replica runs its own admission + decode tick ---
        for rep in replicas:
            rep.admit_all()
            rep.advance(rng)

        # --- per-step invariants: per replica, then cross-replica ---
        for rep in replicas:
            rep.check_invariants(seed)
            # a view entry exists exactly as long as the replica's pin does
            assert rep.index.live_paths() == view.paths_for(rep.k), \
                f"view/index divergence (replica {rep.k}, seed {seed})"

    completed = sum(rep.completed for rep in replicas)
    assert completed == submitted, (completed, submitted, seed)
    for rep in replicas:
        rep.index.clear(rep.allocator, host=rep.host)
        assert rep.allocator.check_balanced(), \
            f"device page leak (replica {rep.k}, seed {seed})"
        assert rep.host.check_balanced(), \
            f"host page leak (replica {rep.k}, seed {seed})"
        assert rep.sched.bytes_admitted == 0 and rep.sched.pages_admitted == 0
        assert not view.paths_for(rep.k)
    assert len(view) == 0, f"view outlived every pin (seed {seed})"

    violations = replay_check_multi(
        {rep.k: rep.journal.events for rep in replicas}, router_log.events)
    assert violations == [], (seed, [str(v) for v in violations])
    return {"steps": steps, "completed": completed, "policy": policy.name,
            "replicas_used": sum(1 for rep in replicas if rep.admitted),
            "hits": sum(rep.hits for rep in replicas),
            "demotions": sum(rep.demotions for rep in replicas),
            "promotions": sum(rep.promotions for rep in replicas)}


def test_router_lifecycle_fuzz_many_traces():
    stats = [_run_router_trace(seed) for seed in range(110)]
    # every routing policy got fuzzed, and traffic genuinely spread: some
    # trace had two or more replicas admit requests
    assert {x["policy"] for x in stats} == {"rr", "load", "affinity"}
    assert max(x["replicas_used"] for x in stats) >= 2
    # sharing and tiering genuinely happened inside the routed traces
    assert sum(x["hits"] for x in stats) > 40
    assert sum(x["demotions"] for x in stats) > 50
    assert sum(x["promotions"] for x in stats) > 5
    assert sum(x["completed"] for x in stats) > 300


def test_allocator_demote_promote_state_machine():
    """demote is not free: the refcount transfers out whole and comes back
    whole; the vacated device id is immediately reusable; misuse raises."""
    from repro.serving import NULL_PAGE, PagePoolExhausted

    a = PageAllocator(3, 4)               # 2 usable pages
    (p,) = a.alloc(1)
    a.incref(p)
    assert a.demote(p) == 2               # whole count transferred
    assert a.n_free == 2 and a.refcount(p) == 0
    with pytest.raises(KeyError, match="demote after free"):
        a.demote(p)
    with pytest.raises(ValueError, match="never demoted"):
        a.demote(NULL_PAGE)
    # the vacated id can be re-handed out while the logical page is swapped
    both = a.alloc(2)
    assert p in both
    with pytest.raises(PagePoolExhausted):
        a.promote(2)                      # nothing free to promote into
    a.free(both)
    back = a.promote(2)
    assert a.refcount(back) == 2
    with pytest.raises(ValueError, match=">= 1 holder"):
        a.promote(0)
    a.decref(back)
    a.decref(back)
    assert a.check_balanced()
    assert a.pages_demoted == 1 and a.pages_promoted == 1


def test_double_free_is_detected():
    allocator = PageAllocator(6, 4)
    pages = allocator.alloc(2)
    allocator.free(pages)
    with pytest.raises(KeyError, match="double free"):
        allocator.free(pages)


def test_refcounted_page_survives_one_owner_retiring():
    """Prefix-sharing contract: a page pinned by two owners only returns to
    the free list when the second ref drops."""
    allocator = PageAllocator(6, 4)
    (page,) = allocator.alloc(1)
    allocator.incref(page)          # second owner
    allocator.decref(page)
    assert allocator.refcount(page) == 1 and allocator.n_used == 1
    allocator.decref(page)
    assert allocator.check_balanced()
