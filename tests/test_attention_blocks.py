"""blocked_attention vs naive softmax attention (causal / window / cross)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import blocked_attention, dense_decode_attention


def _naive(q, k, v, causal=True, window=None, q_offset=0):
    B, KV, G, Tq, hd = q.shape
    Tk = k.shape[2]
    s = jnp.einsum("bkgqh,bkth->bkgqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    qp = q_offset + jnp.arange(Tq)[:, None]
    kp = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,bkth->bkgqh", p, v.astype(jnp.float32))


@pytest.mark.parametrize("causal,window", [(True, None), (True, 7), (False, None)])
def test_blocked_matches_naive(rng, causal, window):
    B, KV, G, T, hd = 2, 2, 2, 24, 8
    q = jnp.asarray(rng.normal(size=(B, KV, G, T, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    w = None if window is None else jnp.int32(window)
    out = blocked_attention(q, k, v, causal=causal, window=w,
                            q_chunk=8, kv_chunk=8)
    ref = _naive(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_non_divisible_chunks(rng):
    B, KV, G, T, hd = 1, 1, 1, 15, 8   # 15 not divisible by default chunks
    q = jnp.asarray(rng.normal(size=(B, KV, G, T, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    out = blocked_attention(q, k, k, causal=True, q_chunk=8, kv_chunk=8)
    ref = _naive(q, k, k, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_mla_style_different_v_dim(rng):
    B, KV, G, T, hd, hv = 1, 2, 1, 16, 8, 4
    q = jnp.asarray(rng.normal(size=(B, KV, G, T, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, T, hv)), jnp.float32)
    out = blocked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    assert out.shape == (B, KV, G, T, hv)
    ref = _naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_dense_decode(rng):
    B, KV, G, T, hd = 2, 2, 2, 12, 8
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    length = 9
    out = dense_decode_attention(q, k, v, length=jnp.int32(length))
    s = jnp.einsum("bkgh,bkth->bkgt", q, k[:, :, :length]) / np.sqrt(hd)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bkgt,bkth->bkgh", p, v[:, :, :length])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
