"""OMP correctness: against the naive oracle + hypothesis invariants.
hypothesis is optional — property tests skip when it isn't installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import given, settings, st

from repro.core.omp import (
    clear_gram_cache, gram_cache_info, gram_for, omp_batch, omp_multi_dict,
    reconstruct,
)
from repro.core.ref_omp import omp_ref_batch
from tests.conftest import make_unit_dict


@pytest.mark.parametrize("use_gram", [True, False])
@pytest.mark.parametrize("m,N,s", [(16, 64, 6), (32, 128, 8), (8, 32, 8)])
def test_omp_matches_reference(rng, use_gram, m, N, s):
    D = make_unit_dict(rng, m, N)
    K = rng.normal(size=(6, m)).astype(np.float32)
    res = omp_batch(jnp.asarray(K), jnp.asarray(D, jnp.float32), s, use_gram=use_gram)
    rv, ri, rn, rr2 = omp_ref_batch(K, D, s)
    np.testing.assert_array_equal(np.sort(np.asarray(res.idx), -1), np.sort(ri, -1))
    np.testing.assert_allclose(np.asarray(res.vals), rv, atol=2e-3)
    np.testing.assert_allclose(np.asarray(res.resid2), rr2, rtol=1e-2, atol=1e-4)


def test_omp_precomputed_gram_matches(rng):
    D = jnp.asarray(make_unit_dict(rng, 16, 64), jnp.float32)
    K = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    G = D.T @ D
    a = omp_batch(K, D, 5, use_gram=True)
    b = omp_batch(K, D, 5, use_gram=True, G=G)
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    np.testing.assert_allclose(np.asarray(a.vals), np.asarray(b.vals), atol=1e-6)


def test_gram_cache_single_materialisation(rng):
    """Repeated omp_batch calls with G=None materialise DᵀD exactly once per
    concrete dictionary; dropping the dictionary evicts its entry."""
    clear_gram_cache()
    D = jnp.asarray(make_unit_dict(rng, 16, 64), jnp.float32)
    K = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    for _ in range(4):
        omp_batch(K, D, 5, use_gram=True)
    info = gram_cache_info()
    assert info["misses"] == 1 and info["hits"] == 3, info
    # cached G is the real Gram, and identity-keyed: a copy recomputes
    np.testing.assert_allclose(np.asarray(gram_for(D)),
                               np.asarray(D.T @ D), atol=1e-6)
    D2 = jnp.array(D)
    omp_batch(K, D2, 5, use_gram=True)
    assert gram_cache_info()["misses"] == 2
    # weakref eviction: dropping the dictionaries empties the cache
    del D, D2
    import gc
    gc.collect()
    assert gram_cache_info()["size"] == 0
    clear_gram_cache()


def test_gram_cache_inline_under_trace(rng):
    """Tracers can't be host-cached — gram_for computes inline under jit
    without touching the cache."""
    clear_gram_cache()
    D = jnp.asarray(make_unit_dict(rng, 8, 32), jnp.float32)
    G = jax.jit(gram_for)(D)
    np.testing.assert_allclose(np.asarray(G), np.asarray(D.T @ D), atol=1e-6)
    assert gram_cache_info()["size"] == 0
    clear_gram_cache()


def test_exact_recovery_of_sparse_signals(rng):
    """A signal that IS s-sparse in D is recovered (near-)exactly."""
    m, N, s = 32, 128, 4
    D = make_unit_dict(rng, m, N)
    true_idx = rng.choice(N, size=(8, s), replace=False)
    coef = rng.normal(size=(8, s)) + np.sign(rng.normal(size=(8, s))) * 1.0
    K = np.einsum("bs,mbs->bm", coef, D[:, true_idx.T].transpose(0, 2, 1))
    res = omp_batch(jnp.asarray(K, jnp.float32), jnp.asarray(D, jnp.float32), s)
    rel = np.sqrt(np.asarray(res.resid2)) / np.linalg.norm(K, axis=-1)
    assert np.all(rel < 0.05), rel


@settings(max_examples=20, deadline=None)
@given(s1=st.integers(1, 4), extra=st.integers(1, 4), seed=st.integers(0, 2**16))
def test_error_monotone_in_sparsity(s1, extra, seed):
    """Residual is non-increasing in s (greedy nesting property)."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(make_unit_dict(rng, 12, 48), jnp.float32)
    K = jnp.asarray(rng.normal(size=(3, 12)), jnp.float32)
    r1 = omp_batch(K, D, s1)
    r2 = omp_batch(K, D, s1 + extra)
    assert np.all(np.asarray(r2.resid2) <= np.asarray(r1.resid2) + 1e-5)
    # greedy nesting: first s1 indices agree
    np.testing.assert_array_equal(np.asarray(r1.idx)[:, :s1],
                                  np.asarray(r2.idx)[:, :s1])


@settings(max_examples=15, deadline=None)
@given(delta=st.floats(0.1, 0.9), seed=st.integers(0, 2**16))
def test_threshold_semantics(delta, seed):
    """With early stop at delta, either the target error is met or all s_max
    slots are used; nnz reflects the used slots; truncation == smaller-s run."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(make_unit_dict(rng, 12, 48), jnp.float32)
    K = jnp.asarray(rng.normal(size=(4, 12)), jnp.float32)
    s_max = 10
    res = omp_batch(K, D, s_max, delta=delta)
    nnz = np.asarray(res.nnz)
    rel = np.sqrt(np.asarray(res.resid2)) / np.linalg.norm(np.asarray(K), axis=-1)
    assert np.all((rel <= delta + 1e-5) | (nnz == s_max))
    # unused slots are zeroed
    vals = np.asarray(res.vals)
    for b in range(vals.shape[0]):
        assert np.all(vals[b, nnz[b]:] == 0)


def test_multi_dict_batching(rng):
    d, B, m, N, s = 3, 5, 16, 64, 4
    D = np.stack([make_unit_dict(rng, m, N) for _ in range(d)])
    K = rng.normal(size=(d, B, m)).astype(np.float32)
    res = omp_multi_dict(jnp.asarray(K), jnp.asarray(D, jnp.float32), s)
    for i in range(d):
        single = omp_batch(jnp.asarray(K[i]), jnp.asarray(D[i], jnp.float32), s)
        np.testing.assert_array_equal(np.asarray(res.idx[i]), np.asarray(single.idx))


def test_reconstruct_shapes(rng):
    D = jnp.asarray(make_unit_dict(rng, 16, 64), jnp.float32)
    K = jnp.asarray(rng.normal(size=(2, 3, 16)), jnp.float32)
    res = omp_batch(K, D, 4)
    rec = reconstruct(res, D)
    assert rec.shape == (2, 3, 16)
    rel = jnp.linalg.norm(rec - K, axis=-1) / jnp.linalg.norm(K, axis=-1)
    assert float(jnp.max(rel)) < 1.0
