"""Cache-policy baselines through the serving stack: interface conformance and
the expected fidelity ordering (dense < kivi-4 ~ ptq-4 < kivi-2 < eviction)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.baselines import EvictionPolicy, KIVIPolicy, PerTokenQuantPolicy
from repro.models import model as M
from repro.models.cache_policy import DensePolicy, make_policy


def _decode_errs(cfg, params, tokens, full, policy, T, Tp):
    pb = {"tokens": tokens[:, :Tp]}
    lg, state = M.prefill(params, cfg, policy, pb, bank=None, t_max=T + 8)
    errs = [float(jnp.max(jnp.abs(lg - full[:, Tp - 1])))]
    for t in range(Tp, T):
        lg, state = M.decode_step(params, cfg, policy, state, tokens[:, t], bank=None)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    return max(errs)


def test_policy_fidelity_ordering(rng):
    cfg = configs.get_smoke("llama3.2-1b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, T, Tp = 2, 24, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    full = M.forward_train(params, cfg, {"tokens": tokens, "labels": tokens})
    errs = {}
    for name, pol in [
        ("dense", DensePolicy()),
        ("kivi4", KIVIPolicy(bits=4, group=8, n_b=8)),
        ("kivi2", KIVIPolicy(bits=2, group=8, n_b=8)),
        ("ptq4", PerTokenQuantPolicy(bits=4, n_b=4)),
        ("evict", EvictionPolicy(budget=12, recent=4)),
    ]:
        errs[name] = _decode_errs(cfg, params, tokens, full, pol, T, Tp)
    assert errs["dense"] < errs["kivi4"] < errs["kivi2"]
    assert errs["dense"] < errs["ptq4"]
    assert errs["kivi2"] < errs["evict"]  # eviction drops tokens entirely


def test_make_policy_registry():
    from repro.configs.base import LexicoConfig
    assert make_policy("lexico", LexicoConfig()).__class__.__name__ == "LexicoPolicy"
    assert make_policy("dense").__class__.__name__ == "DensePolicy"
    assert make_policy("kivi", bits=2).bits == 2
    assert make_policy("per_token").bits == 4
    assert make_policy("eviction", budget=64).budget == 64
    with pytest.raises(KeyError):
        make_policy("nope")


def test_kivi_memory_fraction():
    k2 = KIVIPolicy(bits=2, group=32)
    # 2-bit + per-group scales at m=128: 32B payload + 32B meta = 25% of 256B
    assert abs(k2.kv_size_fraction(128) - 0.25) < 0.01
    k4 = KIVIPolicy(bits=4, group=32)
    assert abs(k4.kv_size_fraction(128) - 0.375) < 0.01


def test_eviction_budget_respected(rng):
    cfg = configs.get_smoke("llama3.2-1b")
    pol = EvictionPolicy(budget=8, recent=2)
    cache = pol.init(2, cfg.num_kv_heads, cfg.hd, t_max=64)
    K = jnp.asarray(rng.normal(size=(2, cfg.num_kv_heads, 32, cfg.hd)), jnp.float32)
    cache = pol.prefill(cache, K, K, None)
    assert cache.k.shape[2] == 8                    # budget slots only
    assert int(cache.length[0]) == 32               # but tracks true length
    kt = jnp.asarray(rng.normal(size=(2, cfg.num_kv_heads, cfg.hd)), jnp.float32)
    cache = pol.decode(cache, kt, kt, None)
    assert int(cache.length[0]) == 33
    assert int(jnp.max(cache.pos)) == 32            # newest kept
