"""Fused batched-OMP prefill encoder: the encoder-parity contract.

Four layers of pinning, mirroring tests/test_paged_sparse_attn.py and
docs/kernels.md:

  * differential sweep — ``omp_batch(backend="fused"/"fused_kernel")`` vs
    the vmapped per-vector oracle (``backend="ref"``) across Gram /
    Gram-free correlation, ``delta`` early stop, per-row ``s_cap`` tiers,
    fp32/bf16 inputs and multi-tile batches. idx must match EXACTLY (the
    greedy support is discrete — one flipped atom cascades), vals to fp32
    accumulation-order tolerance;
  * selection-kernel parity — ``omp_gram_argmax`` (interpret mode) vs
    ``ref.omp_gram_corr_ref`` at ragged N, padded idx slots, and
    tie-breaking pinned to the lowest atom index via duplicated atoms;
  * property harness (hypothesis, optional) — s_cap-truncated codes equal
    the smaller-s run, rows are independent (batch permutation equivariance),
    and the early-exit ``while_loop`` is bitwise the ``fori_loop`` result;
  * engine acceptance — ``fused_omp`` on (oracle AND forced kernel)
    reproduces the baseline engine's greedy tokens exactly on a
    prefix-shared + swap-tiered workload, with the prefill compile count
    unchanged and decode still compiling once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.core.omp import omp_batch
from repro.kernels import ops, ref
from repro.kernels.omp_corr import omp_gram_argmax
from repro.kernels.omp_encode import omp_encode_batch
from repro.models import model as M
from repro.roofline.kernel_model import (
    OMPEncodeShape, compare_omp_encode, omp_gathered_bytes,
    omp_streamed_bytes,
)
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, Request, SwapConfig,
)
from tests.conftest import given, settings, st, make_unit_dict

# The fused path batches the matmuls/solves the oracle runs per-vector, so
# vals differ by fp32 accumulation order only; the selected support must be
# identical atom-for-atom.
VTOL = dict(atol=2e-5, rtol=1e-5)


def _setup(rng, B=21, m=16, N=72, dtype=jnp.float32):
    D = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    K = jnp.asarray(rng.normal(size=(B, m)), jnp.float32).astype(dtype)
    return K, D


def _assert_same(res, exp):
    np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(exp.idx))
    np.testing.assert_array_equal(np.asarray(res.nnz), np.asarray(exp.nnz))
    np.testing.assert_allclose(np.asarray(res.vals), np.asarray(exp.vals),
                               **VTOL)
    np.testing.assert_allclose(np.asarray(res.resid2), np.asarray(exp.resid2),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# differential sweep vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["fused", "fused_kernel"])
@pytest.mark.parametrize("use_gram", [True, False])
@pytest.mark.parametrize("delta", [0.0, 0.35])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_ref_sweep(rng, backend, use_gram, delta, dtype):
    K, D = _setup(rng, dtype=dtype)
    exp = omp_batch(K, D, 6, use_gram=use_gram, delta=delta, backend="ref")
    res = omp_batch(K, D, 6, use_gram=use_gram, delta=delta, backend=backend)
    _assert_same(res, exp)
    if delta > 0:
        # the sweep actually exercises early stop: some rows terminate short
        assert int(np.min(np.asarray(res.nnz))) < 6


@pytest.mark.parametrize("backend", ["fused", "fused_kernel"])
def test_fused_s_cap_tiers(rng, backend):
    """Per-row sparsity tiers ride on one s_max-shaped call, both paths."""
    K, D = _setup(rng)
    cap = jnp.asarray(rng.integers(1, 7, K.shape[0]), jnp.int32)
    exp = omp_batch(K, D, 6, s_cap=cap, backend="ref")
    res = omp_batch(K, D, 6, s_cap=cap, backend=backend)
    _assert_same(res, exp)
    assert np.all(np.asarray(res.nnz) <= np.asarray(cap))


def test_fused_multi_tile_and_batch_shape(rng):
    """tile_b smaller than B exercises the pad + lax.map tile loop, and the
    leading batch shape round-trips like the oracle's."""
    D = jnp.asarray(make_unit_dict(rng, 16, 64), jnp.float32)
    K = jnp.asarray(rng.normal(size=(3, 2, 7, 16)), jnp.float32)
    exp = omp_batch(K, D, 5, backend="ref")
    res = omp_batch(K, D, 5, backend="fused", tile_b=8)  # 42 rows -> 6 tiles
    assert res.vals.shape == (3, 2, 7, 5) and res.nnz.shape == (3, 2, 7)
    _assert_same(res, exp)


@pytest.mark.parametrize("backend", ["fused", "fused_kernel"])
def test_tie_breaking_lowest_index(rng, backend):
    """Duplicated atoms correlate exactly equally; every path must resolve
    the tie to the lowest atom index (jnp.argmax first-max == the kernel's
    strictly-greater cross-tile merge)."""
    D = np.asarray(make_unit_dict(rng, 8, 32))
    D[:, 19] = D[:, 3]
    D[:, 27] = D[:, 3]  # triple tie spanning tiles at block_n <= 16
    D = jnp.asarray(D, jnp.float32)
    K = jnp.asarray(rng.normal(size=(9, 8)), jnp.float32)
    exp = omp_batch(K, D, 4, backend="ref")
    res = omp_batch(K, D, 4, backend=backend)
    np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(exp.idx))
    assert not np.any(np.isin(np.asarray(res.idx), [19, 27]))


# ---------------------------------------------------------------------------
# selection-kernel parity (interpret mode) vs the gathered oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N,s,bn", [(7, 72, 5, 32), (16, 64, 8, 64),
                                      (3, 100, 4, 48), (1, 33, 2, 16)])
def test_gram_argmax_parity_ragged(rng, B, N, s, bn):
    """Streamed kernel == gathered oracle at ragged N (pad atoms masked),
    partially-filled idx slots (trailing y zero), random selected masks."""
    alpha0 = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
    G = jnp.asarray(rng.normal(size=(N, N)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, N, (B, s)), jnp.int32)
    y = np.asarray(rng.normal(size=(B, s)), np.float32)
    y[:, s // 2:] = 0.0  # unfilled suffix: idx there must be inert
    y = jnp.asarray(y)
    sel = jnp.zeros((B, N), bool).at[:, rng.integers(0, N, 3)].set(True)
    arg, mx = omp_gram_argmax(alpha0, G, idx, y, sel, block_n=bn,
                              interpret=True)
    rarg, rmx = ref.omp_gram_corr_ref(alpha0, G, idx, y, sel)
    np.testing.assert_array_equal(np.asarray(arg), np.asarray(rarg))
    np.testing.assert_allclose(np.asarray(mx), np.asarray(rmx), **VTOL)


def test_gram_select_op_dispatch(monkeypatch):
    """omp_gram_select_op routes through resolve_dispatch: oracle only when
    nothing asked for the kernel, force_kernel/interpret pin the kernel."""
    calls = []
    monkeypatch.setattr(ops, "_on_tpu", lambda: False)
    monkeypatch.setattr(ops, "omp_gram_argmax",
                        lambda *a, **k: calls.append("kernel"))
    monkeypatch.setattr(ops.ref, "omp_gram_corr_ref",
                        lambda *a, **k: calls.append("oracle"))
    for kw, want in [(dict(), "oracle"),
                     (dict(force_kernel=True), "kernel"),
                     (dict(interpret=True), "kernel")]:
        calls.clear()
        ops.omp_gram_select_op(None, None, None, None, None, **kw)
        assert calls == [want], (kw, calls)


# ---------------------------------------------------------------------------
# property harness (hypothesis optional — skips when not installed)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), c=st.integers(1, 5))
def test_property_truncation_equals_smaller_s(seed, c):
    """Greedy nesting survives fusion: capping at c inside an s_max-shaped
    run yields exactly the code of an s_max=c run (paper §4.2.1)."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(make_unit_dict(rng, 12, 48), jnp.float32)
    K = jnp.asarray(rng.normal(size=(5, 12)), jnp.float32)
    capped = omp_batch(K, D, 6, s_cap=jnp.full((5,), c, jnp.int32),
                       backend="fused")
    small = omp_batch(K, D, c, backend="fused")
    np.testing.assert_array_equal(np.asarray(capped.idx)[:, :c],
                                  np.asarray(small.idx))
    np.testing.assert_allclose(np.asarray(capped.vals)[:, :c],
                               np.asarray(small.vals), atol=1e-6)
    assert np.all(np.asarray(capped.vals)[:, c:] == 0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_row_independence(seed):
    """Rows don't interact: permuting the batch permutes the outputs
    bitwise (single tile, so the early-exit decision sees the same set)."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(make_unit_dict(rng, 12, 48), jnp.float32)
    K = jnp.asarray(rng.normal(size=(8, 12)), jnp.float32)
    perm = jnp.asarray(rng.permutation(8))
    a = omp_encode_batch(K, D, 5, G=D.T @ D, delta=0.3, tile_b=64)
    b = omp_encode_batch(K[perm], D, 5, G=D.T @ D, delta=0.3, tile_b=64)
    np.testing.assert_array_equal(np.asarray(a.vals)[perm], np.asarray(b.vals))
    np.testing.assert_array_equal(np.asarray(a.idx)[perm], np.asarray(b.idx))
    np.testing.assert_array_equal(np.asarray(a.nnz)[perm], np.asarray(b.nnz))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), delta=st.floats(0.0, 0.8))
def test_property_while_equals_fori_bitwise(seed, delta):
    """Early exit is a pure wall-clock win: inactive rows are no-ops in the
    body, so stopping when no row is active is bitwise running all s_max
    steps (the always-s_max baseline the benchmark measures against)."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(make_unit_dict(rng, 12, 48), jnp.float32)
    K = jnp.asarray(rng.normal(size=(6, 12)), jnp.float32)
    G = D.T @ D
    kw = dict(G=G, delta=float(delta), tile_b=64)
    a = omp_encode_batch(K, D, 6, early_exit=True, **kw)
    b = omp_encode_batch(K, D, 6, early_exit=False, **kw)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# analytic kernel model: streamed selection must predict strictly fewer bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    OMPEncodeShape(batch=8, head_dim=16, n_dict=64, s=2),
    OMPEncodeShape(batch=256, head_dim=64, n_dict=4096, s=16),
    OMPEncodeShape(batch=4096, head_dim=128, n_dict=8192, s=32),
])
def test_kernel_model_streamed_strictly_fewer_bytes(shape):
    g, f = omp_gathered_bytes(shape), omp_streamed_bytes(shape)
    assert f["total_bytes"] < g["total_bytes"], shape
    # the win is the dropped gather copy/reread + the (B, N) corr matrix
    assert g["total_bytes"] - f["total_bytes"] >= (
        g["gather_write"] + g["gather_reread"])
    cmp = compare_omp_encode(shape)
    assert cmp["bytes_ratio"] < 1.0
    assert cmp["streamed"]["t_roofline_s"] <= cmp["gathered"]["t_roofline_s"]
    assert cmp["flops_per_iter"] == shape.flops
    # iters scales whole-encode bytes linearly (early exit's multiplier)
    half = compare_omp_encode(shape, iters=max(1, shape.s // 2))
    assert (half["streamed"]["encode_total_bytes"]
            < cmp["streamed"]["encode_total_bytes"])


# ---------------------------------------------------------------------------
# engine acceptance: fused_omp on/off token identity, compile counts unchanged
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _shared_prefix_requests(rng, n=5):
    system = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
    reqs = []
    for rid in range(n):
        tail = rng.integers(0, CFG.vocab_size,
                            int(rng.integers(2, 14))).astype(np.int32)
        reqs.append(Request(rid=rid, prompt=np.concatenate([system, tail]),
                            max_new_tokens=int(rng.integers(3, 6)), tier=8))
    return reqs


def test_engine_fused_omp_token_identity(served):
    """The acceptance gate: fused_omp on (oracle AND forced kernel)
    reproduces the baseline engine's greedy tokens exactly on a workload
    exercising prefix sharing and the host swap tier; the prefill compile
    count is unchanged (the backend is a static policy attribute, and the
    while_loop traces once per bucket like the fori_loop) and decode still
    compiles exactly once."""
    params, bank = served
    base = EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                        page_size=8, n_pages=18, share_prefixes=True,
                        swap=SwapConfig())
    tokens, engines = {}, {}
    for mode, over in (("off", {}),
                       ("fused", dict(fused_omp=True)),
                       ("fused_kernel", dict(fused_omp=True,
                                             fused_omp_force_kernel=True))):
        eng = ContinuousBatchingEngine(params, CFG, LEX, bank,
                                       dataclasses.replace(base, **over))
        for r in _shared_prefix_requests(np.random.default_rng(11)):
            eng.submit(r)
        done = eng.run()
        tokens[mode] = {rid: done[rid].generated_tokens for rid in done}
        engines[mode] = eng
    assert tokens["fused"] == tokens["off"]
    assert tokens["fused_kernel"] == tokens["off"]
    prefill_counts = {m: e.compile_counts["prefill"]
                      for m, e in engines.items()}
    assert prefill_counts["fused"] == prefill_counts["off"], prefill_counts
    assert prefill_counts["fused_kernel"] == prefill_counts["off"], \
        prefill_counts
    for mode, eng in engines.items():
        cc = eng.compile_counts
        assert cc["decode"] == 1, (mode, cc)
        assert eng.metrics.to_dict()["requests_completed"] == 5, mode
