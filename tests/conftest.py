import numpy as np
import pytest

# hypothesis is an optional dev dependency: when absent, `given` degrades to a
# skip marker so property tests vanish cleanly and the rest of each module
# still collects and runs.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True

    # CI runs `--hypothesis-profile ci`: fewer, deadline-free examples —
    # interpret-mode Pallas calls are seconds each, so the default 100
    # examples x default deadline would flake, not verify.
    settings.register_profile(
        "ci", max_examples=10, deadline=None, derandomize=True)
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Stub:
        def __getattr__(self, name):
            def strategy(*a, **k):
                return None
            return strategy

    st = _Stub()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_unit_dict(rng, m, N):
    D = rng.normal(size=(m, N))
    return D / np.linalg.norm(D, axis=0, keepdims=True)
