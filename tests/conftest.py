import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_unit_dict(rng, m, N):
    D = rng.normal(size=(m, N))
    return D / np.linalg.norm(D, axis=0, keepdims=True)
