"""Per-arch smoke tests: reduced configs, one train forward + loss + one
prefill + decode steps on CPU, asserting shapes and no NaNs. (Deliverable f.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.models import model as M
from repro.models.cache_policy import LexicoPolicy

LEX = LexicoConfig(N=64, s=4, n_b=4, chunk=8)


@pytest.mark.parametrize("name", configs.ARCHS)
def test_arch_smoke(name, rng):
    cfg = configs.get_smoke(name)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), cfg, LEX)
    if cfg.attn_free:
        assert bank is None
    B, T = 2, 16
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)

    logits = M.forward_train(params, cfg, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss = float(M.lm_loss(params, cfg, batch))
    assert 0 < loss < 20

    policy = LexicoPolicy(LEX)
    lg, state = M.prefill(params, cfg, policy, batch, bank=bank,
                          t_max=T + cfg.num_meta_tokens + 8)
    assert lg.shape == (B, cfg.vocab_size)
    for _ in range(3):
        lg, state = M.decode_step(params, cfg, policy, state, tokens[:, 0], bank=bank)
    assert not bool(jnp.isnan(lg).any())
    assert state.length.shape == (B,)
    assert int(state.length[0]) == T + cfg.num_meta_tokens + 3


@pytest.mark.parametrize("name", ["llama3.2-1b", "qwen3-0.6b", "hymba-1.5b",
                                  "deepseek-v2-lite-16b", "whisper-tiny"])
def test_serve_matches_teacher_forcing(name, rng):
    """Golden test: at s = cached_dim (full-rank OMP) the compressed serving
    path reproduces the teacher-forced logits (up to codec rounding)."""
    cfg = configs.get_smoke(name)
    m = cfg.cached_vector_dim
    lex = LexicoConfig(N=128, s=m, n_b=4, chunk=None, codec="fp16")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), cfg, lex)
    B, T, Tp = 2, 12, 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)
    full = M.forward_train(params, cfg, batch)
    scale = float(jnp.max(jnp.abs(full)))

    pb = {"tokens": tokens[:, :Tp], **({"frames": batch["frames"]} if cfg.enc_dec else {})}
    policy = LexicoPolicy(lex)
    lg, state = M.prefill(params, cfg, policy, pb, bank=bank,
                          t_max=T + cfg.num_meta_tokens + 4)
    assert float(jnp.max(jnp.abs(lg - full[:, Tp - 1]))) < 1e-3 * max(scale, 1)
    worst = 0.0
    for t in range(Tp, T):
        lg, state = M.decode_step(params, cfg, policy, state, tokens[:, t], bank=bank)
        worst = max(worst, float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert worst < 0.05 * max(scale, 1), worst


def test_param_counts_sane():
    for name in configs.ARCHS:
        cfg = configs.get(name)
        n = cfg.param_count()
        assert n > 3e7, (name, n)   # whisper-tiny is ~57M; everything else >0.5B
    assert 1.0e11 < configs.get("mistral-large-123b").param_count() < 1.4e11
    assert 2.6e9 < configs.get("starcoder2-3b").param_count() < 3.6e9
    moe = configs.get("qwen3-moe-235b-a22b")
    assert 1.8e11 < moe.param_count() < 2.9e11
    assert moe.active_param_count() < 0.2 * moe.param_count()
