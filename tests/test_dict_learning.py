"""Dictionary learning: loss decreases, unit-norm invariant, beats random
dictionaries (the Table-1 claim in miniature); adaptive growth (§4.2.4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import adaptive_encode, adaptive_extra_bytes, init_adaptive
from repro.core.dict_learning import dict_train_init, dict_train_step, relative_error
from repro.core.dictionary import init_dictionary, normalize_atoms, project_gradient
from tests.conftest import make_unit_dict


def _structured_batch(rng, B, m, k_subspaces=4, rank=3, bases=None):
    """Vectors drawn from a mixture of low-dim subspaces (the paper's Fig. 3
    structure) — learnable by a dictionary, unlike isotropic noise. Pass the
    same ``bases`` to sample train/held-out splits of one distribution."""
    if bases is None:
        bases = rng.normal(size=(k_subspaces, m, rank))
    which = rng.integers(0, k_subspaces, B)
    coef = rng.normal(size=(B, rank))
    X = np.einsum("bmr,br->bm", bases[which], coef)
    return X + 0.05 * rng.normal(size=(B, m))


def test_projection_orthogonal(rng):
    D = jnp.asarray(make_unit_dict(rng, 16, 32), jnp.float32)
    g = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    pg = project_gradient(D, g)
    dots = jnp.sum(pg * D, axis=0)
    np.testing.assert_allclose(np.asarray(dots), 0, atol=1e-5)


def test_training_reduces_error_and_beats_random(rng):
    m, N, s, B = 16, 48, 4, 256
    D0 = init_dictionary(jax.random.PRNGKey(0), m, N)
    state = dict_train_init(D0)
    bases = rng.normal(size=(4, m, 3))
    X = jnp.asarray(_structured_batch(rng, B, m, bases=bases), jnp.float32)
    first = None
    for i in range(30):
        state, metrics = dict_train_step(state, X, s=s, base_lr=3e-3,
                                         lr_schedule_len=30)
        if first is None:
            first = float(metrics["rel_err_mean"])
    last = float(metrics["rel_err_mean"])
    assert last < first * 0.9, (first, last)
    # unit-norm preserved
    norms = jnp.linalg.norm(state.D, axis=-2)
    np.testing.assert_allclose(np.asarray(norms), 1.0, atol=1e-3)
    # beats a random dictionary on held-out data from the same distribution
    X_test = jnp.asarray(_structured_batch(rng, 64, m, bases=bases), jnp.float32)
    err_trained = float(jnp.mean(relative_error(state.D, X_test, s)))
    err_random = float(jnp.mean(relative_error(
        jnp.asarray(make_unit_dict(rng, m, N), jnp.float32), X_test, s)))
    assert err_trained < err_random, (err_trained, err_random)


def test_adaptive_growth(rng):
    m, N, s = 16, 32, 4
    D = jnp.asarray(make_unit_dict(rng, m, N), jnp.float32)
    ad = init_adaptive(D, capacity=N + 8)
    K = jnp.asarray(rng.normal(size=(6, m)), jnp.float32)  # random: hard to hit δ
    ad2, res = adaptive_encode(ad, K, s=s, delta=0.05)
    grown = int(ad2.n_used) - N
    assert grown > 0
    # grown atoms produce 1-sparse exact codes
    nnz = np.asarray(res.nnz)
    r2 = np.asarray(res.resid2)
    for i in range(6):
        if nnz[i] == 1:
            assert r2[i] < 1e-6
    assert int(adaptive_extra_bytes(ad2)) == grown * m * 2
    # capacity cap respected
    K2 = jnp.asarray(rng.normal(size=(32, m)), jnp.float32)
    ad3, _ = adaptive_encode(ad2, K2, s=s, delta=0.01)
    assert int(ad3.n_used) <= N + 8


def test_bank_shaped_training_step(rng):
    """Stacked (L, roles) dictionary banks train in one step (regression:
    the reconstruction gather must be take_along_axis, not take)."""
    from repro.core.omp import omp_batch, reconstruct
    from repro.core.dict_learning import reconstruction_loss
    L, R, m, N, B, s = 2, 2, 16, 48, 8, 4
    keys = jax.random.split(jax.random.PRNGKey(0), L * R)
    D = jax.vmap(lambda k: init_dictionary(k, m, N))(keys).reshape(L, R, m, N)
    K = jnp.asarray(rng.normal(size=(L, R, B, m)), jnp.float32)
    state = dict_train_init(D)
    state, metrics = dict_train_step(state, K, s=s, base_lr=1e-3)
    assert float(metrics["loss"]) > 0
    assert state.D.shape == (L, R, m, N)
    # single-dict slice consistency
    res = omp_batch(K[1, 0], D[1, 0], s)
    manual = reconstruction_loss(D[1, 0], res.vals, res.idx, K[1, 0])
    rec = reconstruct(res, D[1, 0])
    direct = jnp.mean(jnp.sum((K[1, 0] - rec) ** 2, axis=-1))
    assert float(jnp.abs(manual - direct)) < 1e-5
