"""Substrate tests: optimizer, checkpointing (atomic/async/elastic restore),
data pipeline determinism, fault-tolerance policies, gradient compression."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data.pipeline import DataPipeline
from repro.optim import adamw_tree_init, adamw_tree_update, clip_by_global_norm
from repro.optim.adam import AdamState, adam_init, adam_update
from repro.runtime.compression import (
    init_error_buffers, int8_compress, int8_compress_with_feedback,
)
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, PreemptionGuard, run_with_retries,
)


def test_adam_matches_analytic():
    p = jnp.array([1.0, -2.0])
    g = jnp.array([0.1, 0.2])
    st = adam_init(p)
    newp, st = adam_update(p, g, st, lr=0.01)
    # first step: m_hat = g, v_hat = g^2 -> update = -lr * g/|g| (+eps)
    expected = p - 0.01 * g / (jnp.abs(g) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp), np.asarray(expected), atol=1e-6)


def test_adamw_tree_and_clip(rng):
    params = {"a": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32),
              "b": {"c": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}}
    grads = jax.tree.map(lambda x: jnp.ones_like(x) * 10.0, params)
    clipped, norm = clip_by_global_norm(grads, 1.0)
    from repro.optim.clip import global_norm
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4
    st = adamw_tree_init(params)
    new, st2 = adamw_tree_update(params, clipped, st, lr=0.1, weight_decay=0.0)
    assert jax.tree.structure(new) == jax.tree.structure(params)
    assert int(st2.count) == 1


def test_checkpoint_roundtrip_atomic_retention(tmp_path, rng):
    tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
            "scalar": jnp.float32(3.5)}
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    for step in (1, 2, 3):
        mgr.save(tree, step=step)
    assert mgr.latest_step() == 3
    assert sorted(os.listdir(d)) == ["2", "3"]  # retention
    restored = mgr.restore_latest(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async(tmp_path, rng):
    tree = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path / "c"), keep=2)
    mgr.save(tree, step=7, blocking=False)
    mgr.wait()
    r = mgr.restore_latest(tree)
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(r["w"]))


def test_checkpoint_restore_with_shardings(tmp_path, rng):
    """Elastic path: restore places leaves onto explicit (1-device) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    tree = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    save_pytree(tree, str(tmp_path), step=0)
    sh = {"w": NamedSharding(mesh, P())}
    r = restore_pytree(tree, str(tmp_path), step=0, shardings=sh)
    assert r["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(tree["w"]))


def test_pipeline_determinism_and_resume():
    p1 = DataPipeline(100, global_batch=4, seq_len=8, seed=3).start(from_step=0)
    a = [next(p1) for _ in range(3)]
    p1.stop()
    p2 = DataPipeline(100, global_batch=4, seq_len=8, seed=3).start(from_step=2)
    b = next(p2)
    p2.stop()
    np.testing.assert_array_equal(a[2]["tokens"], b["tokens"])
    # different processes see different shards
    q = DataPipeline(100, global_batch=4, seq_len=8, seed=3, process_index=1,
                     process_count=2)
    assert not np.array_equal(q.batch_at(0)["tokens"],
                              DataPipeline(100, global_batch=4, seq_len=8, seed=3,
                                           process_index=0, process_count=2).batch_at(0)["tokens"])


def test_heartbeat_straggler():
    mon = HeartbeatMonitor(window=4, threshold=1.5)
    for _ in range(4):
        for h in ("h0", "h1", "h2", "h3"):
            mon.record(h, 1.0)
        mon.record("slow", 3.0)
    assert mon.stragglers() == ["slow"]
    assert mon.missing(["h0", "gone"], now=100.0, deadline_s=10,
                       last_seen={"h0": 95.0, "gone": 0.0}) == ["gone"]


def test_retries_and_recovery():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return state + batch

    restored = []
    out = run_with_retries(flaky, 1, 2, retries=3,
                           on_failure=lambda a, e: restored.append(a) or 1)
    assert out == 3 and len(restored) == 2

    with pytest.raises(RuntimeError):
        run_with_retries(lambda s, b: 1 / 0, 0, 0, retries=1)


def test_preemption_guard():
    g = PreemptionGuard(signals=())
    assert not g.should_stop()
    g._handler(None, None)
    assert g.should_stop()


def test_int8_error_feedback(rng):
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    # stateless: bounded error
    err = jnp.max(jnp.abs(int8_compress(g) - g))
    assert float(err) <= float(jnp.max(jnp.abs(g))) / 127 + 1e-6
    # with feedback: accumulated compressed sum converges to accumulated true sum
    grads = {"w": g}
    ebuf = init_error_buffers(grads)
    acc_c = jnp.zeros_like(g)
    for _ in range(50):
        comp, ebuf = int8_compress_with_feedback(grads, ebuf)
        acc_c = acc_c + comp["w"]
    acc_t = 50 * g
    rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
    assert rel < 0.01, rel


def test_elastic_plan_mesh():
    from repro.runtime.elastic import plan_mesh
    assert plan_mesh(256)[0] == (16, 16)
    assert plan_mesh(128)[0] == (8, 16)
    assert plan_mesh(24, prefer_model=16)[0] == (3, 8)
    shape, axes = plan_mesh(512, with_pod=True)
    assert shape == (2, 16, 16) and axes == ("pod", "data", "model")
