"""Elastic restore: save on one mesh, restore on another (promised by
repro/runtime/elastic.py).

Checkpoints store global logical arrays, so a tree saved under any mesh
restores bit-identically under any other. The cross-mesh case needs more
than one device — a subprocess forces a 4-device host platform and round-
trips a (2,2)-sharded tree onto a (4,1) mesh; the in-process tests cover
the single-device remesh path.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_pytree, save_pytree
from repro.runtime.elastic import plan_mesh, remesh, reshard
from repro.runtime.sharding import param_shardings


def _tree(rng):
    return {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
            "layers": {"attn": {"wq": jnp.asarray(
                rng.normal(size=(2, 4, 4)), jnp.float32)}}}


def test_save_restore_across_meshes(tmp_path, rng):
    """Save under the current mesh, restore with shardings built on a fresh
    remesh() — logical contents are bit-identical."""
    tree = _tree(rng)
    d = str(tmp_path / "ckpt")
    save_pytree(tree, d, step=1)
    mesh = remesh(prefer_model=1)
    sh = param_shardings(mesh, tree, moe=False)
    restored = restore_pytree(tree, d, step=1, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_moves_leaves(rng):
    tree = _tree(rng)
    mesh = remesh(prefer_model=1)
    sh = param_shardings(mesh, tree, moe=False)
    moved = reshard(tree, sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_pytree, save_pytree
from repro.launch.mesh import make_mesh

assert len(jax.devices()) == 4
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}

# save sharded on a (2,2) mesh
m1 = make_mesh((2, 2), ("data", "model"))
sharded = jax.device_put(tree["w"], NamedSharding(m1, P("data", "model")))
save_pytree({"w": sharded}, "CKPT", step=7)

# restore onto a (4,1) mesh with a different layout
m2 = make_mesh((4, 1), ("data", "model"))
sh2 = {"w": NamedSharding(m2, P("data", None))}
out = restore_pytree(tree, "CKPT", step=7, shardings=sh2)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
assert out["w"].sharding.mesh.shape["data"] == 4
print("OK")
"""


def test_cross_mesh_restore_multidevice(tmp_path):
    """Real multi-device save/restore via a forced 4-device host platform."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get("PYTHONPATH", "")
    code = _SUBPROC.replace("CKPT", str(tmp_path / "ckpt"))
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr
    assert "OK" in res.stdout
