"""Paged slot storage vs the contiguous oracle: the differential harness.

The paged layout rewires every decode hot path (prefill scatter, decode
append, attention gather, slot splice), so the proof obligations are:

  * differential — the same prefill→decode→evict trace through both layouts
    produces *identical* attention outputs and bookkeeping (the shared
    compression core makes this exact, not approximate), including ragged
    per-row lengths and per-row ``s_cap`` tiers;
  * compile counts — decode over the paged pool is ONE trace no matter how
    page tables and counters move;
  * engine — the full continuous-batching engine emits identical greedy
    tokens under both layouts, with page-granular admission and a lower real
    footprint;
  * hypothesis invariants for ``decode_update``/``paged_decode_update``
    (ring bounds, idle-row bit-identity, ``t_c`` monotone, row independence)
    — skip cleanly when hypothesis is absent (conftest fallback).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import given, make_unit_dict, settings, st

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.core import sparse_cache as sc
from repro.core.attention import gather_pages
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, NULL_PAGE, PageAllocator,
    PagePoolExhausted, Request, pages_needed,
)

B, KV, m, s, n_b = 3, 2, 16, 4, 3
P, MP = 4, 6                      # page_size, max pages per row
N_PAGES = 1 + B * MP
N_DICT = 64


def unit_dict(rng):
    return jnp.asarray(make_unit_dict(rng, m, N_DICT), jnp.float32)


def shuffled_tables(rng):
    """Every row's pages drawn shuffled from one shared pool — adjacency in
    token space never implies adjacency in the pool."""
    perm = rng.permutation(np.arange(1, N_PAGES))
    return jnp.asarray(perm[: B * MP].reshape(B, MP), jnp.int32)


def fresh_pair(rng, T=12):
    """(contiguous, paged) caches holding the same prefilled prompt."""
    D = unit_dict(rng)
    K = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    cont = sc.init_layer_cache(B, KV, m, t_max=MP * P, n_b=n_b, s=s)
    cont = sc.prefill_compress(cont, K, V, D, D, s=s)
    paged = sc.init_paged_layer_cache(B, KV, m, n_pages=N_PAGES, page_size=P,
                                      max_pages=MP, n_b=n_b, s=s)
    paged = paged._replace(page_table=shuffled_tables(rng))
    paged = sc.paged_prefill_compress(paged, K, V, D, D, s=s)
    return cont, paged, D


def assert_same_bookkeeping(cont, paged):
    for f in ("t_c", "buf_len", "buf_start"):
        np.testing.assert_array_equal(np.asarray(getattr(cont, f)),
                                      np.asarray(getattr(paged, f)), err_msg=f)


def assert_same_stores(cont, paged):
    """Valid positions (< t_c per row) of the gathered paged view must equal
    the contiguous stripe; beyond t_c both layouts hold don't-care padding."""
    g = sc.to_contiguous(paged)
    t_c = np.asarray(cont.t_c)
    for f in ("k_vals", "k_idx", "v_vals", "v_idx"):
        a = np.asarray(getattr(cont, f)).astype(np.float32)
        b = np.asarray(getattr(g, f)).astype(np.float32)
        for row in range(B):
            np.testing.assert_array_equal(a[row, :, :t_c[row]],
                                          b[row, :, :t_c[row]], err_msg=f)
    np.testing.assert_array_equal(np.asarray(cont.k_buf), np.asarray(paged.k_buf))
    np.testing.assert_array_equal(np.asarray(cont.v_buf), np.asarray(paged.v_buf))


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_refcount():
    a = PageAllocator(8, 4)
    assert a.capacity == 7 and a.n_free == 7
    pages = a.alloc(3)
    assert len(set(pages)) == 3 and NULL_PAGE not in pages
    assert a.n_used == 3
    a.incref(pages[0])
    a.decref(pages[0])
    assert a.refcount(pages[0]) == 1      # still held by the original ref
    a.free(pages)
    assert a.check_balanced()


def test_allocator_double_free_and_exhaustion():
    a = PageAllocator(4, 2)
    pages = a.alloc(3)
    with pytest.raises(PagePoolExhausted):
        a.alloc(1)
    a.free(pages)
    with pytest.raises(KeyError, match="double free"):
        a.decref(pages[0])
    assert a.check_balanced()


def test_pages_needed():
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2


# ---------------------------------------------------------------------------
# differential: cache level
# ---------------------------------------------------------------------------

def test_prefill_differential(rng):
    cont, paged, D = fresh_pair(rng)
    assert_same_bookkeeping(cont, paged)
    assert_same_stores(cont, paged)
    q = jnp.asarray(rng.normal(size=(B, KV, 2, m)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sc.attend(cont, q, D, D, N=N_DICT)),
        np.asarray(sc.paged_attend(paged, q, D, D, N=N_DICT)))


@pytest.mark.parametrize("chunk", [None, 8])
def test_decode_evict_differential_ragged(rng, chunk):
    """prefill → decode/evict with ragged per-row activity and per-row s_cap
    tiers: bookkeeping identical, outputs identical at every step."""
    cont, paged, D = fresh_pair(rng)
    caps = jnp.asarray([2, 3, 4], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, KV, 2, m)), jnp.float32)
    for step in range(10):
        act = jnp.asarray(rng.random(B) < 0.7)
        k_t = jnp.asarray(rng.normal(size=(B, KV, m)), jnp.float32)
        v_t = jnp.asarray(rng.normal(size=(B, KV, m)), jnp.float32)
        cont = sc.decode_update(cont, k_t, v_t, D, D, s=s, active=act,
                                s_cap=caps)
        paged = sc.paged_decode_update(paged, k_t, v_t, D, D, s=s, active=act,
                                       s_cap=caps)
        assert_same_bookkeeping(cont, paged)
        np.testing.assert_array_equal(
            np.asarray(sc.attend(cont, q, D, D, N=N_DICT, chunk=chunk)),
            np.asarray(sc.paged_attend(paged, q, D, D, N=N_DICT, chunk=chunk)))
    # rows advanced raggedly, and decode appends crossed page boundaries
    t_c = np.asarray(cont.t_c)
    assert len(set(t_c.tolist())) > 1
    assert t_c.max() >= 13          # prefill ends at 9; page span is 4
    assert_same_stores(cont, paged)


def test_to_paged_round_trip(rng):
    cont, _, D = fresh_pair(rng)
    paged = sc.to_paged(cont, shuffled_tables(rng), N_PAGES, P)
    assert_same_stores(cont, paged)
    q = jnp.asarray(rng.normal(size=(B, KV, 2, m)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sc.attend(cont, q, D, D, N=N_DICT)),
        np.asarray(sc.paged_attend(paged, q, D, D, N=N_DICT)))


def test_gather_pages_null_entries_are_clamped(rng):
    pool = jnp.asarray(rng.normal(size=(5, KV, P, s)), jnp.float32)
    table = jnp.asarray([[2, 0, -1]], jnp.int32)     # null + out-of-range
    g = gather_pages(pool, table)
    assert g.shape == (1, KV, 3 * P, s)
    np.testing.assert_array_equal(np.asarray(g[0, :, :P]), np.asarray(pool[2]))
    # both invalid entries resolve to page 0 (the trash page)
    np.testing.assert_array_equal(np.asarray(g[0, :, P:2 * P]),
                                  np.asarray(pool[0]))
    np.testing.assert_array_equal(np.asarray(g[0, :, 2 * P:]),
                                  np.asarray(pool[0]))


def test_paged_decode_single_trace(rng):
    """One jitted paged decode step serves every (page table, counters)
    configuration — moving pages around never retraces."""
    _, paged, D = fresh_pair(rng)

    @jax.jit
    def step(cache, k_t, v_t, act):
        return sc.paged_decode_update(cache, k_t, v_t, D, D, s=s, active=act)

    for i in range(4):
        k_t = jnp.asarray(np.full((B, KV, m), float(i)), jnp.float32)
        act = jnp.asarray([True, i % 2 == 0, False])
        paged = step(paged, k_t, k_t, act)
        # shuffle the table between steps: same trace must serve it
        paged = paged._replace(page_table=shuffled_tables(np.random.default_rng(i)))
    assert step._cache_size() == 1


def test_write_read_slot_paged_round_trip(rng):
    """Splicing a B=1 contiguous prefill into the paged pool and reading the
    slot back reproduces the stripe exactly (valid positions + buffers +
    counters + length)."""
    from repro.serving import slots as slots_mod

    D = unit_dict(rng)
    T = 10
    K1 = jnp.asarray(rng.normal(size=(1, KV, T, m)), jnp.float32)
    one_layer = sc.init_layer_cache(1, KV, m, t_max=MP * P, n_b=n_b, s=s)
    one_layer = sc.prefill_compress(one_layer, K1, K1, D, D, s=s)
    stack = lambda layer: jax.tree.map(lambda *xs: jnp.stack(xs), layer, layer)
    one = M.ServeState(cache=stack(one_layer),
                       length=jnp.full((1,), T, jnp.int32))

    pool_layer = sc.init_paged_layer_cache(B, KV, m, n_pages=N_PAGES,
                                           page_size=P, max_pages=MP,
                                           n_b=n_b, s=s)
    pool = M.ServeState(cache=stack(pool_layer),
                        length=jnp.zeros((B,), jnp.int32))
    row = np.zeros(MP, np.int32)
    row[:2] = [3, 5]                       # t_c = 7 -> 2 pages of 4
    pool = slots_mod.write_slot_paged(pool, one, 1, jnp.asarray(row))
    np.testing.assert_array_equal(
        np.asarray(pool.cache.page_table)[:, 1], np.tile(row, (2, 1)))

    back = slots_mod.read_slot_paged(pool, 1)
    t_c = T - n_b
    for f in ("k_vals", "k_idx", "v_vals", "v_idx"):
        np.testing.assert_array_equal(
            np.asarray(getattr(one.cache, f)).astype(np.float32)[:, :, :, :t_c],
            np.asarray(getattr(back.cache, f)).astype(np.float32)[:, :, :, :t_c],
            err_msg=f)
    for f in ("k_buf", "v_buf", "t_c", "buf_len", "buf_start"):
        np.testing.assert_array_equal(np.asarray(getattr(one.cache, f)),
                                      np.asarray(getattr(back.cache, f)),
                                      err_msg=f)
    np.testing.assert_array_equal(np.asarray(back.length), [T])

    # clearing the slot zeroes its counters and unbinds its pages
    cleared = slots_mod.clear_slot_paged(pool, 1)
    assert int(cleared.cache.t_c[0, 1]) == 0
    assert int(cleared.cache.buf_len[0, 1]) == 0
    np.testing.assert_array_equal(np.asarray(cleared.cache.page_table)[:, 1], 0)
    # other rows untouched
    np.testing.assert_array_equal(np.asarray(cleared.cache.page_table)[:, 0],
                                  np.asarray(pool.cache.page_table)[:, 0])


# ---------------------------------------------------------------------------
# differential: engine level (the acceptance gate)
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _requests(rng):
    # short/long mix: the workload where padded stripes waste the most
    spec = [(9, 3, 2), (30, 4, 8), (12, 2, 4), (26, 3, 6), (8, 2, 2)]
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, pl).astype(np.int32),
                    max_new_tokens=mn, tier=tier)
            for i, (pl, mn, tier) in enumerate(spec)]


def test_engine_paged_matches_contiguous_oracle(served):
    """The acceptance gate: identical greedy tokens under both layouts, ONE
    decode trace with admit/retire of mixed-length requests, zero page leaks,
    and a strictly lower real footprint under paging."""
    params, bank = served
    base = EngineConfig(n_slots=3, t_max=64, min_bucket=8)
    results, engines = {}, {}
    for layout in ("contiguous", "paged"):
        eng = ContinuousBatchingEngine(
            params, CFG, LEX, bank,
            dataclasses.replace(base, layout=layout, page_size=8))
        for r in _requests(np.random.default_rng(7)):
            eng.submit(r)
        results[layout] = eng.run()
        engines[layout] = eng
    assert sorted(results["paged"]) == sorted(results["contiguous"])
    for rid in results["contiguous"]:
        assert (results["paged"][rid].generated_tokens
                == results["contiguous"][rid].generated_tokens), rid

    cc = engines["paged"].compile_counts
    assert cc["decode"] == 1, cc          # zero retraces across admit/retire
    assert cc["write_slot"] == 1 and cc["assign_page"] == 1, cc
    assert engines["paged"].allocator.check_balanced()

    m_cont = engines["contiguous"].metrics.to_dict()
    m_paged = engines["paged"].metrics.to_dict()
    assert (m_paged["kv_bytes_resident_peak"]
            < m_cont["kv_bytes_resident_peak"])
    # paper accounting is layout-independent — same workload, same bytes
    assert (m_paged["kv_bytes_in_flight_peak"]
            == m_cont["kv_bytes_in_flight_peak"])


def test_engine_paged_oversubscribed_pool(served):
    """A pool smaller than n_slots * max_pages still completes every request:
    page-granular admission head-of-line blocks instead of overflowing."""
    params, bank = served
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, n_pages=11))   # 10 usable pages < 3*8
    reqs = _requests(np.random.default_rng(3))
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(done) == [r.rid for r in reqs]
    assert eng.allocator.check_balanced()
    assert eng.metrics.to_dict()["pages_in_use_peak"] <= 10


def test_engine_paged_rejects_never_admissible(served):
    params, bank = served
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=2, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, n_pages=3))    # 2 usable pages
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(0, 64, 30).astype(np.int32),
                  max_new_tokens=8, tier=8)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(req)


# ---------------------------------------------------------------------------
# hypothesis: decode_update invariants (both layouts)
# ---------------------------------------------------------------------------

def _mk_cache(layout, rng, prefill_T):
    D = unit_dict(rng)
    K = jnp.asarray(rng.normal(size=(B, KV, prefill_T, m)), jnp.float32)
    if layout == "paged":
        cache = sc.init_paged_layer_cache(B, KV, m, n_pages=N_PAGES,
                                          page_size=P, max_pages=MP,
                                          n_b=n_b, s=s)
        cache = cache._replace(page_table=shuffled_tables(rng))
        return sc.paged_prefill_compress(cache, K, K, D, D, s=s), D
    cache = sc.init_layer_cache(B, KV, m, t_max=MP * P, n_b=n_b, s=s)
    return sc.prefill_compress(cache, K, K, D, D, s=s), D


def _step(cache, D, k_t, act):
    fn = (sc.paged_decode_update if isinstance(cache, sc.PagedLexicoLayerCache)
          else sc.decode_update)
    return fn(cache, k_t, k_t, D, D, s=s, active=act)


def _row_state(cache, row):
    """Everything one batch row owns (its gathered store view, buffers,
    counters) as numpy, for bit-identity checks."""
    c = cache if isinstance(cache, sc.LexicoLayerCache) else sc.to_contiguous(cache)
    t_c = int(c.t_c[row])
    return [np.asarray(x)[row][..., :t_c, :] if x.ndim == 4 else np.asarray(x)[row]
            for x in (c.k_vals, c.k_idx, c.v_vals, c.v_idx)] + \
           [np.asarray(c.k_buf)[row], np.asarray(c.v_buf)[row],
            np.asarray(c.t_c)[row], np.asarray(c.buf_len)[row],
            np.asarray(c.buf_start)[row]]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), layout=st.sampled_from(["contiguous", "paged"]),
       n_steps=st.integers(1, 6))
def test_decode_update_invariants(seed, layout, n_steps):
    """Ring head/len stay in bounds, t_c is monotone, idle rows are
    bit-identical, and no row's step writes into another row's state."""
    rng = np.random.default_rng(seed)
    cache, D = _mk_cache(layout, rng, prefill_T=int(rng.integers(n_b + 1, 10)))
    for _ in range(n_steps):
        act_np = rng.random(B) < 0.6
        act = jnp.asarray(act_np)
        k_t = jnp.asarray(rng.normal(size=(B, KV, m)), jnp.float32)
        before = [_row_state(cache, r) for r in range(B)]
        t_c_before = np.asarray(cache.t_c)
        cache = _step(cache, D, k_t, act)
        # bounds + monotonicity
        assert np.all(np.asarray(cache.buf_len) <= n_b)
        assert np.all(np.asarray(cache.buf_len) >= 0)
        assert np.all((np.asarray(cache.buf_start) >= 0)
                      & (np.asarray(cache.buf_start) < n_b))
        assert np.all(np.asarray(cache.t_c) >= t_c_before)
        # idle rows bit-identical
        for r in np.flatnonzero(~act_np):
            for a, b in zip(before[r], _row_state(cache, r)):
                np.testing.assert_array_equal(a, b)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), layout=st.sampled_from(["contiguous", "paged"]))
def test_decode_update_row_independence(seed, layout):
    """A batched step with mask M equals composing per-row solo steps — rows
    cannot observe (or clobber) each other through the shared pool."""
    rng = np.random.default_rng(seed)
    cache, D = _mk_cache(layout, rng, prefill_T=8)
    k_t = jnp.asarray(rng.normal(size=(B, KV, m)), jnp.float32)
    act_np = rng.random(B) < 0.6
    batched = _step(cache, D, k_t, jnp.asarray(act_np))
    solo = cache
    for r in range(B):
        mask = np.zeros(B, bool)
        mask[r] = act_np[r]
        solo = _step(solo, D, k_t, jnp.asarray(mask))
    for r in range(B):
        for a, b in zip(_row_state(batched, r), _row_state(solo, r)):
            np.testing.assert_array_equal(a, b)
