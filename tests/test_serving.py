"""Continuous-batching engine: heterogeneous requests through one slot pool.

The load-bearing assertions:
  * pooled decode with per-slot (B,) bookkeeping reproduces each request's
    isolated B=1 serving trajectory bit-for-bit in token space;
  * admitting/retiring requests never recompiles (compile count == #buckets
    for prefill, exactly 1 for decode and slot splice);
  * the scheduler's byte-budget admission respects the paper's 3s+2 law.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.models import model as M
from repro.models.cache_policy import LexicoPolicy
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, FCFSScheduler, Request, SlotPool,
    request_kv_bytes,
)
from repro.serving.engine import _bucket
from repro.serving.slots import SlotInfo


CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _mk_requests(rng, n=8):
    spec = [(9, 3, 2), (17, 4, 8), (12, 2, 4), (30, 3, 6),
            (8, 2, 2), (21, 5, 8), (13, 3, 4), (10, 2, 8)][:n]
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, pl).astype(np.int32),
                    max_new_tokens=mn, tier=tier)
            for i, (pl, mn, tier) in enumerate(spec)]


def _serve_alone(params, bank, req, engine_cfg):
    """Reference: the same request through its own single-slot engine."""
    eng = ContinuousBatchingEngine(params, CFG, LEX, bank,
                                   dataclasses.replace(engine_cfg, n_slots=1))
    eng.submit(dataclasses.replace(req))
    done = eng.run()
    return done[req.rid].generated_tokens


def test_engine_completes_heterogeneous_requests(served):
    params, bank = served
    rng = np.random.default_rng(0)
    reqs = _mk_requests(rng)
    assert len({r.prompt_len for r in reqs}) >= 5   # genuinely heterogeneous
    assert len({r.tier for r in reqs}) >= 3
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank, EngineConfig(n_slots=4, t_max=64, min_bucket=8))
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert sorted(done) == [r.rid for r in reqs]
    for r in reqs:
        assert len(done[r.rid].generated_tokens) == r.max_new_tokens
    # engine really interleaved: the pool is smaller than the request count
    assert eng.metrics.to_dict()["slot_occupancy_peak"] <= 4
    assert eng.metrics.to_dict()["requests_completed"] == len(reqs)


def test_no_recompile_per_request(served):
    """Compile counts are bucket-bound, not request-bound."""
    params, bank = served
    rng = np.random.default_rng(1)
    reqs = _mk_requests(rng)
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank, EngineConfig(n_slots=4, t_max=64, min_bucket=8))
    for r in reqs:
        eng.submit(r)
    eng.run()
    buckets = {_bucket(r.prompt_len, 8) for r in reqs}
    cc = eng.compile_counts
    assert cc["decode"] == 1, cc
    assert cc["write_slot"] == 1, cc
    assert cc["prefill"] == len(buckets), (cc, buckets)


def test_pooled_matches_isolated(served):
    """Golden: requests decoded in a shared heterogeneous pool produce the
    same greedy tokens as each request served alone (per-slot bookkeeping is
    exact, not approximate)."""
    params, bank = served
    rng = np.random.default_rng(2)
    engine_cfg = EngineConfig(n_slots=3, t_max=64, min_bucket=8)
    reqs = _mk_requests(rng, n=5)
    eng = ContinuousBatchingEngine(params, CFG, LEX, bank, engine_cfg)
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    pooled = eng.run()
    for r in reqs:
        alone = _serve_alone(params, bank, r, engine_cfg)
        assert pooled[r.rid].generated_tokens == alone, r.rid


def test_active_mask_freezes_idle_slots(served):
    """decode_step with active=False must leave a slot's cache and length
    untouched."""
    params, bank = served
    policy = LexicoPolicy(LEX)
    B, T = 2, 12
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (B, T)), jnp.int32)
    _, state = M.prefill(params, CFG, policy, {"tokens": tokens},
                         bank=bank, t_max=32)
    tok = jnp.asarray(rng.integers(0, CFG.vocab_size, (B,)), jnp.int32)
    active = jnp.asarray([True, False])
    _, new_state = M.decode_step(params, CFG, policy, state, tok,
                                 bank=bank, active=active)
    assert int(new_state.length[0]) == T + 1
    assert int(new_state.length[1]) == T
    # frozen slot's cache rows are bit-identical
    for leaf_old, leaf_new in zip(jax.tree.leaves(state.cache),
                                  jax.tree.leaves(new_state.cache)):
        np.testing.assert_array_equal(np.asarray(leaf_old)[:, 1],
                                      np.asarray(leaf_new)[:, 1])


def test_submit_rejects_never_admissible(served):
    """A request whose projected bytes exceed the whole budget must be
    rejected at submit time, not livelock the FCFS head."""
    params, bank = served
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=2, t_max=64, min_bucket=8, kv_byte_budget=100))
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(0, 64, 20).astype(np.int32),
                  max_new_tokens=4, tier=8)
    with pytest.raises(ValueError, match="never be admitted"):
        eng.submit(req)


def test_scheduler_budget_respected():
    sched = FCFSScheduler(kv_byte_budget=20_000, n_b=4, m=16,
                          num_layers=2, kv_heads=2)
    rng = np.random.default_rng(0)
    mk = lambda rid: Request(rid=rid, prompt=rng.integers(0, 64, 20).astype(np.int32),
                             max_new_tokens=10, tier=8)
    cost = sched.projected_bytes(mk(0))
    assert cost == request_kv_bytes(30, tier=8, n_b=4, m=16,
                                    num_layers=2, kv_heads=2)
    for i in range(6):
        sched.submit(mk(i))
    admitted = sched.admit(free_slots=6)
    # FCFS prefix that fits the byte budget, head-of-line blocking after
    assert len(admitted) == 20_000 // cost
    assert sched.bytes_admitted == len(admitted) * cost
    sched.release(admitted[0])
    assert sched.bytes_admitted == (len(admitted) - 1) * cost
    # freed bytes re-admit the queue head
    assert len(sched.admit(free_slots=6)) == 1


def test_slot_pool_lifecycle():
    pool = SlotPool(3)
    req = Request(rid=0, prompt=np.arange(10, dtype=np.int32),
                  max_new_tokens=2, tier=4)
    s = pool.allocate(SlotInfo(request=req, fed=8))
    assert pool.occupancy() == 1 and s == 0
    assert pool.compact()["prompt_phase"] == 1
    info = pool.slots[s]
    info.fed = 10
    assert not info.in_prompt_phase
    pool.retire(s)
    assert pool.occupancy() == 0
    with pytest.raises(KeyError):
        pool.retire(s)


def test_tier_cap_matches_small_s(served):
    """A request at tier t through the s_max-compiled encoder equals an
    encoder compiled at s=t (greedy nesting + per-step LS refit)."""
    from repro.core import omp as omp_mod
    from tests.conftest import make_unit_dict
    rng = np.random.default_rng(4)
    D = jnp.asarray(make_unit_dict(rng, 16, 64), jnp.float32)
    K = jnp.asarray(rng.normal(size=(5, 16)), jnp.float32)
    capped = omp_mod.omp_batch(K, D, 8, s_cap=jnp.full((5,), 3, jnp.int32))
    small = omp_mod.omp_batch(K, D, 3)
    np.testing.assert_array_equal(np.asarray(capped.idx)[:, :3],
                                  np.asarray(small.idx))
    np.testing.assert_allclose(np.asarray(capped.vals)[:, :3],
                               np.asarray(small.vals), atol=1e-5)
    assert np.all(np.asarray(capped.vals)[:, 3:] == 0)
    np.testing.assert_array_equal(np.asarray(capped.nnz), 3)
