"""Copy-on-write prefix sharing: the differential + edge-case harness.

Sharing rewires admission (restartable prefill, page-table aliasing, CoW of
the boundary page, charge-only-new-pages budgets), so the proof obligations
are:

  * differential — an engine run with ``share_prefixes=True`` over requests
    sharing a page-aligned prompt prefix emits tokens *bitwise identical* to
    the unshared run, while strictly fewer prompt positions go through the
    prefill OMP and >= 1 physical page is referenced by >= 2 slots;
  * restartable prefill — ``prefill_compress(start=c)`` produces the same
    tail codes as a full encode, bitwise, in both layouts;
  * allocator hardening — refcount overflow/underflow, double free of a
    shared page, incref-after-free, and null-page sharing all raise;
    copy-on-write of the trash page 0 is impossible (it is never
    registered, aliased, or handed out);
  * retire-while-shared — a donor retiring keeps every shared page live for
    the surviving slots and the prefix cache; the pool only balances after
    the index drops its pins;
  * eviction — when the free list runs dry, cached (index-pinned) pages are
    evicted LRU-first and admissions still complete.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests.conftest import make_unit_dict

import repro.configs as configs
from repro.configs.base import LexicoConfig
from repro.core import sparse_cache as sc
from repro.models import model as M
from repro.serving import (
    ContinuousBatchingEngine, EngineConfig, NULL_PAGE, PageAllocator,
    PrefixIndex, RefcountOverflow, Request, SharePlan,
)
from repro.serving import slots as slots_mod


# ---------------------------------------------------------------------------
# PrefixIndex (host-side radix trie)
# ---------------------------------------------------------------------------

def test_prefix_index_register_lookup_full_and_partial():
    a = PageAllocator(16, 4)
    idx = PrefixIndex(4)
    pages = a.alloc(3)                      # covers 10 codes: 2 full + 1 partial
    toks = list(range(100, 110))
    assert idx.register(toks, tier=8, pages=pages, n_codes=10, allocator=a) == 3
    for p in pages:
        assert a.refcount(p) == 2           # slot + index pin

    # same tokens, same span: full pages aliased, boundary page CoW'd
    plan = idx.lookup(toks, tier=8, n_codes=10)
    assert plan.aliased == pages[:2]
    assert plan.copy_src == pages[2] and plan.copy_valid == 2
    assert plan.shared_codes == 10 and plan.hit

    # shorter page-aligned prefix: aliasing only
    plan = idx.lookup(toks[:8], tier=8, n_codes=8)
    assert plan.aliased == pages[:2] and plan.copy_src is None
    assert plan.shared_codes == 8

    # diverging tokens inside the first page: no sharing
    bad = [1] + toks[1:]
    plan = idx.lookup(bad, tier=8, n_codes=10)
    assert not plan.hit and plan.aliased == []

    # diverging only in the partial region: full pages still alias
    bad_tail = toks[:9] + [999]
    plan = idx.lookup(bad_tail, tier=8, n_codes=10)
    assert plan.aliased == pages[:2] and plan.copy_src is None

    # a different tier never shares (codes depend on the OMP atom cap)
    plan = idx.lookup(toks, tier=4, n_codes=10)
    assert not plan.hit


def test_prefix_index_boundary_cow_from_full_page():
    """A recipient whose compressed span ends inside a page can CoW a
    *full* cached page whose leading codes match."""
    a = PageAllocator(16, 4)
    idx = PrefixIndex(4)
    pages = a.alloc(2)
    toks = list(range(8))
    idx.register(toks, tier=8, pages=pages, n_codes=8, allocator=a)
    plan = idx.lookup(toks[:6], tier=8, n_codes=6)
    assert plan.aliased == pages[:1]
    assert plan.copy_src == pages[1] and plan.copy_valid == 4
    assert plan.shared_codes == 6


def test_prefix_index_lookup_is_pure_peek():
    """Repeated lookups (a budget-blocked queue head re-peeking every step)
    must not refresh LRU stamps — only commit does. Otherwise a forever-
    blocked head would keep its subtree MRU and starve eviction of
    genuinely reused prefixes."""
    a = PageAllocator(32, 4)
    idx = PrefixIndex(4)
    blocked = a.alloc(1)
    idx.register(list(range(4)), tier=8, pages=blocked, n_codes=4, allocator=a)
    used = a.alloc(1)
    idx.register(list(range(10, 14)), tier=8, pages=used, n_codes=4,
                 allocator=a)
    a.free(blocked)
    a.free(used)
    idx.commit(idx.lookup(list(range(10, 14)), tier=8, n_codes=4))
    for _ in range(5):          # peeks for the blocked head: no commit
        assert idx.lookup(list(range(4)), tier=8, n_codes=4).hit
    assert idx.evict(a, max_pages=1) == 1
    # the peeked-but-never-admitted prefix was evicted, the committed one
    # survives
    assert not idx.lookup(list(range(4)), tier=8, n_codes=4).hit
    assert idx.lookup(list(range(10, 14)), tier=8, n_codes=4).hit
    idx.clear(a)
    assert a.check_balanced()


def test_prefix_index_never_registers_null_page():
    a = PageAllocator(8, 4)
    idx = PrefixIndex(4)
    with pytest.raises(ValueError, match="null/trash"):
        idx.register([1, 2, 3, 4], tier=8, pages=[NULL_PAGE], n_codes=4,
                     allocator=a)


def test_prefix_index_eviction_frees_lru_first():
    a = PageAllocator(16, 4)
    idx = PrefixIndex(4)
    old = a.alloc(2)
    idx.register([0, 1, 2, 3, 4, 5, 6, 7], tier=8, pages=old, n_codes=8,
                 allocator=a)
    new = a.alloc(2)
    idx.register([9, 1, 2, 3, 4, 5, 6, 7], tier=8, pages=new, n_codes=8,
                 allocator=a)
    a.free(old)      # donors retire: only the index pins their pages now
    a.free(new)
    # refresh `new`'s LRU stamp the way an admission would: lookup + commit
    idx.commit(idx.lookup([9, 1, 2, 3, 4, 5, 6, 7], tier=8, n_codes=8))
    assert idx.evictable_pages(a) == 4
    freed = idx.evict(a, max_pages=2)
    assert freed == 2
    # LRU subtree (the `old` family) went first; `new` still cached
    assert idx.lookup([9, 1, 2, 3, 4, 5, 6, 7], tier=8, n_codes=8).hit
    assert not idx.lookup([0, 1, 2, 3, 4, 5, 6, 7], tier=8, n_codes=8).hit
    assert idx.clear(a) == 2
    assert a.check_balanced()


def test_prefix_index_evict_skips_slot_held_pages():
    """only_free eviction never drops pins whose removal frees nothing —
    pages aliased by live slots stay cached."""
    a = PageAllocator(16, 4)
    idx = PrefixIndex(4)
    pages = a.alloc(2)
    idx.register(list(range(8)), tier=8, pages=pages, n_codes=8, allocator=a)
    # pages still held by the (live) donor slot: refcount 2 each
    assert idx.evictable_pages(a) == 0
    assert idx.evict(a, max_pages=2) == 0
    assert idx.lookup(list(range(8)), tier=8, n_codes=8).hit
    a.free(pages)
    assert idx.evict(a, max_pages=2) == 2
    assert a.check_balanced()


def test_prefix_index_max_cached_pages_trims():
    a = PageAllocator(32, 4)
    idx = PrefixIndex(4, max_cached_pages=2)
    p1 = a.alloc(2)
    idx.register(list(range(8)), tier=8, pages=p1, n_codes=8, allocator=a)
    p2 = a.alloc(2)
    idx.register(list(range(10, 18)), tier=8, pages=p2, n_codes=8, allocator=a)
    assert idx.n_cached_pages() <= 2


# ---------------------------------------------------------------------------
# allocator hardening (refcount edges prefix sharing stresses)
# ---------------------------------------------------------------------------

def test_incref_null_page_impossible():
    a = PageAllocator(8, 4)
    with pytest.raises(ValueError, match="null/trash"):
        a.incref(NULL_PAGE)
    with pytest.raises(ValueError, match="null/trash"):
        a.decref(NULL_PAGE)


def test_incref_after_free_raises():
    a = PageAllocator(8, 4)
    (p,) = a.alloc(1)
    a.decref(p)
    with pytest.raises(KeyError, match="incref after free"):
        a.incref(p)


def test_refcount_overflow_guarded(monkeypatch):
    a = PageAllocator(8, 4)
    monkeypatch.setattr(PageAllocator, "MAX_REFS", 3)
    (p,) = a.alloc(1)
    a.incref(p)
    a.incref(p)
    with pytest.raises(RefcountOverflow):
        a.incref(p)
    assert a.refcount(p) == 3


def test_refcount_underflow_on_double_free_of_shared_page():
    """A page shared by two holders survives one free; the third decref (a
    double free by one holder) raises instead of corrupting the free list."""
    a = PageAllocator(8, 4)
    (p,) = a.alloc(1)
    a.incref(p)                    # second holder
    a.decref(p)
    a.decref(p)                    # page freed
    with pytest.raises(KeyError, match="double free"):
        a.decref(p)
    assert a.check_balanced()


# ---------------------------------------------------------------------------
# device ops: copy_page + start-masked splice
# ---------------------------------------------------------------------------

B, KV, m, s, n_b = 2, 2, 16, 4, 3
P, MP = 4, 6
N_PAGES = 1 + B * MP
N_DICT = 64


def _stack(layer):
    return jax.tree.map(lambda *xs: jnp.stack(xs), layer, layer)


def test_copy_page_clones_one_page(rng):
    pool_layer = sc.init_paged_layer_cache(B, KV, m, n_pages=N_PAGES,
                                           page_size=P, max_pages=MP,
                                           n_b=n_b, s=s)
    pool_layer = pool_layer._replace(
        k_vals=jnp.asarray(rng.normal(size=pool_layer.k_vals.shape),
                           pool_layer.k_vals.dtype))
    pool = M.ServeState(cache=_stack(pool_layer),
                        length=jnp.zeros((B,), jnp.int32))
    out = slots_mod.copy_page(pool, 3, 5)
    kv = np.asarray(out.cache.k_vals, np.float32)
    src = np.asarray(pool.cache.k_vals, np.float32)
    np.testing.assert_array_equal(kv[:, 5], src[:, 3])
    np.testing.assert_array_equal(kv[:, 1], src[:, 1])     # others untouched


def test_write_slot_paged_start_masks_aliased_entries(rng):
    """Splicing with start=c must leave pages below the start page bitwise
    untouched — they may be another slot's."""
    D = jnp.asarray(make_unit_dict(rng, m, N_DICT), jnp.float32)
    T = 11                                   # n_comp = 8 = 2 pages
    K1 = jnp.asarray(rng.normal(size=(1, KV, T, m)), jnp.float32)
    one_layer = sc.init_layer_cache(1, KV, m, t_max=MP * P, n_b=n_b, s=s)
    one_layer = sc.prefill_compress(one_layer, K1, K1, D, D, s=s)
    one = M.ServeState(cache=_stack(one_layer),
                       length=jnp.full((1,), T, jnp.int32))

    pool_layer = sc.init_paged_layer_cache(B, KV, m, n_pages=N_PAGES,
                                           page_size=P, max_pages=MP,
                                           n_b=n_b, s=s)
    pool_layer = pool_layer._replace(
        k_vals=jnp.asarray(rng.normal(size=pool_layer.k_vals.shape),
                           pool_layer.k_vals.dtype))
    pool = M.ServeState(cache=_stack(pool_layer),
                        length=jnp.zeros((B,), jnp.int32))
    row = np.zeros(MP, np.int32)
    row[:2] = [3, 5]
    out = slots_mod.write_slot_paged(pool, one, 0, jnp.asarray(row),
                                     jnp.int32(P))      # skip page 0 of the row
    kv_out = np.asarray(out.cache.k_vals, np.float32)
    kv_in = np.asarray(pool.cache.k_vals, np.float32)
    np.testing.assert_array_equal(kv_out[:, 3], kv_in[:, 3])   # aliased: kept
    one_kv = np.asarray(one.cache.k_vals, np.float32)
    np.testing.assert_array_equal(kv_out[:, 5, :, :, :],
                                  one_kv[:, 0, :, P:2 * P, :])  # tail: written
    # table + counters installed as usual
    np.testing.assert_array_equal(np.asarray(out.cache.page_table)[:, 0],
                                  np.tile(row, (2, 1)))
    assert int(out.cache.t_c[0, 0]) == T - n_b


# ---------------------------------------------------------------------------
# restartable prefill (cache level, both layouts)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start", [P, 2 * P, 8])
def test_prefill_compress_restart_bitwise(rng, start):
    """A start=c prefill writes the same tail codes as a full encode —
    bitwise — and identical bookkeeping (OMP is per-position)."""
    D = jnp.asarray(make_unit_dict(rng, m, N_DICT), jnp.float32)
    T = 14                                  # n_comp = 11
    K = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    V = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    caps = jnp.asarray([2, 4], jnp.int32)
    full = sc.prefill_compress(
        sc.init_layer_cache(B, KV, m, t_max=MP * P, n_b=n_b, s=s),
        K, V, D, D, s=s, s_cap=caps)
    part = sc.prefill_compress(
        sc.init_layer_cache(B, KV, m, t_max=MP * P, n_b=n_b, s=s),
        K, V, D, D, s=s, s_cap=caps, start=start)
    n_comp = T - n_b
    for f in ("k_vals", "k_idx", "v_vals", "v_idx"):
        a = np.asarray(getattr(full, f)).astype(np.float32)
        b = np.asarray(getattr(part, f)).astype(np.float32)
        np.testing.assert_array_equal(a[:, :, start:n_comp],
                                      b[:, :, start:n_comp], err_msg=f)
        # skipped prefix untouched (zeros from init)
        assert not np.any(b[:, :, :min(start, n_comp)])
    for f in ("k_buf", "v_buf", "t_c", "buf_len", "buf_start"):
        np.testing.assert_array_equal(np.asarray(getattr(full, f)),
                                      np.asarray(getattr(part, f)), err_msg=f)


def test_paged_prefill_restart_skips_aliased_pages(rng):
    """The paged twin with start=P must not write the first page — it may
    alias another row's."""
    D = jnp.asarray(make_unit_dict(rng, m, N_DICT), jnp.float32)
    T = 14
    K = jnp.asarray(rng.normal(size=(B, KV, T, m)), jnp.float32)
    perm = rng.permutation(np.arange(1, N_PAGES))
    table = jnp.asarray(perm[:B * MP].reshape(B, MP), jnp.int32)

    def mk():
        c = sc.init_paged_layer_cache(B, KV, m, n_pages=N_PAGES, page_size=P,
                                      max_pages=MP, n_b=n_b, s=s)
        return c._replace(page_table=table)

    full = sc.paged_prefill_compress(mk(), K, K, D, D, s=s)
    part = sc.paged_prefill_compress(mk(), K, K, D, D, s=s, start=P)
    gf = sc.to_contiguous(full)
    gp = sc.to_contiguous(part)
    n_comp = T - n_b
    for f in ("k_vals", "k_idx", "v_vals", "v_idx"):
        a = np.asarray(getattr(gf, f)).astype(np.float32)
        b = np.asarray(getattr(gp, f)).astype(np.float32)
        np.testing.assert_array_equal(a[:, :, P:n_comp], b[:, :, P:n_comp],
                                      err_msg=f)
        assert not np.any(b[:, :, :P])       # first page never written


def test_model_prefill_compress_start_logits_bitwise(rng):
    """The restartable model prefill runs the identical forward — logits and
    the encoded tail must match the full prefill bitwise."""
    CFG = configs.get_smoke("llama3.2-1b")
    LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    from repro.models.cache_policy import LexicoPolicy
    policy = LexicoPolicy(LEX)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (1, 16)), jnp.int32)
    lg0, st0 = M.prefill(params, CFG, policy, {"tokens": toks}, bank=bank,
                         t_max=32)
    lg1, st1 = M.prefill(params, CFG, policy, {"tokens": toks}, bank=bank,
                         t_max=32, compress_start=8)
    np.testing.assert_array_equal(np.asarray(lg0), np.asarray(lg1))
    n_comp = 16 - LEX.n_b
    for f in ("k_vals", "k_idx", "v_vals", "v_idx"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st0.cache, f)).astype(np.float32)[:, :, :, 8:n_comp],
            np.asarray(getattr(st1.cache, f)).astype(np.float32)[:, :, :, 8:n_comp],
            err_msg=f)
    for f in ("k_buf", "v_buf", "t_c", "buf_len", "buf_start"):
        np.testing.assert_array_equal(np.asarray(getattr(st0.cache, f)),
                                      np.asarray(getattr(st1.cache, f)),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# engine differential (the acceptance gate)
# ---------------------------------------------------------------------------

CFG = configs.get_smoke("llama3.2-1b")
LEX = LexicoConfig(N=64, s=8, n_b=4, chunk=None)


@pytest.fixture(scope="module")
def served():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    bank = M.init_dictionary_bank(jax.random.PRNGKey(1), CFG, LEX)
    return params, bank


def _shared_prefix_requests(rng, n=4):
    """>= 3 requests sharing a page-aligned 32-token prompt prefix (bucket
    32, page_size 8 => 3 full shared pages + a shared boundary region),
    plus one unrelated prompt as a control."""
    prefix = rng.integers(0, CFG.vocab_size, 32).astype(np.int32)
    tails = [rng.integers(0, CFG.vocab_size, k).astype(np.int32)
             for k in (3, 8, 1)]
    reqs = [Request(rid=i, prompt=np.concatenate([prefix, tails[i]]),
                    max_new_tokens=mnt, tier=8)
            for i, mnt in enumerate((3, 4, 3))]
    reqs.append(Request(
        rid=3, prompt=rng.integers(0, CFG.vocab_size, 20).astype(np.int32),
        max_new_tokens=2, tier=4))
    return reqs[:n]


def _run_engine(params, bank, reqs, **cfg_kw):
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, **cfg_kw))
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    done = eng.run()
    return {rid: done[rid].generated_tokens for rid in done}, eng


def test_engine_shared_matches_unshared_bitwise(served):
    """The acceptance gate: identical greedy tokens with sharing on/off,
    strictly fewer prefill-OMP'd positions, >= 1 physical page referenced by
    >= 2 slots, bounded compile counts, and zero leaks once the prefix cache
    drops its pins."""
    params, bank = served
    reqs = _shared_prefix_requests(np.random.default_rng(11))
    base, base_eng = _run_engine(params, bank, reqs, share_prefixes=False)
    shared, eng = _run_engine(params, bank, reqs, share_prefixes=True)

    assert sorted(shared) == sorted(base)
    for rid in base:
        assert shared[rid] == base[rid], rid

    md = eng.metrics.to_dict()
    md_base = base_eng.metrics.to_dict()
    assert md["prefill_tokens_skipped"] > 0
    # strictly fewer positions went through the prefill OMP, none were lost
    assert (md["prefill_tokens_compressed"]
            < md_base["prefill_tokens_compressed"])
    assert (md["prefill_tokens_compressed"] + md["prefill_tokens_skipped"]
            == md_base["prefill_tokens_compressed"])
    assert md["pages_aliased"] >= 3             # the 3 full prefix pages
    assert md["pages_copied"] >= 1              # boundary page CoW
    assert md["shared_pages_peak"] >= 1         # >=1 page held by >=2 slots
    assert md["shared_page_hit_rate"] > 0
    assert md["bytes_deduped"] > 0

    cc = eng.compile_counts
    assert cc["decode"] == 1 and cc["write_slot"] == 1, cc
    assert cc["copy_page"] == 1, cc
    # prefill: one trace per (bucket, compress_start) pair — here (32, 0),
    # (16, 0) for the control, and (32, full-skip)
    assert cc["prefill"] <= 3, cc

    # the index keeps retired donors' pages pinned ("recently retired"
    # reuse); dropping the pins balances the pool exactly
    assert eng.prefix_index.n_cached_pages() > 0
    assert not eng.allocator.check_balanced()
    eng.prefix_index.clear(eng.allocator)
    assert eng.allocator.check_balanced()


def test_engine_shared_page_refcounts_while_live(served):
    """Mid-run: after all sharers are admitted, some physical page must be
    bound into >= 2 slot tables with refcount >= 3 (2 slots + index pin)."""
    params, bank = served
    reqs = _shared_prefix_requests(np.random.default_rng(5), n=3)
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=3, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, share_prefixes=True))
    for r in reqs:
        eng.submit(dataclasses.replace(r))
    eng.step()
    from collections import Counter
    held = Counter(p for i in eng.pool.active_slots()
                   for p in eng.pool.slots[i].pages)
    shared = [p for p, c in held.items() if c >= 2]
    assert len(shared) >= 3
    for p in shared:
        assert eng.allocator.refcount(p) >= 3    # sharers + index pin
        assert p != NULL_PAGE
    eng.run()
    eng.prefix_index.clear(eng.allocator)
    assert eng.allocator.check_balanced()


def test_engine_retire_while_shared_keeps_pages_live(served):
    """The donor retires first; its shared pages must stay resident (and
    bitwise intact) for the surviving recipient."""
    params, bank = served
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, CFG.vocab_size, 32).astype(np.int32)
    donor = Request(rid=0, prompt=prefix.copy(), max_new_tokens=4, tier=8)
    recip = Request(rid=1, prompt=np.concatenate(
        [prefix, rng.integers(0, CFG.vocab_size, 4).astype(np.int32)]),
        max_new_tokens=12, tier=8)
    eng = ContinuousBatchingEngine(
        params, CFG, LEX, bank,
        EngineConfig(n_slots=2, t_max=64, min_bucket=8, layout="paged",
                     page_size=8, share_prefixes=True))
    eng.submit(donor)
    eng.submit(recip)
    eng.step()
    shared_pages = [p for p in eng.pool.slots[0].pages
                    if p in set(eng.pool.slots[1].pages)]
    assert shared_pages
    while 0 not in eng.completed:
        eng.step()
    # donor gone, recipient still running: shared pages alive under it
    assert eng.pool.slots[1] is not None
    for p in shared_pages:
        assert eng.allocator.refcount(p) >= 2    # recipient + index pin
    eng.run()
    eng.prefix_index.clear(eng.allocator)
    assert eng.allocator.check_balanced()


def test_engine_eviction_when_free_list_runs_dry(served):
    """An oversubscribed pool: cached prefix pages must be evicted to admit
    prefix-missing requests, and every request still completes with the
    right token streams."""
    params, bank = served
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, CFG.vocab_size, 16).astype(np.int32)
    reqs = [Request(rid=i, prompt=np.concatenate(
                [prefix, rng.integers(0, CFG.vocab_size, i).astype(np.int32)])
                if i else prefix.copy(),
                max_new_tokens=3, tier=8)
            for i in range(2)]
    # unrelated prompts force misses -> fresh pages -> eviction pressure
    reqs += [Request(rid=2 + i,
                     prompt=rng.integers(0, CFG.vocab_size, 24).astype(np.int32),
                     max_new_tokens=3, tier=8) for i in range(3)]
    base, _ = _run_engine(params, bank, reqs, share_prefixes=False, n_pages=13)
    shared, eng = _run_engine(params, bank, reqs, share_prefixes=True,
                              n_pages=13)
    assert shared == base
    eng.prefix_index.clear(eng.allocator)
    assert eng.allocator.check_balanced()


def test_share_prefixes_requires_paged_layout(served):
    params, bank = served
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(
            params, CFG, LEX, bank,
            EngineConfig(n_slots=2, t_max=64, min_bucket=8,
                         layout="contiguous", share_prefixes=True))
