"""Roofline tooling: the HLO cost model must multiply loop bodies by trip
count (XLA's cost_analysis does not — the reason this module exists), and the
collective parser must see bytes inside loops."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hlo_cost
from repro.roofline.analysis import V5E, collective_bytes_from_hlo, model_flops_for


def test_scan_flops_exact():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == 2 * 128 * 256 * 256 * 8
    # XLA's own counter counts the body once — document the discrepancy
    # (exact value drifts a few scalar flops across XLA versions)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert abs(ca["flops"] - 2 * 128 * 256 * 256) < 1e3  # one iteration only


def test_nested_scan_flops():
    def nested(x, ws):
        def outer(xx, w):
            def inner(y, _):
                return jnp.tanh(y @ w), None
            y, _ = jax.lax.scan(inner, xx, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    c = jax.jit(nested).lower(x, ws).compile()
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == 2 * 64 * 128 * 128 * 8 * 4


def test_plain_matmul_matches_xla():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
    r = hlo_cost.analyze(c.as_text())
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert r["flops"] == ca["flops"]


def test_shape_bytes_parsing():
    assert hlo_cost._shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert hlo_cost._shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert hlo_cost._shape_bytes("f8e4m3fn[100]") == 100
    assert hlo_cost._shape_bytes("pred[]") == 1


def test_collective_regex():
    txt = """
  %ag = bf16[16,128]{1,0} all-gather(%x), dimensions={0}
  %ar.1 = f32[1024]{0} all-reduce-start(%y), to_apply=%add
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    got = collective_bytes_from_hlo(txt)
    assert got["all-gather"] == 16 * 128 * 2
    assert got["all-reduce"] == 4096
    assert got["collective-permute"] == 32


def test_model_flops():
    import repro.configs as configs
    cfg = configs.get("llama3.2-1b")
    t = model_flops_for(cfg, "train", 4096, 256)
    assert t == 6.0 * cfg.active_param_count() * 4096 * 256
    d = model_flops_for(cfg, "decode", 32768, 128)
    assert d == 2.0 * cfg.active_param_count() * 128
    assert V5E.peak_flops == 197e12
